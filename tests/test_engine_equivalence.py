"""The batched JAX engine must match the scalar NumPy oracle (Algorithm 1/2),
and the Pallas-kernel engine must match the jnp reference engine."""
import numpy as np
import pytest

from repro.core.ref_search import search_ref
from repro.core.search import build_search_fn, search_batch
from repro.core.spec import SearchSpec


def _pools_match(eng_ids, ref_ids, n):
    a = sorted(int(x) for x in eng_ids if 0 <= x < n)
    b = sorted(int(x) for x in ref_ids if x >= 0)
    return a == b


def test_plain_greedy_exact_match(small_ds, hnsw_index):
    g = hnsw_index
    res = search_batch(g, small_ds.queries, SearchSpec(efs=40, router="none"))
    for i, q in enumerate(small_ds.queries):
        ids, _, st = search_ref(g, q, efs=40, k=40)
        assert _pools_match(res.ids[i], ids, g.n), f"pool mismatch q{i}"
        assert int(res.dist_calls[i]) == st.dist_calls, f"call-count mismatch q{i}"


def test_crouting_matches_stale_bound_oracle(small_ds, hnsw_index, hnsw_profile):
    g = hnsw_index
    ct = hnsw_profile.cos_theta_star
    res = search_batch(g, small_ds.queries,
                       SearchSpec(efs=40, router="crouting"), cos_theta=ct)
    for i, q in enumerate(small_ds.queries):
        ids, _, st = search_ref(g, q, efs=40, k=40, router="crouting",
                                cos_theta=ct, stale_bound=True)
        assert _pools_match(res.ids[i], ids, g.n), f"pool mismatch q{i}"
        assert int(res.dist_calls[i]) == st.dist_calls
        assert int(res.est_calls[i]) == st.est_calls


def test_crouting_o_matches_oracle(small_ds, hnsw_index, hnsw_profile):
    g = hnsw_index
    ct = hnsw_profile.cos_theta_star
    res = search_batch(g, small_ds.queries[:16],
                       SearchSpec(efs=40, router="crouting_o"), cos_theta=ct)
    for i, q in enumerate(small_ds.queries[:16]):
        ids, _, st = search_ref(g, q, efs=40, k=40, router="crouting_o",
                                cos_theta=ct, stale_bound=True)
        assert _pools_match(res.ids[i], ids, g.n)
        assert int(res.dist_calls[i]) == st.dist_calls


def test_triangle_router_is_safe(small_ds, hnsw_index):
    """Triangle-inequality pruning uses an exact lower bound: the result pool
    must equal plain greedy's (paper §3.2: correct but barely prunes)."""
    g = hnsw_index
    plain = search_batch(g, small_ds.queries, SearchSpec(efs=40, router="none"))
    tri = search_batch(g, small_ds.queries, SearchSpec(efs=40, router="triangle"))
    for i in range(len(small_ds.queries)):
        assert _pools_match(tri.ids[i], np.asarray(plain.ids[i]), g.n)
        assert int(tri.dist_calls[i]) <= int(plain.dist_calls[i])


def test_live_vs_frozen_bound_delta_is_small(small_ds, hnsw_index, hnsw_profile):
    """DESIGN.md §3: frozen-bound (SPMD) semantics prune slightly less than
    the paper's live bound; the distance-call delta must be tiny."""
    g = hnsw_index
    ct = hnsw_profile.cos_theta_star
    live = frozen = 0
    for q in small_ds.queries[:20]:
        _, _, st1 = search_ref(g, q, efs=40, router="crouting", cos_theta=ct)
        _, _, st2 = search_ref(g, q, efs=40, router="crouting", cos_theta=ct,
                               stale_bound=True)
        live += st1.dist_calls
        frozen += st2.dist_calls
    assert frozen >= live * 0.95
    assert frozen <= live * 1.15, (live, frozen)


# --------------------------------------------------------------------------
# Pallas engine vs jnp reference engine (kernel-integrated hot path)
# --------------------------------------------------------------------------
@pytest.fixture(scope="module")
def tiny_graph():
    """Small graph + profile so per-config jit of the Pallas engine stays
    cheap (interpret mode unrolls one kernel program per query lane)."""
    from repro.data.vectors import make_dataset
    from repro.core.hnsw import build_hnsw
    from repro.core.angles import sample_angle_profile

    ds = make_dataset(n_base=600, n_query=8, dim=24, n_clusters=12, seed=3)
    g = build_hnsw(ds.base, m=8, efc=48, seed=0)
    prof = sample_angle_profile(g, n_sample=6, efs=32, seed=1)
    return ds, g, prof.cos_theta_star


def _assert_engines_match(g, queries, ct, cfg_jnp, cfg_pallas):
    a = search_batch(g, queries, cfg_jnp, cos_theta=ct)
    b = search_batch(g, queries, cfg_pallas, cos_theta=ct)
    np.testing.assert_array_equal(np.asarray(a.ids), np.asarray(b.ids))
    np.testing.assert_allclose(np.asarray(a.dists), np.asarray(b.dists),
                               rtol=1e-6, atol=1e-6)
    # kernel path computes exactly the same set of exact distances
    assert (np.asarray(b.dist_calls) == np.asarray(a.dist_calls)).all()
    assert (np.asarray(b.est_calls) == np.asarray(a.est_calls)).all()
    assert (np.asarray(b.rerank_calls) == np.asarray(a.rerank_calls)).all()
    assert (np.asarray(b.sq8_calls) == np.asarray(a.sq8_calls)).all()
    assert int(b.iters) == int(a.iters)


@pytest.mark.parametrize("router", ["none", "crouting", "crouting_o",
                                    "triangle"])
def test_pallas_engine_matches_jnp(tiny_graph, router):
    ds, g, ct = tiny_graph
    _assert_engines_match(
        g, ds.queries, ct,
        SearchSpec(efs=24, router=router),
        SearchSpec(efs=24, router=router, engine="pallas"))


@pytest.mark.parametrize("beam_prune", ["best", "all"])
def test_pallas_engine_matches_jnp_beam(tiny_graph, beam_prune):
    ds, g, ct = tiny_graph
    _assert_engines_match(
        g, ds.queries, ct,
        SearchSpec(efs=24, router="crouting", beam_width=4,
                     beam_prune=beam_prune),
        SearchSpec(efs=24, router="crouting", beam_width=4,
                     beam_prune=beam_prune, engine="pallas"))


def test_beam_prune_best_holds_recall_where_all_collapses():
    """The q-strand hazard of beam_prune='all' (estimates from far parents
    mis-pruning a doorway node) must not affect the default 'best' policy.
    This dataset/seed is a pinned adversarial case: with 'all' one query's
    recall collapses to 0 (its doorway node is pruned from a far parent and
    never re-encountered), while 'best' matches the W=1 profile."""
    from repro.data.vectors import make_dataset, exact_ground_truth, recall_at_k
    from repro.core.index import AnnIndex

    ds = make_dataset(n_base=1200, n_query=16, dim=32, n_clusters=16, seed=5)
    idx = AnnIndex.build(ds.base, graph="hnsw", m=8, efc=48)
    gt = exact_ground_truth(ds, k=10)
    base = SearchSpec(k=10, efs=32, router="crouting")
    r1, _, _ = idx.search(ds.queries, spec=base)
    rb, _, _ = idx.search(ds.queries, spec=base.replace(beam_width=4,
                                                        beam_prune="best"))
    ra, _, _ = idx.search(ds.queries, spec=base.replace(beam_width=4,
                                                        beam_prune="all"))
    rec1, rec_b = recall_at_k(r1, gt, 10), recall_at_k(rb, gt, 10)
    rec_a = recall_at_k(ra, gt, 10)
    assert rec_b >= rec1 - 1e-9, (rec1, rec_b)
    # 'all' must not silently behave like 'best': on this pinned case it
    # trades recall for its lower distance-call count
    assert rec_a <= rec_b, (rec_a, rec_b)


def test_beam_prune_all_saves_distance_calls():
    """'all' keeps the W=1 call profile while 'best' dilutes toward the
    unrouted engine as W grows."""
    from repro.data.vectors import make_dataset
    from repro.core.index import AnnIndex

    ds = make_dataset(n_base=1200, n_query=16, dim=32, n_clusters=16, seed=5)
    idx = AnnIndex.build(ds.base, graph="hnsw", m=8, efc=48)
    base = SearchSpec(k=10, efs=32, router="crouting")
    _, _, i1 = idx.search(ds.queries, spec=base)
    _, _, ib = idx.search(ds.queries, spec=base.replace(beam_width=4,
                                                        beam_prune="best"))
    _, _, ia = idx.search(ds.queries, spec=base.replace(beam_width=4,
                                                        beam_prune="all"))
    assert ia.dist_calls.mean() <= 1.10 * i1.dist_calls.mean()
    assert ib.dist_calls.mean() >= ia.dist_calls.mean()


def test_pallas_unfused_engine_matches_jnp(tiny_graph):
    """The composable crouting_prune + gather_distance_pruned + pool_merge
    pipeline (engine="pallas_unfused") is exact too."""
    ds, g, ct = tiny_graph
    _assert_engines_match(
        g, ds.queries[:4], ct,
        SearchSpec(efs=16, router="crouting", beam_width=2),
        SearchSpec(efs=16, router="crouting", beam_width=2,
                     engine="pallas_unfused"))


@pytest.mark.parametrize("router,estimate,W", [("none", "sq8", 1),
                                               ("crouting", "sq8", 4),
                                               ("crouting", "both", 4)])
def test_pallas_engine_matches_jnp_sq8(tiny_graph, router, estimate, W):
    """Two-stage quantized path: the sq8_distance kernel + gather reranks
    must reproduce the jnp engine's pools, counters and approx-flag
    bookkeeping exactly."""
    ds, g, ct = tiny_graph
    _assert_engines_match(
        g, ds.queries, ct,
        SearchSpec(efs=24, router=router, estimate=estimate, beam_width=W),
        SearchSpec(efs=24, router=router, estimate=estimate, beam_width=W,
                     engine="pallas"))


def test_pallas_unfused_engine_matches_jnp_sq8(tiny_graph):
    ds, g, ct = tiny_graph
    _assert_engines_match(
        g, ds.queries[:4], ct,
        SearchSpec(efs=16, router="crouting", estimate="both",
                     beam_width=2),
        SearchSpec(efs=16, router="crouting", estimate="both", beam_width=2,
                     engine="pallas_unfused"))


def test_beam_cuts_iterations_without_recall_loss(small_ds, hnsw_index,
                                                  ground_truth):
    """Acceptance: hop-loop iteration count drops ~beam_width x at equal
    recall (beam only ever adds expansions, never removes them)."""
    from repro.data.vectors import recall_at_k

    g = hnsw_index
    r1 = search_batch(g, small_ds.queries, SearchSpec(efs=40), k=10)
    r4 = search_batch(g, small_ds.queries,
                      SearchSpec(efs=40, beam_width=4), k=10)
    assert int(r4.iters) * 2 <= int(r1.iters), (int(r1.iters), int(r4.iters))
    rec1 = recall_at_k(np.asarray(r1.ids), ground_truth, 10)
    rec4 = recall_at_k(np.asarray(r4.ids), ground_truth, 10)
    assert rec4 >= rec1 - 1e-9, (rec1, rec4)
    # the beam trades a few extra expansions for the iteration cut
    assert int(np.asarray(r4.hops).sum()) >= int(np.asarray(r1.hops).sum())


def test_beam_tile_dedup_first_valid_occurrence_wins():
    """Two beam nodes naming the same neighbor must process it once (else
    dist_calls double-count and the pool holds duplicate ids)."""
    import jax.numpy as jnp
    from repro.core.search import _first_occurrence

    nbrs = jnp.asarray([[3, 5, 3, 7, 5, 3], [1, 1, 1, 2, 9, 9]], jnp.int32)
    valid = jnp.asarray([[1, 1, 1, 1, 0, 1], [0, 1, 1, 1, 1, 1]], bool)
    first, order, sk = _first_occurrence(nbrs, valid, 10)
    exp = np.asarray([[1, 1, 0, 1, 0, 0], [0, 1, 0, 1, 1, 0]], bool)
    assert (np.asarray(first) == exp).all()

    # rescue: prune row0's id-3 (lane 0) -> its second valid lane (lane 2)
    # computes and the prune mark clears; pruned id-7 has no dup and sticks
    from repro.core.search import _rescue_pruned_duplicates
    prune = jnp.asarray([[1, 0, 0, 1, 0, 0], [0, 0, 0, 0, 0, 0]], bool)
    rescued, kept = _rescue_pruned_duplicates(order, sk, prune)
    assert (np.asarray(rescued) == np.asarray(
        [[0, 0, 1, 0, 0, 0], [0, 0, 0, 0, 0, 0]], bool)).all()
    assert (np.asarray(kept) == np.asarray(
        [[0, 0, 0, 1, 0, 0], [0, 0, 0, 0, 0, 0]], bool)).all()


def test_beam_pools_have_no_duplicate_ids(small_ds, hnsw_index):
    g = hnsw_index
    res = search_batch(g, small_ds.queries,
                       SearchSpec(efs=40, router="crouting", beam_width=6),
                       cos_theta=0.9)
    for row in np.asarray(res.ids):
        real = row[row < g.n]
        assert len(set(real.tolist())) == len(real)


def test_beam_respects_exact_hop_budget(small_ds, hnsw_index):
    """max_hops is a hard per-query bound (the sharded straggler contract)
    even when the beam would overshoot mid-iteration."""
    g = hnsw_index
    res = search_batch(g, small_ds.queries,
                       SearchSpec(efs=40, beam_width=4, max_hops=9))
    assert int(np.asarray(res.hops).max()) <= 9


def test_build_search_fn_caches_compiled_engine(hnsw_index):
    """search_batch must reuse the jitted executable across calls (the
    serving path re-enters with fresh batches every request)."""
    cfg = SearchSpec(efs=12, router="none")
    arrays1, fn1 = build_search_fn(hnsw_index, cfg)
    arrays2, fn2 = build_search_fn(hnsw_index, SearchSpec(efs=12,
                                                            router="none"))
    assert fn1 is fn2 and arrays1 is arrays2
    _, fn3 = build_search_fn(hnsw_index, SearchSpec(efs=13, router="none"))
    assert fn3 is not fn1


def test_engine_cache_does_not_grow_across_rebuilt_indexes():
    """Regression (ISSUE 3): rebuilding an index must not accumulate dead
    entries in either engine cache — a stale compiled-fn entry pins the
    graph's fp32 + SQ8 device tables."""
    import gc

    from repro.core.hnsw import build_hnsw
    from repro.core.search import (_ARRAYS_CACHE, _ENGINE_CACHE,
                                   _purge_dead_cache_entries)
    from repro.data.vectors import make_dataset

    ds = make_dataset(n_base=300, n_query=2, dim=16, n_clusters=6, seed=1)
    baseline_arrays = len(_ARRAYS_CACHE)
    baseline_engine = len(_ENGINE_CACHE)
    for i in range(6):
        g = build_hnsw(ds.base, m=6, efc=24, seed=i)
        # two configs per rebuild: both compiled-fn entries must die with g
        search_batch(g, ds.queries, SearchSpec(efs=12, router="none"))
        search_batch(g, ds.queries, SearchSpec(efs=12, router="crouting"))
        del g
        gc.collect()
        assert len(_ARRAYS_CACHE) <= baseline_arrays + 1
        assert len(_ENGINE_CACHE) <= baseline_engine + 2
    # after the last graph dies, a purge leaves nothing of this test behind
    _purge_dead_cache_entries()
    assert len(_ARRAYS_CACHE) <= baseline_arrays
    assert len(_ENGINE_CACHE) <= baseline_engine
    # and a compiled-fn entry never outlives its arrays-cache twin
    assert all(k[0] in _ARRAYS_CACHE for k in _ENGINE_CACHE)

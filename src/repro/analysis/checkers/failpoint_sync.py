"""failpoint-sync: hit() literals vs the declared registry vs DESIGN.md §10.

Three places name failpoint sites, and they drift independently: the
``fault.hit("site")`` call sites across the production modules, the
``DECLARED_SITES`` frozenset in ``repro/fault/failpoints.py``, and the
site table in DESIGN.md §10.  This checker makes the three agree in both
directions:

* every ``hit()`` literal (including sites passed through ``write_site=``
  / ``rename_site=`` kwargs into ``atomic_write_bytes``-style helpers)
  must appear in ``DECLARED_SITES`` and in the §10 table;
* every declared site must have at least one call site (no dead registry
  entries) and a §10 row (no undocumented sites);
* every §10 row must name a declared site (no dead documentation).

``DECLARED_SITES`` is deliberately *passive*: ``arm()`` accepts any name
so tests can use scratch sites — the registry exists for this checker and
for operators reading the code, not as a runtime gate.
"""
from __future__ import annotations

import ast
import re
from typing import Dict, Iterable, List, Optional, Set, Tuple

from repro.analysis.core import (Finding, Project, const_str, dotted_name,
                                 register_checker)

FAILPOINTS_PATH = "fault/failpoints.py"
DOC_PATH = "DESIGN.md"
SECTION_HEAD = "## §10"
# a §10 table row:  | `wal.append` | ... |   (the [.N] marks sub-targeting)
DOC_ROW_RE = re.compile(r"^\|\s*`([a-z0-9_.]+)(?:\[\.N\])?`\s*\|")
SITE_KWARGS = ("write_site", "rename_site")


def _call_site_literals(project: Project
                        ) -> Iterable[Tuple[str, str, int]]:
    """Yield (site, relpath, line) for every literal site name in code."""
    for sf in project.files:
        if sf.tree is None or sf.relpath.endswith(FAILPOINTS_PATH):
            continue
        for node in ast.walk(sf.tree):
            if not isinstance(node, ast.Call):
                continue
            head = dotted_name(node.func)
            if head and head.split(".")[-1] == "hit" and node.args:
                site = const_str(node.args[0])
                if site is not None:
                    yield site, sf.relpath, node.lineno
            for kw in node.keywords:
                if kw.arg in SITE_KWARGS:
                    site = const_str(kw.value)
                    if site is not None:
                        yield site, sf.relpath, kw.value.lineno


def _declared_sites(project: Project
                    ) -> Tuple[Optional[Dict[str, int]], Optional[str], int]:
    """(site -> decl line, relpath, set line) from DECLARED_SITES."""
    sf = project.find(FAILPOINTS_PATH)
    if sf is None or sf.tree is None:
        return None, None, 0
    for node in sf.tree.body:
        if not isinstance(node, ast.Assign):
            continue
        if not any(isinstance(t, ast.Name) and t.id == "DECLARED_SITES"
                   for t in node.targets):
            continue
        value = node.value
        if isinstance(value, ast.Call) \
                and dotted_name(value.func) == "frozenset" and value.args:
            value = value.args[0]
        if isinstance(value, (ast.Tuple, ast.List, ast.Set)):
            sites = {}
            for e in value.elts:
                s = const_str(e)
                if s is not None:
                    sites[s] = e.lineno
            return sites, sf.relpath, node.lineno
        return {}, sf.relpath, node.lineno
    return None, sf.relpath, 0


def _doc_sites(project: Project) -> Optional[Dict[str, int]]:
    text = project.read_text(DOC_PATH)
    if text is None:
        return None
    sites: Dict[str, int] = {}
    in_section = False
    for i, line in enumerate(text.splitlines(), start=1):
        if line.startswith("## "):
            in_section = line.startswith(SECTION_HEAD)
            continue
        if not in_section:
            continue
        m = DOC_ROW_RE.match(line.strip())
        if m:
            sites[m.group(1)] = i
    return sites


@register_checker(
    "failpoint-sync",
    "fault.hit() literals, the failpoints.py DECLARED_SITES registry, and "
    "the DESIGN.md §10 site table agree in both directions")
def check_failpoint_sync(project: Project) -> Iterable[Finding]:
    if project.find(FAILPOINTS_PATH) is None:
        return      # partial scan without the fault module: inapplicable
    calls: List[Tuple[str, str, int]] = list(_call_site_literals(project))
    declared, fp_relpath, decl_line = _declared_sites(project)
    docs = _doc_sites(project)

    if declared is None:
        where = fp_relpath or FAILPOINTS_PATH
        yield Finding(
            checker="failpoint-sync", path=where, line=max(decl_line, 1),
            message="DECLARED_SITES registry not found in failpoints.py",
            hint="declare `DECLARED_SITES = frozenset({...})` listing every "
                 "production site name")
        declared = {}
    if docs is None and project.find(FAILPOINTS_PATH) is not None:
        yield Finding(
            checker="failpoint-sync", path=FAILPOINTS_PATH,
            line=max(decl_line, 1),
            message=f"{DOC_PATH} not found — the §10 site table cannot be "
                    "cross-checked",
            hint="run the analyzer from the repo root")

    called: Set[str] = set()
    for site, relpath, line in calls:
        called.add(site)
        if declared and site not in declared:
            yield Finding(
                checker="failpoint-sync", path=relpath, line=line,
                message=f"failpoint site {site!r} is not in the "
                        "DECLARED_SITES registry",
                hint="add it to failpoints.DECLARED_SITES (and the "
                     "DESIGN.md §10 table)")
        if docs is not None and site not in docs:
            yield Finding(
                checker="failpoint-sync", path=relpath, line=line,
                message=f"failpoint site {site!r} is missing from the "
                        f"{DOC_PATH} §10 site table",
                hint="add a table row: | `" + site + "` | <layer> | "
                     "<kinds> |")

    for site, line in sorted((declared or {}).items()):
        if site not in called:
            yield Finding(
                checker="failpoint-sync", path=fp_relpath or FAILPOINTS_PATH,
                line=line,
                message=f"declared failpoint site {site!r} has no hit() "
                        "call site (dead registry entry)",
                hint="remove it, or wire the site into the code path it "
                     "documents")
        if docs is not None and site not in docs:
            yield Finding(
                checker="failpoint-sync", path=fp_relpath or FAILPOINTS_PATH,
                line=line,
                message=f"declared failpoint site {site!r} is undocumented "
                        f"(no {DOC_PATH} §10 row)",
                hint="add a table row: | `" + site + "` | <layer> | "
                     "<kinds> |")

    if docs is not None and declared:
        for site, line in sorted(docs.items()):
            if site not in declared:
                yield Finding(
                    checker="failpoint-sync", path=DOC_PATH, line=line,
                    message=f"{DOC_PATH} §10 documents failpoint site "
                            f"{site!r}, which is not declared in the "
                            "registry (dead documentation)",
                    hint="delete the row, or declare + wire the site")

"""Service module with one good and one undeclared failpoint site."""
from fault import failpoints as fault


def go():
    fault.hit("svc.ok")
    fault.hit("svc.undeclared")     # expect[failpoint-sync,failpoint-sync]

"""End-to-end behaviour: the public AnnIndex API reproduces the paper's
workflow (build -> angle profile -> CRouting search) and the training driver
learns on synthetic data."""
import numpy as np

from repro.core.index import AnnIndex
from repro.core.spec import SearchSpec
from repro.data.vectors import make_dataset, exact_ground_truth, recall_at_k


def test_end_to_end_crouting_workflow():
    ds = make_dataset(n_base=1500, n_query=40, dim=64, n_clusters=24, seed=7)
    idx = AnnIndex.build(ds.base, graph="hnsw", m=12, efc=64)
    assert idx.profile is not None
    assert 0.2 * np.pi < idx.profile.theta_star < 0.7 * np.pi
    gt = exact_ground_truth(ds, k=10)

    ids_p, _, ip = idx.search(ds.queries, spec=SearchSpec(k=10, efs=64,
                                                          router="none"))
    ids_c, _, ic = idx.search(ds.queries, spec=SearchSpec(k=10, efs=64,
                                                          router="crouting"))
    rp, rc = recall_at_k(ids_p, gt, 10), recall_at_k(ids_c, gt, 10)
    assert rp > 0.9
    # fixed-efs gap is expected (paper Table 3); iso-recall test below
    assert rc > rp - 0.16
    saved = 1 - ic.dist_calls.mean() / ip.dist_calls.mean()
    assert saved > 0.2, f"CRouting saved only {saved:.1%}"
    # est_calls only happen under the router
    assert ic.est_calls.mean() > 0 and ip.est_calls.mean() == 0


def test_iso_recall_speedup():
    """The paper's headline framing: at ~equal recall (tuning efs), CRouting
    uses fewer distance calls than plain greedy."""
    ds = make_dataset(n_base=1500, n_query=40, dim=64, n_clusters=24, seed=3)
    idx = AnnIndex.build(ds.base, graph="hnsw", m=12, efc=64)
    gt = exact_ground_truth(ds, k=10)

    def at(router, efs):
        ids, _, stats = idx.search(ds.queries,
                                   spec=SearchSpec(k=10, efs=efs,
                                                   router=router))
        return recall_at_k(ids, gt, 10), stats.dist_calls.mean()

    # find plain greedy's recall at efs=40, then CRouting efs to match
    r_p, c_p = at("none", 40)
    best = None
    for efs in (40, 56, 72, 96, 128):
        r_c, c_c = at("crouting", efs)
        if r_c >= r_p - 0.005:
            best = (efs, r_c, c_c)
            break
    assert best is not None, "CRouting never reached iso-recall"
    _, r_c, c_c = best
    assert c_c < c_p, f"no call saving at iso-recall: {c_c} vs {c_p}"


def test_train_driver_learns():
    """examples/train_lm pathway: loss decreases on structured synthetic data."""
    import jax
    from repro.data.synthetic import LMStream
    from repro.models import transformer as T
    from repro.train import optimizer as opt
    from repro.train.trainer import Trainer, TrainerConfig

    cfg = T.LMConfig(name="t", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                     d_ff=128, vocab=64, dtype="float32", block_q=8,
                     block_k=16, loss_chunk=8)
    ocfg = opt.AdamWConfig(lr=1e-2, warmup_steps=5, total_steps=60)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    state = opt.adamw_init(params, ocfg)
    tr = Trainer(TrainerConfig(total_steps=60, ckpt_every=1000,
                               ckpt_dir="/tmp/repro_sys_ck", log_every=1000),
                 T.make_train_step(cfg, ocfg), params, state,
                 LMStream(cfg.vocab, 8, 32, seed=0))
    out = tr.run()
    start = np.mean(out["history"][:5])
    end = np.mean(out["history"][-5:])
    assert end < start - 0.3, f"no learning: {start:.3f} -> {end:.3f}"

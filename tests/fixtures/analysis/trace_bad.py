"""Deliberate trace-safety hazards: Python control flow on traced values."""
import functools

import jax
import jax.numpy as jnp
from jax import lax


@jax.jit
def branch_on_arg(x):
    if x > 0:                              # expect[trace-safety]
        return x
    return -x


@jax.jit
def loop_and_cast(x):
    total = x * 2
    while total > 0:                       # expect[trace-safety]
        total = total - 1
    return int(total)                      # expect[trace-safety]


@functools.partial(jax.jit, static_argnames=("mode",))
def static_is_exempt(x, mode):
    if mode == "l2":                       # static_argnames: no finding
        return jnp.sum(x * x)
    return jnp.sum(jnp.abs(x))


@jax.jit
def shape_facts_are_concrete(x):
    y = jnp.asarray(x)
    if y.shape[0] > 4:                     # shape: no finding
        return y
    if y is None:                          # identity: no finding
        return y
    return y


def body(state):
    i, acc = state
    flag = bool(acc)                       # expect[trace-safety]
    return i + 1, acc + jnp.float32(flag)


def cond(state):
    i, _ = state
    return i < 8


def run():
    # body/cond resolved by name: their params are traced
    return lax.while_loop(cond, body, (0, jnp.float32(0)))

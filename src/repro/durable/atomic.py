"""The atomic-persistence recipe shared by every durable artifact.

One protocol (DESIGN.md §10/§11) for index snapshots (``AnnIndex.save``),
durability checkpoints, and manifests: write ``{path}.tmp.{pid}``, stamp a
content checksum, flush + fsync the file, ``os.replace`` into place, fsync
the directory.  A crash at ANY instant leaves ``path`` holding the old
version or the complete new one, never a torn file; readers verify the
checksum and raise ``CorruptIndexError`` on damage.

Failpoint plumbing: each writer names its own sites (``index.save.write``
/ ``index.save.rename`` for snapshots, ``checkpoint.write`` for
checkpoints, ``manifest.rename`` for manifests) so the chaos suite can
crash each artifact's write→publish window independently.  The data kinds
(``corrupt``/``truncate``) damage the temp file before publication,
exercising the reader-side integrity checks.
"""
from __future__ import annotations

import os
import zipfile
import zlib
from typing import Dict, Optional

import numpy as np

from repro.fault import CorruptIndexError, failpoints as fault


def payload_checksum(payload: Dict[str, np.ndarray]) -> int:
    """CRC32 over every array's name, dtype, shape, and bytes (sorted by
    name) — deterministic across a save/load round trip, independent of the
    zip container, so it catches damage the container's own CRCs can miss
    (and torn rewrites of uncompressed entries)."""
    crc = 0
    for name in sorted(payload):
        a = np.ascontiguousarray(payload[name])
        for token in (name, str(a.dtype), str(a.shape)):
            crc = zlib.crc32(token.encode(), crc)
        crc = zlib.crc32(a.tobytes(), crc)
    return crc


def damage_file(path: str, kind: str) -> None:
    """Apply an armed data fault (``corrupt``/``truncate``) to a file."""
    size = os.path.getsize(path)
    if kind == "truncate":
        with open(path, "r+b") as f:
            f.truncate(max(size // 2, 1))
        return
    with open(path, "r+b") as f:          # "corrupt": flip a byte run
        f.seek(size // 3)
        chunk = bytearray(f.read(min(64, max(size - size // 3, 1))))
        f.seek(size // 3)
        f.write(bytes(b ^ 0xFF for b in chunk))


def fsync_dir(dirname: str) -> None:
    """Make a rename/create in ``dirname`` durable (POSIX dir fsync)."""
    dfd = os.open(dirname, os.O_RDONLY)
    try:
        os.fsync(dfd)
    finally:
        os.close(dfd)


def atomic_replace(tmp: str, path: str) -> None:
    """``os.replace`` + directory fsync: the publish step of the recipe."""
    os.replace(tmp, path)
    fsync_dir(os.path.dirname(os.path.abspath(path)))


def atomic_write_bytes(path: str, data: bytes,
                       rename_site: Optional[str] = None) -> None:
    """Atomically publish raw bytes (the manifest writer's primitive)."""
    dirname = os.path.dirname(os.path.abspath(path))
    os.makedirs(dirname, exist_ok=True)
    tmp = f"{path}.tmp.{os.getpid()}"
    try:
        with open(tmp, "wb") as f:
            f.write(data)
            f.flush()
            os.fsync(f.fileno())
        if rename_site is not None:
            fault.hit(rename_site)
        atomic_replace(tmp, path)
    except BaseException:   # noqa: BLE001 — temp-file hygiene, re-raised
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise


def atomic_write_npz(path: str, payload: Dict[str, np.ndarray], *,
                     write_site: Optional[str] = None,
                     rename_site: Optional[str] = None) -> None:
    """Atomically publish an .npz payload, stamping its content checksum.

    ``payload`` must not already carry a ``checksum`` entry — the writer
    owns that key.  ``write_site`` fires between the bytes landing and the
    fsync (``raise`` = crash mid-save; ``corrupt``/``truncate`` = damage
    the temp file so the reader-side checks are exercised);
    ``rename_site`` fires in the write→publish window.
    """
    assert "checksum" not in payload, "checksum is stamped by the writer"
    payload = dict(payload)
    payload["checksum"] = np.asarray(payload_checksum(payload), np.uint64)
    dirname = os.path.dirname(os.path.abspath(path))
    os.makedirs(dirname, exist_ok=True)
    tmp = f"{path}.tmp.{os.getpid()}"
    try:
        with open(tmp, "wb") as f:
            np.savez_compressed(f, **payload)
            action = fault.hit(write_site) if write_site else None
            f.flush()
            os.fsync(f.fileno())
        if action in ("corrupt", "truncate"):
            damage_file(tmp, action)
        if rename_site is not None:
            fault.hit(rename_site)
        atomic_replace(tmp, path)
    except BaseException:   # noqa: BLE001 — temp-file hygiene, re-raised
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise


def read_npz(path: str) -> Dict[str, np.ndarray]:
    """Read an .npz into a dict, converting container damage into
    ``CorruptIndexError`` (``FileNotFoundError`` passes through)."""
    try:
        with np.load(path, allow_pickle=False) as npz:
            return {k: npz[k] for k in npz.files}
    except FileNotFoundError:
        raise
    except (zipfile.BadZipFile, zlib.error, OSError, EOFError,
            KeyError, ValueError) as e:
        raise CorruptIndexError(
            f"{path}: unreadable file ({type(e).__name__}: {e}); "
            "the bytes on disk are truncated or corrupted") from e


def verify_checksum(path: str, z: Dict[str, np.ndarray],
                    required: bool = True) -> None:
    """Verify a payload's stamped content checksum (see ``payload_checksum``).

    ``required=False`` tolerates a missing stamp (pre-v3 snapshot files);
    a PRESENT stamp is always verified.
    """
    if "checksum" not in z:
        if required:
            raise CorruptIndexError(
                f"{path}: file is missing its content checksum")
        return
    want = int(z["checksum"])
    got = payload_checksum({k: v for k, v in z.items() if k != "checksum"})
    if got != want:
        raise CorruptIndexError(
            f"{path}: content checksum mismatch (stored {want:#010x}, "
            f"computed {got:#010x}) — the payload was corrupted after it "
            "was written")


def read_npz_verified(path: str, required: bool = True
                      ) -> Dict[str, np.ndarray]:
    """``read_npz`` + ``verify_checksum`` in one step (checkpoint reader)."""
    z = read_npz(path)
    verify_checksum(path, z, required=required)
    return z

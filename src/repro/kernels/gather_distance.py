"""Pallas TPU kernel: fused gather + squared-L2 distance (scalar prefetch).

The exact-distance path of one expansion: for each (query b, neighbor slot m)
the neighbor's vector row is DMA'd from the HBM-resident table straight into
VMEM — the row choice is driven by the scalar-prefetched index array via the
BlockSpec index_map (PrefetchScalarGridSpec), the idiomatic TPU pattern for
data-dependent gathers.

CRouting integration: callers remap pruned lanes' indices to a single
sentinel row (ops.gather_distance does this from the prune mask).  Repeated
block indices are *not re-fetched* (the pipeline skips the DMA when the block
index is unchanged), so pruned lanes cost no HBM traffic — the kernel-level
realization of "skipping the distance call" (DESIGN.md §3).

Grid: (B, M/bm) — per step a (bm, d) row-gather... rows are gathered one at a
time within the step via a fori_loop of dynamic loads from the table ref kept
in ANY/HBM memory space, computing dist2 against the (1, d) query tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gather_kernel(idx_ref, q_ref, table_ref, o_ref):
    b = pl.program_id(0)
    q = q_ref[...].astype(jnp.float32)          # [1, d]
    row = idx_ref[b, pl.program_id(1)]          # scalar-prefetched index
    v = pl.load(table_ref, (pl.dslice(row, 1), slice(None)))  # row DMA
    diff = q[0, :] - v[0, :].astype(jnp.float32)
    o_ref[0, 0] = jnp.sum(diff * diff)


@functools.partial(jax.jit, static_argnames=("interpret",))
def gather_distance_pallas(indices, queries, table, *, interpret: bool = True):
    """indices [B, M] int32 (rows of table), queries [B, d], table [N, d]
    -> dist2 [B, M] float32."""
    B, M = indices.shape
    _, d = queries.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, M),
        in_specs=[
            pl.BlockSpec((1, d), lambda b, m, idx: (b, 0)),
            pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY),  # table in HBM
        ],
        out_specs=pl.BlockSpec((1, 1), lambda b, m, idx: (b, m)),
    )
    return pl.pallas_call(
        _gather_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, M), jnp.float32),
        interpret=interpret,
    )(indices, queries, table)

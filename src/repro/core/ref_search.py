"""Faithful scalar reference of the paper's Algorithm 1 / Algorithm 2 (NumPy).

This is the oracle the batched JAX engine (core/search.py) is tested against:
two priority queues (candidate queue C, top-results queue T), per-node
visited/pruned status, exact distance-call counting, and optional angle
instrumentation (paper §3.3 / Fig. 7-8).

It is also the construction-time searcher for sequential HNSW insertion.
"""
from __future__ import annotations

import heapq
from typing import List, Optional, Tuple

import numpy as np

from repro.core.graph import GraphIndex

STATUS_UNVISITED = 0
STATUS_VISITED = 1
STATUS_PRUNED = 2


class SearchStats:
    __slots__ = ("dist_calls", "est_calls", "hops", "angles", "est_pairs",
                 "pruned_ids", "visited_ids")

    def __init__(self):
        self.dist_calls = 0     # exact distance evaluations (paper's "hops")
        self.est_calls = 0      # cosine-theorem estimates evaluated
        self.hops = 0           # node expansions
        self.angles: List[float] = []         # instrumented theta values
        self.est_pairs: List[Tuple[float, float]] = []  # (est_eu, true_eu)
        self.pruned_ids: set = set()
        self.visited_ids: set = set()


def _rank_dist(q, x, metric):
    if metric == "l2":
        d = q - x
        return float(np.dot(d, d))
    return float(1.0 - np.dot(q, x))


def _rank_to_eu(rank, nq, nx, metric):
    if metric == "l2":
        return float(np.sqrt(max(rank, 0.0)))
    return float(np.sqrt(max(nx * nx + nq * nq + 2.0 * rank - 2.0, 0.0)))


def _eu_to_rank(eu, nq, nx, metric):
    if metric == "l2":
        return eu * eu
    return (eu * eu - nx * nx - nq * nq + 2.0) / 2.0


def greedy_search_ref(
    g: GraphIndex,
    q: np.ndarray,
    entry: int,
    efs: int,
    router: Optional[str] = None,          # None | "triangle" | "crouting" | "crouting_o"
    cos_theta: float = 0.0,                # cos(theta*) for crouting
    record_angles: bool = False,
    record_est_error: bool = False,
    max_hops: int = 10**9,
    stale_bound: bool = False,
) -> Tuple[np.ndarray, np.ndarray, SearchStats]:
    """Algorithm 1 (router=None) / Algorithm 2 (router='crouting').

    Returns (ids[efs], rank_dists[efs]) sorted ascending, plus stats.
    ``crouting_o`` disables error correction: a pruned node is treated like a
    visited node on revisit (skipped), reproducing the paper's CRouting_O.
    ``stale_bound=True`` freezes the upper bound at expansion start (the
    batched engine's SPMD semantics) for exact-equivalence testing.
    """
    n = g.n
    metric = g.metric
    vecs = g.vectors
    norms = g.norms if g.norms is not None else None
    nq = float(np.linalg.norm(q)) if metric != "l2" else 1.0
    status = np.zeros(n, dtype=np.uint8)
    stats = SearchStats()

    def exact(i):
        stats.dist_calls += 1
        return _rank_dist(q, vecs[i], metric)

    d0 = exact(entry)
    status[entry] = STATUS_VISITED
    stats.visited_ids.add(entry)
    # C: min-heap of (dist, id); T: max-heap of (-dist, id)
    C = [(d0, entry)]
    T = [(-d0, entry)]

    while C and stats.hops < max_hops:
        dc, c = heapq.heappop(C)
        upper = -T[0][0]
        if dc > upper and len(T) >= efs:
            break
        stats.hops += 1
        nx_c = float(norms[c]) if norms is not None else 1.0
        d_cq_eu = _rank_to_eu(dc, nq, nx_c, metric)
        frozen_upper = upper
        frozen_full = len(T) >= efs

        nbrs = g.neighbors[c]
        edists = g.edge_eu_dist[c]
        for slot in range(len(nbrs)):
            nid = int(nbrs[slot])
            if nid >= n:
                break
            st = status[nid]
            if st == STATUS_VISITED:
                continue
            d_cn_eu = float(edists[slot])
            pool_full = frozen_full if stale_bound else len(T) >= efs
            prune_bound = frozen_upper if stale_bound else upper

            if st == STATUS_PRUNED and router == "crouting_o":
                continue  # no error correction: pruned is final

            if (st == STATUS_UNVISITED and router is not None and pool_full):
                # --- pruning strategies -------------------------------------
                if router in ("crouting", "crouting_o"):
                    stats.est_calls += 1
                    est2 = (d_cn_eu * d_cn_eu + d_cq_eu * d_cq_eu
                            - 2.0 * d_cn_eu * d_cq_eu * cos_theta)
                    est_eu = np.sqrt(max(est2, 0.0))
                    nx_n = float(norms[nid]) if norms is not None else 1.0
                    est_rank = _eu_to_rank(est_eu, nq, nx_n, metric)
                    if record_est_error:
                        true_rank = _rank_dist(q, vecs[nid], metric)
                        true_eu = _rank_to_eu(true_rank, nq, nx_n, metric)
                        stats.est_pairs.append((est_eu, true_eu))
                    if est_rank >= prune_bound:
                        status[nid] = STATUS_PRUNED
                        stats.pruned_ids.add(nid)
                        continue
                elif router == "triangle":
                    # lower bound from the triangle inequality (paper §3.2);
                    # exact bound => safe to discard permanently.
                    lb_eu = abs(d_cn_eu - d_cq_eu)
                    nx_n = float(norms[nid]) if norms is not None else 1.0
                    lb_rank = _eu_to_rank(lb_eu, nq, nx_n, metric)
                    if lb_rank >= prune_bound:
                        status[nid] = STATUS_VISITED
                        stats.visited_ids.add(nid)
                        continue

            # --- exact-distance path (incl. error-corrected revisits) -------
            status[nid] = STATUS_VISITED
            stats.visited_ids.add(nid)
            dn = exact(nid)
            if record_angles and np.isfinite(d_cn_eu) and d_cn_eu > 1e-9 and d_cq_eu > 1e-9:
                nx_n = float(norms[nid]) if norms is not None else 1.0
                d_nq_eu = _rank_to_eu(dn, nq, nx_n, metric)
                cosv = (d_cq_eu**2 + d_cn_eu**2 - d_nq_eu**2) / (2.0 * d_cq_eu * d_cn_eu)
                stats.angles.append(float(np.arccos(np.clip(cosv, -1.0, 1.0))))
            if dn < upper or len(T) < efs:
                heapq.heappush(C, (dn, nid))
                heapq.heappush(T, (-dn, nid))
                if len(T) > efs:
                    heapq.heappop(T)
                upper = -T[0][0]

    out = sorted(((-d, i) for d, i in T))
    ids = np.full(efs, -1, dtype=np.int64)
    ds = np.full(efs, np.inf, dtype=np.float32)
    for j, (d, i) in enumerate(out[:efs]):
        ids[j] = i
        ds[j] = d
    return ids, ds, stats


def descend_hierarchy_ref(g: GraphIndex, q: np.ndarray) -> Tuple[int, int]:
    """HNSW upper-layer greedy 1-NN descent. Returns (entry_for_layer0, dist_calls)."""
    if not g.upper_neighbors:
        return g.entry_point, 0
    cur = g.entry_point
    calls = 1
    d_cur = _rank_dist(q, g.vectors[cur], g.metric)
    for lvl in range(len(g.upper_neighbors)):  # top..1
        ids = g.upper_ids[lvl]
        pos = {int(v): j for j, v in enumerate(ids)}
        improved = True
        while improved:
            improved = False
            j = pos.get(cur)
            if j is None:
                break
            for nid in g.upper_neighbors[lvl][j]:
                nid = int(nid)
                if nid >= g.n:
                    break
                d = _rank_dist(q, g.vectors[nid], g.metric)
                calls += 1
                if d < d_cur:
                    d_cur = d
                    cur = nid
                    improved = True
    return cur, calls


def search_ref(g: GraphIndex, q: np.ndarray, efs: int, k: int = 10, **kw):
    """Full query = hierarchy descent + layer-0 Algorithm 1/2 search."""
    entry, upper_calls = descend_hierarchy_ref(g, q)
    ids, ds, stats = greedy_search_ref(g, q, entry, efs, **kw)
    # greedy re-evaluates the entry distance the descent already computed;
    # count it once (hnswlib reuses the descent's value).
    stats.dist_calls += max(0, upper_calls - 1)
    return ids[:k], ds[:k], stats

"""Distributed serving: sharded search must merge to (near-)single-device
results; straggler hop-budget degrades gracefully.  Runs in a subprocess so
the 8 host devices don't leak into other tests."""
import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import json
import numpy as np, jax
from repro.core.sharded_index import shard_dataset, ShardedAnnIndex
from repro.core.index import AnnIndex
from repro.core.spec import SearchSpec
from repro.data.vectors import make_dataset, exact_ground_truth, recall_at_k
from repro.launch.mesh import make_local_mesh

ds = make_dataset(n_base=3000, n_query=40, dim=48, n_clusters=24, seed=0)
gt = exact_ground_truth(ds, k=10)
arrays = shard_dataset(ds.base, n_shards=8, graph="hnsw", m=12, efc=64)
mesh = make_local_mesh(8, "shards")
out = {}

spec = SearchSpec(k=10, efs=48, router="crouting", max_hops=2048)
idx = ShardedAnnIndex(arrays, mesh, spec=spec)
ids, d, stats = idx.search(ds.queries)
out["recall_sharded"] = recall_at_k(ids, gt, 10)
out["calls"] = int(stats.dist_calls)
# the typed stats carry the registry router name + aggregate counters
out["stats_ok"] = bool(stats.router == "crouting"
                       and int(stats.est_calls) > 0
                       and int(stats.iters) > 0)

# global ids must be valid and deduplicated per query
ok = True
for row in ids:
    real = [i for i in row if i >= 0]
    ok &= len(set(real)) == len(real) and all(0 <= i < 3000 for i in real)
out["ids_valid"] = bool(ok)

# single- index reference (same total data, one graph)
ref = AnnIndex.build(ds.base, graph="hnsw", m=12, efc=64)
rids, _, _ = ref.search(ds.queries, spec=SearchSpec(k=10, efs=48,
                                                    router="crouting"))
out["recall_single"] = recall_at_k(rids, gt, 10)

# straggler mitigation: tiny hop budget must still return (degraded) results
idx2 = ShardedAnnIndex(arrays, mesh, spec=spec.replace(max_hops=8))
ids2, _, stats2 = idx2.search(ds.queries)
out["recall_budget"] = recall_at_k(ids2, gt, 10)
out["calls_budget"] = int(stats2.dist_calls)

# a plugin router's extra counters must survive the shard psum (review
# finding: the serve step used to drop SearchResult.extra silently)
import dataclasses
import jax.numpy as jnp
from repro.core.routers import EdgeAngleRouter, register_router

@dataclasses.dataclass(frozen=True)
class CountingRouter(EdgeAngleRouter):
    def estimate_rank(self, ctx):
        est_rank, _ = super().estimate_rank(ctx)
        return est_rank, {"my_tests": jnp.sum(ctx.try_prune, axis=1,
                                              dtype=jnp.int32)}

register_router(CountingRouter(name="counting", prunes=True,
                               extra_counters=("my_tests",)))
idx3 = ShardedAnnIndex(arrays, mesh, spec=spec.replace(router="counting"))
_, _, stats3 = idx3.search(ds.queries[:8])
out["extra_counter"] = int(stats3.extra["my_tests"])
print("RESULT " + json.dumps(out))
"""


@pytest.mark.slow
def test_sharded_index_subprocess():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stderr[-3000:]
    line = [l for l in r.stdout.splitlines() if l.startswith("RESULT ")][-1]
    out = json.loads(line[len("RESULT "):])
    assert out["ids_valid"]
    assert out["stats_ok"]
    # sharded top-k merge over 8 sub-indexes should beat one global graph at
    # equal efs (it runs efs per shard) — require >= single-graph - 2%
    assert out["recall_sharded"] >= out["recall_single"] - 0.02, out
    assert out["recall_sharded"] > 0.9, out
    # bounded-hop straggler mode: returns, degraded but nonzero
    assert out["calls_budget"] < out["calls"], out
    assert out["recall_budget"] > 0.2, out
    # plugin-router extra counters round-trip through the shard reduction
    assert out["extra_counter"] > 0, out

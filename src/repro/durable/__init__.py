"""Durable mutations (DESIGN.md §11): write-ahead log + checkpoint/recovery.

Public surface::

    from repro import durable

    store = durable.DurableStore.create(dir, fsync="every")
    lsn = store.append_insert(ids, vectors)   # write-ahead
    store.ack(lsn)                            # durability point = ack point

    store = durable.DurableStore.open(dir)    # recovery
    state = store.load_checkpoint()
    for rec in store.replay():                # torn tail truncated,
        ...                                   # mid-log damage raises
    store.attach()                            # keep appending

The high-level entry points live on the mutation stack:
``MutableAnnIndex(..., durable_dir=...)`` / ``MutableAnnIndex.recover`` /
``.checkpoint()``, and ``MutableShardedAnnIndex.save/load/recover``.
"""
from repro.durable.atomic import (atomic_write_bytes, atomic_write_npz,
                                  damage_file, fsync_dir, payload_checksum,
                                  read_npz, read_npz_verified,
                                  verify_checksum)
from repro.durable.manifest import (MANIFEST_NAME, Manifest, read_manifest,
                                    write_manifest)
from repro.durable.store import DurableStore, has_manifest
from repro.durable.wal import (FSYNC_POLICIES, DeleteRecord, InsertRecord,
                               SegmentWriter, WalFailedError, read_segment)

__all__ = [
    "atomic_write_bytes", "atomic_write_npz", "damage_file", "fsync_dir",
    "payload_checksum", "read_npz", "read_npz_verified", "verify_checksum",
    "MANIFEST_NAME", "Manifest", "read_manifest", "write_manifest",
    "DurableStore", "has_manifest",
    "FSYNC_POLICIES", "DeleteRecord", "InsertRecord", "SegmentWriter",
    "WalFailedError", "read_segment",
]

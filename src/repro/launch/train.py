"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Runs a REDUCED config end-to-end on local devices (the full configs only
lower via dryrun.py on this CPU container; on a real TPU slice pass
--full to use the assigned config with the production mesh).
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import get_arch
from repro.data.synthetic import LMStream
from repro.train import optimizer as opt
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--full", action="store_true",
                    help="use the full assigned config (TPU slice only)")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    spec = get_arch(args.arch)
    assert spec.family == "lm", "train.py drives LM archs; see examples/ for others"
    cfg = spec.model_cfg if args.full else spec.smoke_cfg

    from repro.models import transformer as T
    ocfg = opt.AdamWConfig(lr=args.lr, warmup_steps=20, total_steps=args.steps)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    state = opt.adamw_init(params, ocfg)
    stream = LMStream(cfg.vocab, args.batch, args.seq, seed=0)

    trainer = Trainer(
        TrainerConfig(total_steps=args.steps, ckpt_every=args.ckpt_every,
                      ckpt_dir=args.ckpt_dir, log_every=10),
        T.make_train_step(cfg, ocfg), params, state, stream)
    if args.resume and trainer.maybe_resume():
        print(f"resumed from step {trainer.step}")
    out = trainer.run()
    print(f"done: final loss {out['final_loss']:.4f} "
          f"(start {out['history'][0]:.4f})")


if __name__ == "__main__":
    main()

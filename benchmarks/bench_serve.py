"""Serving-frontend benchmarks (persisted to committed BENCH_serve.json).

One ragged request trace (log-uniform sizes up to the top bucket) replayed
through ``repro.serve.ServeFrontend`` against both backends:

* ``serve_single``  — one ``AnnIndex`` in-process;
* ``serve_sharded`` — a ``ShardedAnnIndex`` over 8 host devices, run in a
  subprocess (``--xla_force_host_platform_device_count`` must be set before
  jax initializes, which the parent process already did).

Acceptance (ISSUE 5): per-bucket p50/p95/p99 latency + QPS for both
backends, and ``recompiles_after_warmup == 0`` across the ragged trace —
every batch shape a request can produce was pre-jitted by the bucket
warmup.  ``BENCH_SMOKE=1`` shrinks sizes and diverts the JSON to .cache/.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

import numpy as np

from benchmarks.common import (SMOKE, dataset, cached_index, emit,
                               persist_bench, smoke_scale)
from repro.core.spec import SearchSpec
from repro.data.vectors import exact_ground_truth, recall_at_k
from repro.serve import ServeFrontend

BUCKETS = (1, 4, 8) if SMOKE else (1, 8, 32, 64)
N_REQUESTS = 6 if SMOKE else 48


def ragged_trace(n_requests: int, top: int, seed: int = 7) -> np.ndarray:
    """Log-uniform request sizes in [1, top]: mostly small, a few full
    (same distribution as ``repro.launch.serve.ragged_sizes`` — size 1 MUST
    occur so the committed numbers cover the single-query rung)."""
    rng = np.random.default_rng(seed)
    sizes = np.exp(rng.uniform(0, np.log(top + 1), n_requests)).astype(int)
    return np.clip(sizes, 1, top)


def replay(fe: ServeFrontend, queries: np.ndarray, sizes: np.ndarray,
           coalesce: int = 3) -> np.ndarray:
    """Submit the trace; returns the concatenated result ids.

    The first quarter dispatches one request at a time (an idle server:
    every rung — including bucket 1 — gets solo-dispatch latency samples);
    the rest flushes every ``coalesce`` submissions (a loaded server: the
    micro-batcher coalesces)."""
    solo = max(1, len(sizes) // 4)
    offs = np.concatenate([[0], np.cumsum(sizes)])
    futs = []
    for i in range(len(sizes)):
        futs.append(fe.submit(queries[offs[i]:offs[i + 1]]))
        if i < solo or i % coalesce == coalesce - 1:
            fe.flush()
    fe.flush()
    return np.concatenate([f.result()[0] for f in futs])


def _run_trace(index, spec: SearchSpec, ds, gt) -> dict:
    sizes = ragged_trace(N_REQUESTS, BUCKETS[-1])
    need = int(sizes.sum())
    q = np.take(ds.queries, np.arange(need) % len(ds.queries), axis=0)
    gtr = np.take(gt, np.arange(need) % len(ds.queries), axis=0)
    fe = ServeFrontend(index, spec, buckets=BUCKETS,
                       max_pending_rows=4 * BUCKETS[-1])
    ids = replay(fe, q, sizes)
    summ = fe.telemetry.summary()
    summ["recall_at_k"] = round(recall_at_k(ids, gtr, spec.k), 3)
    summ["trace"] = {"requests": len(sizes), "rows": need,
                     "sizes_min_max": [int(sizes.min()), int(sizes.max())]}
    assert summ["recompiles_after_warmup"] == 0, \
        f"a batch shape escaped the bucket ladder: {summ}"
    return summ


def serve_single():
    """Single-index backend behind the bucketed frontend."""
    ds = dataset("sift-synth", n_base=smoke_scale(4000, 600))
    idx = cached_index(ds)
    gt = exact_ground_truth(ds, k=10)
    spec = SearchSpec(efs=64, k=10, router="crouting")
    summ = _run_trace(idx, spec, ds, gt)
    emit("serve_single", 0.0,
         {"qps": summ["qps"], "p50_ms": summ["latency"]["p50_ms"],
          "p99_ms": summ["latency"]["p99_ms"],
          "recall": summ["recall_at_k"],
          "recompiles": summ["recompiles_after_warmup"]})
    summ["n_base"] = int(ds.base.shape[0])
    persist_bench("serve_single", summ, file="BENCH_serve.json")
    return summ


_SHARDED_CHILD = r"""
import json
import numpy as np
from benchmarks import bench_serve as BS
from benchmarks.common import dataset, smoke_scale
from repro.core.sharded_index import shard_dataset, ShardedAnnIndex
from repro.core.spec import SearchSpec
from repro.data.vectors import exact_ground_truth
from repro.launch.mesh import make_local_mesh
import jax

n_dev = len(jax.devices())
ds = dataset("sift-synth", n_base=smoke_scale(4000, 600))
gt = exact_ground_truth(ds, k=10)
arrays = shard_dataset(ds.base, n_shards=n_dev, graph="hnsw",
                       m=smoke_scale(16, 8), efc=smoke_scale(96, 48))
mesh = make_local_mesh(n_dev, "shards")
spec = SearchSpec(efs=64, k=10, router="crouting", max_hops=2048)
idx = ShardedAnnIndex(arrays, mesh, spec=spec)
summ = BS._run_trace(idx, spec, ds, gt)
summ["n_base"] = int(ds.base.shape[0])
summ["n_shards"] = n_dev
print("RESULT " + json.dumps(summ))
"""


def serve_sharded():
    """Sharded backend over 8 host devices (subprocess: the device-count
    flag must be set before jax initializes)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        " --xla_force_host_platform_device_count=" +
                        ("4" if SMOKE else "8")).strip()
    env.setdefault("PYTHONPATH", "src")
    r = subprocess.run([sys.executable, "-c", _SHARDED_CHILD], env=env,
                       capture_output=True, text=True,
                       cwd=os.path.join(os.path.dirname(__file__), ".."),
                       timeout=3000)
    if r.returncode != 0:
        raise RuntimeError(f"sharded serve child failed:\n{r.stderr[-3000:]}")
    line = [l for l in r.stdout.splitlines() if l.startswith("RESULT ")][-1]
    summ = json.loads(line[len("RESULT "):])
    emit("serve_sharded", 0.0,
         {"qps": summ["qps"], "p50_ms": summ["latency"]["p50_ms"],
          "p99_ms": summ["latency"]["p99_ms"],
          "recall": summ["recall_at_k"], "shards": summ["n_shards"],
          "recompiles": summ["recompiles_after_warmup"]})
    persist_bench("serve_sharded", summ, file="BENCH_serve.json")
    return summ

"""The batched JAX engine must match the scalar NumPy oracle (Algorithm 1/2)."""
import numpy as np
import pytest

from repro.core.ref_search import search_ref
from repro.core.search import EngineConfig, search_batch


def _pools_match(eng_ids, ref_ids, n):
    a = sorted(int(x) for x in eng_ids if 0 <= x < n)
    b = sorted(int(x) for x in ref_ids if x >= 0)
    return a == b


def test_plain_greedy_exact_match(small_ds, hnsw_index):
    g = hnsw_index
    res = search_batch(g, small_ds.queries, EngineConfig(efs=40, router="none"))
    for i, q in enumerate(small_ds.queries):
        ids, _, st = search_ref(g, q, efs=40, k=40)
        assert _pools_match(res.ids[i], ids, g.n), f"pool mismatch q{i}"
        assert int(res.dist_calls[i]) == st.dist_calls, f"call-count mismatch q{i}"


def test_crouting_matches_stale_bound_oracle(small_ds, hnsw_index, hnsw_profile):
    g = hnsw_index
    ct = hnsw_profile.cos_theta_star
    res = search_batch(g, small_ds.queries,
                       EngineConfig(efs=40, router="crouting"), cos_theta=ct)
    for i, q in enumerate(small_ds.queries):
        ids, _, st = search_ref(g, q, efs=40, k=40, router="crouting",
                                cos_theta=ct, stale_bound=True)
        assert _pools_match(res.ids[i], ids, g.n), f"pool mismatch q{i}"
        assert int(res.dist_calls[i]) == st.dist_calls
        assert int(res.est_calls[i]) == st.est_calls


def test_crouting_o_matches_oracle(small_ds, hnsw_index, hnsw_profile):
    g = hnsw_index
    ct = hnsw_profile.cos_theta_star
    res = search_batch(g, small_ds.queries[:16],
                       EngineConfig(efs=40, router="crouting_o"), cos_theta=ct)
    for i, q in enumerate(small_ds.queries[:16]):
        ids, _, st = search_ref(g, q, efs=40, k=40, router="crouting_o",
                                cos_theta=ct, stale_bound=True)
        assert _pools_match(res.ids[i], ids, g.n)
        assert int(res.dist_calls[i]) == st.dist_calls


def test_triangle_router_is_safe(small_ds, hnsw_index):
    """Triangle-inequality pruning uses an exact lower bound: the result pool
    must equal plain greedy's (paper §3.2: correct but barely prunes)."""
    g = hnsw_index
    plain = search_batch(g, small_ds.queries, EngineConfig(efs=40, router="none"))
    tri = search_batch(g, small_ds.queries, EngineConfig(efs=40, router="triangle"))
    for i in range(len(small_ds.queries)):
        assert _pools_match(tri.ids[i], np.asarray(plain.ids[i]), g.n)
        assert int(tri.dist_calls[i]) <= int(plain.dist_calls[i])


def test_live_vs_frozen_bound_delta_is_small(small_ds, hnsw_index, hnsw_profile):
    """DESIGN.md §3: frozen-bound (SPMD) semantics prune slightly less than
    the paper's live bound; the distance-call delta must be tiny."""
    g = hnsw_index
    ct = hnsw_profile.cos_theta_star
    live = frozen = 0
    for q in small_ds.queries[:20]:
        _, _, st1 = search_ref(g, q, efs=40, router="crouting", cos_theta=ct)
        _, _, st2 = search_ref(g, q, efs=40, router="crouting", cos_theta=ct,
                               stale_bound=True)
        live += st1.dist_calls
        frozen += st2.dist_calls
    assert frozen >= live * 0.95
    assert frozen <= live * 1.15, (live, frozen)

"""SQ8 scalar quantization: bound soundness, roundtrip error, the two-stage
engine's recall floor, and the single-implementation contract with
train/compress.py."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.quant import sq8 as SQ

RNG = np.random.default_rng(7)


# --------------------------------------------------------------------------
# encode/decode + bound math
# --------------------------------------------------------------------------
@pytest.mark.parametrize("n,d,scale_kind", [(300, 16, "unit"),
                                            (200, 64, "wide"),
                                            (128, 128, "skewed")])
def test_sq8_roundtrip_error_within_eps(n, d, scale_kind):
    x = RNG.normal(size=(n, d)).astype(np.float32)
    if scale_kind == "wide":
        x *= 50.0
    elif scale_kind == "skewed":
        x *= np.geomspace(1e-3, 1e3, d).astype(np.float32)[None, :]
    p = SQ.sq8_train(x)
    xhat = SQ.sq8_decode(SQ.sq8_encode(x, p), p)
    assert (np.abs(x - xhat) <= p.eps[None, :]).all()


def test_sq8_constant_dimension_is_exactly_reconstructed():
    x = RNG.normal(size=(50, 8)).astype(np.float32)
    x[:, 3] = 2.5
    p = SQ.sq8_train(x)
    xhat = SQ.sq8_decode(SQ.sq8_encode(x, p), p)
    np.testing.assert_allclose(xhat[:, 3], 2.5, atol=1e-5)


def test_sq8_lower_bound_never_exceeds_true_distance():
    """Property (the engine's skip-safety contract): for random tables,
    grids and queries, lb2 <= true squared distance — always."""
    for seed in range(20):
        rng = np.random.default_rng(seed)
        n, d = 200, int(rng.integers(4, 160))
        spread = 10.0 ** rng.uniform(-2, 2)
        x = (rng.normal(size=(n, d)) * spread).astype(np.float32)
        q = (rng.normal(size=(8, d)) * spread).astype(np.float32)
        p = SQ.sq8_train(x)
        xhat = SQ.sq8_decode(SQ.sq8_encode(x, p), p)
        rows = jnp.asarray(np.broadcast_to(xhat[None], (8, n, d)))
        ad2, lb2 = SQ.sq8_estimate(jnp.asarray(q), rows, jnp.asarray(p.eps))
        true_d2 = ((q[:, None, :] - x[None, :, :]) ** 2).sum(-1)
        lb2 = np.asarray(lb2)
        assert (lb2 <= true_d2 + 1e-4 * (1.0 + true_d2)).all(), \
            (seed, float((lb2 - true_d2).max()))


def test_sq8_estimate_tracks_true_distance():
    """The stage-1 estimate itself (not just the bound) must be tight: the
    relative error of ad2 stays far below the efs-level slack the two-stage
    engine tolerates."""
    x = RNG.normal(size=(500, 96)).astype(np.float32)
    q = RNG.normal(size=(16, 96)).astype(np.float32)
    p = SQ.sq8_train(x)
    xhat = SQ.sq8_decode(SQ.sq8_encode(x, p), p)
    rows = jnp.asarray(np.broadcast_to(xhat[None], (16, 500, 96)))
    ad2, _ = SQ.sq8_estimate(jnp.asarray(q), rows, jnp.asarray(p.eps))
    true_d2 = ((q[:, None, :] - x[None, :, :]) ** 2).sum(-1)
    rel = np.abs(np.asarray(ad2) - true_d2) / (true_d2 + 1e-9)
    assert np.median(rel) < 5e-3 and rel.max() < 5e-2


# --------------------------------------------------------------------------
# symmetric int8 (the gradient-compression quantizer now lives here)
# --------------------------------------------------------------------------
def test_symmetric_int8_roundtrip():
    x = jnp.asarray(RNG.normal(size=(64, 32)), jnp.float32) * 3.0
    q, scale = SQ.quantize_int8(x)
    err = np.abs(np.asarray(SQ.dequantize_int8(q, scale)) - np.asarray(x))
    assert err.max() <= float(scale) * 0.5 + 1e-6


def test_compress_reexports_are_the_same_functions():
    """train/compress.py must not grow a second int8 implementation."""
    from repro.train import compress as C

    assert C.quantize_int8 is SQ.quantize_int8
    assert C.dequantize_int8 is SQ.dequantize_int8


# --------------------------------------------------------------------------
# two-stage engine: recall floor + fp32-DMA reduction
# --------------------------------------------------------------------------
@pytest.fixture(scope="module")
def suite():
    from repro.data.vectors import make_dataset, exact_ground_truth
    from repro.core.index import AnnIndex

    out = []
    for name, dim, seed in (("a", 48, 0), ("b", 96, 11)):
        ds = make_dataset(n_base=1500, n_query=32, dim=dim, n_clusters=24,
                          seed=seed)
        idx = AnnIndex.build(ds.base, graph="hnsw", m=12, efc=80)
        out.append((ds, idx, exact_ground_truth(ds, k=10)))
    return out


@pytest.mark.parametrize("estimate,router", [("sq8", "none"),
                                             ("both", "crouting")])
def test_sq8_recall_floor_at_efs64(suite, estimate, router):
    """Acceptance: estimate="sq8" (with rerank) matches the exact path's
    top-k recall within 0.01 at efs >= 64 on the synthetic suite."""
    from repro.data.vectors import recall_at_k

    for ds, idx, gt in suite:
        from repro.core.spec import SearchSpec
        ids_e, _, info_e = idx.search(
            ds.queries, spec=SearchSpec(k=10, efs=64, router="none",
                                        estimate="exact"))
        ids_q, _, info_q = idx.search(
            ds.queries, spec=SearchSpec(k=10, efs=64, router=router,
                                        estimate=estimate))
        rec_e = recall_at_k(ids_e, gt, 10)
        rec_q = recall_at_k(ids_q, gt, 10)
        assert rec_q >= rec_e - 0.01, (rec_e, rec_q)
        # the point of the two stages: far fewer fp32 row fetches than the
        # exact baseline performs distance calls
        assert info_q.rerank_calls.mean() < info_e.dist_calls.mean()
        assert info_q.dist_calls.mean() < info_e.dist_calls.mean()
        # stage-1 ran, and every returned candidate was re-ranked exactly
        assert info_q.sq8_calls.mean() > 0
        assert info_q.rerank_calls.mean() > 0


def test_sq8_returned_distances_are_exact(suite):
    """Approx pool entries must be re-ranked before being returned: the
    reported top-k distances equal the true distances of the returned ids."""
    ds, idx, _ = suite[0]
    from repro.core.spec import SearchSpec
    ids, dists, _ = idx.search(ds.queries,
                               spec=SearchSpec(k=10, efs=64, router="none",
                                               estimate="sq8"))
    for qi in range(0, len(ds.queries), 7):
        for j in range(10):
            if ids[qi, j] < 0:
                continue
            true = float(((ds.queries[qi] - ds.base[ids[qi, j]]) ** 2).sum())
            assert abs(true - float(dists[qi, j])) <= 1e-3 * (1 + true)


def test_estimate_validation():
    from repro.core.search import search_batch
    from repro.core.spec import SearchSpec
    from repro.data.vectors import make_dataset
    from repro.core.hnsw import build_hnsw

    ds = make_dataset(n_base=300, n_query=2, dim=16, n_clusters=6, seed=1)
    g = build_hnsw(ds.base, m=6, efc=24, seed=0)
    with pytest.raises(AssertionError):
        search_batch(g, ds.queries, SearchSpec(efs=16, estimate="nope"))
    with pytest.raises(AssertionError):
        # "angle"/"both" demand a pruning router
        search_batch(g, ds.queries,
                     SearchSpec(efs=16, router="none", estimate="angle"))

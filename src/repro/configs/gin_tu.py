"""gin-tu [gnn] — 5L, d=64, sum aggregator, learnable eps [arXiv:1810.00826]."""
from repro.configs import ArchSpec
from repro.configs.shapes import GNN_SHAPES
from repro.models.gnn import GnnConfig

SPEC = ArchSpec(
    arch_id="gin-tu",
    family="gnn",
    model_cfg=GnnConfig(name="gin-tu", arch="gin", n_layers=5, d_hidden=64,
                        task="node_class"),
    shapes=GNN_SHAPES,
    source="arXiv:1810.00826; paper",
    smoke_cfg=GnnConfig(name="gin-smoke", arch="gin", n_layers=2, d_hidden=16,
                        n_classes=4, task="node_class"),
)

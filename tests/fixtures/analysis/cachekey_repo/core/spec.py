"""Bad SearchSpec classification + canonical() drift (cache-key fixture)."""
import dataclasses

KNOB_DOMAINS = {                    # expect[cache-key] stale_knob not a field
    "efs": (32, 64),
    "stale_knob": (1, 2),
}
REQUEST_ONLY_FIELDS = ("k",)
STRUCTURAL_FIELDS = ("metric",)


@dataclasses.dataclass(frozen=True)
class SearchSpec:
    efs: int = 64
    metric: str = "l2"
    k: int = 10
    cos_theta: float = 0.0          # expect[cache-key] unclassified

    def canonical(self):
        # resets a knob, forgets the request-only field: two findings
        return dataclasses.replace(self, efs=64)  # expect[cache-key,cache-key]

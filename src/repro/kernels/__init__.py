# Pallas TPU kernels for the paper's compute hot spots (distance evaluation
# is >=83% of ANNS query time — Fig. 2).  Each kernel: <name>.py (pallas_call
# + BlockSpec), validated in interpret mode against ref.py oracles; ops.py is
# the jit'd public wrapper layer.
#
#   l2_distance.py      tiled distance matrix (MXU)           [brute force/KNN/DLRM retrieval]
#   crouting_prune.py   fused cosine-estimate + prune (VPU)   [paper Alg. 2 inner loop]
#   gather_distance.py  fused gather + distance (scalar-prefetch DMA)
#   pool_merge.py       bitonic sorted-pool merge (VPU network)
#   fused_expand.py     estimate + prune + conditional gather + distance in
#                       one kernel — the beam engine's per-iteration tile op
#                       (core/search.py, SearchSpec.engine="pallas")
#   sq8_distance.py     uint8 code-row gather + dequantized distance +
#                       conservative lower bound — stage 1 of the two-stage
#                       engine (SearchSpec.estimate="sq8"|"both")

from repro.kernels import ops  # noqa: F401

"""``DurableStore``: one directory of manifest + checkpoint + WAL segments.

The store owns the durability *state machine* (DESIGN.md §11); the
mutation stack (``MutableAnnIndex``) owns WHAT gets logged and HOW records
replay.  Directory layout::

    dir/
      MANIFEST                  root of truth: checkpoint + segment binding
      checkpoint-00000001.npz   full-state checkpoint (v3 atomic-save recipe)
      wal-00000001.log          CRC32-framed mutation records since the ckpt

Protocol (every arrow is an atomic publish; a crash between any two leaves
a consistent binding):

1. ``create``   → empty segment S1 exists, ``{ckpt: ∅, segments: [S1]}``
2. initial ``publish_checkpoint`` → ``{ckpt: C1, segments: [S1]}``
3. mutations append to S1 (acked at their fsync-policy durability point)
4. ``rotate``   (caller holds the mutation lock, so the segment boundary
   is a mutation-order boundary) → S2 created, ``{C1, [S1, S2]}``
5. ``publish_checkpoint(state captured at the rotate boundary)``
   → ``{C2, [S2]}``; C1 + S1 are garbage, unlinked best-effort
6. recovery: load the manifest's checkpoint, replay its segments in
   order (torn tail on the final segment truncated; mid-log corruption
   raises), ``attach`` to the final segment and keep appending.

The store is also the *export* format: ``create`` + ``publish_checkpoint``
+ ``close`` writes a self-contained durable directory with an empty log —
that is exactly ``MutableShardedAnnIndex.save``.
"""
from __future__ import annotations

import glob
import os
import threading
from typing import Dict, List, Optional

import numpy as np

from repro.fault import CorruptIndexError, failpoints as fault

from repro.durable import wal
from repro.durable.atomic import atomic_write_npz, fsync_dir, read_npz_verified
from repro.durable.manifest import (MANIFEST_NAME, Manifest, read_manifest,
                                    write_manifest)

_SEG_FMT = "wal-{:08d}.log"
_CKPT_FMT = "checkpoint-{:08d}.npz"


def _seq_of(name: str) -> int:
    """The 8-digit sequence number embedded in a segment/checkpoint name."""
    stem = os.path.splitext(name)[0]
    return int(stem.rsplit("-", 1)[1])


def has_manifest(dirname: str) -> bool:
    """True when ``dirname`` holds durable state to ``recover`` from."""
    return os.path.exists(os.path.join(dirname, MANIFEST_NAME))


class DurableStore:
    """Manifest + checkpoint + WAL segment files under one directory."""

    def __init__(self, dirname: str, manifest: Manifest, *,
                 fsync: str = "every", fsync_interval_s: float = 0.002):
        assert fsync in wal.FSYNC_POLICIES, f"unknown fsync policy {fsync!r}"
        self.dir = os.path.abspath(dirname)
        self.fsync = fsync
        self.fsync_interval_s = float(fsync_interval_s)
        self._manifest = manifest            # guarded by: self._lock
        self._writer: Optional[wal.SegmentWriter] = None  # guarded by: self._lock
        self._lock = threading.Lock()        # manifest + writer swaps
        self._replayed_next_lsn: Optional[int] = None

    # --- lifecycle --------------------------------------------------------
    @classmethod
    def create(cls, dirname: str, *, fsync: str = "every",
               fsync_interval_s: float = 0.002,
               meta: Optional[Dict] = None) -> "DurableStore":
        """Initialize a fresh durable directory (refuses an existing one)."""
        dirname = os.path.abspath(dirname)
        if has_manifest(dirname):
            raise ValueError(
                f"{dirname} already holds durable state; recover() from it "
                "or point at a fresh directory")
        os.makedirs(dirname, exist_ok=True)
        seg = _SEG_FMT.format(1)
        with open(os.path.join(dirname, seg), "ab") as f:
            os.fsync(f.fileno())
        fsync_dir(dirname)
        manifest = Manifest(checkpoint=None, segments=[seg], next_lsn=0,
                            meta=dict(meta or {}))
        write_manifest(dirname, manifest)
        return cls(dirname, manifest, fsync=fsync,
                   fsync_interval_s=fsync_interval_s)

    @classmethod
    def open(cls, dirname: str, *, fsync: str = "every",
             fsync_interval_s: float = 0.002) -> "DurableStore":
        """Open existing durable state (recovery entry point).  Raises
        ``FileNotFoundError`` when there is no manifest."""
        dirname = os.path.abspath(dirname)
        manifest = read_manifest(dirname)
        return cls(dirname, manifest, fsync=fsync,
                   fsync_interval_s=fsync_interval_s)

    @property
    def manifest(self) -> Manifest:
        with self._lock:
            return self._manifest

    def path(self, name: str) -> str:
        return os.path.join(self.dir, name)

    def close(self) -> None:
        with self._lock:
            if self._writer is not None:
                self._writer.close()
                self._writer = None

    # --- mutation logging -------------------------------------------------
    def _require_writer(self) -> wal.SegmentWriter:
        with self._lock:
            w = self._writer
        if w is None:
            raise wal.WalFailedError(
                "store has no active WAL writer (not attached, or closed)")
        return w

    def append_insert(self, ext_ids: np.ndarray, vectors: np.ndarray) -> int:
        """Write-ahead one insert batch; returns its LSN (ack separately)."""
        return self._require_writer().append(wal.encode_insert,
                                             ext_ids, vectors)

    def append_delete(self, ext_ids) -> int:
        return self._require_writer().append(wal.encode_delete,
                                             np.asarray(ext_ids, np.int64))

    def ack(self, lsn: int) -> None:
        """Block until ``lsn`` is durable per the fsync policy — the
        acknowledgment point of the mutation that logged it."""
        self._require_writer().wait_durable(lsn)

    @property
    def next_lsn(self) -> int:
        with self._lock:
            w = self._writer
            manifest = self._manifest
        return w.next_lsn if w is not None else manifest.next_lsn

    # --- checkpoint protocol ----------------------------------------------
    def rotate(self) -> None:
        """Seal the active segment and open its successor (the caller MUST
        hold the mutation lock: the segment boundary is a mutation-order
        boundary).  The new segment joins the manifest BEFORE any mutation
        is acked into it."""
        fault.hit("wal.rotate")
        with self._lock:
            writer = self._writer
            if writer is None:
                raise wal.WalFailedError(
                    "store has no active WAL writer (not attached, or "
                    "closed)")
            next_lsn = writer.next_lsn
            writer.close(do_fsync=True)   # no torn tail behind a successor
            self._writer = None
            seq = _seq_of(self._manifest.segments[-1]) + 1
            seg = _SEG_FMT.format(seq)
            with open(self.path(seg), "ab") as f:
                os.fsync(f.fileno())
            fsync_dir(self.dir)
            manifest = Manifest(
                checkpoint=self._manifest.checkpoint,
                segments=list(self._manifest.segments) + [seg],
                next_lsn=next_lsn, meta=self._manifest.meta)
            write_manifest(self.dir, manifest)
            self._manifest = manifest
            self._writer = wal.SegmentWriter(
                self.path(seg), fsync=self.fsync,
                interval_s=self.fsync_interval_s, next_lsn=next_lsn)

    def publish_checkpoint(self, payload: Dict[str, np.ndarray]) -> str:
        """Write a full-state checkpoint and swap the manifest to it.

        ``payload`` must be the state captured at the LAST ``rotate``
        boundary (or creation, for the initial checkpoint): after the
        swap, only the active segment remains bound, and every superseded
        checkpoint/segment file is unlinked best-effort.  Returns the
        checkpoint file name.
        """
        with self._lock:
            old = self._manifest
            seq = (_seq_of(old.checkpoint) + 1 if old.checkpoint is not None
                   else 1)
            name = _CKPT_FMT.format(seq)
            atomic_write_npz(self.path(name), payload,
                             write_site="checkpoint.write")
            w = self._writer
            manifest = Manifest(
                checkpoint=name, segments=[old.segments[-1]],
                next_lsn=w.next_lsn if w is not None else old.next_lsn,
                meta=old.meta)
            write_manifest(self.dir, manifest)
            self._manifest = manifest
        self.prune()
        return name

    def prune(self) -> None:
        """Unlink files the manifest no longer references (best-effort —
        a crash leaves garbage, never inconsistency; re-pruned next time)."""
        with self._lock:
            keep = set(self._manifest.segments)
            if self._manifest.checkpoint is not None:
                keep.add(self._manifest.checkpoint)
        for pat in ("wal-*.log", "checkpoint-*.npz", "*.tmp.*"):
            for p in glob.glob(self.path(pat)):
                if os.path.basename(p) in keep:
                    continue
                try:
                    os.unlink(p)
                except OSError:
                    pass

    # --- recovery ---------------------------------------------------------
    def load_checkpoint(self) -> Dict[str, np.ndarray]:
        """Read + verify the manifest's checkpoint payload."""
        name = self.manifest.checkpoint
        if name is None:
            raise CorruptIndexError(
                f"{self.dir}: manifest has no checkpoint — creation "
                "crashed before initialization completed; rebuild the "
                "index instead of recovering")
        return read_npz_verified(self.path(name), required=True)

    def replay(self) -> List[wal.WalRecord]:
        """Read every bound segment in order, applying the recovery rules.

        A torn tail on the FINAL segment is truncated away on disk (the
        records behind it were never acked); mid-log corruption raises
        ``CorruptIndexError``.  LSNs must be strictly increasing across
        the whole replay.  Idempotence is the APPLIER's job — after a
        crash between a checkpoint's rotate and publish, the replay
        legitimately overlaps state the caller already holds.
        """
        records: List[wal.WalRecord] = []
        segments = self.manifest.segments
        for i, seg in enumerate(segments):
            path = self.path(seg)
            final = i == len(segments) - 1
            recs, valid_len, torn = wal.read_segment(path, final=final)
            if torn:
                with open(path, "r+b") as f:
                    f.truncate(valid_len)
                    os.fsync(f.fileno())
            records.extend(recs)
        last = -1
        for r in records:
            if r.lsn <= last:
                raise CorruptIndexError(
                    f"{self.dir}: WAL replay out of order (lsn {r.lsn} "
                    f"after {last}) — segment files were tampered with")
            last = r.lsn
        self._replayed_next_lsn = max(last + 1, self.manifest.next_lsn)
        return records

    def attach(self) -> None:
        """Open the active (final) segment for appending — recovery's last
        step, after ``replay`` has truncated any torn tail.  Also prunes
        files orphaned by a crash mid-protocol."""
        with self._lock:
            assert self._writer is None, "already attached"
            next_lsn = (self._replayed_next_lsn
                        if self._replayed_next_lsn is not None
                        else self._manifest.next_lsn)
            self._writer = wal.SegmentWriter(
                self.path(self._manifest.segments[-1]), fsync=self.fsync,
                interval_s=self.fsync_interval_s, next_lsn=next_lsn)
        self.prune()

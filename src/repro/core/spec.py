"""Typed search configuration + statistics for the whole search stack.

``SearchSpec`` is THE search-request object: one frozen dataclass carried
through ``AnnIndex.search``, ``ShardedAnnIndex``, NSG candidate
acquisition, the model-cell builder, the serving frontend, benchmarks and
examples.  Callers pass ``spec=SearchSpec(...)``; anything else (including
the pre-``SearchSpec`` kwarg style) raises ``TypeError``.

The fields split into two cost classes, and ``canonical()`` is the
authority on which is which (the autotune controller derives its knob
cost classes from it — ``repro.autotune.space``):

* engine-shaping fields (``efs``/``beam_width``/``engine``/``estimate``/
  ``router``/...) key the compiled-engine cache: changing one means a new
  executable per batch shape, so a serving frontend must pre-warm before
  switching;
* request-only fields (``k``/``cos_theta``) never re-trace: ``k`` slices
  the returned pool post-hoc and ``cos_theta`` is a traced scalar
  argument, so they retune instantly.

``SearchStats`` is the typed result-statistics record replacing the ad-hoc
``info`` dict ``AnnIndex.search`` used to return.  It carries the fixed
engine counters plus ``extra`` — per-router counters a registered
``Router`` declares (``Router.extra_counters``, e.g. the finger router's
``finger_est_calls``) — and serializes uniformly into ``BENCH_engine.json``
via ``summary()``.

Not to be confused with ``repro.core.ref_search.SearchStats`` — the scalar
NumPy oracle's instrumentation record (angles, pruned-id sets), which stays
oracle-local.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

ENGINES = ("jnp", "pallas", "pallas_unfused")
ESTIMATES = ("exact", "angle", "sq8", "both")
BEAM_PRUNE_POLICIES = ("best", "all")

_K_DEFAULT = 10

# Enumerable knob domains (the autotune search space, repro.autotune.space).
# The categorical fields enumerate exactly; the integer fields are open-ended
# so these ladders are *recommended* discrete rungs, not hard validation —
# chosen to roughly double engine cost per step.  Router names live in the
# registry (repro.core.routers.available_routers), not here.
EFS_LADDER = (32, 48, 64, 96, 128, 192)
BEAM_LADDER = (1, 2, 4, 8)
KNOB_DOMAINS: Dict[str, tuple] = {
    "efs": EFS_LADDER,
    "beam_width": BEAM_LADDER,
    "engine": ENGINES,
    "estimate": ESTIMATES,
    "beam_prune": BEAM_PRUNE_POLICIES,
}


@dataclasses.dataclass(frozen=True)
class SearchSpec:
    """One frozen object describing a search request end to end.

    Engine-shaping fields (everything except ``k``/``cos_theta``) key the
    compiled-engine cache; ``k`` only slices the returned pool and
    ``cos_theta`` is passed to the jitted engine as a traced scalar, so
    neither triggers a re-trace (see ``canonical()``).

    ``metric`` and ``use_hierarchy`` are *index* properties: ``AnnIndex`` /
    ``ShardedAnnIndex`` overwrite them from the graph, so user-built specs
    can leave the defaults.
    """

    efs: int = 100                # result-pool size (>= k)
    router: str = "none"          # registry name (repro.core.routers)
    metric: str = "l2"
    max_hops: int = 4096          # hard per-query expansion budget
    use_hierarchy: bool = True
    beam_width: int = 1           # W frontier nodes expanded per iteration
    engine: str = "jnp"           # jnp | pallas | pallas_unfused
    # Which beam slots' lanes are eligible for the router's prune test:
    #   "best" (default) — only the best slot's neighbors, i.e. exactly the
    #     lanes sequential Algorithm 2 would test at this moment.  Recall
    #     matches the W=1 risk profile; call savings dilute as W grows.
    #   "all" — every slot's neighbors.  Maximum distance-call savings, but
    #     estimates from the 2nd..Wth-best parents (which sequential search
    #     would expand later, from closer parents) can mis-prune a doorway
    #     node and strand a query — use with efs comfortably above k.
    beam_prune: str = "best"
    # Distance-computation strategy for candidate lanes:
    #   "exact" (default) — every surviving lane fetches its fp32 row and
    #     computes the exact distance (the classic path; the router's prune
    #     still applies).
    #   "angle" — alias of "exact" that *requires* a pruning router; kept as
    #     an explicit name for benchmark configs.
    #   "sq8"   — two-stage: lanes first compute a quantized (uint8 codes,
    #     4x fewer bytes) estimate + conservative lower bound; lanes whose
    #     bound beats the pool bound skip the fp32 row entirely, survivors
    #     enter the pool approximately and are re-ranked exactly only when
    #     expanded or returned.  Composes with a pruning router (the router
    #     test runs first, on adjacency data alone).
    #   "both"  — "sq8" + an assertion that a pruning router is configured.
    estimate: str = "exact"
    # Request-only fields (do not shape the compiled engine):
    k: int = _K_DEFAULT           # how many results to return per query
    cos_theta: Optional[float] = None   # None -> the index's angle profile

    def __post_init__(self):
        assert self.engine in ENGINES, f"unknown engine {self.engine!r}"
        assert self.estimate in ESTIMATES, \
            f"unknown estimate {self.estimate!r}"
        assert self.beam_prune in BEAM_PRUNE_POLICIES, \
            f"unknown beam_prune policy {self.beam_prune!r}"
        assert self.beam_width >= 1, "beam_width must be >= 1"

    def canonical(self) -> "SearchSpec":
        """Strip the request-only fields — the compiled-engine cache key.

        Two specs differing only in ``k``/``cos_theta`` trace to the same
        executable (``k`` slices post-hoc, ``cos_theta`` is a traced arg).
        """
        if self.k == _K_DEFAULT and self.cos_theta is None:
            return self
        return dataclasses.replace(self, k=_K_DEFAULT, cos_theta=None)

    def replace(self, **changes) -> "SearchSpec":
        """Functional update (sugar for ``dataclasses.replace``)."""
        return dataclasses.replace(self, **changes)


def is_request_only(field: str) -> bool:
    """True iff changing ``field`` can never re-jit a compiled engine.

    Derived from ``canonical()`` itself, not from a parallel list that
    could drift: a field is request-only exactly when perturbing it leaves
    the canonical (compiled-engine cache key) form unchanged.  This is the
    contract the serving frontend and the autotune controller's knob cost
    classes rest on.
    """
    base = SearchSpec()
    probe = {"k": base.k + 1, "cos_theta": 0.25,
             "efs": base.efs + 8, "beam_width": base.beam_width + 1,
             "max_hops": base.max_hops + 1, "engine": "pallas",
             "estimate": "sq8", "beam_prune": "all", "router": "crouting",
             "metric": "ip", "use_hierarchy": not base.use_hierarchy}
    if field not in probe:
        raise KeyError(f"unknown SearchSpec field {field!r}")
    return base.replace(**{field: probe[field]}).canonical() == \
        base.canonical()


REQUEST_ONLY_FIELDS = ("k", "cos_theta")
assert all(is_request_only(f) for f in REQUEST_ONLY_FIELDS)

# Engine-shaping fields that are NOT autotune knobs: `router` names a
# registry entry the operator picks, `metric`/`use_hierarchy` are index
# properties the graph overwrites, and `max_hops` is a hard budget, not a
# quality/cost dial.  Together with KNOB_DOMAINS and REQUEST_ONLY_FIELDS
# this classifies every SearchSpec field into exactly one cost class — the
# `cache-key` static checker (repro.analysis) enforces the partition stays
# total as fields are added.
STRUCTURAL_FIELDS = ("router", "metric", "max_hops", "use_hierarchy")
assert not (set(STRUCTURAL_FIELDS) & set(KNOB_DOMAINS)
            | set(STRUCTURAL_FIELDS) & set(REQUEST_ONLY_FIELDS))


def resolve_search_spec(spec: Optional["SearchSpec"],
                        default: "SearchSpec", owner: str) -> "SearchSpec":
    """Validate a per-call ``spec`` (or fall back to ``default``).

    Anything that is not a ``SearchSpec`` (or ``None``) raises
    ``TypeError`` — there is no kwarg fallback.
    """
    if spec is None:
        return default
    if not isinstance(spec, SearchSpec):
        raise TypeError(f"{owner}: spec must be a SearchSpec, "
                        f"got {type(spec).__name__}")
    return spec


@dataclasses.dataclass
class SearchStats:
    """Typed per-search statistics returned by every search entry point.

    On the single-index path the counter fields are per-query ``[B]`` int
    arrays; on the sharded path they are batch totals already reduced across
    shards (``iters`` is the max over shards — the straggler's iteration
    count).  ``extra`` holds per-router counters in registry-declared order
    (``Router.extra_counters``).
    """

    dist_calls: np.ndarray       # exact fp32 distance evaluations
    est_calls: np.ndarray        # router estimate evaluations
    rerank_calls: np.ndarray     # stage-2 exact reranks (sq8 path)
    sq8_calls: np.ndarray        # stage-1 quantized estimates
    hops: np.ndarray             # node expansions
    iters: int                   # batch-level hop-loop iterations
    router: str = "none"
    extra: Dict[str, np.ndarray] = dataclasses.field(default_factory=dict)
    # graceful-degradation record (DESIGN.md §10): a host-composed sharded
    # search that lost shards still RESOLVES, with the survivors' pool and
    # these fields set — partial results are data, not an exception
    shards_failed: int = 0
    degraded: bool = False

    @classmethod
    def from_result(cls, res, router: str = "none") -> "SearchStats":
        """Build from an engine ``SearchResult`` (device arrays -> host)."""
        return cls(
            dist_calls=np.asarray(res.dist_calls),
            est_calls=np.asarray(res.est_calls),
            rerank_calls=np.asarray(res.rerank_calls),
            sq8_calls=np.asarray(res.sq8_calls),
            hops=np.asarray(res.hops),
            iters=int(res.iters),
            router=router,
            extra={k: np.asarray(v)
                   for k, v in (getattr(res, "extra", None) or {}).items()},
        )

    @classmethod
    def merge(cls, stats_list) -> "SearchStats":
        """Fold stats from many dispatches into one record.

        Per-query array counters (single-index path) concatenate, so
        ``summary()`` still reports true per-query means across the whole
        trace; scalar totals (sharded path) add.  ``iters`` is the max over
        dispatches (the worst straggler), ``router`` must agree.  The
        serving telemetry layer folds its per-dispatch stats through here
        so one ``summary()`` covers an entire request trace.
        """
        stats_list = list(stats_list)
        if not stats_list:
            raise ValueError("SearchStats.merge: empty stats list")
        routers = {s.router for s in stats_list}
        if len(routers) > 1:
            raise ValueError(f"SearchStats.merge: mixed routers {routers}")

        def comb(vals):
            if all(np.ndim(v) > 0 for v in vals):
                return np.concatenate([np.asarray(v) for v in vals])
            return sum(int(np.sum(v)) for v in vals)

        keys = set().union(*(s.extra for s in stats_list))
        return cls(
            dist_calls=comb([s.dist_calls for s in stats_list]),
            est_calls=comb([s.est_calls for s in stats_list]),
            rerank_calls=comb([s.rerank_calls for s in stats_list]),
            sq8_calls=comb([s.sq8_calls for s in stats_list]),
            hops=comb([s.hops for s in stats_list]),
            iters=max(int(s.iters) for s in stats_list),
            router=stats_list[0].router,
            extra={k: comb([s.extra[k] for s in stats_list if k in s.extra])
                   for k in sorted(keys)},
            shards_failed=sum(int(s.shards_failed) for s in stats_list),
            degraded=any(s.degraded for s in stats_list),
        )

    def summary(self) -> Dict[str, object]:
        """Uniform JSON-ready digest (per-query means) for benchmark files."""
        out: Dict[str, object] = {"router": self.router, "iters": int(self.iters)}
        for f in ("dist_calls", "est_calls", "rerank_calls", "sq8_calls",
                  "hops"):
            out[f] = round(float(np.mean(getattr(self, f))), 1)
        for k, v in self.extra.items():
            out[k] = round(float(np.mean(v)), 1)
        out["shards_failed"] = int(self.shards_failed)
        out["degraded"] = bool(self.degraded)
        return out

from repro.roofline.hw import TPU_V5E  # noqa: F401
from repro.roofline.analysis import analyze_compiled, roofline_terms  # noqa: F401

"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax.numpy as jnp


def l2_distance_ref(q, x, mode: str = "l2"):
    q = q.astype(jnp.float32)
    x = x.astype(jnp.float32)
    if mode == "l2":
        qn = jnp.sum(q * q, axis=-1, keepdims=True)
        xn = jnp.sum(x * x, axis=-1)
        return jnp.maximum(qn + xn[None, :] - 2.0 * q @ x.T, 0.0)
    return 1.0 - q @ x.T


def crouting_prune_ref(ed, dcq, bound2, valid, cos_theta):
    ed = ed.astype(jnp.float32)
    dcq = dcq.astype(jnp.float32)[:, None]
    est2 = jnp.maximum(ed * ed + dcq * dcq - 2.0 * ed * dcq * cos_theta, 0.0)
    mask = (valid != 0) & (est2 >= bound2[:, None])
    return est2, mask.astype(jnp.int8)


def gather_distance_ref(indices, queries, table):
    rows = table[indices]                       # [B, M, d]
    diff = rows.astype(jnp.float32) - queries.astype(jnp.float32)[:, None, :]
    return jnp.sum(diff * diff, axis=-1)


def pool_merge_ref(pool_d, pool_i, new_d, new_i):
    d = jnp.concatenate([pool_d, new_d], axis=1)
    i = jnp.concatenate([pool_i, new_i], axis=1)
    # tie-break on smaller id to match the kernel's deterministic network
    order = jnp.lexsort((i, d), axis=1)
    P = pool_d.shape[1]
    return (jnp.take_along_axis(d, order, axis=1)[:, :P],
            jnp.take_along_axis(i, order, axis=1)[:, :P])


def fused_expand_ref(nbrs, queries, ed, dcq, bound2, cos_theta, table):
    """Oracle for the fused CRouting expansion kernel."""
    n = table.shape[0]
    est2, _ = crouting_prune_ref(ed, dcq, bound2,
                                 jnp.ones_like(ed, dtype=jnp.int8), cos_theta)
    valid = nbrs < n
    prune = valid & (est2 >= bound2[:, None])
    safe = jnp.where(valid, nbrs, 0)
    d2 = gather_distance_ref(safe, queries, table)
    d2 = jnp.where(valid & ~prune, d2, jnp.inf)
    return d2, prune.astype(jnp.int8)

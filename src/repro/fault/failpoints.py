"""Deterministic failpoints: named fault-injection sites (DESIGN.md §10).

A *failpoint* is a named call site threaded through the serving, mutation,
sharding, persistence, durability and autotune paths (``serve.dispatch``,
``shard.search``, ``mutate.merge.build``, ``index.save.write``, the
ISSUE 8 WAL/checkpoint sites ``wal.append`` / ``wal.fsync`` /
``wal.rotate`` / ``checkpoint.write`` / ``manifest.rename``, and the
ISSUE 9 controller sites ``autotune.step`` / ``autotune.probe`` — both
fail-open: a fired fault leaves the last-good spec serving).
Production code calls
``hit(site)`` at each one; with nothing armed that is a single module-flag
check and an immediate return.  Tests and the chaos harness arm sites with
a ``FaultSpec`` describing *when* to fire (explicit hit indices, or a
seeded per-site probability — the schedule is deterministic for a given
seed and call order) and *what* to do:

* ``raise``    — raise ``FaultInjected`` (a process "crash" at that site);
* ``delay``    — sleep ``delay_s`` then continue (stragglers, timeouts);
* ``corrupt``/``truncate`` — return the kind string; the site applies the
  damage itself (only sites that own bytes — e.g. ``index.save.write`` —
  honor these; everywhere else an armed corrupt kind is a no-op).

Sub-targeting: a site that fans out over numbered children (shards) calls
``hit("shard.search", sub="1")``; arming ``shard.search`` fires on every
child while ``shard.search.1`` fires on child 1 only.

Accounting: every armed site counts hits and fires (``snapshot()``), so a
chaos run can persist exactly which faults its seeded schedule delivered.
"""
from __future__ import annotations

import dataclasses
import random
import threading
import time
from contextlib import contextmanager
from typing import Dict, FrozenSet, Optional

KINDS = ("raise", "delay", "corrupt", "truncate")


class FaultInjected(RuntimeError):
    """An armed failpoint fired with ``kind="raise"``."""

    def __init__(self, site: str, hit_index: int):
        super().__init__(f"failpoint {site!r} fired (hit {hit_index})")
        self.site = site
        self.hit_index = hit_index


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """When and how one armed site fires.

    ``hits`` names explicit 0-based hit indices (fully deterministic);
    with ``hits=None`` every hit fires with probability ``p`` drawn from a
    per-site PRNG seeded with ``seed`` (deterministic for a given call
    order).  ``max_fires`` caps total fires either way — the knob for
    "fail twice, then recover" schedules.
    """

    kind: str = "raise"
    hits: Optional[FrozenSet[int]] = None
    p: float = 1.0
    max_fires: Optional[int] = None
    delay_s: float = 0.05
    seed: int = 0

    def __post_init__(self):
        assert self.kind in KINDS, f"unknown fault kind {self.kind!r}"
        assert 0.0 <= self.p <= 1.0, "p must be a probability"
        if self.hits is not None:
            object.__setattr__(self, "hits", frozenset(int(h) for h in self.hits))


class _Armed:
    """Mutable per-site schedule state (guarded by the registry lock)."""

    def __init__(self, spec: FaultSpec):
        self.spec = spec
        self.rng = random.Random(spec.seed)
        self.hit_count = 0
        self.fire_count = 0

    def decide(self) -> bool:
        i, self.hit_count = self.hit_count, self.hit_count + 1
        s = self.spec
        if s.max_fires is not None and self.fire_count >= s.max_fires:
            return False
        if s.hits is not None:
            fire = i in s.hits
        else:
            fire = s.p >= 1.0 or self.rng.random() < s.p
        if fire:
            self.fire_count += 1
        return fire


_LOCK = threading.Lock()
_SITES: Dict[str, _Armed] = {}   # guarded by: _LOCK
_ACTIVE = False          # fast path: hit() is one bool check when disarmed

# Every PRODUCTION failpoint site, one name per `fault.hit(...)` call site
# (the `write_site=`/`rename_site=` kwargs of the atomic-write helpers
# count — the literal lives at the caller).  This registry is PASSIVE:
# `arm()` accepts any name so tests can use scratch sites; the list exists
# for the `failpoint-sync` static checker (repro.analysis), which keeps it
# and the DESIGN.md §10 site table agreeing with the code in both
# directions.  Adding a `hit()` call means adding a name here AND a §10
# table row, or `make analyze` fails.
DECLARED_SITES = frozenset({
    "serve.dispatch",
    "serve.worker",
    "shard.search",
    "sharded.search",
    "mutate.merge.build",
    "mutate.merge.swap",
    "index.save.write",
    "index.save.rename",
    "wal.append",
    "wal.fsync",
    "wal.rotate",
    "checkpoint.write",
    "manifest.rename",
    "autotune.step",
    "autotune.probe",
})


def arm(site: str, spec: Optional[FaultSpec] = None, **kw) -> None:
    """Arm ``site`` with ``spec`` (or ``FaultSpec(**kw)``), resetting its
    hit/fire counters."""
    global _ACTIVE
    if spec is None:
        spec = FaultSpec(**kw)
    elif kw:
        raise TypeError("pass a FaultSpec or kwargs, not both")
    with _LOCK:
        _SITES[site] = _Armed(spec)
        _ACTIVE = True


def disarm(site: Optional[str] = None) -> None:
    """Disarm one site, or every site (``site=None``).  Counters drop."""
    global _ACTIVE
    with _LOCK:
        if site is None:
            _SITES.clear()
        else:
            _SITES.pop(site, None)
        _ACTIVE = bool(_SITES)


@contextmanager
def scoped(schedule: Dict[str, FaultSpec]):
    """Arm a whole schedule for the duration of a ``with`` block."""
    for site, spec in schedule.items():
        arm(site, spec)
    try:
        yield
    finally:
        for site in schedule:
            disarm(site)


def hit(site: str, sub: Optional[str] = None) -> Optional[str]:
    """One pass through the failpoint ``site``.

    Disarmed (the common case): returns ``None`` after a single flag
    check.  Armed and scheduled to fire: ``raise`` kinds raise
    ``FaultInjected``; ``delay`` sleeps then returns ``"delay"``; data
    kinds (``corrupt``/``truncate``) return the kind string for the call
    site to act on.  ``sub`` checks ``f"{site}.{sub}"`` as well, most
    specific first.
    """
    if not _ACTIVE:
        return None
    with _LOCK:
        ent = None
        name = site
        if sub is not None:
            name = f"{site}.{sub}"
            ent = _SITES.get(name)
        if ent is None:
            name = site
            ent = _SITES.get(site)
        if ent is None:
            return None
        fire = ent.decide()
        spec = ent.spec
        index = ent.hit_count - 1
    if not fire:
        return None
    if spec.kind == "raise":
        raise FaultInjected(name, index)
    if spec.kind == "delay":
        time.sleep(spec.delay_s)
        return "delay"
    return spec.kind


def fires(site: str) -> int:
    """How many times ``site`` has fired since it was armed (0 if never)."""
    with _LOCK:
        ent = _SITES.get(site)
        return ent.fire_count if ent is not None else 0


def snapshot() -> Dict[str, Dict[str, int]]:
    """Per-site ``{"hits": n, "fires": m}`` accounting for chaos reports."""
    with _LOCK:
        return {name: {"hits": ent.hit_count, "fires": ent.fire_count}
                for name, ent in sorted(_SITES.items())}

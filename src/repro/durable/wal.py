"""Append-only, CRC32-framed write-ahead log segments (DESIGN.md §11).

Framing: every record is ``[u32 len][u32 crc32(payload)] payload``; the
payload starts ``[u8 type][u64 lsn]`` followed by the record body
(little-endian throughout).  LSNs are globally monotonic across segments,
so replay can assert ordering.

Reading (``read_segment``) applies the recovery rules the mutation stack
relies on:

* a *torn tail* — an incomplete header, a payload running past EOF, or a
  CRC-failed frame that is the LAST thing in the file (a torn in-place
  write) — is tolerated: the segment is valid up to the bad frame, which
  a recovery truncates away.  Only acked-after-fsync records matter, and a
  torn tail can only hold records whose ack never returned;
* a bad frame with MORE bytes after it is *mid-log corruption*: acked
  records may be damaged, so the reader raises ``CorruptIndexError``
  instead of silently dropping them.  Rotation fsyncs a segment before
  opening its successor, so a torn tail in a non-final segment is also
  corruption, never an artifact of a crash.

Writing (``SegmentWriter``) separates the *append* (buffered write under
the writer lock, WAL ordering = apply ordering) from the *ack*
(``wait_durable``): the durability point depends on the fsync policy —

* ``every``    — every ack fsyncs (group-committed: one fsync covers every
  append that landed before it, so concurrent writers batch for free);
* ``interval`` — group commit with an accumulation window: the leader ack
  sleeps ``interval_s`` before its fsync so a burst of concurrent writers
  rides one fsync (PostgreSQL's ``commit_delay``); acks still BLOCK until
  the covering fsync returns, so acknowledged-means-durable holds;
* ``off``      — acks return immediately; durability is best-effort (the
  OS flushes eventually).  For benchmarks and bulk loads only.

A durability failure (an fsync that raised — in production a dying disk,
in the chaos suite the ``wal.fsync`` failpoint) poisons the writer: the
in-memory index may be ahead of the log, so every later append/ack raises
``WalFailedError`` instead of silently diverging.  The process should
recover from disk.

Failpoint sites: ``wal.append`` (``raise`` = crash before the frame is
written; ``truncate`` = a torn write — half a frame lands, then the
"process" dies; ``corrupt`` = the frame's bytes are damaged in place but
appends continue, manufacturing mid-log corruption) and ``wal.fsync``
(crash between write and durability point).
"""
from __future__ import annotations

import dataclasses
import os
import struct
import threading
import time
import zlib
from typing import BinaryIO, List, Optional, Tuple, Union

import numpy as np

from repro.fault import CorruptIndexError, failpoints as fault

FSYNC_POLICIES = ("every", "interval", "off")

_HEADER = struct.Struct("<II")           # frame: len, crc32(payload)
_REC_HEAD = struct.Struct("<BQ")         # payload: type, lsn
_INSERT_HEAD = struct.Struct("<II")      # n rows, dim
_DELETE_HEAD = struct.Struct("<I")       # n ids

REC_INSERT = 1
REC_DELETE = 2

# a frame longer than this is treated as a bad length field, not a request
# to allocate gigabytes (the largest legal record is a delta-capacity
# insert batch: capacity * (8 + 4 * dim) bytes, far below this)
MAX_FRAME_BYTES = 1 << 30


class WalFailedError(RuntimeError):
    """The WAL hit a durability failure earlier; the in-memory index may be
    ahead of the log.  Recover from disk instead of appending further."""


@dataclasses.dataclass(frozen=True)
class InsertRecord:
    lsn: int
    ext_ids: np.ndarray      # [n] int64
    vectors: np.ndarray      # [n, d] f32 (already preprocessed)


@dataclasses.dataclass(frozen=True)
class DeleteRecord:
    lsn: int
    ext_ids: np.ndarray      # [n] int64


WalRecord = Union[InsertRecord, DeleteRecord]


# --------------------------------------------------------------------------
# encoding
# --------------------------------------------------------------------------
def encode_insert(lsn: int, ext_ids: np.ndarray, vectors: np.ndarray) -> bytes:
    ids = np.ascontiguousarray(ext_ids, np.int64)
    vec = np.ascontiguousarray(vectors, np.float32)
    assert ids.ndim == 1 and vec.ndim == 2 and ids.shape[0] == vec.shape[0]
    return (_REC_HEAD.pack(REC_INSERT, lsn)
            + _INSERT_HEAD.pack(ids.shape[0], vec.shape[1])
            + ids.tobytes() + vec.tobytes())


def encode_delete(lsn: int, ext_ids) -> bytes:
    ids = np.ascontiguousarray(ext_ids, np.int64)
    assert ids.ndim == 1
    return (_REC_HEAD.pack(REC_DELETE, lsn)
            + _DELETE_HEAD.pack(ids.shape[0]) + ids.tobytes())


def frame(payload: bytes) -> bytes:
    return _HEADER.pack(len(payload), zlib.crc32(payload)) + payload


def decode_record(payload: bytes, path: str, offset: int) -> WalRecord:
    """Decode one CRC-verified payload; malformed bodies are corruption
    (the CRC passed, so the bytes are what the writer wrote — a decode
    failure means a broken writer or damage the CRC happened to miss)."""
    try:
        rtype, lsn = _REC_HEAD.unpack_from(payload, 0)
        off = _REC_HEAD.size
        if rtype == REC_INSERT:
            n, d = _INSERT_HEAD.unpack_from(payload, off)
            off += _INSERT_HEAD.size
            ids = np.frombuffer(payload, np.int64, n, off)
            off += 8 * n
            vec = np.frombuffer(payload, np.float32, n * d, off
                                ).reshape(n, d)
            if off + 4 * n * d != len(payload):
                raise ValueError("trailing bytes in insert record")
            return InsertRecord(lsn=lsn, ext_ids=ids.copy(),
                                vectors=vec.copy())
        if rtype == REC_DELETE:
            (n,) = _DELETE_HEAD.unpack_from(payload, off)
            off += _DELETE_HEAD.size
            ids = np.frombuffer(payload, np.int64, n, off)
            if off + 8 * n != len(payload):
                raise ValueError("trailing bytes in delete record")
            return DeleteRecord(lsn=lsn, ext_ids=ids.copy())
        raise ValueError(f"unknown record type {rtype}")
    except (struct.error, ValueError) as e:
        raise CorruptIndexError(
            f"{path}: undecodable WAL record at offset {offset} "
            f"({e})") from e


# --------------------------------------------------------------------------
# reading
# --------------------------------------------------------------------------
def read_segment(path: str, *, final: bool
                 ) -> Tuple[List[WalRecord], int, bool]:
    """Scan one segment; returns ``(records, valid_len, torn)``.

    ``final`` marks the manifest's LAST segment — the only place a torn
    tail is legal.  ``valid_len`` is the byte offset of the first bad
    frame (== file size when the segment is clean); a recovery truncates
    the file there before appending continues.  Mid-log corruption — a bad
    frame with valid bytes after it, or ANY bad frame in a non-final
    segment — raises ``CorruptIndexError``.
    """
    with open(path, "rb") as f:
        data = f.read()
    size = len(data)
    records: List[WalRecord] = []
    off = 0

    def tail_or_raise(why: str) -> Tuple[List[WalRecord], int, bool]:
        if final:
            return records, off, True
        raise CorruptIndexError(
            f"{path}: {why} at offset {off} in a non-final WAL segment — "
            "mid-log corruption, not a torn tail (rotation fsyncs a "
            "segment before opening its successor)")

    while off < size:
        if size - off < _HEADER.size:
            return tail_or_raise("incomplete frame header")
        length, crc = _HEADER.unpack_from(data, off)
        if length > MAX_FRAME_BYTES:
            return tail_or_raise(f"implausible frame length {length}")
        lo, hi = off + _HEADER.size, off + _HEADER.size + length
        if hi > size:
            return tail_or_raise("frame payload runs past EOF")
        payload = data[lo:hi]
        if zlib.crc32(payload) != crc:
            if hi == size:
                # CRC-failed FINAL frame: a torn in-place write
                return tail_or_raise("CRC mismatch on the final frame")
            raise CorruptIndexError(
                f"{path}: WAL frame CRC mismatch at offset {off} with "
                f"{size - hi} valid bytes after it — mid-log corruption "
                "(acked records may be damaged); refusing to replay")
        records.append(decode_record(payload, path, off))
        off = hi
    return records, off, False


# --------------------------------------------------------------------------
# writing
# --------------------------------------------------------------------------
class SegmentWriter:
    """Append/ack on ONE open segment file (see the module docstring)."""

    def __init__(self, path: str, *, fsync: str = "every",
                 interval_s: float = 0.002, next_lsn: int = 0):
        assert fsync in FSYNC_POLICIES, f"unknown fsync policy {fsync!r}"
        self.path = path
        self.fsync = fsync
        self.interval_s = float(interval_s)
        self._f: Optional[BinaryIO] = open(path, "ab")
        self._write_lock = threading.Lock()
        self._cond = threading.Condition()
        self._next_lsn = next_lsn                # guarded by: self._write_lock
        self._synced_lsn = next_lsn - 1          # guarded by: self._cond
        self._sync_in_progress = False           # guarded by: self._cond
        # poison marker; read on BOTH lock paths (append under _write_lock,
        # wait_durable under _cond) so it carries no single-lock annotation:
        # a stale read only delays the WalFailedError by one call
        self._failed: Optional[BaseException] = None

    # -- append -----------------------------------------------------------
    def append(self, encode, *args) -> int:
        """Write one framed record; returns its LSN.  ``encode`` is
        ``encode_insert``/``encode_delete`` (called with the assigned LSN
        first).  The write is buffered — durability comes from
        ``wait_durable``."""
        with self._write_lock:
            self._check_alive()
            lsn = self._next_lsn
            buf = frame(encode(lsn, *args))
            action = fault.hit("wal.append")
            if action == "truncate":
                # a torn write: half the frame lands, then the "process"
                # dies.  The writer is poisoned like any crash site.
                self._f.write(buf[:max(len(buf) // 2, 1)])
                self._f.flush()
                err = fault.FaultInjected("wal.append[torn-write]", -1)
                self._failed = err
                raise err
            if action == "corrupt":
                # damaged frame, appends continue: manufactures MID-log
                # corruption once later records land after it
                bad = bytearray(buf)
                bad[_HEADER.size] ^= 0xFF
                buf = bytes(bad)
            self._f.write(buf)
            self._next_lsn = lsn + 1
            return lsn

    def _check_alive(self):
        if self._failed is not None:
            raise WalFailedError(
                "WAL poisoned by an earlier durability failure; recover "
                "from disk") from self._failed
        if self._f is None:
            raise WalFailedError("WAL segment is closed")

    # -- durability point --------------------------------------------------
    def wait_durable(self, lsn: int) -> None:
        """Block until ``lsn`` is covered by an fsync (the ack point).

        Group commit: the first waiter becomes the leader and fsyncs once
        for every append that landed so far; the rest just wait for
        coverage.  ``off`` policy: returns immediately.
        """
        if self.fsync == "off":
            return
        while True:
            with self._cond:
                if self._failed is not None:
                    raise WalFailedError(
                        "WAL poisoned by an earlier durability failure"
                    ) from self._failed
                if self._synced_lsn >= lsn:
                    return
                if not self._sync_in_progress:
                    self._sync_in_progress = True
                    break
                self._cond.wait(0.5)
        try:
            if self.fsync == "interval" and self.interval_s > 0:
                time.sleep(self.interval_s)   # group-accumulation window
            self.sync()
        except BaseException as e:   # noqa: BLE001 — poison + wake waiters
            with self._cond:
                if self._failed is None:
                    self._failed = e
                self._sync_in_progress = False
                self._cond.notify_all()
            raise
        with self._cond:
            self._sync_in_progress = False
            self._cond.notify_all()

    def sync(self) -> None:
        """Flush + fsync everything appended so far (one leader commit)."""
        with self._write_lock:
            self._check_alive()
            target = self._next_lsn - 1
            self._f.flush()
            fault.hit("wal.fsync")
            os.fsync(self._f.fileno())
        with self._cond:
            self._synced_lsn = max(self._synced_lsn, target)

    # -- lifecycle ---------------------------------------------------------
    @property
    def next_lsn(self) -> int:
        with self._write_lock:
            return self._next_lsn

    def close(self, *, do_fsync: bool = True) -> None:
        """Flush (+fsync) and close.  Rotation closes the old segment with
        ``do_fsync=True`` so a torn tail can never appear behind a
        successor segment."""
        with self._cond:
            while self._sync_in_progress:
                self._cond.wait(0.5)
        with self._write_lock:
            if self._f is None:
                return
            if self._failed is None and do_fsync:
                self._f.flush()
                os.fsync(self._f.fileno())
                with self._cond:
                    self._synced_lsn = self._next_lsn - 1
            self._f.close()
            self._f = None
        with self._cond:
            self._cond.notify_all()

"""Minimal KD-tree used by the TOGG baseline (per-node trees over neighbors).

Array-encoded balanced KD-tree: median splits on the max-spread axis.  Only
``descend`` (leaf lookup, O(depth) scalar comparisons — no full-vector
distance calls) is needed by TOGG's stage-S1 directional filtering.
"""
from __future__ import annotations

import dataclasses
from typing import List

import numpy as np


@dataclasses.dataclass
class KDTree:
    # internal nodes: split axis + threshold; negative axis => leaf
    axis: np.ndarray        # [n_nodes] int32 (-1 = leaf)
    thresh: np.ndarray      # [n_nodes] float32
    left: np.ndarray        # [n_nodes] int32 child index
    right: np.ndarray       # [n_nodes] int32
    leaf_start: np.ndarray  # [n_nodes] int32 into `items`
    leaf_end: np.ndarray    # [n_nodes] int32
    items: np.ndarray       # [n_points] int32 (permutation of input ids)


def build_kdtree(points: np.ndarray, ids: np.ndarray, leaf_size: int = 8) -> KDTree:
    axis: List[int] = []
    thresh: List[float] = []
    left: List[int] = []
    right: List[int] = []
    ls: List[int] = []
    le: List[int] = []
    items: List[int] = []

    def rec(idx: np.ndarray) -> int:
        node = len(axis)
        axis.append(-1); thresh.append(0.0); left.append(-1); right.append(-1)
        ls.append(-1); le.append(-1)
        if len(idx) <= leaf_size:
            ls[node] = len(items)
            items.extend(int(ids[i]) for i in idx)
            le[node] = len(items)
            return node
        pts = points[idx]
        ax = int(np.argmax(pts.max(axis=0) - pts.min(axis=0)))
        med = float(np.median(pts[:, ax]))
        lo = idx[pts[:, ax] <= med]
        hi = idx[pts[:, ax] > med]
        if len(lo) == 0 or len(hi) == 0:     # degenerate split -> leaf
            ls[node] = len(items)
            items.extend(int(ids[i]) for i in idx)
            le[node] = len(items)
            return node
        axis[node] = ax
        thresh[node] = med
        left[node] = rec(lo)
        right[node] = rec(hi)
        return node

    rec(np.arange(len(ids)))
    return KDTree(axis=np.asarray(axis, np.int32), thresh=np.asarray(thresh, np.float32),
                  left=np.asarray(left, np.int32), right=np.asarray(right, np.int32),
                  leaf_start=np.asarray(ls, np.int32), leaf_end=np.asarray(le, np.int32),
                  items=np.asarray(items, np.int32))


def descend(tree: KDTree, q: np.ndarray) -> np.ndarray:
    """Walk to the leaf containing q; return member ids (no distance calls)."""
    node = 0
    while tree.axis[node] >= 0:
        node = int(tree.left[node] if q[tree.axis[node]] <= tree.thresh[node]
                   else tree.right[node])
    return tree.items[tree.leaf_start[node]: tree.leaf_end[node]]

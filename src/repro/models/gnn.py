"""GNN substrate: message passing via segment ops (no sparse formats needed).

JAX has no CSR/CSC — adjacency is an edge list (src [E], dst [E]) and
aggregation is ``jax.ops.segment_sum`` / segment-softmax over the dst index
(DESIGN.md: "this IS part of the system").  Covers the four assigned archs:

  gin-tu   5L d=64 sum-agg, learnable eps (GIN, arXiv:1810.00826)
  gat-cora 2L d_hidden=8, 8 heads, edge-softmax attention (arXiv:1710.10903)
  schnet   3 interactions, d=64, 300 RBF, cutoff 10 (arXiv:1706.08566)
  egnn     4L d=64, E(n)-equivariant coordinate updates (arXiv:2102.09844)

All models share one GraphBatch layout (padded edge lists, masks) so every
(arch x graph-shape) dry-run cell lowers from the same input_specs builder.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.train import optimizer as opt


# --------------------------------------------------------------------------
# graph batch + segment helpers
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class GnnConfig:
    name: str
    arch: str                  # gin | gat | schnet | egnn
    n_layers: int
    d_hidden: int
    n_heads: int = 1
    n_rbf: int = 300
    cutoff: float = 10.0
    n_classes: int = 16
    task: str = "node_class"   # node_class | graph_reg
    dtype: str = "float32"


def segment_softmax(scores, seg_ids, num_segments):
    smax = jax.ops.segment_max(scores, seg_ids, num_segments=num_segments)
    smax = jnp.where(jnp.isfinite(smax), smax, 0.0)
    ex = jnp.exp(scores - smax[seg_ids])
    den = jax.ops.segment_sum(ex, seg_ids, num_segments=num_segments)
    return ex / jnp.maximum(den[seg_ids], 1e-12)


def _mlp_init(key, dims, dt):
    ks = jax.random.split(key, len(dims) - 1)
    return [{"w": (jax.random.normal(k, (a, b)) / np.sqrt(a)).astype(dt),
             "b": jnp.zeros((b,), dt)} for k, a, b in zip(ks, dims[:-1], dims[1:])]


def _mlp(params, x, act=jax.nn.relu, final_act=False):
    for i, l in enumerate(params):
        x = x @ l["w"] + l["b"]
        if i < len(params) - 1 or final_act:
            x = act(x)
    return x


# --------------------------------------------------------------------------
# per-arch forward passes. batch dict:
#   node_feat [N, F] | atom_z [N] int32, pos [N, 3]
#   edge_src [E], edge_dst [E] int32; node_mask [N]; edge_mask [E]
#   labels [N] int32 (node_class) | graph_ids [N] + g_labels [G] (graph_reg)
# --------------------------------------------------------------------------
def init_gnn(cfg: GnnConfig, d_in: int, key) -> Dict[str, Any]:
    dt = jnp.dtype(cfg.dtype)
    d = cfg.d_hidden
    ks = jax.random.split(key, cfg.n_layers + 4)
    p: Dict[str, Any] = {}
    if cfg.arch == "gin":
        p["embed"] = _mlp_init(ks[0], (d_in, d), dt)
        p["eps"] = jnp.zeros((cfg.n_layers,), dt)
        p["mlps"] = [_mlp_init(ks[i + 1], (d, d, d), dt) for i in range(cfg.n_layers)]
        p["out"] = _mlp_init(ks[-1], (d, cfg.n_classes), dt)
    elif cfg.arch == "gat":
        dims_in = d_in
        p["layers"] = []
        for i in range(cfg.n_layers):
            last = i == cfg.n_layers - 1
            heads = 1 if last else cfg.n_heads
            dout = cfg.n_classes if last else d
            k1, k2, k3 = jax.random.split(ks[i], 3)
            p["layers"].append({
                "w": (jax.random.normal(k1, (dims_in, heads, dout)) / np.sqrt(dims_in)).astype(dt),
                "a_l": (0.1 * jax.random.normal(k2, (heads, dout))).astype(dt),
                "a_r": (0.1 * jax.random.normal(k3, (heads, dout))).astype(dt),
            })
            dims_in = heads * dout
    elif cfg.arch == "schnet":
        p["embed"] = (jax.random.normal(ks[0], (100, d)) * 0.1).astype(dt)  # z -> d
        p["interactions"] = []
        for i in range(cfg.n_layers):
            k1, k2, k3 = jax.random.split(ks[i + 1], 3)
            p["interactions"].append({
                "filter": _mlp_init(k1, (cfg.n_rbf, d, d), dt),
                "in_lin": _mlp_init(k2, (d, d), dt),
                "out": _mlp_init(k3, (d, d, d), dt),
            })
        p["head"] = _mlp_init(ks[-1], (d, d // 2, 1), dt)
    elif cfg.arch == "egnn":
        p["embed"] = _mlp_init(ks[0], (d_in, d), dt)
        p["layers"] = []
        for i in range(cfg.n_layers):
            k1, k2, k3 = jax.random.split(ks[i + 1], 3)
            p["layers"].append({
                "phi_e": _mlp_init(k1, (2 * d + 1, d, d), dt),
                "phi_x": _mlp_init(k2, (d, d, 1), dt),
                "phi_h": _mlp_init(k3, (2 * d, d, d), dt),
            })
        p["head"] = _mlp_init(ks[-1], (d, d // 2, 1), dt)
    else:
        raise ValueError(cfg.arch)
    return p


def _rbf_expand(dist, n_rbf, cutoff):
    centers = jnp.linspace(0.0, cutoff, n_rbf)
    gamma = 10.0 / cutoff
    return jnp.exp(-gamma * (dist[:, None] - centers[None, :]) ** 2)


def gnn_forward(params, batch, cfg: GnnConfig):
    src, dst = batch["edge_src"], batch["edge_dst"]
    emask = batch["edge_mask"][:, None]
    n = batch["node_mask"].shape[0]

    if cfg.arch == "gin":
        h = _mlp(params["embed"], batch["node_feat"], final_act=True)
        for i in range(cfg.n_layers):
            agg = jax.ops.segment_sum(h[src] * emask, dst, num_segments=n)
            h = _mlp(params["mlps"][i], (1.0 + params["eps"][i]) * h + agg)
            h = jax.nn.relu(h)
        if cfg.task == "graph_class":
            pooled = jax.ops.segment_sum(h * batch["node_mask"][:, None],
                                         batch["graph_ids"],
                                         num_segments=batch["g_labels"].shape[0])
            return _mlp(params["out"], pooled)
        return _mlp(params["out"], h)

    if cfg.arch == "gat":
        h = batch["node_feat"]
        for li, lp in enumerate(params["layers"]):
            z = jnp.einsum("nf,fhd->nhd", h, lp["w"])         # [N, H, D]
            el = jnp.einsum("nhd,hd->nh", z, lp["a_l"])
            er = jnp.einsum("nhd,hd->nh", z, lp["a_r"])
            e = jax.nn.leaky_relu(el[src] + er[dst], 0.2)     # [E, H]
            e = jnp.where(batch["edge_mask"][:, None] > 0, e, -jnp.inf)
            # edge-softmax per (dst, head): fold head into segment id
            H = e.shape[1]
            seg = dst[:, None] * H + jnp.arange(H)[None, :]
            alpha = segment_softmax(e.reshape(-1), seg.reshape(-1), n * H)
            alpha = alpha.reshape(-1, H) * batch["edge_mask"][:, None]
            msg = alpha[..., None] * z[src]                   # [E, H, D]
            out = jax.ops.segment_sum(msg, dst, num_segments=n)
            last = li == len(params["layers"]) - 1
            h = out.mean(axis=1) if last else jax.nn.elu(out.reshape(n, -1))
        if cfg.task == "graph_class":
            cnt = jax.ops.segment_sum(batch["node_mask"], batch["graph_ids"],
                                      num_segments=batch["g_labels"].shape[0])
            pooled = jax.ops.segment_sum(h * batch["node_mask"][:, None],
                                         batch["graph_ids"],
                                         num_segments=batch["g_labels"].shape[0])
            return pooled / jnp.maximum(cnt, 1.0)[:, None]
        return h

    if cfg.arch == "schnet":
        pos = batch["pos"]
        h = params["embed"][batch["atom_z"]]
        dvec = pos[src] - pos[dst]
        dist = jnp.sqrt(jnp.maximum(jnp.sum(dvec * dvec, axis=-1), 1e-12))
        rbf = _rbf_expand(dist, cfg.n_rbf, cfg.cutoff)
        # cosine cutoff envelope
        env = 0.5 * (jnp.cos(jnp.pi * jnp.clip(dist / cfg.cutoff, 0, 1)) + 1.0)
        for ip in params["interactions"]:
            w = _mlp(ip["filter"], rbf) * (env * batch["edge_mask"])[:, None]
            xin = _mlp(ip["in_lin"], h)
            m = jax.ops.segment_sum(xin[src] * w, dst, num_segments=n)
            h = h + _mlp(ip["out"], m)
        atom_e = _mlp(params["head"], h)[:, 0] * batch["node_mask"]
        return jax.ops.segment_sum(atom_e, batch["graph_ids"],
                                   num_segments=batch["g_labels"].shape[0])

    if cfg.arch == "egnn":
        pos = batch["pos"]
        h = _mlp(params["embed"], batch["node_feat"], final_act=True)
        for lp in params["layers"]:
            dvec = pos[src] - pos[dst]
            d2 = jnp.sum(dvec * dvec, axis=-1, keepdims=True)
            m = _mlp(lp["phi_e"], jnp.concatenate([h[src], h[dst], d2], -1),
                     final_act=True) * emask
            coef = jnp.tanh(_mlp(lp["phi_x"], m))             # bounded update
            pos = pos + jax.ops.segment_sum(dvec * coef * emask, dst,
                                            num_segments=n) / 16.0
            magg = jax.ops.segment_sum(m, dst, num_segments=n)
            h = h + _mlp(lp["phi_h"], jnp.concatenate([h, magg], -1))
        atom_e = _mlp(params["head"], h)[:, 0] * batch["node_mask"]
        return jax.ops.segment_sum(atom_e, batch["graph_ids"],
                                   num_segments=batch["g_labels"].shape[0])

    raise ValueError(cfg.arch)


def gnn_loss(params, batch, cfg: GnnConfig):
    out = gnn_forward(params, batch, cfg)
    if cfg.task == "node_class":
        logits = out.astype(jnp.float32)
        mask = batch["label_mask"]
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, batch["labels"][:, None], axis=-1)[:, 0]
        return jnp.sum((lse - gold) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    if cfg.task == "graph_class":
        logits = out.astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, batch["g_labels"][:, None].astype(jnp.int32), axis=-1)[:, 0]
        return jnp.mean(lse - gold)
    # graph regression (energy): MSE
    pred = out.astype(jnp.float32)
    return jnp.mean((pred - batch["g_labels"].astype(jnp.float32)) ** 2)


def make_gnn_train_step(cfg: GnnConfig, ocfg: opt.AdamWConfig):
    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(gnn_loss)(params, batch, cfg)
        newp, news, metrics = opt.adamw_update(grads, opt_state, params, ocfg)
        metrics["loss"] = loss
        return newp, news, metrics
    return train_step


def make_gnn_serve_step(cfg: GnnConfig):
    def serve_step(params, batch):
        return gnn_forward(params, batch, cfg)
    return serve_step

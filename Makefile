# Developer entry points.  PYTHONPATH=src everywhere (src-layout, no install).

.PHONY: verify test lint analyze bench bench-engine bench-smoke \
	bench-serve-smoke bench-mutate-smoke bench-chaos-smoke \
	bench-recovery-smoke bench-autotune-smoke

# Fast tier: every push. Hard wall-clock timeout so a hung jit/compile
# fails loudly instead of wedging CI.
verify:
	PYTHONPATH=src timeout 420 python -m pytest -x -q -m "not slow"

# Full tier (the tier-1 command): everything, including slow markers.
test:
	PYTHONPATH=src python -m pytest -x -q

# Lint tier: ruff's default rule set (pyflakes + pycodestyle errors), see
# ruff.toml.  CI runs this as its own fast job.
lint:
	ruff check .

# Static-analysis tier: the repo-specific invariant checkers of DESIGN.md
# §13 (lock discipline, trace safety, cache-key hygiene, failpoint sync,
# fail-open).  --strict fails on any unsuppressed finding; the JSON report
# is written even when findings fail the run, so CI can upload it.
analyze:
	@mkdir -p .cache
	PYTHONPATH=src python -m repro.analysis --strict \
		--json .cache/repolint.json

bench:
	PYTHONPATH=src python -m benchmarks.run

bench-engine:
	PYTHONPATH=src python -m benchmarks.run --only engine

# CI tier: tiny-n engine benchmarks in interpret mode so the benchmark
# entrypoints (and the BENCH_engine.json writer) can't silently rot.
# Results go to .cache/, never to the committed trajectory file.
bench-smoke:
	BENCH_SMOKE=1 BENCH_Q=32 PYTHONPATH=src timeout 420 \
		python -m benchmarks.run --only engine

# CI tier: tiny ragged trace through the serving frontend (both backends)
# so bucket warmup, the zero-recompile invariant, and the telemetry digest
# stay exercised per-PR.  Results go to .cache/, never to BENCH_serve.json.
bench-serve-smoke:
	BENCH_SMOKE=1 BENCH_Q=32 PYTHONPATH=src timeout 420 \
		python -m benchmarks.run --only serve

# CI tier: shrunk two-phase shifting trace through the autotuned frontend —
# screen/probe/decide/pre-warm-then-switch all exercised per-PR with the
# zero-recompile invariant asserted.  Results go to .cache/, never to
# BENCH_autotune.json.
bench-autotune-smoke:
	BENCH_SMOKE=1 BENCH_Q=32 PYTHONPATH=src timeout 420 \
		python -m benchmarks.run --only autotune

# CI tier: tiny streaming insert+delete trace through the mutable index
# behind the frontend, spanning a background merge — keeps the delta +
# tombstone + swap machinery and its zero-recompile invariant exercised
# per-PR.  Results go to .cache/, never to BENCH_mutate.json.
bench-mutate-smoke:
	BENCH_SMOKE=1 BENCH_Q=32 PYTHONPATH=src timeout 420 \
		python -m benchmarks.run --only mutate

# CI tier: seeded fault schedule through the frontend over a sharded
# mutable index — availability (every admitted request resolves), partial
# results with shards_failed set, merge retry/quarantine recovery, all
# exercised per-PR.  Results go to .cache/, never to BENCH_chaos.json.
bench-chaos-smoke:
	BENCH_SMOKE=1 BENCH_Q=32 PYTHONPATH=src timeout 420 \
		python -m benchmarks.run --only chaos

# CI tier: tiny WAL ingest / crash-recover / kill-at-every-site sweep so
# the durability stack (fsync ack point, checkpoint rotation, replay) and
# its zero-acked-loss guarantee stay exercised per-PR.  Results go to
# .cache/, never to BENCH_recovery.json.
bench-recovery-smoke:
	BENCH_SMOKE=1 BENCH_Q=32 PYTHONPATH=src timeout 420 \
		python -m benchmarks.run --only recovery

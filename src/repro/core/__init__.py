# The paper's primary contribution: CRouting — cosine-theorem distance-call
# pruning with error correction, as a plugin over graph-based ANNS search.
#
# Layout:
#   distances.py    metric registry (l2 / ip / cosine) + Euclidean conversions
#   graph.py        padded TPU-native graph container (+ stored edge dists)
#   ref_search.py   scalar NumPy oracle of Algorithm 1/2 (tests + construction)
#   spec.py         SearchSpec (the one search-request object) + SearchStats
#   routers.py      Router protocol + registry (none | crouting | crouting_o
#                   | triangle | finger) — pluggable prune strategies
#   search.py       batched JAX engine (lax.while_loop) consuming the hooks
#   angles.py       angle-distribution sampling, theta* selection (Eq. 3)
#   hnsw.py/nsg.py  index construction (keeps edge distances for CRouting)
#   knn_graph.py    exact KNN graph (NSG substrate, brute-force oracle)
#   finger.py/togg.py/kdtree.py   comparison routing strategies (paper §5.7)
#   index.py        user-facing AnnIndex (build/search/save/load)
#   sharded_index.py  multi-device dataset-sharded serving (shard_map)

from repro.core.distances import get_metric, METRICS  # noqa: F401
from repro.core.graph import GraphIndex  # noqa: F401
from repro.core.spec import SearchSpec, SearchStats  # noqa: F401
from repro.core.routers import (Router, available_routers, get_router,  # noqa: F401
                                register_router)
from repro.core.search import SearchResult, search_batch  # noqa: F401
from repro.core.angles import AngleProfile, sample_angle_profile, theoretical_angle_pdf  # noqa: F401
from repro.core.index import AnnIndex  # noqa: F401

"""One benchmark function per paper table/figure (DESIGN.md §8 index).

Each returns a derived-metrics dict and emits a ``name,us_per_call,derived``
CSV row via common.emit.  Dataset scale is container-sized (BENCH_N env to
grow); dimensionalities match the paper's Table 2.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import BENCH_DATASETS, cached_index, dataset, emit
from repro.core.angles import sample_angle_profile, theoretical_angle_pdf
from repro.core.ref_search import search_ref, descend_hierarchy_ref
from repro.core.spec import SearchSpec
from repro.data.vectors import exact_ground_truth, recall_at_k


def _search(idx, queries, router, efs, k=10):
    ids, dists, stats = idx.search(queries,
                                   spec=SearchSpec(k=k, efs=efs, router=router))
    return ids, stats


def _recall_curve(idx, ds, gt, router, efs_grid, k=10):
    """Returns list of (efs, recall, qps, dist_calls/query)."""
    out = []
    for efs in efs_grid:
        # warm the jit, then time
        spec = SearchSpec(k=k, efs=efs, router=router)
        idx.search(ds.queries[:4], spec=spec)
        t0 = time.perf_counter()
        ids, _, stats = idx.search(ds.queries, spec=spec)
        dt = time.perf_counter() - t0
        out.append((efs, recall_at_k(ids, gt, k),
                    len(ds.queries) / dt, float(stats.dist_calls.mean())))
    return out


# --------------------------------------------------------------------------
def fig2_time_breakdown():
    """Fig. 2: fraction of greedy-search time spent in distance calls."""
    derived = {}
    for name in ("sift-synth", "gist-synth"):
        ds = dataset(name, n_base=3000)
        idx = cached_index(ds)
        g = idx.graph
        import repro.core.ref_search as R

        dist_time = 0.0
        orig = R._rank_dist

        def timed_dist(q, x, metric):
            nonlocal dist_time
            t0 = time.perf_counter()
            out = orig(q, x, metric)
            dist_time += time.perf_counter() - t0
            return out

        R._rank_dist = timed_dist
        t0 = time.perf_counter()
        for q in ds.queries[:20]:
            search_ref(g, q, efs=64)
        total = time.perf_counter() - t0
        R._rank_dist = orig
        derived[name] = {"dist_frac": round(dist_time / total, 3),
                         "dim": ds.base.shape[1]}
    emit("fig2_time_breakdown", 0.0, derived)
    return derived


def fig6_8_angles():
    """Fig. 6/7/8: empirical angle distribution vs dimension + invariance."""
    derived = {}
    for name in BENCH_DATASETS:
        ds = dataset(name, n_base=3000)
        idx = cached_index(ds)
        prof = idx.profile
        d = ds.base.shape[1]
        eta = np.linspace(0.01, np.pi - 0.01, 2000)
        pdf = theoretical_angle_pdf(eta, d)
        derived[name] = {
            "dim": d,
            "median_over_pi": round(float(np.median(prof.samples)) / np.pi, 4),
            "p90_over_pi": round(float(np.percentile(prof.samples, 90)) / np.pi, 4),
            "std_over_pi": round(float(prof.samples.std()) / np.pi, 4),
            "theory_mode_over_pi": round(float(eta[np.argmax(pdf)]) / np.pi, 4),
        }
    # invariance in query count (Fig. 8)
    ds = dataset("sift-synth", n_base=3000)
    idx = cached_index(ds)
    meds = []
    for ns in (4, 16, 64):
        p = sample_angle_profile(idx.graph, n_sample=ns, efs=64, seed=9)
        meds.append(float(np.median(p.samples)) / np.pi)
    derived["query_count_invariance_medians"] = [round(m, 4) for m in meds]
    emit("fig6_8_angles", 0.0, derived)
    return derived


def fig10_recall_qps():
    """Fig. 10: recall-QPS curves, HNSW & NSG, plain vs CRouting(_O)."""
    derived = {}
    efs_grid = (24, 48, 96, 160)
    for gname in ("hnsw", "nsg"):
        ds = dataset("sift-synth")
        idx = cached_index(ds, graph=gname)
        gt = exact_ground_truth(ds, k=10)
        rows = {}
        for router in ("none", "crouting", "crouting_o"):
            rows[router] = [(e, round(r, 3), round(q, 1), round(c, 1))
                            for e, r, q, c in
                            _recall_curve(idx, ds, gt, router, efs_grid)]
        # iso-recall QPS gain at ~0.9
        def qps_at(router, target):
            pts = [(abs(r - target), q) for _, r, q, _ in rows[router]]
            return min(pts)[1]
        derived[gname] = {"curves": rows,
                          "qps_gain_at_0.9": round(
                              qps_at("crouting", 0.9) / max(qps_at("none", 0.9), 1e-9), 2)}
    emit("fig10_recall_qps", 0.0,
         {g: d["qps_gain_at_0.9"] for g, d in derived.items()})
    return derived


def fig11_recall_speedup():
    """Fig. 11: distance-call speedup (plain calls / CRouting calls) at
    matched recall."""
    derived = {}
    for gname in ("hnsw", "nsg"):
        ds = dataset("sift-synth")
        idx = cached_index(ds, graph=gname)
        gt = exact_ground_truth(ds, k=10)
        plain = _recall_curve(idx, ds, gt, "none", (24, 48, 96, 160))
        cr = _recall_curve(idx, ds, gt, "crouting", (24, 48, 96, 160, 256))
        speedups = []
        for _, r_p, _, c_p in plain:
            ok = [(abs(r_c - r_p), c_c) for _, r_c, _, c_c in cr if r_c >= r_p - 0.01]
            if ok:
                speedups.append(round(c_p / min(ok)[1], 3))
        derived[gname] = {"recall_pts": [round(r, 3) for _, r, _, _ in plain],
                          "call_speedups": speedups}
    emit("fig11_recall_speedup", 0.0, derived)
    return derived


def table3_efs_ablation():
    """Table 3: recall + hops (exact distance calls) across efs."""
    ds = dataset("deep-synth")
    idx = cached_index(ds)
    gt = exact_ground_truth(ds, k=10)
    rows = []
    for efs in (24, 48, 96, 160, 256):
        row = {"efs": efs}
        for router in ("none", "crouting_o", "crouting"):
            ids, _, stats = idx.search(
                ds.queries, spec=SearchSpec(k=10, efs=efs, router=router))
            row[router] = {"recall": round(recall_at_k(ids, gt, 10), 3),
                           "hops": int(stats.dist_calls.sum())}
        rows.append(row)
    emit("table3_efs_ablation", 0.0, {"rows": rows})
    return rows


def table4_5_error_analysis():
    """Tables 4/5: relative estimation error + incorrect-prune ratio."""
    derived = {}
    for name in BENCH_DATASETS:
        ds = dataset(name, n_base=3000)
        idx = cached_index(ds)
        g, prof = idx.graph, idx.profile
        errs, bad, tot = [], 0, 0
        for q in ds.queries[:25]:
            _, _, st_p = search_ref(g, q, efs=64)
            ids, _, st = search_ref(g, q, efs=64, router="crouting",
                                    cos_theta=prof.cos_theta_star,
                                    record_est_error=True)
            for est, true in st.est_pairs:
                if true > 1e-9:
                    errs.append(abs(true - est) / true)
            tot += max(len(st.pruned_ids), 1)
            bad += len(st.pruned_ids & st_p.visited_ids
                       & set(int(x) for x in ids if x >= 0))
        derived[name] = {"mean_rel_err": round(float(np.mean(errs)), 4),
                         "incorrect_prune_ratio": round(bad / tot, 4)}
    emit("table4_5_error_analysis", 0.0, derived)
    return derived


def fig13_threshold():
    """Fig. 13: pruning-threshold percentile sweep."""
    ds = dataset("sift-synth")
    idx = cached_index(ds)
    gt = exact_ground_truth(ds, k=10)
    rows = []
    for pct in (10, 50, 75, 90, 99):
        prof = idx.profile.at_percentile(pct)
        ids, _, stats = idx.search(
            ds.queries, spec=SearchSpec(k=10, efs=64, router="crouting",
                                        cos_theta=prof.cos_theta_star))
        rows.append({"pct": pct,
                     "recall": round(recall_at_k(ids, gt, 10), 3),
                     "calls": round(float(stats.dist_calls.mean()), 1)})
    emit("fig13_threshold", 0.0, {"rows": rows})
    return rows


def fig14_15_neighbors_k():
    """Fig. 14/15: M sweep and result-number K sweep."""
    ds = dataset("sift-synth")
    gt100 = exact_ground_truth(ds, k=100)
    derived = {"m_sweep": [], "k_sweep": []}
    for m in (8, 16, 32):
        idx = cached_index(ds, m=m, efc=8 * m)
        gt = exact_ground_truth(ds, k=10)
        r = {}
        for router in ("none", "crouting"):
            ids, _, stats = idx.search(
                ds.queries, spec=SearchSpec(k=10, efs=64, router=router))
            r[router] = {"recall": round(recall_at_k(ids, gt, 10), 3),
                         "calls": round(float(stats.dist_calls.mean()), 1)}
        derived["m_sweep"].append({"m": m, **r})
    idx = cached_index(ds, m=16, efc=128)
    for k in (1, 10, 100):
        r = {}
        for router in ("none", "crouting"):
            ids, _, stats = idx.search(
                ds.queries, spec=SearchSpec(k=k, efs=max(128, k),
                                            router=router))
            r[router] = {"recall": round(recall_at_k(ids, gt100[:, :k], k), 3),
                         "calls": round(float(stats.dist_calls.mean()), 1)}
        derived["k_sweep"].append({"k": k, **r})
    emit("fig14_15_neighbors_k", 0.0, derived)
    return derived


def fig16_metrics():
    """Fig. 16: generality across l2 / ip / cosine."""
    derived = {}
    for metric in ("l2", "cosine", "ip"):
        ds = dataset("deep-synth", n_base=3000, metric=metric)
        idx = cached_index(ds)
        gt = exact_ground_truth(ds, k=10)
        prof = idx.profile
        row = {"theta_median_over_pi":
               round(float(np.median(prof.samples)) / np.pi, 4)}
        for router in ("none", "crouting"):
            ids, _, stats = idx.search(
                ds.queries, spec=SearchSpec(k=10, efs=64, router=router))
            row[router] = {"recall": round(recall_at_k(ids, gt, 10), 3),
                           "calls": round(float(stats.dist_calls.mean()), 1)}
        derived[metric] = row
    emit("fig16_metrics", 0.0, derived)
    return derived


def fig17_scalability():
    """Fig. 17: call-speedup holds as N grows."""
    derived = {}
    for n in (2000, 8000, 20000):
        ds = dataset("sift-synth", n_base=n)
        idx = cached_index(ds)
        gt = exact_ground_truth(ds, k=10)
        row = {}
        for router in ("none", "crouting"):
            ids, _, stats = idx.search(
                ds.queries, spec=SearchSpec(k=10, efs=64, router=router))
            row[router] = {"recall": round(recall_at_k(ids, gt, 10), 3),
                           "calls": round(float(stats.dist_calls.mean()), 1)}
        row["call_speedup"] = round(row["none"]["calls"]
                                    / row["crouting"]["calls"], 3)
        derived[f"n={n}"] = row
    emit("fig17_scalability", 0.0,
         {k: v["call_speedup"] for k, v in derived.items()})
    return derived


def table6_7_construction():
    """Tables 6/7: construction time + index size across routing strategies."""
    from repro.core.finger import build_finger
    from repro.core.togg import build_togg

    ds = dataset("sift-synth", n_base=4000)
    idx = cached_index(ds)
    g = idx.graph
    base_secs = (g.build_stats or {}).get("build_secs", 1.0)
    prof_secs = (g.build_stats or {}).get("profile_secs", 0.0)
    if not prof_secs:
        prof_secs = sample_angle_profile(g, seed=5).sample_secs
    fi = build_finger(g)
    ti = build_togg(g)
    mem = g.memory_bytes()
    base_bytes = mem["total"] - mem["mem_dist"]
    derived = {
        "construction_overhead": {
            "crouting": round(prof_secs / base_secs, 4),
            "finger": round(fi.build_secs / base_secs, 4),
            "togg": round(ti.build_secs / base_secs, 4),
        },
        "index_size_overhead": {
            "crouting": round(mem["mem_dist"] / base_bytes, 4),
            "finger": round(fi.extra_bytes() / base_bytes, 4),
            "togg": round(ti.extra_bytes() / base_bytes, 4),
        },
    }
    emit("table6_7_construction", 0.0, derived)
    return derived


def fig18_strategies():
    """Fig. 18: routing-strategy comparison at fixed efs (recall + calls)."""
    from repro.core.finger import build_finger, finger_search
    from repro.core.togg import build_togg, togg_search

    ds = dataset("sift-synth", n_base=4000)
    idx = cached_index(ds)
    g = idx.graph
    gt = exact_ground_truth(ds, k=10)
    derived = {}
    ids_c, _, st_c = idx.search(
        ds.queries, spec=SearchSpec(k=10, efs=64, router="crouting"))
    derived["crouting"] = {"recall": round(recall_at_k(ids_c, gt, 10), 3),
                           "calls": round(float(st_c.dist_calls.mean()), 1)}
    fi = build_finger(g)
    ti = build_togg(g)
    for name, fn in (("finger", lambda q, e: finger_search(fi, q, e, 64)),
                     ("togg", lambda q, e: togg_search(ti, q, e, 64))):
        ids_all, calls = [], 0
        for q in ds.queries[:50]:
            e, ec = descend_hierarchy_ref(g, q)
            ids, _, st = fn(q, e)
            ids_all.append(ids[:10])
            calls += st.dist_calls + ec
        derived[name] = {"recall": round(
            recall_at_k(np.asarray(ids_all), gt[:50], 10), 3),
            "calls": round(calls / 50, 1)}
    emit("fig18_strategies", 0.0, derived)
    return derived

"""FINGER baseline (Chen et al., WWW'23) — residual-subspace distance estimate.

For every edge (c -> n) FINGER decomposes n into a component parallel to c and
a residual, and estimates at query time (paper Eq. 1):

    |q - n|^2 ~= (t_q - t_n)^2 |c|^2 + |q_res|^2 + |n_res|^2
                 - 2 |q_res| |n_res| cos(pi * rho)

where rho is the hamming distance ratio between sign-LSH signatures of the
residuals.  Deviations from the original (documented in DESIGN.md §7): global
random hyperplanes instead of per-node subspaces.  Signatures of q_res w.r.t.
node c are formed as sign(Hq - t_q * Hc), so the per-expansion cost is O(r),
with Hq computed once per query.

Construction stores, per edge: t_n, |n_res|, packed signature bits; per node:
|c|^2 and Hc — this is the memory overhead the paper's Table 7 highlights.
"""
from __future__ import annotations

import dataclasses
import heapq
import time
from typing import Tuple

import numpy as np

from repro.core.graph import GraphIndex
from repro.core.ref_search import SearchStats, STATUS_VISITED, STATUS_UNVISITED


@dataclasses.dataclass
class FingerIndex:
    graph: GraphIndex
    hyperplanes: np.ndarray    # [r, d]
    node_c2: np.ndarray        # [N] |c|^2
    node_hc: np.ndarray        # [N, r] H @ c
    edge_t: np.ndarray         # [N, M] projection coefficient t_n
    edge_res_norm: np.ndarray  # [N, M] |n_res|
    edge_sig: np.ndarray       # [N, M, r//64] packed sign bits
    build_secs: float = 0.0

    def extra_bytes(self) -> int:
        return int(self.node_c2.nbytes + self.node_hc.nbytes + self.edge_t.nbytes
                   + self.edge_res_norm.nbytes + self.edge_sig.nbytes)


def _pack_signs(x: np.ndarray) -> np.ndarray:
    """x [..., r] floats -> packed uint64 [..., r//64]."""
    bits = (x > 0).astype(np.uint64)
    r = bits.shape[-1]
    words = r // 64
    out = np.zeros(bits.shape[:-1] + (words,), dtype=np.uint64)
    for w in range(words):
        for b in range(64):
            out[..., w] |= bits[..., w * 64 + b] << np.uint64(b)
    return out


_POPCOUNT = np.array([bin(i).count("1") for i in range(65536)], dtype=np.int32)


def _hamming(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    x = a ^ b
    h = np.zeros(x.shape[:-1], dtype=np.int32)
    for w in range(x.shape[-1]):
        v = x[..., w]
        for s in (0, 16, 32, 48):
            h += _POPCOUNT[((v >> np.uint64(s)) & np.uint64(0xFFFF)).astype(np.int64)]
    return h


def build_finger(g: GraphIndex, r_bits: int = 64, seed: int = 0) -> FingerIndex:
    t0 = time.time()
    assert r_bits % 64 == 0
    n, d = g.n, g.dim
    m = g.max_degree
    rng = np.random.default_rng(seed)
    H = rng.normal(size=(r_bits, d)).astype(np.float32)
    vecs = g.vectors
    c2 = np.einsum("nd,nd->n", vecs, vecs).astype(np.float32)
    hc = (vecs @ H.T).astype(np.float32)
    edge_t = np.zeros((n, m), np.float32)
    edge_rn = np.zeros((n, m), np.float32)
    edge_sig = np.zeros((n, m, r_bits // 64), np.uint64)
    for i in range(n):
        nbrs = g.neighbors[i]
        k = int((nbrs < n).sum())
        if k == 0:
            continue
        ids = nbrs[:k].astype(np.int64)
        nv = vecs[ids]                       # [k, d]
        t = (nv @ vecs[i]) / max(c2[i], 1e-12)
        res = nv - t[:, None] * vecs[i][None, :]
        edge_t[i, :k] = t
        edge_rn[i, :k] = np.linalg.norm(res, axis=1)
        edge_sig[i, :k] = _pack_signs(res @ H.T)
    return FingerIndex(graph=g, hyperplanes=H, node_c2=c2, node_hc=hc,
                       edge_t=edge_t, edge_res_norm=edge_rn, edge_sig=edge_sig,
                       build_secs=time.time() - t0)


def finger_search(fi: FingerIndex, q: np.ndarray, entry: int, efs: int,
                  max_hops: int = 10**9) -> Tuple[np.ndarray, np.ndarray, SearchStats]:
    """Greedy search with FINGER distance-estimate pruning (L2 metric)."""
    g = fi.graph
    n = g.n
    vecs = g.vectors
    status = np.zeros(n, np.uint8)
    stats = SearchStats()
    Hq = fi.hyperplanes @ q                  # once per query
    r_bits = fi.hyperplanes.shape[0]

    def exact(i):
        stats.dist_calls += 1
        d = q - vecs[i]
        return float(np.dot(d, d))

    d0 = exact(entry)
    status[entry] = STATUS_VISITED
    C = [(d0, entry)]
    T = [(-d0, entry)]
    while C and stats.hops < max_hops:
        dc, c = heapq.heappop(C)
        upper = -T[0][0]
        if dc > upper and len(T) >= efs:
            break
        stats.hops += 1
        nbrs = g.neighbors[c]
        k = int((nbrs < n).sum())
        if k == 0:
            continue
        ids = nbrs[:k].astype(np.int64)
        c2 = max(float(fi.node_c2[c]), 1e-12)
        t_q = float(np.dot(q, vecs[c])) / c2
        q_res2 = max(float(np.dot(q, q)) - t_q * t_q * c2, 0.0)
        q_rn = np.sqrt(q_res2)
        sig_q = _pack_signs((Hq - t_q * fi.node_hc[c])[None, :])[0]

        st = status[ids]
        fresh = st == STATUS_UNVISITED
        pool_full = len(T) >= efs
        if pool_full and fresh.any():
            sel = np.nonzero(fresh)[0]
            t_n = fi.edge_t[c, sel]
            n_rn = fi.edge_res_norm[c, sel]
            rho = _hamming(sig_q[None, :], fi.edge_sig[c, sel]) / r_bits
            stats.est_calls += len(sel)
            est = ((t_q - t_n) ** 2 * c2 + q_res2 + n_rn**2
                   - 2.0 * q_rn * n_rn * np.cos(np.pi * rho))
            pruned = sel[est >= upper]
            status[ids[pruned]] = STATUS_VISITED  # FINGER prunes permanently
            stats.pruned_ids.update(int(ids[p]) for p in pruned)
        for slot in range(k):
            nid = int(ids[slot])
            if status[nid] == STATUS_VISITED:
                continue
            status[nid] = STATUS_VISITED
            dn = exact(nid)
            if dn < upper or len(T) < efs:
                heapq.heappush(C, (dn, nid))
                heapq.heappush(T, (-dn, nid))
                if len(T) > efs:
                    heapq.heappop(T)
                upper = -T[0][0]
    out = sorted(((-d, i) for d, i in T))
    ids_out = np.full(efs, -1, np.int64)
    ds_out = np.full(efs, np.inf, np.float32)
    for j, (d, i) in enumerate(out[:efs]):
        ids_out[j] = i
        ds_out[j] = d
    return ids_out, ds_out, stats

"""Comparison routing strategies (paper §5.7): FINGER and TOGG behave per
their Table-1 signatures — FINGER: high memory, strong pruning; TOGG: cheap
build, weak accuracy/work tradeoff."""
import numpy as np
import pytest

from repro.core.finger import build_finger, finger_search
from repro.core.togg import build_togg, togg_search
from repro.core.ref_search import descend_hierarchy_ref, search_ref
from repro.data.vectors import recall_at_k


@pytest.fixture(scope="module")
def baselines(small_ds, hnsw_index):
    plain_calls, plain_ids = 0, []
    for q in small_ds.queries:
        ids, _, st = search_ref(hnsw_index, q, efs=48)
        plain_ids.append(ids[:10])
        plain_calls += st.dist_calls
    return np.asarray(plain_ids), plain_calls / len(small_ds.queries)


def test_finger_prunes_with_reasonable_recall(small_ds, hnsw_index,
                                              ground_truth, baselines):
    plain_ids, plain_calls = baselines
    fi = build_finger(hnsw_index, r_bits=64, seed=0)
    ids_all, calls = [], 0
    for q in small_ds.queries:
        e, _ = descend_hierarchy_ref(hnsw_index, q)
        ids, _, st = finger_search(fi, q, e, efs=48)
        ids_all.append(ids[:10])
        calls += st.dist_calls
    calls /= len(small_ds.queries)
    rec = recall_at_k(np.asarray(ids_all), ground_truth, 10)
    assert calls < plain_calls * 0.8, (calls, plain_calls)
    assert rec > 0.6, rec


def test_finger_memory_signature(hnsw_index):
    """Table 7: FINGER's extra index state is large (vs CRouting's edge
    distances)."""
    fi = build_finger(hnsw_index)
    crouting_extra = hnsw_index.memory_bytes()["mem_dist"]
    assert fi.extra_bytes() > 3 * crouting_extra


def test_togg_worst_work_tradeoff(small_ds, hnsw_index, ground_truth,
                                  baselines):
    """Our TOGG variant (DESIGN.md §7) lands on the poor side of the
    comparison: no distance-call saving vs plain greedy."""
    plain_ids, plain_calls = baselines
    ti = build_togg(hnsw_index)
    ids_all, calls = [], 0
    for q in small_ds.queries[:20]:
        e, _ = descend_hierarchy_ref(hnsw_index, q)
        ids, _, st = togg_search(ti, q, e, efs=48)
        ids_all.append(ids[:10])
        calls += st.dist_calls
    calls /= 20
    assert calls > plain_calls * 0.8, (calls, plain_calls)


def test_construction_overhead_ordering(small_ds, hnsw_index):
    """Table 6 signature: CRouting's profile sampling is cheap; FINGER build
    costs much more than the angle profile."""
    from repro.core.angles import sample_angle_profile
    import time

    t0 = time.time()
    prof = sample_angle_profile(hnsw_index, n_sample=8, efs=48, seed=0)
    crouting_extra_s = time.time() - t0
    fi = build_finger(hnsw_index)
    assert fi.build_secs > 0
    # both are small in absolute terms at this scale; the ordering that
    # matters (paper Table 6) is measured in benchmarks/bench_construction.py
    assert prof.sample_secs < 60

"""Shared shape sets per architecture family (the assignment's shape lists)."""
from __future__ import annotations

from repro.configs import ShapeSpec

LM_SHAPES = (
    ShapeSpec("train_4k", "train", dict(seq_len=4096, global_batch=256),
              "training"),
    ShapeSpec("prefill_32k", "prefill", dict(seq_len=32768, global_batch=32),
              "inference-prefill"),
    ShapeSpec("decode_32k", "serve", dict(seq_len=32768, global_batch=128),
              "inference-decode: 1 new token, KV cache of seq_len"),
    ShapeSpec("long_500k", "serve", dict(seq_len=524288, global_batch=1),
              "long-context decode; O(S) per token with sequence-sharded KV "
              "(full-attention archs: see DESIGN.md §5 long_500k note)"),
)

GNN_SHAPES = (
    ShapeSpec("full_graph_sm", "train",
              dict(n_nodes=2708, n_edges=10556, d_feat=1433, n_classes=7),
              "full-batch (cora-like)"),
    ShapeSpec("minibatch_lg", "train",
              dict(n_nodes=232_965, n_edges=114_615_892, batch_nodes=1024,
                   fanout1=15, fanout2=10, d_feat=602, n_classes=41,
                   # sampled-subgraph static bounds: 1024*(1+15+150) nodes
                   sub_nodes=169_984, sub_edges=168_960),
              "sampled-training (reddit-like, real neighbor sampler)"),
    ShapeSpec("ogb_products", "train",
              dict(n_nodes=2_449_029, n_edges=61_859_140, d_feat=100,
                   n_classes=47),
              "full-batch-large"),
    ShapeSpec("molecule", "train",
              dict(n_nodes=30, n_edges=64, batch=128, d_feat=16),
              "batched-small-graphs"),
)

RECSYS_SHAPES = (
    ShapeSpec("train_batch", "train", dict(batch=65_536), "training"),
    ShapeSpec("serve_p99", "serve", dict(batch=512), "online-inference"),
    ShapeSpec("serve_bulk", "serve", dict(batch=262_144), "offline-scoring"),
    ShapeSpec("retrieval_cand", "retrieval",
              dict(batch=1, n_candidates=1_000_000),
              "retrieval-scoring: batched dot, never a loop; CRouting-ANN "
              "variant in examples/dlrm_retrieval.py"),
)

ANNS_SHAPES = (
    ShapeSpec("serve_1b", "anns_serve",
              dict(n_total=1_000_000_000, dim=128, max_degree=32,
                   batch=1024, efs=128, k=10),
              "SIFT-1B-scale sharded CRouting serving (paper's own system)"),
    ShapeSpec("serve_100m_gist", "anns_serve",
              dict(n_total=100_000_000, dim=960, max_degree=32,
                   batch=256, efs=128, k=10),
              "GIST-dim high-d sharded serving"),
)

"""Pallas TPU kernel: tiled squared-L2 / inner-product distance matrix.

The paper's hot spot — batched exact distance evaluation — as an MXU matmul:

    dist2[i, j] = |q_i|^2 + |x_j|^2 - 2 <q_i, x_j>

Tiling: grid (Q/bq, C/bc, D/bd).  Per step, a (bq, bd) query tile and a
(bc, bd) candidate tile are DMA'd to VMEM, the partial -2*q@x^T accumulates
into the (bq, bc) output tile (revisited across the d-axis grid dim), and the
precomputed norms are added on the final d-step.  Block sizes default to
MXU-aligned 128/256/512 so q-tile + x-tile + out-tile fit comfortably in the
~16 MB v5e VMEM: 128*512*4 + 256*512*4 + 128*256*4 ≈ 0.9 MB.

Used by: brute-force ground truth, KNN-graph construction, DLRM
retrieval_cand scoring.  Validated in interpret mode vs ref.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _dist_kernel(q_ref, x_ref, qn_ref, xn_ref, o_ref, *, n_d_steps: int, mode: str):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    q = q_ref[...].astype(jnp.float32)          # [bq, bd]
    x = x_ref[...].astype(jnp.float32)          # [bc, bd]
    acc = jax.lax.dot_general(q, x, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)
    if mode == "l2":
        o_ref[...] += -2.0 * acc
    else:  # ip
        o_ref[...] += -acc

    @pl.when(k == n_d_steps - 1)
    def _fin():
        if mode == "l2":
            o_ref[...] = jnp.maximum(
                o_ref[...] + qn_ref[...].reshape(-1, 1) + xn_ref[...].reshape(1, -1),
                0.0)
        else:
            o_ref[...] += 1.0  # IPDist = 1 - <q, x>


@functools.partial(jax.jit, static_argnames=("bq", "bc", "bd", "mode", "interpret"))
def l2_distance_pallas(q, x, *, bq: int = 128, bc: int = 256, bd: int = 512,
                       mode: str = "l2", interpret: bool = True):
    """q [Q, d], x [C, d] -> dist [Q, C] (squared L2, or IP distance)."""
    Q, d = q.shape
    C = x.shape[0]
    bq, bc, bd = min(bq, Q), min(bc, C), min(bd, d)
    assert Q % bq == 0 and C % bc == 0 and d % bd == 0, (
        "pad inputs to block multiples (ops.l2_distance handles padding)")
    n_d = d // bd
    qn = jnp.sum(q.astype(jnp.float32) ** 2, axis=-1)
    xn = jnp.sum(x.astype(jnp.float32) ** 2, axis=-1)
    grid = (Q // bq, C // bc, n_d)
    return pl.pallas_call(
        functools.partial(_dist_kernel, n_d_steps=n_d, mode=mode),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, bd), lambda i, j, k: (i, k)),
            pl.BlockSpec((bc, bd), lambda i, j, k: (j, k)),
            pl.BlockSpec((bq,), lambda i, j, k: (i,)),
            pl.BlockSpec((bc,), lambda i, j, k: (j,)),
        ],
        out_specs=pl.BlockSpec((bq, bc), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Q, C), jnp.float32),
        interpret=interpret,
    )(q, x, qn, xn)

"""ANNS serving launcher: a CRouting index sharded over the local devices
behind the bucketed serving frontend (DESIGN.md §6).

  PYTHONPATH=src python -m repro.launch.serve --n-base 20000 --requests 200

Replays a seeded ragged request trace (sizes drawn log-uniform up to the top
bucket) through ``repro.serve.ServeFrontend`` with the background worker
running, then prints the telemetry digest: recall, p50/p95/p99 latency, QPS,
and per-bucket compile counts — zero compiles may land on the request path
(every bucket is pre-jitted at startup).  ``--single`` serves one global
``AnnIndex`` instead of the device-sharded layout.  ``--autotune
--slo-p99-ms 250`` attaches the SLO-driven controller (DESIGN.md §12): the
held-out queries + exact ground truth become the recall-proxy probe set
(so any backend works), the knob space is screened at startup, and the
controller keeps re-deciding on a background thread while the trace
replays, printing its structured decision log at the end.
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np
import jax

from repro.core.index import AnnIndex
from repro.core.sharded_index import shard_dataset, ShardedAnnIndex
from repro.core.spec import SearchSpec
from repro.data.vectors import make_dataset, exact_ground_truth, recall_at_k
from repro.fault import RetryPolicy
from repro.launch.mesh import make_local_mesh
from repro.serve import QueueFull, ServeFrontend


def ragged_sizes(n_requests: int, top: int, seed: int) -> np.ndarray:
    """Log-uniform request sizes in [1, top] — mostly small, some full."""
    rng = np.random.default_rng(seed)
    sizes = np.exp(rng.uniform(0, np.log(top + 1), n_requests)).astype(int)
    return np.clip(sizes, 1, top)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-base", type=int, default=20000)
    ap.add_argument("--dim", type=int, default=128)
    ap.add_argument("--graph", default="hnsw", choices=["hnsw", "nsg"])
    ap.add_argument("--router", default="crouting")
    ap.add_argument("--efs", type=int, default=100)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--requests", type=int, default=100)
    ap.add_argument("--buckets", default="1,8,32,128",
                    help="comma-separated bucket ladder")
    ap.add_argument("--timeout", type=float, default=None,
                    help="per-request admission deadline (s)")
    ap.add_argument("--single", action="store_true",
                    help="serve one AnnIndex instead of sharding per device")
    ap.add_argument("--autotune", action="store_true",
                    help="attach the SLO-driven controller (DESIGN.md §12): "
                         "screen the knob space at startup, then re-decide "
                         "on a background thread while the trace replays")
    ap.add_argument("--slo-p99-ms", type=float, default=250.0,
                    help="p99 latency SLO the autotune controller enforces")
    ap.add_argument("--durable-dir", default=None,
                    help="serve a durable MutableAnnIndex (DESIGN.md §11): "
                         "recover from DIR when it already holds state, "
                         "else build fresh and start write-ahead logging "
                         "there (recall is meaningful only when the build "
                         "args match the logged corpus)")
    ap.add_argument("--m", type=int, default=16)
    ap.add_argument("--efc", type=int, default=128)
    args = ap.parse_args()
    buckets = tuple(int(b) for b in args.buckets.split(","))

    n_dev = len(jax.devices())
    print(f"devices: {n_dev}")
    sizes = ragged_sizes(args.requests, buckets[-1], seed=1)
    ds = make_dataset(n_base=args.n_base, n_query=int(sizes.sum()),
                      dim=args.dim, seed=0)
    spec = SearchSpec(efs=args.efs, k=args.k, router=args.router,
                      max_hops=2048)

    t0 = time.time()
    if args.durable_dir is not None:
        from repro.durable import has_manifest
        from repro.mutate import MutableAnnIndex, MutateConfig

        mcfg = MutateConfig(graph=args.graph)
        if has_manifest(args.durable_dir):
            index = MutableAnnIndex.recover(args.durable_dir, config=mcfg,
                                            spec=spec)
            print(f"recovered {index.n_live} live rows from "
                  f"{args.durable_dir} (epoch {index.epoch})")
        else:
            base_idx = AnnIndex.build(ds.base, graph=args.graph, m=args.m,
                                      efc=args.efc)
            index = MutableAnnIndex(base_idx, config=mcfg, spec=spec,
                                    durable_dir=args.durable_dir)
            print(f"created durable state in {args.durable_dir}")
        profile = index._state.snapshot.index.profile
        theta = np.arccos(profile.cos_theta_star)
    elif args.single:
        index = AnnIndex.build(ds.base, graph=args.graph, m=args.m,
                               efc=args.efc)
        theta = np.arccos(index.profile.cos_theta_star)
    else:
        arrays = shard_dataset(ds.base, n_shards=max(n_dev, 1),
                               graph=args.graph, m=args.m, efc=args.efc)
        theta = np.arccos(arrays.cos_theta)
        mesh = make_local_mesh(n_dev, "shards")
        index = ShardedAnnIndex(arrays, mesh, spec=spec)
    print(f"index built in {time.time()-t0:.1f}s (theta*={theta/np.pi:.3f}pi)")

    t0 = time.time()
    fe = ServeFrontend(index, spec, buckets=buckets,
                       default_timeout=args.timeout)
    print(f"frontend warm in {time.time()-t0:.1f}s "
          f"({fe.telemetry.summary()['compiles_total']} bucket compiles)")

    gt = exact_ground_truth(ds, k=args.k)
    drv = None
    if args.autotune:
        # explicit probe queries + GT: works against every backend here
        # (sharded/durable indexes expose no single corpus to synthesize
        # probes from)
        from repro.autotune import AutotuneDriver, Objective

        t0 = time.time()
        n_probe = min(64, len(ds.queries))
        drv = AutotuneDriver.attach(
            fe, Objective(slo_p99_ms=args.slo_p99_ms),
            probe_queries=ds.queries[:n_probe], probe_gt=gt[:n_probe],
            seed=0)
        print(f"autotune attached in {time.time()-t0:.1f}s: "
              f"incumbent {drv.controller.incumbent} "
              f"(SLO p99<={args.slo_p99_ms:.0f}ms, "
              f"{len(drv.controller.quarantined)} quarantined)")
    offsets = np.concatenate([[0], np.cumsum(sizes)])
    # QueueFull backpressure: capped exponential backoff with jitter
    # (decorrelates many clients) instead of a hand-rolled fixed-sleep spin
    backoff = RetryPolicy(max_attempts=64, base_s=0.005, cap_s=0.25, seed=1)
    with fe:                                     # background flush worker
        if drv is not None:
            drv.start(period_s=0.5)              # controller epochs
        futs = []
        for i in range(len(sizes)):
            q = ds.queries[offsets[i]:offsets[i + 1]]
            futs.append(backoff.call(fe.submit, q, retry_on=QueueFull))
        done = [f.result() for f in futs]
        if drv is not None:
            drv.stop()
    rec = recall_at_k(np.concatenate([ids for ids, _, _ in done]), gt, args.k)

    summ = fe.telemetry.summary()
    lat = summ["latency"]
    print(f"router={args.router}: recall@{args.k}={rec:.3f} "
          f"QPS={summ['qps']:.0f} p50={lat['p50_ms']:.1f}ms "
          f"p95={lat['p95_ms']:.1f}ms p99={lat['p99_ms']:.1f}ms "
          f"recompiles_after_warmup={summ['recompiles_after_warmup']}")
    if drv is not None:
        print(f"autotune: {drv.switches} switches, {drv.failures} failures, "
              f"final spec {drv.controller.incumbent}")
        print("decisions:", json.dumps(drv.decision_log()))
    print("health:", json.dumps(fe.health()))
    print(json.dumps(summ, indent=2))
    if args.durable_dir is not None:
        index.close()               # final WAL fsync + writer release


if __name__ == "__main__":
    main()

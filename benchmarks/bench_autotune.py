"""Autotune benchmark (persisted to committed BENCH_autotune.json).

A two-phase shifting workload replayed against the same index (ISSUE 9
acceptance):

* **calm**  — solo-flushed small requests: an idle server where every
  candidate spec meets the SLO, so the best static config is the richest
  (highest-recall) one;
* **burst** — groups of ``BURST_DEPTH`` near-top-bucket requests submitted
  together: each request is its own dispatch, so the tail of a group waits
  behind the whole queue and the rich spec's p99 blows the SLO.

The SLO is probe-calibrated (``2.5 x`` the richest candidate's solo probe
latency), so the phase structure — rich spec comfortably feasible solo,
infeasible under burst queueing, a cheaper candidate feasible under both —
holds on any machine rather than encoding one box's milliseconds.

Baseline to beat: the **static-best-of-phase-1** grid config (every
candidate replayed through the full trace on its own frontend).  That
config degrades after the shift; the autotuned frontend must reach >= its
SLO attainment on BOTH phases, serve phase-1 recall within 0.01 of it, and
keep ``recompiles_after_warmup == 0`` across every controller switch.
``BENCH_SMOKE=1`` shrinks the trace and diverts the JSON to .cache/.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import (SMOKE, cached_index, dataset, emit,
                               persist_bench, smoke_scale)
from repro.autotune import AutotuneDriver, Objective, RecallProxy, TuneSpace
from repro.autotune.space import spec_key
from repro.core.spec import SearchSpec
from repro.data.vectors import exact_ground_truth, recall_at_k
from repro.serve import ServeFrontend

BUCKETS = (1, 4, 8) if SMOKE else (1, 8, 32)
N_CALM = 8 if SMOKE else 24          # phase-1 solo requests
N_BURST_GROUPS = 3 if SMOKE else 8   # phase-2 groups
BURST_DEPTH = 4                      # requests per burst group
CALM_STEP_EVERY = 4                  # controller cadence in phase 1
SLO_FACTOR = 2.5                     # SLO = factor x rich solo probe lat


def _two_phase_trace(top: int, seed: int = 11):
    """Deterministic request sizes: calm singletons, then burst groups of
    near-top-bucket requests (each > top/2 rows, so no two coalesce into
    one dispatch — the queueing is what shifts the workload)."""
    rng = np.random.default_rng(seed)
    calm = [int(rng.integers(1, max(2, top // 4) + 1))
            for _ in range(N_CALM)]
    bursts = [[int(rng.integers(top // 2 + 1, top + 1))
               for _ in range(BURST_DEPTH)]
              for _ in range(N_BURST_GROUPS)]
    return calm, bursts


def _replay_phases(fe: ServeFrontend, queries, gt, calm, bursts,
                   slo_ms: float, step=None) -> dict:
    """Replay calm then burst; per-phase SLO attainment + served recall.

    ``step`` (the autotuned run) fires between groups — after every
    ``CALM_STEP_EVERY`` calm requests, after every burst group — so the
    controller consumes epoch deltas exactly where an online loop would.
    """
    tm = fe.telemetry
    qpos = 0
    phases = {}
    plan = [("calm", [[n] for n in calm], CALM_STEP_EVERY),
            ("burst", bursts, 1)]
    for name, groups, step_every in plan:
        snap0 = tm.window_snapshot()
        ids_all, gt_all = [], []
        for gi, group in enumerate(groups):
            futs = []
            for n in group:
                futs.append((fe.submit(queries[qpos:qpos + n]),
                             gt[qpos:qpos + n]))
                qpos += n
            fe.flush()
            for f, g in futs:
                ids, _, _ = f.result()
                ids_all.append(ids)
                gt_all.append(g)
            if step is not None and (gi + 1) % step_every == 0:
                step()
        snap1 = tm.window_snapshot()
        served = int(snap1["served"]) - int(snap0["served"])
        lat = snap1["_lat_s"]
        ms = np.asarray(lat[len(lat) - min(served, len(lat)):]) * 1e3
        phases[name] = {
            "requests": served,
            "attainment": round(float(np.mean(ms <= slo_ms)), 4),
            "p50_ms": round(float(np.percentile(ms, 50)), 3),
            "p99_ms": round(float(np.percentile(ms, 99)), 3),
            "recall": round(float(recall_at_k(
                np.concatenate(ids_all), np.concatenate(gt_all), 10)), 4),
        }
    return phases


def bench_autotune():
    """Autotuned frontend vs the static grid on the two-phase trace."""
    # deep-synth + a deliberately weak graph (m=8, efc=48): recall must NOT
    # saturate across the efs ladder, or every candidate ties at 1.0 and
    # "best static of phase 1" stops meaning the rich spec
    ds = dataset("deep-synth", n_base=smoke_scale(6000, 600))
    idx = cached_index(ds, m=8, efc=48)
    gt = exact_ground_truth(ds, k=10)
    top = BUCKETS[-1]
    calm, bursts = _two_phase_trace(top)
    need = sum(calm) + sum(map(sum, bursts))
    q = np.take(ds.queries, np.arange(need) % len(ds.queries), axis=0)
    gtr = np.take(gt, np.arange(need) % len(ds.queries), axis=0)

    base = SearchSpec(efs=32, k=10, router="crouting")
    space = TuneSpace.default(base, efs=(32, 64, 128), beam_width=(1, 2))
    cands = space.candidates()
    # one probe set + exact GT shared by the SLO calibration and the driver
    proxy = RecallProxy.for_index(idx, queries=ds.queries[:top],
                                  gt=gt[:top], buckets=BUCKETS)
    rich = cands[-1]                 # enumeration order: costliest last
    lat_rich_ms = proxy.evaluate(rich, replays=3).lat_s * 1e3
    slo_ms = round(SLO_FACTOR * lat_rich_ms, 3)

    # --- baseline: every static config through the full trace ------------
    static = {}
    for spec in cands:
        fe = ServeFrontend(idx, spec, buckets=BUCKETS,
                           max_pending_rows=8 * top)
        static[spec_key(spec)] = _replay_phases(fe, q, gtr, calm, bursts,
                                                slo_ms)
        assert fe.telemetry.recompiles_after_warmup == 0
    # "best static config of phase 1": attainment first, then recall
    best_key = max(static, key=lambda k: (static[k]["calm"]["attainment"],
                                          static[k]["calm"]["recall"]))
    best = static[best_key]

    # --- autotuned: one frontend, controller stepped along the trace ------
    fe = ServeFrontend(idx, base, buckets=BUCKETS, max_pending_rows=8 * top)
    drv = AutotuneDriver.attach(fe, Objective(slo_p99_ms=slo_ms),
                                space=space, proxy=proxy, seed=0)
    incumbent_phase1 = drv.controller.incumbent
    tuned = _replay_phases(fe, q, gtr, calm, bursts, slo_ms, step=drv.step)
    assert fe.telemetry.recompiles_after_warmup == 0, \
        "a controller switch compiled on the request path"

    # decisions-to-recover: switches after the burst shift began
    n_calm_steps = 1 + N_CALM // CALM_STEP_EVERY      # screen + calm epochs
    post_shift = drv.decision_log()[n_calm_steps:]
    recover = next((i + 1 for i, d in enumerate(post_shift)
                    if d["kind"] == "switch"), None)

    acceptance = {
        "attainment_calm": [tuned["calm"]["attainment"],
                            best["calm"]["attainment"]],
        "attainment_burst": [tuned["burst"]["attainment"],
                             best["burst"]["attainment"]],
        "recall_gap_phase1": round(
            best["calm"]["recall"] - tuned["calm"]["recall"], 4),
        "decisions_to_recover": recover,
        "recompiles_after_warmup": fe.telemetry.recompiles_after_warmup,
    }
    assert tuned["calm"]["attainment"] >= best["calm"]["attainment"], \
        acceptance
    assert tuned["burst"]["attainment"] >= best["burst"]["attainment"], \
        acceptance
    assert acceptance["recall_gap_phase1"] <= 0.01, acceptance

    payload = {
        "slo_p99_ms": slo_ms,
        "slo_calibration": {"factor": SLO_FACTOR, "rich_key": spec_key(rich),
                            "rich_probe_lat_ms": round(lat_rich_ms, 3)},
        "space": space.describe(),
        "trace": {"calm_requests": len(calm),
                  "burst_groups": len(bursts), "burst_depth": BURST_DEPTH,
                  "rows": int(need), "buckets": list(BUCKETS)},
        "static": static,
        "static_best_phase1": best_key,
        "autotuned": {
            "phases": tuned,
            "screen_incumbent": incumbent_phase1,
            "final_incumbent": drv.controller.incumbent,
            "switches": drv.switches,
            "failures": drv.failures,
            "proxy_gt_secs": round(proxy.gt_secs, 3),
            "decisions": drv.decision_log(),
        },
        "acceptance": acceptance,
        "n_base": int(ds.base.shape[0]),
    }
    emit("autotune_two_phase", 0.0, {
        "slo_ms": slo_ms,
        "calm": acceptance["attainment_calm"],
        "burst": acceptance["attainment_burst"],
        "recall_gap": acceptance["recall_gap_phase1"],
        "switches": drv.switches, "recover": recover})
    persist_bench("autotune_two_phase", payload, file="BENCH_autotune.json")
    return payload

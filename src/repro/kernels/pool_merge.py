"""Pallas TPU kernel: sorted-pool merge via an in-VMEM bitonic network.

The second hot spot of best-first search: merging M freshly-computed
candidate distances into the sorted size-P result pool each hop.  XLA lowers
the naive concat+argsort to a full sort; here the merge is a fixed
compare-exchange network over a power-of-two padded buffer held in VREGs —
data-independent control flow, exactly what the VPU wants.

Payload trick: ids ride along as the low 32 bits of a float64-free packing —
we sort a single int32 "key" tensor built as (quantized dist, id) pairs?  No:
Pallas TPU has no 64-bit sort lanes; instead we run the compare-exchange on
the distance tensor and apply identical where-swaps to the id tensor.

Grid: one program per batch row block (bb rows), network length L = pow2(P+M).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _next_pow2(x: int) -> int:
    p = 1
    while p < x:
        p *= 2
    return p


def _bitonic_stages(L: int):
    """Yield (stride, block) pairs of a full bitonic sort network of length L."""
    k = 2
    while k <= L:
        j = k // 2
        while j >= 1:
            yield j, k
            j //= 2
        k *= 2


def _merge_kernel(pool_d_ref, pool_i_ref, new_d_ref, new_i_ref,
                  out_d_ref, out_i_ref, *, L: int, P: int):
    d = jnp.concatenate([pool_d_ref[...], new_d_ref[...]], axis=1)  # [bb, P+M]
    i = jnp.concatenate([pool_i_ref[...], new_i_ref[...]], axis=1)
    pad = L - d.shape[1]
    if pad:
        # network pad must sort AFTER every real input under the (dist, id)
        # tie-break, or +inf pool sentinels get displaced by fake entries —
        # so pad ids with int32 max, not -1
        d = jnp.concatenate([d, jnp.full((d.shape[0], pad), jnp.inf, d.dtype)], axis=1)
        i = jnp.concatenate([i, jnp.full((i.shape[0], pad),
                                         jnp.iinfo(jnp.int32).max, i.dtype)], axis=1)
    bb = d.shape[0]
    # Gather-free butterfly: lane l = block*2j + half*j + r pairs with l^j,
    # i.e. the two halves of each reshaped [.., 2, j] group.  Static reshapes
    # + selects only — XLA's compile time stays linear in the stage count
    # (take_along_axis-based exchanges blow up superlinearly on this path),
    # and on real TPU the strided selects map onto VPU shuffles.
    for j, k in _bitonic_stages(L):
        nb = L // (2 * j)
        d4 = d.reshape(bb, nb, 2, j)
        i4 = i.reshape(bb, nb, 2, j)
        a_d, b_d = d4[:, :, 0, :], d4[:, :, 1, :]
        a_i, b_i = i4[:, :, 0, :], i4[:, :, 1, :]
        # ascending block?  bit k of the lane index is constant per 2j-group
        base = jax.lax.broadcasted_iota(jnp.int32, (1, nb, 1), 1) * (2 * j)
        up = (base & k) == 0
        # lexicographic (dist, id): ties resolve to the smaller id
        a_min = (a_d < b_d) | ((a_d == b_d) & (a_i <= b_i))
        mn_d, mx_d = jnp.where(a_min, a_d, b_d), jnp.where(a_min, b_d, a_d)
        mn_i, mx_i = jnp.where(a_min, a_i, b_i), jnp.where(a_min, b_i, a_i)
        d = jnp.stack([jnp.where(up, mn_d, mx_d),
                       jnp.where(up, mx_d, mn_d)], axis=2).reshape(bb, L)
        i = jnp.stack([jnp.where(up, mn_i, mx_i),
                       jnp.where(up, mx_i, mn_i)], axis=2).reshape(bb, L)
    out_d_ref[...] = d[:, :P]
    out_i_ref[...] = i[:, :P]


@functools.partial(jax.jit, static_argnames=("bb", "interpret"))
def pool_merge_pallas(pool_d, pool_i, new_d, new_i, *, bb: int = 8,
                      interpret: bool = True):
    """pool_d/i [B, P] sorted asc, new_d/i [B, M] -> best-P of the union, sorted.

    Ties on distance resolve to the smaller id (deterministic).
    """
    B, P = pool_d.shape
    M = new_d.shape[1]
    bb = min(bb, B)
    assert B % bb == 0
    L = _next_pow2(P + M)
    grid = (B // bb,)
    return pl.pallas_call(
        functools.partial(_merge_kernel, L=L, P=P),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, P), lambda r: (r, 0)),
            pl.BlockSpec((bb, P), lambda r: (r, 0)),
            pl.BlockSpec((bb, M), lambda r: (r, 0)),
            pl.BlockSpec((bb, M), lambda r: (r, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bb, P), lambda r: (r, 0)),
            pl.BlockSpec((bb, P), lambda r: (r, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, P), pool_d.dtype),
            jax.ShapeDtypeStruct((B, P), pool_i.dtype),
        ],
        interpret=interpret,
    )(pool_d, pool_i, new_d, new_i)

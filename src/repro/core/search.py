"""Batched beam-expansion graph search in JAX (the TPU-native serving hot path).

Re-derivation of the paper's Algorithm 1/2 for fixed-shape SPMD execution
(DESIGN.md §3), restructured as a BATCH-LEVEL loop with per-hop beams:

* the candidate queue C and result queue T collapse into ONE sorted pool of
  size ``efs`` with per-slot expanded flags — provably equivalent to the
  two-heap formulation for expansion/termination decisions;
* per-node state is a dense uint8 status array (0 unvisited / 1 visited /
  2 pruned) — the pruned state doubles as CRouting's error-correction flag;
* ONE ``lax.while_loop`` drives the whole query batch: each iteration picks
  the best W (= ``SearchSpec.beam_width``) unexpanded pool entries per
  query and expands them together, producing a dense ``[B, W*M]`` neighbor
  tile.  Estimate + prune runs on the VPU path, exact distances on the
  MXU/DMA path, pool maintenance as one merge — and the fixed per-hop cost
  (candidate select, status scatter, loop overhead) is amortized ~W×.
* routing (which lanes skip their exact distance) is pluggable: the
  ``SearchSpec.router`` name resolves through the registry in
  ``repro.core.routers``, and the engine consumes the router's declared
  flags + ``estimate_rank`` hook instead of branching on strings.
* ``SearchSpec.engine`` dispatches the tile work:
    - ``"jnp"``     — pure-jnp reference semantics (the oracle path);
    - ``"pallas"``  — ``ops.fused_expand`` (estimate + prune + conditional
      row DMA + exact distance in one kernel) and the bitonic
      ``ops.pool_merge`` network in place of concat+argsort;
    - ``"pallas_unfused"`` — ``ops.crouting_prune`` + masked
      ``ops.gather_distance_pruned`` + ``ops.pool_merge`` (the composable
      kernel pipeline; slower in interpret mode, kept for kernel-level
      attribution).

Pad-row sentinel convention (repo-wide): ``graph_device_arrays`` appends one
zero vector at row index N; every masked/pruned/out-of-range lane gathers
that row (``ops.gather_distance_pruned`` remaps to the table's last row).
Pool slots holding no candidate carry id N and distance +inf.

Two-stage quantized distances (``SearchSpec.estimate``, PAPERS.md: VSAG /
Probabilistic Routing): with ``estimate="sq8"`` or ``"both"`` the surviving
lanes of a tile do NOT fetch fp32 rows.  Stage 1 reads the uint8 SQ8 code
row (4x fewer bytes, kernels/sq8_distance.py) and computes an approximate
distance plus a conservative lower bound (repro/quant/sq8.py); a lane whose
lower bound already exceeds the pool bound is discarded (status PRUNED)
without ever touching the fp32 table.  Survivors enter the pool with their
approximate distance and a per-slot ``approx`` flag; stage 2 (the fp32 row
DMA + exact distance) runs lazily — when an approx entry is selected for
beam expansion, and for every approx entry left in the pool at return — so
candidates displaced from the pool before either event never pay the fp32
fetch.  ``SearchResult.rerank_calls`` counts stage-2 evaluations,
``sq8_calls`` stage-1 evaluations.

Semantic notes (tested in tests/test_engine_equivalence.py):

* Frozen bound: within one iteration all W*M lanes are evaluated against the
  *iteration-start* upper bound, whereas the scalar Algorithm 1 updates the
  bound after every insertion.  At W=1 the final pool per expansion is
  identical either way (merge-then-truncate == insert-with-evolving-bound);
  only CRouting prune decisions can differ, strictly toward *fewer* prunes
  (frozen bound >= evolving bound), i.e. toward accuracy.  The NumPy oracle
  exposes ``stale_bound=True`` to check exact equivalence.
* Beam semantics (W>1): the W expansion nodes are the W best unexpanded pool
  entries whose distance beats the frozen bound; each distinct neighbor id
  is processed at most once per tile (first-occurrence dedup).  This trades
  a few extra expansions (the 2nd..Wth choices may be refuted by the 1st's
  results) for ~W× fewer loop iterations — recall at equal efs is no worse,
  dist_calls grow mildly; see benchmarks/bench_engine.py for the sweep.
"""
from __future__ import annotations

import weakref
from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import GraphIndex
from repro.core.routers import RouterContext, get_router
from repro.core.spec import ENGINES, ESTIMATES, SearchSpec

STATUS_UNVISITED = 0
STATUS_VISITED = 1
STATUS_PRUNED = 2


class SearchResult(NamedTuple):
    ids: jax.Array        # [B, efs] int32, N = empty
    dists: jax.Array      # [B, efs] ranking distance
    dist_calls: jax.Array  # [B] int32 exact distance evaluations
    est_calls: jax.Array   # [B] int32 router estimates evaluated
    hops: jax.Array        # [B] int32 node expansions
    iters: jax.Array       # [] int32 batch-level hop-loop iterations
    rerank_calls: jax.Array  # [B] int32 stage-2 exact reranks (sq8 path)
    sq8_calls: jax.Array     # [B] int32 stage-1 quantized estimates
    # per-router counters ([B] int32 each), keys = Router.extra_counters.
    # None (not {}: NamedTuple defaults are class-level, a dict would be
    # shared mutable state) when constructed without one; the engine always
    # passes a real dict.
    extra: Optional[Dict[str, jax.Array]] = None


def graph_device_arrays(g: GraphIndex, with_sq8: bool = False) -> Dict[str, Any]:
    """Pack a GraphIndex into device arrays with a sentinel pad row at index N.

    Pad-row convention: row N of ``vectors`` (an all-zero vector, norm slot 1)
    is THE sentinel every masked lane resolves to — adjacency pad slots point
    at it, dead beam slots expand it (its neighbor list is all-pad), and the
    Pallas gather kernels remap pruned lanes to it so the skipped DMA is
    de-duplicated.  Pool slots holding no candidate carry id N.

    ``with_sq8`` adds the quantized companion tables (same pad-row
    convention: row N encodes the zero vector).  The default path skips them
    — exact-only configs shouldn't pay the encode pass or the +25% device
    memory; ``build_search_fn`` upgrades the cached dict in place the first
    time an sq8/both config asks (``ensure_sq8_arrays``).
    """
    n, d = g.n, g.dim
    vecs = np.concatenate([g.vectors, np.zeros((1, d), np.float32)], axis=0)
    nbrs = np.concatenate([g.neighbors, np.full((1, g.max_degree), n, np.int32)], axis=0)
    ed = np.concatenate([g.edge_eu_dist, np.full((1, g.max_degree), np.inf, np.float32)], axis=0)
    norms = g.norms if g.norms is not None else np.linalg.norm(g.vectors, axis=1)
    norms = np.concatenate([norms.astype(np.float32), np.ones(1, np.float32)])
    out = {
        "vectors": jnp.asarray(vecs),
        "neighbors": jnp.asarray(nbrs),
        "edge_eu": jnp.asarray(ed),
        "norms": jnp.asarray(norms),
        "entry": jnp.asarray(g.entry_point, jnp.int32),
        "n": n,
    }
    if with_sq8:
        ensure_sq8_arrays(g, out)
    # HNSW hierarchy: id->row maps + per-layer adjacency (top..1).
    if g.upper_neighbors:
        pos_maps, layer_nbrs = [], []
        for ids, mat in zip(g.upper_ids, g.upper_neighbors):
            pos = np.full(n + 1, -1, dtype=np.int32)
            pos[ids] = np.arange(len(ids), dtype=np.int32)
            pos_maps.append(jnp.asarray(pos))
            layer_nbrs.append(jnp.asarray(np.concatenate(
                [mat, np.full((1, mat.shape[1]), n, np.int32)], axis=0)))
        out["upper_pos"] = pos_maps
        out["upper_nbrs"] = layer_nbrs
    return out


def ensure_sq8_arrays(g: GraphIndex, arrays: Dict[str, Any]) -> Dict[str, Any]:
    """Add the SQ8 companion tables to a packed arrays dict (idempotent).

    Grid fit on the real rows; the pad row encodes the zero vector with the
    same params (its distances are always masked out)."""
    if "sq8_codes" not in arrays:
        from repro.quant import sq8 as SQ

        qp = SQ.sq8_train(g.vectors)
        vecs = np.concatenate(
            [g.vectors, np.zeros((1, g.dim), np.float32)], axis=0)
        arrays["sq8_codes"] = jnp.asarray(SQ.sq8_encode(vecs, qp))
        arrays["sq8_lo"] = jnp.asarray(qp.lo)
        arrays["sq8_scale"] = jnp.asarray(qp.scale)
        arrays["sq8_eps"] = jnp.asarray(qp.eps)
    return arrays


def _rank_many(q, X, metric):
    """q [d], X [m, d] -> ranking distances [m]."""
    if metric == "l2":
        diff = X - q[None, :]
        return jnp.sum(diff * diff, axis=-1)
    return 1.0 - X @ q


def _rank_tile(queries, X, metric):
    """queries [B, d], X [B, L, d] -> ranking distances [B, L]."""
    if metric == "l2":
        diff = X - queries[:, None, :]
        return jnp.sum(diff * diff, axis=-1)
    return 1.0 - jnp.einsum("bld,bd->bl", X, queries)


def _rank_to_eu(rank, nq, nx, metric):
    if metric == "l2":
        return jnp.sqrt(jnp.maximum(rank, 0.0))
    return jnp.sqrt(jnp.maximum(nx * nx + nq * nq + 2.0 * rank - 2.0, 0.0))


def _eu2_to_rank(eu2, nq, nx, metric):
    if metric == "l2":
        return eu2
    return (eu2 - nx * nx - nq * nq + 2.0) / 2.0


def _descend(arrays, q, cfg: SearchSpec):
    """Greedy 1-NN descent through HNSW upper layers. Returns (entry, dist_calls)."""
    metric = cfg.metric
    cur = arrays["entry"]
    d_cur = _rank_many(q, arrays["vectors"][cur][None, :], metric)[0]
    calls = jnp.asarray(1, jnp.int32)
    if "upper_nbrs" not in arrays:
        return cur, d_cur, calls
    n = arrays["n"]
    for pos_map, lnbrs in zip(arrays["upper_pos"], arrays["upper_nbrs"]):
        def cond(s):
            cur, d_cur, calls, improved = s
            return improved

        def body(s):
            cur, d_cur, calls, _ = s
            row = pos_map[cur]
            nbrs = lnbrs[jnp.where(row >= 0, row, lnbrs.shape[0] - 1)]
            valid = nbrs < n
            dists = _rank_many(q, arrays["vectors"][nbrs], metric)
            dists = jnp.where(valid, dists, jnp.inf)
            calls = calls + jnp.sum(valid.astype(jnp.int32))
            j = jnp.argmin(dists)
            better = dists[j] < d_cur
            return (jnp.where(better, nbrs[j], cur).astype(jnp.int32),
                    jnp.where(better, dists[j], d_cur), calls, better)

        cur, d_cur, calls, _ = jax.lax.while_loop(
            cond, body, (cur, d_cur, calls, jnp.asarray(True)))
    return cur, d_cur, calls


def _first_occurrence(nbrs, valid, n):
    """Keep only the first valid lane per distinct neighbor id (per row).

    With a beam of W nodes the [B, W*M] tile can name the same neighbor from
    two expansion nodes; sequential Algorithm 1 would visit it once, so the
    tile must too (duplicates would double-count dist_calls and insert the
    id twice into the pool).

    Returns (first_mask, order, sorted_keys); the latter two let
    _rescue_pruned_duplicates reuse the same O(L log L) sort instead of
    re-sorting in the hot loop."""
    key = jnp.where(valid, nbrs, n + 1)
    order = jnp.argsort(key, axis=1, stable=True)
    sk = jnp.take_along_axis(key, order, axis=1)
    dup_sorted = jnp.concatenate(
        [jnp.zeros((nbrs.shape[0], 1), bool), sk[:, 1:] == sk[:, :-1]], axis=1)
    rows = jnp.arange(nbrs.shape[0])[:, None]
    dup = jnp.zeros_like(valid).at[rows, order].set(dup_sorted)
    return valid & ~dup, order, sk


def _rescue_pruned_duplicates(order, sk, prune):
    """Within-tile error correction, tile-local (O(L), reusing the dedup
    sort's (order, sorted_keys)).

    Returns (rescued, prune_final): ``rescued`` marks the SECOND valid lane
    of each id whose first lane was pruned (it must be computed exactly —
    the paper's PRUNED-revisit rule collapsed into one tile);
    ``prune_final`` clears the prune mark for such rescued ids.

    The stable sort by id groups each id's valid lanes in lane order, so the
    group head is the dedup winner (= the only lane ``prune`` can mark) and
    the slot right after it is the rescue candidate."""
    rows = jnp.arange(sk.shape[0])[:, None]
    pr_s = jnp.take_along_axis(prune, order, axis=1)
    pad = jnp.zeros((sk.shape[0], 1), bool)
    same = sk[:, 1:] == sk[:, :-1]
    same_prev = jnp.concatenate([pad, same], axis=1)
    prev_pruned = jnp.concatenate([pad, pr_s[:, :-1]], axis=1)
    rescued_s = same_prev & prev_pruned
    same_next = jnp.concatenate([same, pad], axis=1)
    keep_prune_s = pr_s & ~same_next      # pruned ids with no second lane
    zeros = jnp.zeros_like(prune)
    rescued = zeros.at[rows, order].set(rescued_s)
    prune_final = zeros.at[rows, order].set(keep_prune_s)
    return rescued, prune_final


def _search_batch(arrays, queries, cos_theta, cfg: SearchSpec, valid=None,
                  tombstone=None):
    """Whole-batch Algorithm 1/2 with W-wide beam expansion per iteration.

    Routing is delegated to the registry (``repro.core.routers``): the
    router's flags shape the trace (which lanes are eligible, whether a
    prune is final, whether the Pallas kernels may decide it) and its
    ``estimate_rank`` hook supplies the per-lane estimate when the decision
    is made on the jnp path.

    ``valid`` ([B] bool, optional) marks the real query lanes of a padded
    batch (the serving frontend rounds ragged batches up to a bucket size):
    padded lanes start ``done``, never expand a node, and contribute ZERO to
    every counter — so shard-reduced totals (``ShardedAnnIndex``) stay exact
    under bucket padding.  ``None`` means all lanes are real.

    ``tombstone`` ([n+1] bool device array, optional; pad row MUST be
    False) marks deleted nodes for the live-mutation path
    (``repro.mutate``).  Dead nodes keep routing — they enter the pool,
    get expanded, and their edges stay traversable, exactly as live nodes
    do — but they are masked out of the RESULT pool after the hop loop
    (id -> pad sentinel n, dist -> +inf, then re-sorted), so a deleted id
    can never be emitted.  Tombstones deliberately do not change the
    traversal trace: recall through a sparsely-tombstoned region matches
    the undeleted graph's routing behavior (FreshDiskANN-style filtered
    search).  ``None`` compiles the mask out entirely.
    """
    metric, efs, n = cfg.metric, cfg.efs, arrays["n"]
    W, engine = cfg.beam_width, cfg.engine
    rt = get_router(cfg.router)
    assert engine in ENGINES, f"unknown engine {engine!r}"
    assert cfg.estimate in ESTIMATES, f"unknown estimate {cfg.estimate!r}"
    assert 1 <= W <= efs, "beam_width must be in [1, efs]"
    assert cfg.beam_prune in ("best", "all"), \
        f"unknown beam_prune policy {cfg.beam_prune!r}"
    sq8_on = cfg.estimate in ("sq8", "both")
    if cfg.estimate in ("angle", "both"):
        assert rt.prunes, \
            f"estimate={cfg.estimate!r} needs a pruning router, " \
            f"got {cfg.router!r}"
    # pallas pool_merge rides the (approx, expanded) flags in the id low
    # bits (id*4 + approx*2 + exp)
    assert engine == "jnp" or n < 2 ** 29, \
        "pallas engines encode ids as id*4+flags in int32: shard below 2^29 " \
        "vectors or use engine='jnp'"
    B = queries.shape[0]
    M = arrays["neighbors"].shape[1]
    L = W * M
    rows = jnp.arange(B)
    use_pallas = engine in ("pallas", "pallas_unfused")
    if use_pallas:
        from repro.kernels import ops

    nq = (jnp.linalg.norm(queries, axis=1) if metric != "l2"
          else jnp.ones((B,), jnp.float32))

    def _exact_rerank(ids, mask):
        """Stage-2: masked exact ranking distances for pool entries.  The
        fp32 row DMA happens HERE (and only here) on the sq8 path; masked
        lanes resolve to the pad row / +inf."""
        idx = jnp.where(mask, ids, n).astype(jnp.int32)
        if use_pallas:
            eu2 = ops.gather_distance_pruned(
                idx, (~mask).astype(jnp.int8), queries, arrays["vectors"])
            r = _eu2_to_rank(eu2, nq[:, None], arrays["norms"][idx], metric)
        else:
            r = _rank_tile(queries, arrays["vectors"][idx], metric)
        return jnp.where(mask, r, jnp.inf)

    if cfg.use_hierarchy:
        entry, d_entry, calls0 = jax.vmap(
            lambda q: _descend(arrays, q, cfg))(queries)
    else:
        entry = jnp.broadcast_to(arrays["entry"], (B,)).astype(jnp.int32)
        ev = jnp.broadcast_to(arrays["vectors"][arrays["entry"]],
                              (B, queries.shape[1]))
        d_entry = _rank_tile(queries, ev[:, None, :], metric)[:, 0]
        calls0 = jnp.ones((B,), jnp.int32)

    if valid is None:
        done0 = jnp.zeros((B,), bool)
    else:
        # padded lanes are born done: zero hops, zero counters (the entry
        # distance above is masked out of calls0 too)
        done0 = ~valid
        calls0 = jnp.where(valid, calls0, 0)

    pool_d = jnp.full((B, efs), jnp.inf, jnp.float32).at[:, 0].set(d_entry)
    pool_id = jnp.full((B, efs), n, jnp.int32).at[:, 0].set(entry)
    pool_exp = jnp.zeros((B, efs), bool)
    pool_apx = jnp.zeros((B, efs), bool)   # slot holds a stage-1 estimate
    status = jnp.zeros((B, n + 1), jnp.uint8).at[rows, entry].set(STATUS_VISITED)

    State = (pool_d, pool_id, pool_exp, pool_apx, status, calls0,
             jnp.zeros((B,), jnp.int32),   # est_calls
             jnp.zeros((B,), jnp.int32),   # rerank_calls
             jnp.zeros((B,), jnp.int32),   # sq8_calls
             # per-router counters (registry-declared, see Router.extra_counters)
             {name: jnp.zeros((B,), jnp.int32) for name in rt.extra_counters},
             jnp.zeros((B,), jnp.int32),   # hops
             done0,                        # done (padded lanes born done)
             jnp.asarray(0, jnp.int32))    # iters

    def cond(s):
        *_, done, iters = s
        return jnp.any(~done) & (iters < cfg.max_hops)

    def body(s):
        (pool_d, pool_id, pool_exp, pool_apx, status, dcalls, ecalls,
         rrcalls, sqcalls, extras, hops, done, iters) = s

        # --- beam selection: best W unexpanded pool entries per query ------
        cand = (~pool_exp) & (pool_id < n)
        cand_d = jnp.where(cand, pool_d, jnp.inf)
        neg_top, beam_idx = jax.lax.top_k(-cand_d, W)          # [B, W]
        beam_d = -neg_top
        pool_full = pool_id[:, efs - 1] < n
        upper = jnp.where(pool_full, pool_d[:, efs - 1], jnp.inf)  # [B]
        active = (~done) & (hops < cfg.max_hops)
        slot_live = jnp.isfinite(beam_d) & (beam_d <= upper[:, None]) \
            & active[:, None]                                   # [B, W]
        # keep the per-query hop budget exact: only the first
        # (max_hops - hops) live slots may expand this iteration
        budget = cfg.max_hops - hops                            # [B]
        slot_live = slot_live & (jnp.cumsum(slot_live, axis=1)
                                 <= budget[:, None])
        done = done | ~jnp.any(slot_live, axis=1)

        c = jnp.where(slot_live,
                      jnp.take_along_axis(pool_id, beam_idx, axis=1),
                      n).astype(jnp.int32)                      # [B, W]
        dc = jnp.take_along_axis(pool_d, beam_idx, axis=1)      # [B, W]
        if sq8_on:
            # stage-2 rerank at expansion: an approx entry selected for the
            # beam gets its exact distance (and its flag cleared) before its
            # stored distance is used as d(c, q) for the tile's estimates
            sel_apx = jnp.take_along_axis(pool_apx, beam_idx, axis=1) \
                & slot_live
            dc = jnp.where(sel_apx, _exact_rerank(c, sel_apx), dc)
            pool_d = pool_d.at[rows[:, None], beam_idx].set(dc)
            pool_apx = pool_apx.at[rows[:, None], beam_idx].set(
                jnp.take_along_axis(pool_apx, beam_idx, axis=1) & ~sel_apx)
            nrr = jnp.sum(sel_apx, axis=1, dtype=jnp.int32)
            rrcalls = rrcalls + nrr
            dcalls = dcalls + nrr
        pool_exp = pool_exp.at[rows[:, None], beam_idx].set(
            jnp.take_along_axis(pool_exp, beam_idx, axis=1) | slot_live)

        # --- dense [B, W*M] neighbor tile ----------------------------------
        nbrs = arrays["neighbors"][c].reshape(B, L)             # [B, L]
        # stored edge distances may be bf16 (§Perf HC3); estimate math in f32
        ed = arrays["edge_eu"][c].astype(jnp.float32).reshape(B, L)
        st = jnp.take_along_axis(status, nbrs, axis=1)          # [B, L]
        in_range = nbrs < n
        lane_live = jnp.broadcast_to(slot_live[:, :, None],
                                     (B, W, M)).reshape(B, L)
        valid = in_range & (st != STATUS_VISITED) & lane_live
        if not rt.revisit_pruned:
            # no error correction (crouting_o): pruned lanes stay skipped
            valid = valid & (st != STATUS_PRUNED)
        if W > 1:
            first, dd_order, dd_keys = _first_occurrence(nbrs, valid, n)
        else:
            first = valid

        norms_c = arrays["norms"][c]                            # [B, W]
        dcq_eu = _rank_to_eu(dc, nq[:, None], norms_c, metric)  # [B, W]
        dcq_l = jnp.broadcast_to(dcq_eu[:, :, None], (B, W, M)).reshape(B, L)
        nx = arrays["norms"][nbrs]                              # [B, L]

        if metric == "l2":
            bound2 = jnp.broadcast_to(upper[:, None], (B, L))
        else:
            # est_rank >= upper  <=>  est2 >= inverse rank->eu^2 per lane
            bound2 = 2.0 * upper[:, None] + nx * nx \
                + (nq * nq)[:, None] - 2.0

        # --- router: estimate + prune (no neighbor-vector fetch here).
        # Edge-angle routers (Router.kernel_estimate) may have the decision
        # taken inside the Pallas kernels: the fused engine inside
        # fused_expand (est + prune + conditional DMA in one kernel), the
        # unfused engine in the crouting_prune kernel; otherwise the
        # router's estimate_rank hook runs on the jnp path.  All paths
        # evaluate the identical f32 expression for the edge-angle family,
        # so the decisions are bit-equal for l2.  The beam rescue path
        # (W>1, error-correcting router) must know prune BEFORE the fetch
        # set exists, so there the hook decides and the fused kernel's
        # eligible set is empty (its DMA skip still comes from eval_mask). -
        prunes = rt.prunes
        ct_eff = rt.cos_theta_eff(cos_theta)
        rescue = W > 1 and prunes and rt.revisit_pruned and not rt.permanent
        # with sq8 the fused fp32 kernel never runs, so the prune decision
        # is made outside it (jnp / crouting_prune — the same f32 math)
        kernel_prunes = engine == "pallas" and rt.kernel_estimate \
            and not rescue and not sq8_on
        if prunes:
            try_prune = first & (st == STATUS_UNVISITED) & pool_full[:, None]
            if W > 1 and cfg.beam_prune == "best":
                # top_k orders slots by distance, so slot 0 = the node
                # sequential search would be expanding right now; only its
                # lanes run the estimate test (see SearchSpec.beam_prune)
                try_prune = try_prune & (jnp.arange(L) < M)[None, :]
            if rt.counts_est:
                ecalls = ecalls + jnp.sum(try_prune, axis=1, dtype=jnp.int32)
        else:
            try_prune = jnp.zeros_like(first)

        if not prunes or kernel_prunes:
            prune = jnp.zeros_like(first)
        elif engine == "pallas_unfused" and rt.kernel_estimate:
            _, prune8 = ops.crouting_prune(ed, dcq_l, bound2, try_prune,
                                           ct_eff)
            prune = prune8 != 0
        else:
            ctx = RouterContext(
                arrays=arrays, queries=queries, nq=nq, c=c, dc=dc, nbrs=nbrs,
                ed=ed, dcq=dcq_l, nx=nx, try_prune=try_prune, upper=upper,
                cos_theta=cos_theta, metric=metric, n=n, beam_width=W,
                max_degree=M)
            est_rank, extra_inc = rt.estimate_rank(ctx)
            prune = try_prune & (est_rank >= upper[:, None])
            # repolint: ignore[trace-safety] extra_inc is a host dict of
            # counter names (Router.extra_counters), not a tracer — its
            # truthiness is concrete during tracing
            extras = {key: extras[key] + extra_inc.get(key, 0)
                      for key in extras} if extra_inc else extras

        if rescue:
            # Within-tile error correction (paper Alg. 2): sequentially, the
            # second encounter of a just-pruned node recomputes it exactly
            # (status PRUNED exempts it from re-estimation).  Collapsed into
            # the tile: a second valid lane of a pruned id computes, and the
            # id is then VISITED, not PRUNED.  Without this, beam dedup
            # silently disables error correction and recall drops.
            rescued, prune_kept = _rescue_pruned_duplicates(dd_order, dd_keys,
                                                            prune)
            compute = (first & ~prune) | rescued
            prune = prune_kept    # rescued ids end VISITED, not PRUNED
        else:
            compute = first & ~prune

        # --- distances: stage-1 quantized estimate (sq8) or exact fp32 ------
        if sq8_on:
            # stage 1: uint8 code-row gather + dequantized accumulate +
            # conservative lower bound for EVERY surviving lane — no fp32
            # row DMA on this path (that is stage 2's job, in _exact_rerank)
            if use_pallas:
                ad2, lb2 = ops.sq8_estimate(
                    nbrs, queries, compute, arrays["sq8_codes"],
                    arrays["sq8_lo"], arrays["sq8_scale"], arrays["sq8_eps"])
            else:
                from repro.quant.sq8 import sq8_dequantize_rows, sq8_estimate
                xhat = sq8_dequantize_rows(
                    arrays["sq8_codes"][jnp.where(compute, nbrs, n)],
                    arrays["sq8_lo"], arrays["sq8_scale"])
                ad2, lb2 = sq8_estimate(queries, xhat, arrays["sq8_eps"])
                ad2 = jnp.where(compute, ad2, jnp.inf)
                lb2 = jnp.where(compute, lb2, jnp.inf)
            ad_rank = _eu2_to_rank(ad2, nq[:, None], nx, metric)
            lb_rank = _eu2_to_rank(lb2, nq[:, None], nx, metric)
            # a lane whose true distance provably cannot beat the pool bound
            # is discarded without its fp32 row; PRUNED (not VISITED) so a
            # later encounter may re-estimate it against a tighter bound
            sq8_skip = compute & pool_full[:, None] \
                & (lb_rank >= upper[:, None])
            insert = compute & ~sq8_skip
            sqcalls = sqcalls + jnp.sum(compute, axis=1, dtype=jnp.int32)
            new_d = jnp.where(insert, ad_rank, jnp.inf)
        else:
            # exact fp32 distances (masked; non-compute lanes skip the HBM
            # row fetch on real TPU)
            if engine == "pallas":
                d2eu, prune8 = ops.fused_expand(
                    nbrs, queries, ed, dcq_l, bound2, ct_eff,
                    arrays["vectors"], eval_mask=compute,
                    prune_eligible=try_prune if kernel_prunes
                    else jnp.zeros_like(try_prune))
                if kernel_prunes:
                    # the kernel both made the prune decision and skipped
                    # those lanes' DMAs (eval ∩ eligible lanes fetch only if
                    # unpruned)
                    prune = prune8 != 0
                    compute = compute & ~prune
                exact = _eu2_to_rank(d2eu, nq[:, None], nx, metric)
            elif engine == "pallas_unfused":
                d2eu = ops.gather_distance_pruned(
                    jnp.where(compute, nbrs, n), (~compute).astype(jnp.int8),
                    queries, arrays["vectors"])
                exact = _eu2_to_rank(d2eu, nq[:, None], nx, metric)
            else:
                gathered = arrays["vectors"][jnp.where(compute, nbrs, n)]
                exact = _rank_tile(queries, gathered, metric)
            insert = compute
            new_d = jnp.where(compute, exact, jnp.inf)
            dcalls = dcalls + jnp.sum(compute, axis=1, dtype=jnp.int32)

        # --- status scatter: only lanes whose status changes write; all
        # other lanes are redirected to the pad column (same-value writes,
        # so the scatter stays deterministic) -------------------------------
        change = compute | prune
        if rt.permanent:
            # exact/trusted bound => discard is permanent (mark visited)
            new_st = jnp.full_like(st, STATUS_VISITED)
        else:
            new_st = jnp.where(insert, STATUS_VISITED, STATUS_PRUNED
                               ).astype(jnp.uint8)
        pad_val = status[:, n][:, None]
        status = status.at[rows[:, None], jnp.where(change, nbrs, n)].set(
            jnp.where(change, new_st, pad_val))

        # --- pool merge (merge-then-truncate == evolving-bound insertion) ---
        new_id = jnp.where(insert, nbrs, n).astype(jnp.int32)
        new_apx = insert if sq8_on else jnp.zeros_like(insert)
        if use_pallas:
            # approx + expanded flags ride the bitonic network in the id
            # low bits
            enc_pool = pool_id * 4 + pool_apx.astype(jnp.int32) * 2 \
                + pool_exp.astype(jnp.int32)
            enc_new = new_id * 4 + new_apx.astype(jnp.int32) * 2
            pool_d, enc = ops.pool_merge(pool_d, enc_pool, new_d, enc_new)
            pool_id = enc // 4
            pool_apx = (enc & 2) == 2
            pool_exp = (enc & 1) == 1
        else:
            md = jnp.concatenate([pool_d, new_d], axis=1)
            mi = jnp.concatenate([pool_id, new_id], axis=1)
            me = jnp.concatenate([pool_exp, jnp.zeros_like(insert)], axis=1)
            ma = jnp.concatenate([pool_apx, new_apx], axis=1)
            # lexicographic (dist, id) — the SAME tie-break as the pallas
            # pool_merge network, so the engines agree even on exact ties
            order = jnp.lexsort((mi, md), axis=1)[:, :efs]
            pool_d = jnp.take_along_axis(md, order, axis=1)
            pool_id = jnp.take_along_axis(mi, order, axis=1)
            pool_exp = jnp.take_along_axis(me, order, axis=1)
            pool_apx = jnp.take_along_axis(ma, order, axis=1)

        hops = hops + jnp.sum(slot_live, axis=1, dtype=jnp.int32)
        return (pool_d, pool_id, pool_exp, pool_apx, status, dcalls, ecalls,
                rrcalls, sqcalls, extras, hops, done, iters + 1)

    (pool_d, pool_id, pool_exp, pool_apx, status, dcalls, ecalls, rrcalls,
     sqcalls, extras, hops, done, iters) = jax.lax.while_loop(cond, body,
                                                              State)
    if tombstone is not None:
        # emission-time masking: dead entries routed normally through the
        # loop above; here they collapse to the pad sentinel so neither the
        # sq8 final rerank nor the caller ever sees them
        dead = tombstone[pool_id]          # pool_id in [0..n]; row n is False
        pool_d = jnp.where(dead, jnp.inf, pool_d)
        pool_id = jnp.where(dead, n, pool_id)
    if sq8_on:
        # stage-2 final rerank: every approx survivor still in the pool gets
        # its exact distance before results can be returned; entries
        # displaced earlier never paid their fp32 fetch
        mask = pool_apx & (pool_id < n)
        pool_d = jnp.where(mask, _exact_rerank(pool_id, mask), pool_d)
        nrr = jnp.sum(mask, axis=1, dtype=jnp.int32)
        rrcalls = rrcalls + nrr
        dcalls = dcalls + nrr
        order = jnp.lexsort((pool_id, pool_d), axis=1)
        pool_d = jnp.take_along_axis(pool_d, order, axis=1)
        pool_id = jnp.take_along_axis(pool_id, order, axis=1)
    elif tombstone is not None:
        # the sq8 branch above already re-sorted; the exact path must push
        # the newly-masked dead slots behind the survivors itself
        order = jnp.lexsort((pool_id, pool_d), axis=1)
        pool_d = jnp.take_along_axis(pool_d, order, axis=1)
        pool_id = jnp.take_along_axis(pool_id, order, axis=1)
    if valid is not None:
        # belt and braces: padded lanes never ran, but the counters must be
        # provably zero whatever path produced them (they feed shard psums)
        dcalls, ecalls, rrcalls, sqcalls, hops = (
            jnp.where(valid, a, 0)
            for a in (dcalls, ecalls, rrcalls, sqcalls, hops))
        extras = {k: jnp.where(valid, v, 0) for k, v in extras.items()}
    return SearchResult(ids=pool_id, dists=pool_d, dist_calls=dcalls,
                        est_calls=ecalls, hops=hops, iters=iters,
                        rerank_calls=rrcalls, sq8_calls=sqcalls, extra=extras)


# --- compiled-engine cache ---------------------------------------------------
# search_batch used to re-trace + re-jit on every call; repeated batches (the
# examples/serve_anns.py serving path, NSG construction) now hit a small
# keyed cache of compiled executables.  Device arrays are cached per GRAPH
# (one copy shared by every config sweeping that graph); jitted fns per
# (graph identity, cfg).  Weakrefs guard against id() reuse after gc, and
# dead-graph entries are purged on every call so their device buffers don't
# stay pinned.
_ARRAYS_CACHE: "dict[int, tuple]" = {}
_ENGINE_CACHE: "dict[tuple, tuple]" = {}
_ENGINE_CACHE_MAX = 16


def _purge_dead_cache_entries():
    """Drop every cache entry tied to a collected graph.

    The compiled-fn cache needs BOTH checks: its own weakref, and that the
    graph id its key references still names a live arrays-cache entry — a
    stale (graph_id, cfg) entry would otherwise keep the fp32 + SQ8 device
    tables pinned (the jitted fn closes over them) long after the index is
    gone and its id has been reused (regression-tested in
    tests/test_engine_equivalence.py::test_engine_cache_does_not_grow...).
    """
    for k in [k for k, v in _ARRAYS_CACHE.items() if v[0]() is None]:
        del _ARRAYS_CACHE[k]
    for k in [k for k, v in _ENGINE_CACHE.items()
              if v[0]() is None or k[0] not in _ARRAYS_CACHE]:
        del _ENGINE_CACHE[k]


def _graph_arrays_cached(g: GraphIndex):
    hit = _ARRAYS_CACHE.get(id(g))
    if hit is not None and hit[0]() is g:
        return hit[1]
    arrays = graph_device_arrays(g)
    _ARRAYS_CACHE[id(g)] = (weakref.ref(g), arrays)
    return arrays


def build_search_fn(g: GraphIndex, cfg: SearchSpec, tombstones: bool = False):
    """Returns (arrays, jitted fn) for searching ``g`` under ``cfg``.

    The fn signature depends on ``tombstones``: the default is
    ``fn(queries [B,d], cos_theta) -> SearchResult``; with
    ``tombstones=True`` (the live-mutation path, ``repro.mutate``) it is
    ``fn(queries, cos_theta, tombstone [n+1] bool)`` — the mask is a traced
    argument, so flipping tombstones on/off per delete never re-jits.

    Cached per (graph identity, canonical spec, router instance,
    tombstones): calling twice with the same live graph and an equal spec
    returns the SAME jitted callable, so repeated search_batch calls reuse
    the compiled executable instead of re-tracing.  ``SearchSpec.k``/
    ``cos_theta`` are stripped from the key — they do not shape the trace.
    The resolved Router is part of the key because the jitted fn bakes its
    hooks in: re-registering a different router under the same name must
    miss.
    """
    _purge_dead_cache_entries()
    cfg = cfg.canonical()
    rt = get_router(cfg.router)
    key = (id(g), cfg, rt, tombstones)
    hit = _ENGINE_CACHE.get(key)
    if hit is not None:
        ref, arrays, fn = hit
        if ref() is g:
            return arrays, fn
        del _ENGINE_CACHE[key]

    arrays = _graph_arrays_cached(g)
    if cfg.estimate in ("sq8", "both"):
        # lazily upgrade the (shared) cached dict: exact-only graphs never
        # pay the encode pass or the extra device tables
        ensure_sq8_arrays(g, arrays)
    # router companion tables (e.g. finger signatures) upgrade it the same
    # lazy way the first time the router is configured for this graph
    rt.prepare(g, arrays)

    if tombstones:
        @jax.jit
        def run(queries, cos_theta, tombstone):
            queries = queries.astype(jnp.float32)
            return _search_batch(arrays, queries, cos_theta, cfg,
                                 tombstone=tombstone)
    else:
        @jax.jit
        def run(queries, cos_theta):
            queries = queries.astype(jnp.float32)
            return _search_batch(arrays, queries, cos_theta, cfg)

    while len(_ENGINE_CACHE) >= _ENGINE_CACHE_MAX:
        _ENGINE_CACHE.pop(next(iter(_ENGINE_CACHE)))
    _ENGINE_CACHE[key] = (weakref.ref(g), arrays, run)
    return arrays, run


def search_batch(g: GraphIndex, queries: np.ndarray, cfg: SearchSpec,
                 cos_theta: float = 0.0, k: Optional[int] = None) -> SearchResult:
    """Convenience one-shot batched search (compiled fn cached per (graph, cfg))."""
    _, fn = build_search_fn(g, cfg)
    res = fn(jnp.asarray(queries), jnp.asarray(cos_theta, jnp.float32))
    if k is not None:
        res = res._replace(ids=res.ids[:, :k], dists=res.dists[:, :k])
    return res

"""repolint core: findings, parsed sources, suppressions, checker registry.

A *checker* is a function ``(project: Project) -> Iterable[Finding]``
registered under a stable id.  The runner parses every target file once
(AST + per-line comments via ``tokenize``), hands the whole ``Project`` to
each checker, then applies the suppression rules to the combined finding
list — checkers never need to know about ``# repolint: ignore``.

Suppression grammar (DESIGN.md §13)::

    # repolint: ignore[checker-id] one-line justification
    # repolint: ignore[id-a,id-b] shared justification

A suppression silences findings of the named checker(s) on its own line,
or — when the comment stands alone — on the next non-comment line.  A
suppression with an EMPTY justification silences nothing and is itself
reported under the ``suppression`` checker id: the justification is the
reviewable artifact, not the tag.
"""
from __future__ import annotations

import ast
import dataclasses
import io
import os
import re
import tokenize
from typing import Callable, Dict, Iterable, List, Optional, Tuple

SUPPRESS_RE = re.compile(
    r"#\s*repolint:\s*ignore\[([A-Za-z0-9_,\- ]+)\]\s*(.*)$")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One defect: a stable checker id, a location, a claim, a fix hint."""

    checker: str
    path: str            # repo-relative, "/"-separated
    line: int            # 1-based
    message: str
    hint: str = ""

    def text(self) -> str:
        s = f"{self.path}:{self.line}: [{self.checker}] {self.message}"
        if self.hint:
            s += f"\n    hint: {self.hint}"
        return s

    def to_dict(self) -> Dict[str, object]:
        return {"checker": self.checker, "path": self.path,
                "line": self.line, "message": self.message,
                "hint": self.hint}


@dataclasses.dataclass
class Suppression:
    line: int            # line the comment sits on
    checkers: Tuple[str, ...]
    justification: str
    standalone: bool     # comment-only line: applies to the NEXT code line


class SourceFile:
    """One parsed Python file: text, AST, per-line comments, suppressions."""

    def __init__(self, path: str, relpath: str, text: str):
        self.path = path
        self.relpath = relpath.replace(os.sep, "/")
        self.text = text
        self.lines = text.splitlines()
        self.tree: Optional[ast.AST] = None
        self.parse_error: Optional[str] = None
        try:
            self.tree = ast.parse(text, filename=relpath)
        except SyntaxError as e:
            self.parse_error = f"{e.msg} (line {e.lineno})"
        # line -> comment text (with leading '#'), from tokenize so that
        # '#' inside string literals never miscounts as a comment
        self.comments: Dict[int, str] = {}
        self._comment_only: Dict[int, bool] = {}
        try:
            for tok in tokenize.generate_tokens(io.StringIO(text).readline):
                if tok.type == tokenize.COMMENT:
                    ln = tok.start[0]
                    self.comments[ln] = tok.string
                    self._comment_only[ln] = \
                        self.lines[ln - 1].lstrip().startswith("#")
        except tokenize.TokenizeError:
            pass
        self.suppressions: List[Suppression] = []
        for ln, comment in sorted(self.comments.items()):
            m = SUPPRESS_RE.search(comment)
            if m:
                ids = tuple(c.strip() for c in m.group(1).split(",")
                            if c.strip())
                self.suppressions.append(Suppression(
                    line=ln, checkers=ids,
                    justification=m.group(2).strip(),
                    standalone=self._comment_only.get(ln, False)))

    @classmethod
    def load(cls, path: str, root: str) -> "SourceFile":
        with open(path, encoding="utf-8") as f:
            text = f.read()
        return cls(path, os.path.relpath(path, root), text)

    def comment_on(self, line: int) -> str:
        return self.comments.get(line, "")

    def _next_code_line(self, line: int) -> int:
        """First non-blank, non-comment line after ``line``."""
        ln = line + 1
        while ln <= len(self.lines):
            stripped = self.lines[ln - 1].strip()
            if stripped and not stripped.startswith("#"):
                return ln
            ln += 1
        return ln

    def _suppressed_lines(self, checker: str, justified: bool
                          ) -> Iterable[int]:
        for s in self.suppressions:
            if checker not in s.checkers:
                continue
            if bool(s.justification) != justified:
                continue
            # a standalone comment covers the next CODE line (continuation
            # comment lines may wrap the justification); an inline comment
            # covers its own line
            yield self._next_code_line(s.line) if s.standalone else s.line

    def is_suppressed(self, checker: str, line: int) -> bool:
        """Justified suppressions only — bare tags never silence."""
        return line in set(self._suppressed_lines(checker, justified=True))


class Project:
    """Everything one analysis run sees: parsed files + the repo root."""

    def __init__(self, root: str, files: List[SourceFile]):
        self.root = root
        self.files = files
        self.by_relpath = {f.relpath: f for f in files}

    def find(self, suffix: str) -> Optional[SourceFile]:
        """The unique file whose relpath ends with ``suffix`` (or None)."""
        hits = [f for f in self.files if f.relpath.endswith(suffix)]
        return hits[0] if len(hits) == 1 else None

    def read_text(self, relpath: str) -> Optional[str]:
        """Non-Python project file (e.g. DESIGN.md), if present."""
        p = os.path.join(self.root, relpath)
        if not os.path.exists(p):
            return None
        with open(p, encoding="utf-8") as f:
            return f.read()


CheckerFn = Callable[[Project], Iterable[Finding]]

# id -> (fn, one-line description).  Insertion order = report order.
CHECKERS: Dict[str, Tuple[CheckerFn, str]] = {}


def register_checker(checker_id: str, description: str
                     ) -> Callable[[CheckerFn], CheckerFn]:
    """Decorator: add a checker to the registry under a stable id."""

    def deco(fn: CheckerFn) -> CheckerFn:
        if checker_id in CHECKERS:
            raise ValueError(f"duplicate checker id {checker_id!r}")
        CHECKERS[checker_id] = (fn, description)
        return fn

    return deco


def apply_suppressions(project: Project, findings: List[Finding]
                       ) -> Tuple[List[Finding], List[Finding]]:
    """Split into (active, suppressed) and report defective suppressions.

    Appends a ``suppression`` finding for every bare (justification-less)
    tag — those silence nothing by design — and for every justified tag
    that matches no finding and no registered checker id (a typo'd id
    would otherwise silently stop guarding anything).
    """
    active: List[Finding] = []
    suppressed: List[Finding] = []
    for fi in findings:
        sf = project.by_relpath.get(fi.path)
        if sf is not None and sf.is_suppressed(fi.checker, fi.line):
            suppressed.append(fi)
        else:
            active.append(fi)
    for sf in project.files:
        for s in sf.suppressions:
            if not s.justification:
                active.append(Finding(
                    checker="suppression", path=sf.relpath, line=s.line,
                    message="suppression without a justification "
                            f"(ignore[{','.join(s.checkers)}]) — bare tags "
                            "silence nothing",
                    hint="append a one-line reason: # repolint: "
                         "ignore[id] <why this is safe>"))
                continue
            unknown = [c for c in s.checkers
                       if c not in CHECKERS and c != "suppression"]
            if unknown:
                active.append(Finding(
                    checker="suppression", path=sf.relpath, line=s.line,
                    message=f"suppression names unknown checker id(s) "
                            f"{', '.join(repr(u) for u in unknown)}",
                    hint="valid ids: " + ", ".join(sorted(CHECKERS))))
    return active, suppressed


# --- shared AST helpers ------------------------------------------------------
def dotted_name(node: ast.AST) -> Optional[str]:
    """``a.b.c`` for Name/Attribute chains, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def const_str(node: ast.AST) -> Optional[str]:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value
    return None

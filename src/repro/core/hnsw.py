"""HNSW graph construction (Malkov & Yashunin) with CRouting bookkeeping.

Construction is the offline path (DESIGN.md §3): sequential inserts with
BLAS-vectorized distance blocks.  Unlike stock hnswlib, the edge distances
computed during construction are *kept* — that is CRouting's only extra index
state (paper §4.1, "Acquisition of additional information").

Parameters follow the paper §5.1 defaults: M (neighbor limit, default 32),
efc (insertion candidate limit, default 256), maxM0 = 2·M at layer 0.
"""
from __future__ import annotations

import heapq
import time
from typing import List, Optional, Tuple

import numpy as np

from repro.core import distances as D
from repro.core.graph import GraphIndex, pad_adjacency


def _rank_block(q: np.ndarray, X: np.ndarray, metric: str) -> np.ndarray:
    if metric == "l2":
        d = X - q[None, :]
        return np.einsum("nd,nd->n", d, d)
    return 1.0 - X @ q


class _HnswBuilder:
    def __init__(self, dim: int, metric: str, m: int, efc: int, seed: int):
        self.dim = dim
        self.metric = metric
        self.m = m
        self.max_m = m
        self.max_m0 = 2 * m
        self.efc = efc
        self.ml = 1.0 / np.log(m)
        self.rng = np.random.default_rng(seed)
        self.vectors: Optional[np.ndarray] = None
        self.n = 0
        # adjacency per level: level -> list over nodes of (ids list, dists list)
        self.adj: List[dict] = []
        self.levels: List[int] = []
        self.entry = -1
        self.top = -1
        self.dist_calls = 0

    # -- distance helpers ----------------------------------------------------
    def _d1(self, q: np.ndarray, i: int) -> float:
        self.dist_calls += 1
        return float(_rank_block(q, self.vectors[i : i + 1], self.metric)[0])

    def _dblock(self, q: np.ndarray, ids: List[int]) -> np.ndarray:
        self.dist_calls += len(ids)
        return _rank_block(q, self.vectors[np.asarray(ids)], self.metric)

    # -- core search over the partial graph ----------------------------------
    def _greedy_level(self, q: np.ndarray, cur: int, d_cur: float, lvl: int):
        improved = True
        while improved:
            improved = False
            ids = self.adj[lvl].get(cur, ([], []))[0]
            if not ids:
                break
            ds = self._dblock(q, ids)
            j = int(np.argmin(ds))
            if ds[j] < d_cur:
                d_cur = float(ds[j])
                cur = ids[j]
                improved = True
        return cur, d_cur

    def _search_layer(self, q: np.ndarray, entry: int, d_entry: float,
                      ef: int, lvl: int) -> List[Tuple[float, int]]:
        visited = {entry}
        C = [(d_entry, entry)]
        T = [(-d_entry, entry)]
        while C:
            dc, c = heapq.heappop(C)
            if dc > -T[0][0] and len(T) >= ef:
                break
            ids = [i for i in self.adj[lvl].get(c, ([], []))[0] if i not in visited]
            if not ids:
                continue
            visited.update(ids)
            ds = self._dblock(q, ids)
            upper = -T[0][0]
            for d, i in zip(ds, ids):
                if d < upper or len(T) < ef:
                    heapq.heappush(C, (float(d), i))
                    heapq.heappush(T, (-float(d), i))
                    if len(T) > ef:
                        heapq.heappop(T)
                    upper = -T[0][0]
        return sorted((-d, i) for d, i in T)

    # -- hnswlib heuristic neighbor selection --------------------------------
    def _select_heuristic(self, cands: List[Tuple[float, int]], m: int):
        """Keep c iff dist(c, q) < dist(c, any already-selected)."""
        selected: List[Tuple[float, int]] = []
        if len(cands) <= m:
            return list(cands)
        cand_ids = np.asarray([i for _, i in cands])
        cvecs = self.vectors[cand_ids]
        # pairwise among candidates, one shot
        pw = D.pairwise_np(cvecs, cvecs, self.metric)
        self.dist_calls += len(cands) * (len(cands) - 1) // 2
        sel_pos: List[int] = []
        for pos, (dq, i) in enumerate(cands):
            if len(sel_pos) >= m:
                break
            if all(pw[pos, sp] > dq for sp in sel_pos):
                selected.append((dq, i))
                sel_pos.append(pos)
        return selected

    def _connect(self, a: int, b: int, dist: float, lvl: int):
        ids, ds = self.adj[lvl].setdefault(a, ([], []))
        ids.append(b)
        ds.append(dist)
        cap = self.max_m0 if lvl == 0 else self.max_m
        if len(ids) > cap:
            cands = sorted(zip(ds, ids))
            kept = self._select_heuristic(cands, cap)
            ids[:], ds[:] = [i for _, i in kept], [d for d, _ in kept]

    # -- insertion ------------------------------------------------------------
    def insert(self, idx: int):
        q = self.vectors[idx]
        l = int(-np.log(max(self.rng.random(), 1e-12)) * self.ml)
        self.levels.append(l)
        while len(self.adj) <= l:
            self.adj.append({})
        if self.entry < 0:
            self.entry, self.top = idx, l
            for lc in range(l + 1):
                self.adj[lc][idx] = ([], [])
            return
        cur = self.entry
        d_cur = self._d1(q, cur)
        for lc in range(self.top, l, -1):
            cur, d_cur = self._greedy_level(q, cur, d_cur, lc)
        for lc in range(min(l, self.top), -1, -1):
            cands = self._search_layer(q, cur, d_cur, self.efc, lc)
            selected = self._select_heuristic(cands, self.m)
            self.adj[lc].setdefault(idx, ([], []))
            for dq, s in selected:
                self._connect(idx, s, dq, lc)
                self._connect(s, idx, dq, lc)
            cur, d_cur = selected[0][1], selected[0][0]
        if l > self.top:
            self.top, self.entry = l, idx


def build_hnsw(
    base: np.ndarray,
    metric: str = "l2",
    m: int = 32,
    efc: int = 256,
    seed: int = 0,
    progress_every: int = 0,
) -> GraphIndex:
    """Build an HNSW index; returns the padded GraphIndex with stored edge dists."""
    base = D.preprocess_vectors(np.ascontiguousarray(base, dtype=np.float32), metric)
    n, dim = base.shape
    b = _HnswBuilder(dim, metric, m, efc, seed)
    b.vectors = base
    b.n = n
    t0 = time.time()
    for i in range(n):
        b.insert(i)
        if progress_every and (i + 1) % progress_every == 0:
            print(f"hnsw insert {i+1}/{n} ({time.time()-t0:.1f}s)")
    build_secs = time.time() - t0

    norms = np.linalg.norm(base, axis=1).astype(np.float32)
    # layer-0 padded adjacency with *Euclidean* stored distances
    adj0 = b.adj[0]
    lists, dlists = [], []
    for i in range(n):
        ids, ds = adj0.get(i, ([], []))
        rank = np.asarray(ds, dtype=np.float32)
        if metric == "l2":
            eu = np.sqrt(np.maximum(rank, 0.0))
        else:
            eu = np.sqrt(np.maximum(norms[i] ** 2 + norms[np.asarray(ids, int)] ** 2
                                    + 2.0 * rank - 2.0, 0.0)) if len(ids) else rank
        lists.append(np.asarray(ids, dtype=np.int64))
        dlists.append(eu)
    nb, ed = pad_adjacency(lists, dlists, n, b.max_m0)

    upper_ids, upper_nbrs = [], []
    for lvl in range(len(b.adj) - 1, 0, -1):
        ids = np.asarray(sorted(b.adj[lvl].keys()), dtype=np.int64)
        mat = np.full((len(ids), b.max_m), n, dtype=np.int32)
        for j, node in enumerate(ids):
            a = b.adj[lvl][node][0][: b.max_m]
            mat[j, : len(a)] = a
        upper_ids.append(ids)
        upper_nbrs.append(mat)

    return GraphIndex(
        vectors=base, neighbors=nb, edge_eu_dist=ed, entry_point=b.entry,
        metric=metric, norms=norms, upper_ids=upper_ids or None,
        upper_neighbors=upper_nbrs or None, kind="hnsw",
        build_stats={"build_secs": build_secs, "dist_calls": b.dist_calls,
                     "m": m, "efc": efc, "levels": len(b.adj)},
    )

"""High-level ANNS index API: build -> profile angles -> search.

This is the user-facing entry point of the CRouting system:

    idx = AnnIndex.build(base, graph="hnsw", metric="l2")
    ids, dists, info = idx.search(queries, k=10, efs=100, router="crouting")

Index persistence is a plain .npz (content-addressed in benchmarks' cache);
a replacement serving node re-pulls only its shard (DESIGN.md §6).
"""
from __future__ import annotations

import dataclasses
import os
from typing import Optional, Tuple

import numpy as np

from repro.core import distances as D
from repro.core.angles import AngleProfile, sample_angle_profile
from repro.core.graph import GraphIndex
from repro.core.hnsw import build_hnsw
from repro.core.nsg import build_nsg
from repro.core.knn_graph import build_knn_graph
from repro.core.search import EngineConfig, SearchResult, build_search_fn

GRAPH_BUILDERS = {"hnsw": build_hnsw, "nsg": build_nsg, "knn": build_knn_graph}


@dataclasses.dataclass
class AnnIndex:
    graph: GraphIndex
    profile: Optional[AngleProfile] = None

    # --- construction --------------------------------------------------------
    @classmethod
    def build(cls, base: np.ndarray, graph: str = "hnsw", metric: str = "l2",
              profile_percentile: float = 90.0, seed: int = 0,
              profile: bool = True, **graph_kw) -> "AnnIndex":
        g = GRAPH_BUILDERS[graph](base, metric=metric, seed=seed, **graph_kw) \
            if graph != "knn" else build_knn_graph(base, metric=metric, **graph_kw)
        prof = sample_angle_profile(g, percentile=profile_percentile, seed=seed) \
            if profile else None
        return cls(graph=g, profile=prof)

    # --- search ---------------------------------------------------------------
    def _engine(self, cfg: EngineConfig):
        # build_search_fn memoizes per (graph identity, cfg) — no local cache
        return build_search_fn(self.graph, cfg)

    def search(self, queries: np.ndarray, k: int = 10, efs: int = 100,
               router: str = "crouting", cos_theta: Optional[float] = None,
               max_hops: int = 4096, beam_width: int = 1,
               engine: str = "jnp", beam_prune: str = "best",
               estimate: str = "exact",
               ) -> Tuple[np.ndarray, np.ndarray, dict]:
        import jax.numpy as jnp

        queries = D.preprocess_vectors(
            np.ascontiguousarray(queries, np.float32), self.graph.metric)
        if cos_theta is None:
            cos_theta = self.profile.cos_theta_star if self.profile else 0.0
        cfg = EngineConfig(efs=max(efs, k), router=router,
                           metric=self.graph.metric, max_hops=max_hops,
                           use_hierarchy=self.graph.upper_neighbors is not None,
                           beam_width=beam_width, engine=engine,
                           beam_prune=beam_prune, estimate=estimate)
        _, fn = self._engine(cfg)
        res: SearchResult = fn(jnp.asarray(queries), jnp.asarray(cos_theta, jnp.float32))
        ids = np.asarray(res.ids[:, :k]).astype(np.int64)
        ids[ids >= self.graph.n] = -1
        info = {
            "dist_calls": np.asarray(res.dist_calls),
            "est_calls": np.asarray(res.est_calls),
            "rerank_calls": np.asarray(res.rerank_calls),
            "sq8_calls": np.asarray(res.sq8_calls),
            "hops": np.asarray(res.hops),
            "iters": int(res.iters),
        }
        return ids, np.asarray(res.dists[:, :k]), info

    # --- persistence ----------------------------------------------------------
    def save(self, path: str):
        g = self.graph
        payload = dict(
            vectors=g.vectors, neighbors=g.neighbors, edge_eu_dist=g.edge_eu_dist,
            entry_point=np.asarray(g.entry_point), metric=np.asarray(g.metric),
            kind=np.asarray(g.kind),
        )
        if g.norms is not None:
            payload["norms"] = g.norms
        if g.upper_neighbors:
            payload["n_upper"] = np.asarray(len(g.upper_neighbors))
            for i, (ids, mat) in enumerate(zip(g.upper_ids, g.upper_neighbors)):
                payload[f"upper_ids_{i}"] = ids
                payload[f"upper_nbrs_{i}"] = mat
        if self.profile is not None:
            payload["theta_samples"] = self.profile.samples
            payload["theta_star"] = np.asarray(self.profile.theta_star)
            payload["theta_pct"] = np.asarray(self.profile.percentile)
        os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
        np.savez_compressed(path, **payload)

    @classmethod
    def load(cls, path: str) -> "AnnIndex":
        z = np.load(path, allow_pickle=False)
        upper_ids = upper_nbrs = None
        if "n_upper" in z:
            k = int(z["n_upper"])
            upper_ids = [z[f"upper_ids_{i}"] for i in range(k)]
            upper_nbrs = [z[f"upper_nbrs_{i}"] for i in range(k)]
        g = GraphIndex(
            vectors=z["vectors"], neighbors=z["neighbors"],
            edge_eu_dist=z["edge_eu_dist"], entry_point=int(z["entry_point"]),
            metric=str(z["metric"]), norms=z.get("norms"),
            upper_ids=upper_ids, upper_neighbors=upper_nbrs, kind=str(z["kind"]))
        prof = None
        if "theta_samples" in z:
            th = float(z["theta_star"])
            prof = AngleProfile(theta_star=th, cos_theta_star=float(np.cos(th)),
                                percentile=float(z["theta_pct"]),
                                samples=z["theta_samples"],
                                n_sample_queries=0, sample_secs=0.0)
        return cls(graph=g, profile=prof)

"""NSG construction (Fu et al., VLDB'19) with CRouting bookkeeping.

Pipeline (faithful to the paper at container scale):
  1. exact K-NN graph (knn_graph.py);
  2. medoid = navigating node;
  3. per node p: candidate pool = search(p, on KNN graph, pool C) — batched on
     device through the JAX engine (all nodes at once, DESIGN.md §7 note on
     vectorized construction);
  4. MRNG edge selection over the candidates (keep c iff no kept s has
     dist(c, s) < dist(c, p));
  5. grow a spanning tree from the medoid to guarantee connectivity.

Defaults follow the paper §5.1: R=70 (degree), C=500 (candidates), L=60
(search pool).
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional

import numpy as np

from repro.core import distances as D
from repro.core.graph import GraphIndex, pad_adjacency
from repro.core.knn_graph import build_knn_graph
from repro.core.spec import SearchSpec


def _mrng_select(p: int, cand_ids: np.ndarray, cand_rank: np.ndarray,
                 base: np.ndarray, metric: str, r: int):
    """MRNG pruning: candidates in ascending distance; keep c iff for all
    already-kept s: dist(c, s) >= dist(c, p)."""
    order = np.argsort(cand_rank, kind="stable")
    cand_ids, cand_rank = cand_ids[order], cand_rank[order]
    cvecs = base[cand_ids]
    pw = D.pairwise_np(cvecs, cvecs, metric)
    kept: List[int] = []
    kept_rank: List[float] = []
    for pos in range(len(cand_ids)):
        if len(kept) >= r:
            break
        ok = True
        for kpos in kept:
            if pw[pos, kpos] < cand_rank[pos]:
                ok = False
                break
        if ok:
            kept.append(pos)
            kept_rank.append(float(cand_rank[pos]))
    return cand_ids[kept], np.asarray(kept_rank, np.float32)


def build_nsg(base: np.ndarray, metric: str = "l2", r: int = 70, c: int = 500,
              l: int = 60, knn_k: int = 64, seed: int = 0,
              search_batch_size: int = 512, beam_width: int = 4,
              estimate: str = "exact",
              search_spec: Optional[SearchSpec] = None) -> GraphIndex:
    """Construct an NSG.  ``search_spec`` configures the candidate-
    acquisition searches (router/engine/beam/estimate); its pool-shaping
    fields (efs, max_hops, metric, hierarchy) are overridden by the
    construction requirements.  ``beam_width``/``estimate`` remain as
    shorthand for the common knobs when no spec is given.
    """
    t0 = time.time()
    base = D.preprocess_vectors(np.ascontiguousarray(base, np.float32), metric)
    n = base.shape[0]
    knn = build_knn_graph(base, k=knn_k, metric=metric)
    norms = knn.norms
    medoid = knn.entry_point

    # --- step 3: batched candidate acquisition on the KNN graph -------------
    pool = max(l, min(c, n - 1))
    # beam expansion cuts the candidate-acquisition hop loop ~beam_width x
    # (construction quality only improves: extra expansions, never fewer);
    # estimate="sq8" swaps the acquisition searches onto quantized stage-1
    # distances (cheaper build, slightly noisier candidate pools)
    if search_spec is None:
        search_spec = SearchSpec(router="none", beam_width=beam_width,
                                 estimate=estimate)
    cfg = dataclasses.replace(
        search_spec, efs=pool, metric=metric, max_hops=4 * pool,
        use_hierarchy=False,
        beam_width=max(1, min(search_spec.beam_width, pool)))
    cand_ids = np.empty((n, pool), np.int64)
    cand_rank = np.empty((n, pool), np.float32)
    from repro.core.search import build_search_fn
    import jax.numpy as jnp
    _, fn = build_search_fn(knn, cfg)
    for s in range(0, n, search_batch_size):
        res = fn(jnp.asarray(base[s : s + search_batch_size]), jnp.asarray(0.0))
        cand_ids[s : s + search_batch_size] = np.asarray(res.ids)
        cand_rank[s : s + search_batch_size] = np.asarray(res.dists)

    # --- step 4: MRNG selection ---------------------------------------------
    adj: List[np.ndarray] = [None] * n
    dists: List[np.ndarray] = [None] * n
    for p in range(n):
        ids, rank = cand_ids[p], cand_rank[p]
        mask = (ids != p) & (ids < n)
        # merge the KNN neighbors in (the NSG paper unions search results with
        # the node's KNN list)
        kn = knn.neighbors[p][knn.neighbors[p] < n].astype(np.int64)
        kn_rank = D.pairwise_np(base[p : p + 1], base[kn], metric)[0]
        ids = np.concatenate([ids[mask], kn])
        rank = np.concatenate([rank[mask], kn_rank])
        ids, uniq = np.unique(ids, return_index=True)
        rank = rank[uniq]
        kept, kept_rank = _mrng_select(p, ids, rank, base, metric, r)
        adj[p] = kept.astype(np.int64)
        dists[p] = D.rank_to_eu_np(kept_rank, norms[p], norms[kept], metric)

    # --- step 5: connectivity (spanning tree from medoid) -------------------
    seen = np.zeros(n, bool)
    stack = [medoid]
    seen[medoid] = True
    order = []
    while stack:
        u = stack.pop()
        order.append(u)
        for v in adj[u]:
            if not seen[v]:
                seen[v] = True
                stack.append(int(v))
    n_orphans = 0
    for p in np.nonzero(~seen)[0]:
        # attach orphan to its nearest reachable node
        reach = np.nonzero(seen)[0]
        dd = D.pairwise_np(base[p : p + 1], base[reach], metric)[0]
        tgt = int(reach[np.argmin(dd)])
        eu = D.rank_to_eu_np(np.asarray([dd.min()]), norms[tgt], norms[p : p + 1], metric)[0]
        adj[tgt] = np.concatenate([adj[tgt], [p]])
        dists[tgt] = np.concatenate([dists[tgt], [eu]])
        seen[p] = True
        n_orphans += 1

    max_deg = max(len(a) for a in adj)
    nb, ed = pad_adjacency(adj, dists, n, max(max_deg, r))
    return GraphIndex(vectors=base, neighbors=nb, edge_eu_dist=ed,
                      entry_point=medoid, metric=metric, norms=norms,
                      kind="nsg",
                      build_stats={"build_secs": time.time() - t0, "r": r,
                                   "c": c, "l": l, "knn_k": knn_k,
                                   "orphans": n_orphans})

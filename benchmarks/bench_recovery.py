"""Durability benchmarks (persisted to committed BENCH_recovery.json).

Three sections over the ISSUE 8 WAL + checkpoint + recover stack:

1. **recovery_ingest** — acked-insert throughput per WAL fsync policy
   (``every`` / ``interval`` / ``off``): the price of the durability ack
   point, measured through the real ``MutableAnnIndex`` mutation path.
2. **recovery_replay** — recovery wall-clock vs log length: crash after N
   acked mutations, then time ``MutableAnnIndex.recover`` (manifest read +
   checkpoint load + WAL replay) back to a serving index.
3. **recovery_chaos** — kill-at-every-site crash/recover sweep over the
   five durability failpoints; records (and asserts) zero acknowledged
   loss and zero deleted-id resurrection.

``BENCH_SMOKE=1`` shrinks sizes and diverts the JSON to .cache/.
"""
from __future__ import annotations

import os
import shutil
import time

from benchmarks.common import (CACHE, dataset, emit, persist_bench,
                               smoke_scale)
from repro import fault
from repro.core.index import AnnIndex
from repro.durable import WalFailedError
from repro.fault import FaultInjected
from repro.mutate import MutableAnnIndex, MutateConfig

FILE = "BENCH_recovery.json"
HNSW_KW = dict(m=8, efc=48)
CHAOS_SITES = ("wal.append", "wal.fsync", "wal.rotate", "checkpoint.write",
               "manifest.rename")


def _workdir(name: str) -> str:
    d = os.path.join(CACHE, "bench_recovery", name)
    shutil.rmtree(d, ignore_errors=True)
    os.makedirs(d)
    return d


def _cfg(**kw):
    base = dict(auto_merge="off", graph="hnsw", graph_kw=dict(HNSW_KW))
    base.update(kw)
    return MutateConfig(**base)


def _base_index(n_base: int) -> AnnIndex:
    ds = dataset("sift-synth", n_base=n_base)
    return AnnIndex.build(ds.base, graph="hnsw", **HNSW_KW)


def recovery_ingest():
    """Acked-insert rows/s per fsync policy (batch=8 through the WAL)."""
    n_base = smoke_scale(2000, 400)
    n_ins = smoke_scale(512, 96)
    batch = 8
    ds = dataset("sift-synth", n_base=n_base + n_ins)
    base = AnnIndex.build(ds.base[:n_base], graph="hnsw", **HNSW_KW)
    derived = {"n_base": n_base, "rows": n_ins, "batch": batch}
    for policy in ("every", "interval", "off"):
        cfg = _cfg(delta_capacity=n_ins + batch, wal_fsync=policy,
                   wal_fsync_interval_s=0.002)
        mi = MutableAnnIndex(base, config=cfg,
                             durable_dir=_workdir(f"ingest-{policy}"))
        t0 = time.perf_counter()
        for lo in range(n_base, n_base + n_ins, batch):
            mi.insert(ds.base[lo:lo + batch])      # returns at the ack point
        dt = time.perf_counter() - t0
        mi.close()
        derived[f"rows_per_s_{policy}"] = round(n_ins / dt, 1)
        derived[f"ack_us_{policy}"] = round(dt / (n_ins / batch) * 1e6, 1)
    emit("recovery_ingest", derived["ack_us_every"], derived)
    persist_bench("recovery_ingest", derived, file=FILE)


def recovery_replay():
    """Recovery wall-clock as the un-checkpointed log grows."""
    n_base = smoke_scale(2000, 400)
    lengths = [smoke_scale(128, 32), smoke_scale(512, 64),
               smoke_scale(1024, 96)]
    ds = dataset("sift-synth", n_base=n_base + max(lengths))
    base = AnnIndex.build(ds.base[:n_base], graph="hnsw", **HNSW_KW)
    derived = {"n_base": n_base, "points": []}
    for n_log in lengths:
        cfg = _cfg(delta_capacity=n_log + 8, wal_fsync="off")
        d = _workdir(f"replay-{n_log}")
        mi = MutableAnnIndex(base, config=cfg, durable_dir=d)
        for lo in range(n_base, n_base + n_log, 8):
            mi.insert(ds.base[lo:lo + 8])
        mi.delete(list(range(0, n_log // 8)))
        want = mi.n_live
        mi.close()                                  # simulated crash point
        t0 = time.perf_counter()
        back = MutableAnnIndex.recover(d, config=cfg)
        dt = time.perf_counter() - t0
        assert back.n_live == want
        back.close()
        derived["points"].append({
            "log_records": n_log // 8 + 1, "log_rows": n_log,
            "recover_ms": round(dt * 1e3, 1)})
    emit("recovery_replay", derived["points"][-1]["recover_ms"] * 1e3,
         derived)
    persist_bench("recovery_replay", derived, file=FILE)


def recovery_chaos():
    """Seeded crash at every durability failpoint; recover; count losses."""
    n_base = smoke_scale(1200, 400)
    ds = dataset("sift-synth", n_base=n_base + 64)
    base = AnnIndex.build(ds.base[:n_base], graph="hnsw", **HNSW_KW)
    lost = resurrected = crashes = 0
    t0 = time.perf_counter()
    for site in CHAOS_SITES:
        fault.disarm()
        cfg = _cfg(delta_capacity=256)
        d = _workdir(f"chaos-{site.replace('.', '-')}")
        mi = MutableAnnIndex(base, config=cfg, durable_dir=d)
        ids = mi.insert(ds.base[n_base:n_base + 48])           # acked
        deleted = [int(ids[1]), int(ids[9]), 3]
        mi.delete(deleted)                                     # acked
        acked = set(map(int, mi.live_ids()))
        fault.arm(site, kind="raise", hits={0})
        try:
            mi.insert(ds.base[n_base + 48:n_base + 64])        # unacked
            mi.checkpoint()             # checkpoint-path sites fire here
        except (FaultInjected, WalFailedError):
            crashes += 1
        fault.disarm()
        back = MutableAnnIndex.recover(d, config=cfg)
        recovered = set(map(int, back.live_ids()))
        lost += len(acked - recovered)
        resurrected += len(recovered & set(deleted))
        back.close()
    dt = time.perf_counter() - t0
    derived = {"sites": len(CHAOS_SITES), "crashes": crashes,
               "acked_lost": lost, "resurrected_deletes": resurrected}
    assert crashes == len(CHAOS_SITES), "every armed site must fire"
    assert lost == 0 and resurrected == 0
    emit("recovery_chaos", dt / len(CHAOS_SITES) * 1e6, derived)
    persist_bench("recovery_chaos", derived, file=FILE)

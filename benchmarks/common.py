"""Shared benchmark plumbing: cached index builds + timing helpers."""
from __future__ import annotations

import json
import os
import time
from typing import Dict, Tuple

from repro.core.angles import sample_angle_profile
from repro.core.hnsw import build_hnsw
from repro.core.index import AnnIndex
from repro.core.nsg import build_nsg
from repro.data.vectors import VectorDataset, make_dataset

CACHE = os.path.join(os.path.dirname(__file__), "..", ".cache")
os.makedirs(CACHE, exist_ok=True)

# benchmark-scale stand-ins for the paper's datasets (dim preserved)
BENCH_DATASETS = {
    "sift-synth": dict(dim=128, n_clusters=64),
    "deep-synth": dict(dim=256, n_clusters=48),
    "gist-synth": dict(dim=960, n_clusters=32),
}
N_BASE = int(os.environ.get("BENCH_N", 6000))
N_QUERY = int(os.environ.get("BENCH_Q", 100))

# BENCH_SMOKE=1 (make bench-smoke / CI): shrink every engine bench to a
# seconds-scale run that still exercises the full code path, and divert the
# persisted results away from the committed trajectory file.
SMOKE = os.environ.get("BENCH_SMOKE", "") not in ("", "0")


def smoke_scale(n: int, smoke_n: int) -> int:
    """Benchmark size knob: the real size, or the smoke-tier size."""
    return smoke_n if SMOKE else n


def bench_json_path(file: str = "BENCH_engine.json") -> str:
    """Resolve a committed trajectory file (smoke runs divert to .cache/)."""
    if SMOKE:
        stem = os.path.splitext(file)[0]
        return os.path.join(CACHE, stem + ".smoke.json")
    return os.path.join(os.path.dirname(__file__), "..", file)


def persist_bench(section: str, payload,
                  file: str = "BENCH_engine.json") -> str:
    """Merge one benchmark's derived dict into a committed BENCH_*.json.

    The file is the machine-readable perf trajectory across PRs: one JSON
    object keyed by benchmark name (plus a ``_meta`` stamp written by
    benchmarks/run.py).  Engine benches share the default
    ``BENCH_engine.json``; the serving benches write ``BENCH_serve.json``.
    Smoke runs write to .cache/ instead so throwaway numbers never clobber
    the committed history.
    """
    path = bench_json_path(file)
    data = {}
    if os.path.exists(path):
        with open(path) as f:
            data = json.load(f)
    data[section] = payload
    with open(path, "w") as f:
        json.dump(data, f, indent=2, sort_keys=True)
        f.write("\n")
    return path


def dataset(name: str, n_base: int = None, metric: str = "l2",
            seed: int = 0) -> VectorDataset:
    cfg = BENCH_DATASETS[name]
    return make_dataset(name=name, n_base=n_base or N_BASE, n_query=N_QUERY,
                        dim=cfg["dim"], n_clusters=cfg["n_clusters"],
                        metric=metric, seed=seed)


def cached_index(ds: VectorDataset, graph: str = "hnsw", m: int = 16,
                 efc: int = 128, **kw) -> AnnIndex:
    key = f"{ds.name}_{ds.base.shape[0]}_{ds.metric}_{graph}_m{m}_efc{efc}"
    path = os.path.join(CACHE, key + ".npz")
    meta = os.path.join(CACHE, key + ".json")
    if os.path.exists(path):
        idx = AnnIndex.load(path)
        if os.path.exists(meta):
            idx.graph.build_stats = json.load(open(meta))
        return idx
    t0 = time.time()
    if graph == "hnsw":
        g = build_hnsw(ds.base, metric=ds.metric, m=m, efc=efc, seed=0)
    else:
        g = build_nsg(ds.base, metric=ds.metric, r=2 * m, c=4 * efc // 2,
                      l=efc // 2, knn_k=2 * m)
    prof = sample_angle_profile(g, seed=0)
    idx = AnnIndex(graph=g, profile=prof)
    idx.save(path)
    stats = dict(g.build_stats or {})
    stats["profile_secs"] = prof.sample_secs
    stats["total_secs"] = time.time() - t0
    json.dump(stats, open(meta, "w"))
    idx.graph.build_stats = stats
    return idx


def timed(fn, *args, warmup: int = 1, iters: int = 3) -> Tuple[float, object]:
    for _ in range(warmup):
        out = fn(*args)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    return (time.perf_counter() - t0) / iters, out


def emit(name: str, us_per_call: float, derived: Dict):
    """The harness's output contract: ``name,us_per_call,derived`` CSV."""
    print(f"{name},{us_per_call:.2f},{json.dumps(derived, sort_keys=True)}")

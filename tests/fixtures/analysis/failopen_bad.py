"""Deliberately state-free broad excepts (the fail-open contract)."""


class Worker:
    def __init__(self):
        self.err = None
        self.failures = 0

    def swallow_pass(self):
        try:
            self.step()
        except Exception:   # noqa: BLE001    expect[fail-open]
            pass

    def swallow_compute_only(self):
        try:
            self.step()
        except Exception as e:   # noqa: BLE001   expect[fail-open]
            str(e)                # computes, records nothing

    def bare_except(self):
        try:
            self.step()
        except:                                # expect[fail-open]
            pass

    def records_field(self):
        try:
            self.step()
        except Exception as e:   # noqa: BLE001 — stored: no finding
            self.err = e

    def records_counter(self):
        try:
            self.step()
        except Exception:   # noqa: BLE001 — counted: no finding
            self.failures += 1

    def reraises(self):
        try:
            self.step()
        except Exception as e:   # noqa: BLE001 — wrapped: no finding
            raise RuntimeError("boom") from e

    def narrow_is_ignored(self):
        try:
            self.step()
        except ValueError:      # not broad: no finding
            pass

    def step(self):
        raise ValueError("x")

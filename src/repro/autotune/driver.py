"""AutotuneDriver: the loop that closes serving telemetry onto knobs.

``AutotuneDriver.attach(frontend, slo)`` binds a ``Controller`` +
``RecallProxy`` to a live ``ServeFrontend``:

* each ``step()`` snapshots the frontend's windowed telemetry, diffs it
  against the previous epoch (``ServeTelemetry.window_delta``), feeds the
  delta to the controller, and — when the controller moved the incumbent
  — promotes the new spec via ``ServeFrontend.activate_spec`` (pre-warm
  every bucket rung off the request path, then the atomic default-session
  flip; ``recompiles_after_warmup`` stays 0 across every switch);
* ``start()``/``stop()`` run ``step()`` on a daemon thread at a fixed
  period — the online mode ``launch/serve.py --autotune`` uses; tests and
  benchmarks drive ``step()`` synchronously;
* every action lands in the structured decision log
  (``driver.decisions``, JSON-ready via ``decision_log()``).

Fail-open is the driver's contract, not an afterthought: ANY exception
inside a step — controller logic, a probe replay, the failpoint sites
``autotune.step``/``autotune.probe``, even a failed pre-warm — is caught,
recorded as a ``kind="fail"`` decision, and leaves the frontend serving
the last-good spec.  The tuner can only ever decline to improve things;
it cannot take serving down.
"""
from __future__ import annotations

import threading
import time
from typing import Dict, List, Optional, Sequence, Union

import numpy as np

from repro.autotune.controller import Controller, Decision, Objective
from repro.autotune.proxy import RecallProxy
from repro.autotune.space import TuneSpace, spec_key
from repro.core.spec import SearchSpec
from repro.fault import failpoints as fault


class AutotuneDriver:
    """Owns the controller thread + the frontend binding (see module doc)."""

    def __init__(self, frontend, controller: Controller, proxy: RecallProxy):
        self.frontend = frontend
        self.controller = controller
        self.proxy = proxy
        self.failures = 0
        self.switches = 0
        self.last_error: Optional[str] = None
        self._worker: Optional[threading.Thread] = None
        self._stop = threading.Event()
        # previous epoch's snapshot -- guarded by: self._lock
        self._snap = None
        self._lock = threading.Lock()          # serializes step()
        frontend.autotune = self               # health() surface

    # --- construction -----------------------------------------------------
    @classmethod
    def attach(cls, frontend, slo: Union[Objective, float], *,
               space: Optional[TuneSpace] = None,
               proxy: Optional[RecallProxy] = None,
               probe_queries: Optional[np.ndarray] = None,
               probe_gt: Optional[np.ndarray] = None,
               n_probe: int = 32, seed: int = 0,
               screen: bool = True, **controller_kw) -> "AutotuneDriver":
        """Bind an autotune loop to a frontend.

        ``slo`` is an ``Objective`` or a bare p99 target in ms.  ``space``
        defaults to the stock efs x beam ladder around the frontend's
        active spec; ``proxy`` (or explicit probe queries/gt) defaults to
        synthesized probes with attach-time exact ground truth.  With
        ``screen=True`` the successive-halving bracket runs immediately —
        attach returns with an incumbent installed and active.
        """
        objective = (slo if isinstance(slo, Objective)
                     else Objective(slo_p99_ms=float(slo)))
        base = frontend.active_spec
        if space is None:
            space = TuneSpace.default(base)
        if proxy is None:
            proxy = RecallProxy.for_index(
                frontend.index, n_probe=n_probe, k=base.k, seed=seed,
                buckets=frontend.buckets, queries=probe_queries,
                gt=probe_gt)
        controller = Controller(space, objective, proxy.evaluate,
                                seed=seed, **controller_kw)
        drv = cls(frontend, controller, proxy)
        if screen:
            drv.step()
        return drv

    # --- the loop body ----------------------------------------------------
    def step(self) -> Decision:
        """One epoch: observe -> decide -> (maybe) pre-warm and switch.

        Never raises.  A failure inside the epoch is contained: the
        decision log records ``kind="fail"``, counters tick, and the
        frontend keeps serving the spec it already had (fail-open).
        """
        with self._lock:
            ctl = self.controller
            active_before = spec_key(self.frontend.active_spec)
            try:
                fault.hit("autotune.step")
                if ctl.incumbent is None:
                    decision = ctl.screen()
                    # baseline the epoch window so the FIRST refinement
                    # step diffs against end-of-screen, not attach time
                    self._snap = self.frontend.telemetry.window_snapshot()
                else:
                    snap = self.frontend.telemetry.window_snapshot()
                    delta = (self.frontend.telemetry.window_delta(
                        self._snap, snap) if self._snap is not None
                        else {"p99_ms": None, "served": 0})
                    self._snap = snap
                    decision = ctl.step(delta)
                if ctl.incumbent is not None and \
                        ctl.incumbent != active_before:
                    self._promote(ctl.by_key[ctl.incumbent])
                    # the switch resets the epoch window: post-switch
                    # latency must not be judged against pre-switch samples
                    self._snap = self.frontend.telemetry.window_snapshot()
                return decision
            except Exception as e:              # noqa: BLE001 — fail-open:
                # any controller/probe/warmup error leaves the last-good
                # spec serving; the failure is data in the decision log.
                # Re-point the controller at what is ACTUALLY active (a
                # failed pre-warm must not leave it believing its own
                # un-promoted switch), when that spec is in its space.
                if active_before in ctl.by_key:
                    ctl.incumbent = active_before
                self.failures += 1
                self.last_error = repr(e)
                d = Decision(ctl.epoch, "fail", active_before,
                             f"controller error (fail-open): {e!r}", {})
                ctl.decisions.append(d)
                return d

    def _promote(self, spec: SearchSpec) -> None:
        """Pre-warm across the bucket ladder, then the atomic flip."""
        t0 = time.perf_counter()
        self.frontend.activate_spec(spec)
        self.switches += 1
        self.controller.decisions[-1].measured["warm_swap_s"] = round(
            time.perf_counter() - t0, 3)

    # --- background mode --------------------------------------------------
    def start(self, period_s: float = 2.0) -> "AutotuneDriver":
        """Run ``step()`` every ``period_s`` on a daemon thread."""
        if self._worker is not None:
            return self
        self._stop.clear()

        def loop():
            while not self._stop.wait(timeout=period_s):
                self.step()

        self._worker = threading.Thread(target=loop, daemon=True,
                                        name="autotune-driver")
        self._worker.start()
        return self

    def stop(self) -> None:
        if self._worker is None:
            return
        self._stop.set()
        self._worker.join()
        self._worker = None

    def __enter__(self) -> "AutotuneDriver":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # --- reporting --------------------------------------------------------
    @property
    def decisions(self) -> List[Decision]:
        return self.controller.decisions

    def decision_log(self) -> List[Dict[str, object]]:
        """The structured decision log, JSON-ready."""
        return [d.to_dict() for d in self.controller.decisions]

    def health(self) -> Dict[str, object]:
        """Controller state for ``ServeFrontend.health()['autotune']``."""
        h = self.controller.health()
        h.update({
            "running": self._worker is not None and self._worker.is_alive(),
            "failures": self.failures,
            "switches": self.switches,
            "last_error": self.last_error,
            "objective": self.controller.objective.to_dict(),
        })
        return h

"""Angle-distribution acquisition (paper §3.3, §4.1).

After the graph is built, ``n_sample`` (default 0.1%·N) random queries are
searched and, at every neighbor expansion (c, n), the angle
theta = ∠(cq, cn) is recovered from the three exact Euclidean distances via
the cosine theorem.  The pruning threshold theta* is a percentile (default
90th, paper §5.5) of the collected distribution.

Also provides the theoretical random-vector angle PDF (paper Eq. 3):
    P(eta) = Gamma(d/2) / (Gamma((d-1)/2) * sqrt(pi)) * sin^(d-2)(eta)
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np
from scipy.special import gammaln

from repro.core.graph import GraphIndex
from repro.core.ref_search import search_ref


@dataclasses.dataclass
class AngleProfile:
    """The dataset's angle distribution + chosen pruning threshold."""

    theta_star: float          # selected angle (radians)
    cos_theta_star: float
    percentile: float          # which percentile theta_star is
    samples: np.ndarray        # raw sampled angles (radians)
    n_sample_queries: int
    sample_secs: float
    # Corpus size at sampling time: after mutation, |n_now - corpus_n| /
    # corpus_n measures profile staleness (MutableAnnIndex refresh policy).
    corpus_n: int = 0

    def at_percentile(self, pct: float) -> "AngleProfile":
        th = float(np.percentile(self.samples, pct))
        return dataclasses.replace(
            self, theta_star=th, cos_theta_star=float(np.cos(th)), percentile=pct)


def theoretical_angle_pdf(eta: np.ndarray, d: int) -> np.ndarray:
    """Paper Eq. 3 — PDF of the angle between two random vectors in R^d."""
    logc = gammaln(d / 2.0) - gammaln((d - 1) / 2.0) - 0.5 * np.log(np.pi)
    return np.exp(logc + (d - 2) * np.log(np.maximum(np.sin(eta), 1e-300)))


def sample_angle_profile(
    g: GraphIndex,
    n_sample: Optional[int] = None,
    efs: int = 100,
    percentile: float = 90.0,
    seed: int = 0,
    queries: Optional[np.ndarray] = None,
) -> AngleProfile:
    """Instrumented searches over random queries -> empirical theta distribution.

    Default n_sample = max(8, 0.1%·N) per paper §4.1; overhead is recorded so
    benchmarks can verify the <4% construction-time claim.

    When ``queries`` is supplied, ALL of them are searched unless the caller
    also passes an explicit ``n_sample`` cap — the default cap applies only
    to the random-sampling path (a held-out query set must never be silently
    truncated to 0.1%·N).  ``n_sample_queries`` records the number of
    queries actually searched.
    """
    import time

    t0 = time.time()
    n = g.n
    if queries is None:
        if n_sample is None:
            n_sample = max(8, int(0.001 * n))
        rng = np.random.default_rng(seed)
        queries = g.vectors[rng.integers(0, n, size=n_sample)]
    elif n_sample is not None:
        queries = queries[:n_sample]

    angles = []
    for q in queries:
        _, _, stats = search_ref(g, q, efs=efs, k=1, router=None, record_angles=True)
        angles.extend(stats.angles)
    samples = np.asarray(angles, dtype=np.float64)
    if samples.size == 0:
        samples = np.asarray([np.pi / 2])
    th = float(np.percentile(samples, percentile))
    return AngleProfile(
        theta_star=th,
        cos_theta_star=float(np.cos(th)),
        percentile=percentile,
        samples=samples,
        n_sample_queries=len(queries),
        sample_secs=time.time() - t0,
        corpus_n=n,
    )

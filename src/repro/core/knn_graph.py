"""Exact K-NN graph construction via blocked brute force on device.

Used as the starting graph for NSG construction (the NSG paper builds its
candidate graph from an approximate KNN graph; at our container scales exact
is affordable and removes one source of noise).  The distance blocks run the
same matmul formulation the Pallas l2_distance kernel implements for TPU.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import distances as D
from repro.core.graph import GraphIndex


def build_knn_graph(base: np.ndarray, k: int = 32, metric: str = "l2",
                    block: int = 1024) -> GraphIndex:
    base = D.preprocess_vectors(np.ascontiguousarray(base, np.float32), metric)
    n = base.shape[0]
    met = D.get_metric(metric)
    xb = jnp.asarray(base)

    @jax.jit
    def topk_block(q):
        dist = met.pairwise(q, xb)
        # k+1 then drop self
        neg_d, idx = jax.lax.top_k(-dist, k + 1)
        return -neg_d, idx

    nb = np.full((n, k), n, dtype=np.int32)
    ed = np.full((n, k), np.inf, dtype=np.float32)
    norms = np.linalg.norm(base, axis=1).astype(np.float32)
    for s in range(0, n, block):
        dvals, idx = topk_block(xb[s : s + block])
        dvals, idx = np.asarray(dvals), np.asarray(idx)
        for r in range(idx.shape[0]):
            row = [(d, j) for d, j in zip(dvals[r], idx[r]) if j != s + r][:k]
            ids = np.asarray([j for _, j in row], np.int32)
            rank = np.asarray([d for d, _ in row], np.float32)
            nb[s + r, : len(ids)] = ids
            ed[s + r, : len(ids)] = D.rank_to_eu_np(rank, norms[s + r], norms[ids], metric)
    # entry = medoid (node nearest to the dataset centroid)
    centroid = base.mean(axis=0, keepdims=True)
    entry = int(np.argmin(D.pairwise_np(centroid, base, metric)[0]))
    return GraphIndex(vectors=base, neighbors=nb, edge_eu_dist=ed,
                      entry_point=entry, metric=metric, norms=norms, kind="knn",
                      build_stats={"k": k})

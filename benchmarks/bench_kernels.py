"""Kernel micro-benchmarks (interpret-mode timings are NOT TPU performance —
they validate call overhead and feed the us_per_call column; TPU numbers come
from the §Roofline dry-run terms)."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import emit, timed
from repro.kernels import ops, ref


def kernels_micro():
    rng = np.random.default_rng(0)
    derived = {}

    q = jnp.asarray(rng.normal(size=(128, 128)).astype(np.float32))
    x = jnp.asarray(rng.normal(size=(1024, 128)).astype(np.float32))
    dt, _ = timed(lambda: ops.l2_distance(q, x).block_until_ready())
    dt_ref, _ = timed(lambda: ref.l2_distance_ref(q, x).block_until_ready())
    derived["l2_distance"] = {"us": round(dt * 1e6, 1),
                              "ref_us": round(dt_ref * 1e6, 1),
                              "gflops": round(2 * 128 * 1024 * 128 / dt / 1e9, 2)}

    ed = jnp.asarray(rng.uniform(0.1, 2, size=(64, 128)).astype(np.float32))
    dcq = jnp.asarray(rng.uniform(0.1, 2, size=(64,)).astype(np.float32))
    b2 = jnp.asarray(rng.uniform(1, 4, size=(64,)).astype(np.float32))
    va = jnp.ones((64, 128), jnp.int8)
    dt, _ = timed(lambda: ops.crouting_prune(ed, dcq, b2, va, 0.15)[0]
                  .block_until_ready())
    derived["crouting_prune"] = {"us": round(dt * 1e6, 1)}

    table = jnp.asarray(rng.normal(size=(4096, 128)).astype(np.float32))
    idx = jnp.asarray(rng.integers(0, 4096, size=(8, 16)).astype(np.int32))
    qs = jnp.asarray(rng.normal(size=(8, 128)).astype(np.float32))
    dt, _ = timed(lambda: ops.gather_distance(idx, qs, table)
                  .block_until_ready())
    derived["gather_distance"] = {"us": round(dt * 1e6, 1)}

    pd = jnp.sort(jnp.asarray(rng.uniform(0, 5, size=(16, 64)).astype(np.float32)), axis=1)
    pi = jnp.asarray(rng.integers(0, 9999, size=(16, 64)).astype(np.int32))
    nd = jnp.asarray(rng.uniform(0, 5, size=(16, 32)).astype(np.float32))
    ni = jnp.asarray(rng.integers(0, 9999, size=(16, 32)).astype(np.int32))
    dt, _ = timed(lambda: ops.pool_merge(pd, pi, nd, ni)[0].block_until_ready())
    derived["pool_merge"] = {"us": round(dt * 1e6, 1)}

    # beam-shaped fused expansion tile ([B, W*M] = [8, 64], per-lane dcq)
    nb = jnp.asarray(rng.integers(0, 4096, size=(8, 64)).astype(np.int32))
    edl = jnp.asarray(rng.uniform(0.1, 2, size=(8, 64)).astype(np.float32))
    dcl = jnp.asarray(rng.uniform(0.1, 2, size=(8, 64)).astype(np.float32))
    b2l = jnp.asarray(rng.uniform(1, 4, size=(8, 64)).astype(np.float32))
    dt, _ = timed(lambda: ops.fused_expand(nb, qs, edl, dcl, b2l, 0.15,
                                           table)[0].block_until_ready())
    derived["fused_expand"] = {"us": round(dt * 1e6, 1)}

    for name, d in derived.items():
        emit(f"kernel_{name}", d["us"], d)
    return derived

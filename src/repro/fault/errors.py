"""Typed failure-domain errors (DESIGN.md §10).

Every degradation path in the stack resolves to one of these instead of an
opaque ``RuntimeError``/``zipfile.BadZipFile``/silent wrong answer, so
callers (and the chaos harness) can tell an injected or operational fault
from a programming bug:

* ``CorruptIndexError`` — a persisted index file failed its integrity
  checks on ``AnnIndex.load`` (truncation, bit flips, stale checksum).  An
  interrupted ``save()`` can never produce one at the *published* path —
  the atomic-rename protocol leaves the old version — so seeing this means
  the bytes on disk were damaged after publication.
* ``DegradedSearchError`` — EVERY shard of a host-composed sharded search
  failed or timed out; there is no surviving pool to answer from.  Partial
  failure is NOT an error: it returns results from the surviving shards
  with ``SearchStats.shards_failed > 0``.
* ``MergeQuarantinedError`` — the delta segment is full while background
  merges are quarantined (the retry budget was exhausted); the mutation is
  refused as typed backpressure rather than risking a poisoned index.
  Retry after the quarantine cooldown, or call ``clear_quarantine()``.
"""
from __future__ import annotations


class CorruptIndexError(RuntimeError):
    """A persisted index failed checksum/structure verification on load."""


class DegradedSearchError(RuntimeError):
    """No shard survived a fan-out search — nothing to degrade onto."""


class MergeQuarantinedError(RuntimeError):
    """Delta full while merges are quarantined: typed mutation backpressure."""

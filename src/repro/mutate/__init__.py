"""Live index mutation (DESIGN.md §9): delta segment + tombstones +
background merge, served without downtime.  Merge failures retry with
backoff and quarantine on exhaustion (DESIGN.md §10) — see
``repro.fault`` for the policy pieces."""
from repro.fault import MergeQuarantinedError
from repro.mutate.delta import DeltaSegment, delta_scan_compile_count
from repro.mutate.index import MutableAnnIndex, MutateConfig
from repro.mutate.sharded import MutableShardedAnnIndex

__all__ = [
    "DeltaSegment",
    "delta_scan_compile_count",
    "MergeQuarantinedError",
    "MutableAnnIndex",
    "MutableShardedAnnIndex",
    "MutateConfig",
]

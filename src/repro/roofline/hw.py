"""Target-hardware constants (TPU v5e) for the roofline terms."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class HwSpec:
    name: str
    peak_flops_bf16: float     # FLOP/s per chip
    hbm_bw: float              # B/s per chip
    ici_link_bw: float         # B/s per link
    hbm_bytes: float           # capacity per chip


TPU_V5E = HwSpec(
    name="tpu-v5e",
    peak_flops_bf16=197e12,
    hbm_bw=819e9,
    ici_link_bw=50e9,
    hbm_bytes=16e9,
)

"""fail-open: every broad except must convert the failure into state.

DESIGN.md §10's graceful-degradation rule: a ``noqa: BLE001`` handler may
swallow a broad exception ONLY by turning it into observable state — an
assignment to an error/degraded/quarantine field, a telemetry counter, a
log of record, or a re-raise.  A handler whose body is ``pass`` (or that
merely computes without storing) silently discards the failure: the serve
path keeps answering, nothing counts the loss, and the degradation
contract the chaos bench measures is quietly void.

What counts as converting the failure into state, checked structurally on
the handler body:

* ``raise`` (re-raise or wrap-and-raise), ``return``/``continue``/``break``
  AFTER some state write do not themselves count — the state write does;
* any assignment (``x = ...``, ``self.err = ...``, ``d[k] = ...``,
  augmented or annotated), which covers error fields, local degradation
  flags folded into results, and counter bumps via ``+=``;
* a call that plausibly records: a method named ``append``/``add``/
  ``put``/``record*``/``observe*``/``incr*``/``count*``/``note*``/
  ``set_exception``/``set_result``, or any ``log``/``logger``/``logging``
  /``warnings`` call;
* ``raise`` anywhere in the handler.

Handlers re-raising under a condition but otherwise falling through with
no state write still fail — that is exactly the silent-discard shape.
"""
from __future__ import annotations

import ast
from typing import Iterable

from repro.analysis.core import (Finding, Project, SourceFile, dotted_name,
                                 register_checker)

_RECORDING_METHODS = ("append", "add", "put", "set_exception", "set_result",
                      "extend", "notify", "notify_all", "cancel")
_RECORDING_PREFIXES = ("record", "observe", "incr", "count", "note", "mark",
                       "log", "warn", "fail", "quarantine", "degrade")
_LOGGING_HEADS = ("log", "logger", "logging", "warnings", "print")


def _is_broad(handler: ast.ExceptHandler) -> bool:
    t = handler.type
    if t is None:
        return True
    names = []
    if isinstance(t, ast.Tuple):
        names = [dotted_name(e) for e in t.elts]
    else:
        names = [dotted_name(t)]
    return any(n in ("Exception", "BaseException") for n in names)


def _call_records(call: ast.Call) -> bool:
    head = dotted_name(call.func)
    if head is not None:
        parts = head.split(".")
        if parts[0] in _LOGGING_HEADS:
            return True
        last = parts[-1]
    elif isinstance(call.func, ast.Attribute):
        last = call.func.attr
    else:
        return False
    if last in _RECORDING_METHODS:
        return True
    return any(last.startswith(p) for p in _RECORDING_PREFIXES)


def _handler_converts(handler: ast.ExceptHandler) -> bool:
    for node in ast.walk(handler):
        if isinstance(node, ast.Raise):
            return True
        if isinstance(node, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
            return True
        if isinstance(node, ast.Call) and _call_records(node):
            return True
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            # a nested def in a handler is declaration, not conversion —
            # but ast.walk into it would miscount its raises; this shape
            # does not occur in the tree, so keep the walk simple
            continue
    return False


def _noqa_ble(sf: SourceFile, line: int) -> bool:
    return "BLE001" in sf.comment_on(line)


@register_checker(
    "fail-open",
    "broad `except` handlers (noqa: BLE001) convert the failure into "
    "state — an error-field/counter assignment, a recording call, or a "
    "re-raise; bare `pass` fails")
def check_fail_open(project: Project) -> Iterable[Finding]:
    for sf in project.files:
        if sf.tree is None:
            continue
        for node in ast.walk(sf.tree):
            if not isinstance(node, ast.Try):
                continue
            for handler in node.handlers:
                if not _is_broad(handler):
                    continue
                broad_marked = _noqa_ble(sf, handler.lineno)
                if not broad_marked and handler.type is not None:
                    # `except Exception:` without the noqa marker is ruff's
                    # problem (BLE001); ours starts once it is waived
                    continue
                if _handler_converts(handler):
                    continue
                only_pass = all(isinstance(s, ast.Pass)
                                for s in handler.body)
                shape = ("a bare `pass`" if only_pass
                         else "no state write, recording call, or re-raise")
                yield Finding(
                    checker="fail-open", path=sf.relpath,
                    line=handler.lineno,
                    message="broad except swallows the failure with "
                            f"{shape} — the loss is invisible to telemetry "
                            "and the degradation contract (DESIGN.md §10)",
                    hint="assign it to an error/degraded field, bump a "
                         "telemetry counter, or re-raise; if discarding is "
                         "genuinely correct, suppress with # repolint: "
                         "ignore[fail-open] <why>")

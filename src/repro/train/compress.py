"""Gradient compression for cross-pod all-reduce (DESIGN.md §6).

int8 stochastic-rounding quantization with per-tensor scale: quantize ->
all-reduce (psum of int-valued floats is exact up to the shared scale) ->
dequantize.  Cuts the gradient all-reduce wire bytes 4x (fp32) / 2x (bf16);
enable with TrainerConfig.grad_compress for the slow cross-pod hop.

Error feedback (residual carry) keeps the quantization noise from biasing
convergence — the standard 1-bit-Adam/PowerSGD-style correction.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

# The int8 quantizer lives in repro.quant.sq8 (one implementation repo-wide,
# shared with the SQ8 base-vector tables); re-exported here for callers.
from repro.quant.sq8 import (dequantize_int8, quantize_int8,  # noqa: F401
                             quantize_int8_with_scale)


def compress_tree(grads, key) -> Tuple[Any, Any]:
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    keys = jax.random.split(key, len(leaves))
    qs, scales = [], []
    for l, k in zip(leaves, keys):
        q, s = quantize_int8(l.astype(jnp.float32), k)
        qs.append(q)
        scales.append(s)
    return (jax.tree_util.tree_unflatten(treedef, qs),
            jax.tree_util.tree_unflatten(treedef, scales))


def decompress_tree(qs, scales):
    return jax.tree_util.tree_map(dequantize_int8, qs, scales)


def compressed_psum(grads, axis_name, key):
    """Quantize -> psum -> dequantize, with the scale itself psum-maxed so
    all shards dequantize identically."""
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    keys = jax.random.split(key, len(leaves))
    out = []
    for l, k in zip(leaves, keys):
        x = l.astype(jnp.float32)
        amax = jax.lax.pmax(jnp.max(jnp.abs(x)), axis_name) + 1e-12
        scale = amax / 127.0
        y = quantize_int8_with_scale(x, scale, k).astype(jnp.float32)
        red = jax.lax.psum(y, axis_name)        # int-valued f32: exact sum
        out.append(red * scale)
    return jax.tree_util.tree_unflatten(treedef, out)


def with_error_feedback(grads, residual):
    """Add carried residual; return (to_compress, new_residual_fn)."""
    if residual is None:
        return grads, lambda q_deq: jax.tree_util.tree_map(
            lambda g, d: g - d, grads, q_deq)
    carried = jax.tree_util.tree_map(lambda g, r: g + r, grads, residual)
    return carried, lambda q_deq: jax.tree_util.tree_map(
        lambda g, d: g - d, carried, q_deq)

"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax.numpy as jnp


def l2_distance_ref(q, x, mode: str = "l2"):
    q = q.astype(jnp.float32)
    x = x.astype(jnp.float32)
    if mode == "l2":
        qn = jnp.sum(q * q, axis=-1, keepdims=True)
        xn = jnp.sum(x * x, axis=-1)
        return jnp.maximum(qn + xn[None, :] - 2.0 * q @ x.T, 0.0)
    return 1.0 - q @ x.T


def crouting_prune_ref(ed, dcq, bound2, valid, cos_theta):
    """dcq/bound2: [B] (broadcast) or per-lane [B, M] (beam tiles)."""
    ed = ed.astype(jnp.float32)
    dcq = dcq.astype(jnp.float32)
    if dcq.ndim == 1:
        dcq = dcq[:, None]
    if bound2.ndim == 1:
        bound2 = bound2[:, None]
    est2 = jnp.maximum(ed * ed + dcq * dcq - 2.0 * ed * dcq * cos_theta, 0.0)
    mask = (valid != 0) & (est2 >= bound2)
    return est2, mask.astype(jnp.int8)


def gather_distance_ref(indices, queries, table):
    rows = table[indices]                       # [B, M, d]
    diff = rows.astype(jnp.float32) - queries.astype(jnp.float32)[:, None, :]
    return jnp.sum(diff * diff, axis=-1)


def pool_merge_ref(pool_d, pool_i, new_d, new_i):
    d = jnp.concatenate([pool_d, new_d], axis=1)
    i = jnp.concatenate([pool_i, new_i], axis=1)
    # tie-break on smaller id to match the kernel's deterministic network
    order = jnp.lexsort((i, d), axis=1)
    P = pool_d.shape[1]
    return (jnp.take_along_axis(d, order, axis=1)[:, :P],
            jnp.take_along_axis(i, order, axis=1)[:, :P])


def sq8_estimate_ref(nbrs, queries, eval_mask, codes, lo, scale, eps):
    """Oracle for the SQ8 stage-1 kernel: identical bound math via
    repro.quant.sq8 (the single quantization implementation)."""
    from repro.quant.sq8 import sq8_dequantize_rows, sq8_estimate

    n = codes.shape[0]
    in_range = nbrs < n
    evalm = in_range if eval_mask is None else ((eval_mask != 0) & in_range)
    safe = jnp.where(in_range, nbrs, n - 1)
    xhat = sq8_dequantize_rows(codes[safe], lo, scale)      # [B, L, d]
    ad2, lb2 = sq8_estimate(queries.astype(jnp.float32), xhat, eps)
    inf = jnp.float32(jnp.inf)
    return jnp.where(evalm, ad2, inf), jnp.where(evalm, lb2, inf)


def fused_expand_ref(nbrs, queries, ed, dcq, bound2, cos_theta, table,
                     eval_mask=None, prune_eligible=None):
    """Oracle for the fused CRouting expansion kernel (beam-tile general)."""
    n = table.shape[0]
    if bound2.ndim == 1:
        bound2 = bound2[:, None]
    est2, _ = crouting_prune_ref(ed, dcq, bound2,
                                 jnp.ones_like(ed, dtype=jnp.int8), cos_theta)
    in_range = nbrs < n
    evalm = in_range if eval_mask is None else (eval_mask != 0)
    elig = in_range if prune_eligible is None else (prune_eligible != 0)
    prune = elig & (est2 >= bound2)
    safe = jnp.where(in_range, nbrs, n - 1)
    d2 = gather_distance_ref(safe, queries, table)
    d2 = jnp.where(evalm & ~prune, d2, jnp.inf)
    return d2, prune.astype(jnp.int8)

"""End-to-end serving driver (deliverable (b)): a dataset-sharded CRouting
index behind the bucketed serving frontend, over all local devices —
ragged request sizes, per-spec sessions, and a straggler-budget
demonstration.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/serve_anns.py
"""
import numpy as np
import jax

from repro.core.sharded_index import shard_dataset, ShardedAnnIndex
from repro.core.spec import SearchSpec
from repro.data.vectors import make_dataset, exact_ground_truth, recall_at_k
from repro.fault import RetryPolicy
from repro.launch.mesh import make_local_mesh
from repro.serve import QueueFull, ServeFrontend


def main():
    n_dev = len(jax.devices())
    print(f"serving over {n_dev} device(s)")
    ds = make_dataset(n_base=8000, n_query=512, dim=128, n_clusters=64, seed=0)
    gt = exact_ground_truth(ds, k=10)

    import time
    t0 = time.time()
    arrays = shard_dataset(ds.base, n_shards=max(n_dev, 2), graph="hnsw",
                           m=16, efc=96)
    print(f"sharded index built in {time.time()-t0:.1f}s "
          f"({arrays.vectors.shape[0]} shards x {arrays.ns} vectors, "
          f"theta*={np.arccos(arrays.cos_theta)/np.pi:.3f}pi)")
    mesh = make_local_mesh(n_dev, "shards")

    base_spec = SearchSpec(efs=64, k=10, router="crouting", max_hops=2048)
    idx = ShardedAnnIndex(arrays, mesh, spec=base_spec)

    # the frontend pre-jits every bucket rung at startup; the ragged request
    # loop below (sizes 1..64) then replays against the compiled
    # executables only — zero XLA compiles on the request path
    fe = ServeFrontend(idx, base_spec, buckets=(1, 8, 32, 64))
    rng = np.random.default_rng(3)
    # QueueFull backpressure: jittered capped backoff (repro.fault) rather
    # than hammering submit in a tight loop
    backoff = RetryPolicy(max_attempts=64, base_s=0.005, cap_s=0.25, seed=3)
    futs, spans = [], []
    s = 0
    while s < 512:
        n = int(min(rng.integers(1, 65), 512 - s))
        futs.append(backoff.call(fe.submit, ds.queries[s:s + n],
                                 retry_on=QueueFull))
        spans.append((s, s + n))
        if len(futs) % 4 == 0:
            fe.flush()                      # micro-batcher coalesces 4-ish
        s += n
    fe.flush()
    hits = [recall_at_k(f.result()[0], gt[a:b], 10)
            for f, (a, b) in zip(futs, spans)]
    summ = fe.telemetry.summary()
    print(f"ragged trace: {summ['requests']['served']} requests, "
          f"recall@10={np.mean(hits):.3f}  "
          f"p50={summ['latency']['p50_ms']:.1f}ms "
          f"p99={summ['latency']['p99_ms']:.1f}ms  QPS={summ['qps']:.0f}  "
          f"recompiles_after_warmup={summ['recompiles_after_warmup']}")
    print(f"per-query engine work: {summ['search']}")

    # straggler mitigation: a bounded hop budget keeps the merge barrier
    # tail-latency-safe at a controlled recall cost (DESIGN.md §6).  A new
    # engine-shaping spec = a new frontend session (warmed on first use).
    ids, _, _ = fe.search(ds.queries[:64],
                          spec=base_spec.replace(max_hops=24))
    rec = recall_at_k(ids, gt[:64], 10)
    print(f"bounded-hop (straggler mode): recall@10={rec:.3f}")

    # beam expansion: W frontier nodes per hop amortize the per-iteration
    # fixed cost (candidate select, status scatter, loop overhead) ~W x
    beam_spec = base_spec.replace(beam_width=4)
    ids, _, _ = fe.search(ds.queries[:64], spec=beam_spec)
    rec = recall_at_k(ids, gt[:64], 10)
    print(f"beam W=4: recall@10={rec:.3f}")

    # two-stage quantized distances: stage 1 reads uint8 code rows (4x fewer
    # bytes), stage 2 re-ranks only survivors in fp32 — `dist_calls` counts
    # fp32 evaluations, the row DMAs the SQ8 estimate avoided
    _, _, st_exact = fe.search(ds.queries[:64], spec=beam_spec)
    _, _, st_sq8 = fe.search(ds.queries[:64],
                             spec=beam_spec.replace(estimate="both"))
    calls_exact, calls_sq8 = int(st_exact.dist_calls), int(st_sq8.dist_calls)
    print(f"sq8 two-stage: fp32 calls {calls_exact} -> {calls_sq8} "
          f"({calls_sq8 / max(calls_exact, 1):.2f}x)")


if __name__ == "__main__":
    main()

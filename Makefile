# Developer entry points.  PYTHONPATH=src everywhere (src-layout, no install).

.PHONY: verify test bench bench-engine

# Fast tier: every push. Hard wall-clock timeout so a hung jit/compile
# fails loudly instead of wedging CI.
verify:
	PYTHONPATH=src timeout 420 python -m pytest -x -q -m "not slow"

# Full tier (the tier-1 command): everything, including slow markers.
test:
	PYTHONPATH=src python -m pytest -x -q

bench:
	PYTHONPATH=src python -m benchmarks.run

bench-engine:
	PYTHONPATH=src python -m benchmarks.run --only engine

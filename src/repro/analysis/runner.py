"""repolint runner: discover files, run checkers, report text/JSON.

``run_analysis`` is the library entry point (tests drive it directly);
``repro.analysis.__main__`` wraps it in a CLI.  Non-strict runs always
exit 0 (a report, not a gate); ``--strict`` exits 1 on any active finding
— that is the CI mode, where every known-deliberate exception must carry
a justified inline suppression.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, Iterable, List, Optional, Sequence

import repro.analysis.checkers  # noqa: F401  — registers the checker ids
from repro.analysis.core import (CHECKERS, Finding, Project, SourceFile,
                                 apply_suppressions)

_SKIP_DIRS = {"__pycache__", ".git", ".ruff_cache", ".pytest_cache"}


def discover_files(root: str, paths: Sequence[str]) -> List[SourceFile]:
    out: List[SourceFile] = []
    for p in paths:
        full = p if os.path.isabs(p) else os.path.join(root, p)
        if os.path.isfile(full):
            out.append(SourceFile.load(full, root))
            continue
        for dirpath, dirnames, filenames in os.walk(full):
            dirnames[:] = sorted(d for d in dirnames if d not in _SKIP_DIRS)
            for fn in sorted(filenames):
                if fn.endswith(".py"):
                    out.append(SourceFile.load(os.path.join(dirpath, fn),
                                               root))
    return out


@dataclasses.dataclass
class AnalysisResult:
    findings: List[Finding]              # active (unsuppressed)
    suppressed: List[Finding]
    parse_errors: List[Finding]
    files_scanned: int
    checks_run: List[str]

    @property
    def exit_code_strict(self) -> int:
        return 1 if (self.findings or self.parse_errors) else 0

    def to_dict(self) -> Dict[str, object]:
        return {
            "files_scanned": self.files_scanned,
            "checks_run": self.checks_run,
            "findings": [f.to_dict() for f in self.findings],
            "suppressed": [f.to_dict() for f in self.suppressed],
            "parse_errors": [f.to_dict() for f in self.parse_errors],
        }


def run_analysis(root: Optional[str] = None,
                 paths: Sequence[str] = ("src",),
                 checks: Optional[Iterable[str]] = None) -> AnalysisResult:
    """Run the registered checkers over ``paths`` (relative to ``root``).

    ``root`` defaults to the repo root inferred from this file's location
    (four levels up: src/repro/analysis/runner.py), which also anchors
    DESIGN.md lookups; pass it explicitly for fixture trees.
    """
    if root is None:
        root = os.path.abspath(os.path.join(os.path.dirname(__file__),
                                            "..", "..", ".."))
    files = discover_files(root, paths)
    project = Project(root, files)

    parse_errors = [
        Finding(checker="parse", path=sf.relpath, line=1,
                message=f"syntax error: {sf.parse_error}",
                hint="repolint skipped this file — fix the parse first")
        for sf in files if sf.parse_error is not None
    ]

    wanted = list(checks) if checks is not None else list(CHECKERS)
    unknown = [c for c in wanted if c not in CHECKERS]
    if unknown:
        raise SystemExit(
            f"unknown checker id(s): {', '.join(unknown)} "
            f"(valid: {', '.join(sorted(CHECKERS))})")

    findings: List[Finding] = []
    for cid in wanted:
        fn, _ = CHECKERS[cid]
        findings.extend(fn(project))
    active, suppressed = apply_suppressions(project, findings)
    active.sort(key=lambda f: (f.path, f.line, f.checker))
    suppressed.sort(key=lambda f: (f.path, f.line, f.checker))
    return AnalysisResult(findings=active, suppressed=suppressed,
                          parse_errors=parse_errors,
                          files_scanned=len(files), checks_run=wanted)


def render_text(result: AnalysisResult, *, show_suppressed: bool = False
                ) -> str:
    lines: List[str] = []
    for f in result.parse_errors + result.findings:
        lines.append(f.text())
    if show_suppressed and result.suppressed:
        lines.append("")
        lines.append(f"suppressed ({len(result.suppressed)}):")
        for f in result.suppressed:
            lines.append("  " + f.text().splitlines()[0])
    n = len(result.findings) + len(result.parse_errors)
    lines.append("")
    lines.append(
        f"repolint: {result.files_scanned} files, "
        f"{len(result.checks_run)} checkers, {n} finding(s), "
        f"{len(result.suppressed)} suppressed")
    return "\n".join(lines).lstrip("\n")


def write_json(result: AnalysisResult, path: str) -> None:
    with open(path, "w", encoding="utf-8") as f:
        json.dump(result.to_dict(), f, indent=2, sort_keys=True)
        f.write("\n")

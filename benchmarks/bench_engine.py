"""Beam-expansion engine benchmarks.

Two entries:

* ``engine_beam_sweep`` — the tuning sweep behind ``EngineConfig.beam_width``:
  for W in {1, 2, 4, 8} report hop-loop iterations, recall, per-query exact
  distance calls and QPS at equal efs.  The headline number is
  ``iter_reduction``: iterations(W=1) / iterations(W), which should track ~W
  until the frontier is too shallow to fill the beam.
* ``engine_pallas_parity`` — jnp vs Pallas engine on a small graph: asserts
  result parity and reports iterations + dist calls before/after (interpret
  mode — wall-clock here is NOT TPU performance, the parity + counter
  deltas are the point).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import cached_index, dataset, emit, timed
from repro.data.vectors import exact_ground_truth, recall_at_k


def engine_beam_sweep():
    ds = dataset("sift-synth", n_base=4000)
    idx = cached_index(ds)
    gt = exact_ground_truth(ds, k=10)
    derived = {}
    base_iters = {}
    # beam_prune policy only matters for pruning routers (see EngineConfig):
    # "best" holds the W=1 recall profile, "all" holds the W=1 call savings
    variants = (("none", "best"), ("crouting", "best"), ("crouting", "all"))
    for router, pol in variants:
        key = router if router == "none" else f"{router}_{pol}"
        rows = []
        for W in (1, 2, 4, 8):
            kw = dict(k=10, efs=64, router=router, beam_width=W,
                      beam_prune=pol)
            # warm with the full batch shape — jit caches per shape, so a
            # smaller warm-up batch would leave the compile in the timing
            idx.search(ds.queries, **kw)
            t0 = time.perf_counter()
            ids, _, info = idx.search(ds.queries, **kw)
            dt = time.perf_counter() - t0
            rows.append({
                "beam_width": W,
                "iters": info["iters"],
                "recall": round(recall_at_k(ids, gt, 10), 3),
                "dist_calls": round(float(info["dist_calls"].mean()), 1),
                "hops": round(float(info["hops"].mean()), 1),
                "qps": round(len(ds.queries) / dt, 1),
            })
            if W == 1:
                base_iters[key] = info["iters"]
        for r in rows:
            r["iter_reduction"] = round(base_iters[key] / max(r["iters"], 1), 2)
        derived[key] = rows
    emit("engine_beam_sweep", 0.0, {
        rt: {f"w{r['beam_width']}": {"iters": r["iters"],
                                     "x": r["iter_reduction"],
                                     "recall": r["recall"],
                                     "calls": r["dist_calls"]}
             for r in rows_}
        for rt, rows_ in derived.items()})
    return derived


def engine_pallas_parity():
    """jnp reference vs kernel-integrated engine: identical results, same
    dist-call counts, iterations cut by the beam."""
    from repro.core.index import AnnIndex

    ds = dataset("sift-synth", n_base=1200)
    ds_q = ds.queries[:8]
    idx = AnnIndex.build(ds.base, graph="hnsw", m=8, efc=48)
    derived = {}
    jnp_ids = {}
    for name, kw in (
            ("jnp_w1", dict(engine="jnp", beam_width=1)),
            ("jnp_w4", dict(engine="jnp", beam_width=4)),
            ("pallas_w1", dict(engine="pallas", beam_width=1)),
            ("pallas_w4", dict(engine="pallas", beam_width=4))):
        dt, out = timed(lambda: idx.search(ds_q, k=10, efs=48,
                                           router="crouting", **kw))
        ids, _, info = out
        row = {"iters": info["iters"],
               "dist_calls": round(float(info["dist_calls"].mean()), 1),
               "us_per_query": round(dt / len(ds_q) * 1e6, 1)}
        if kw["engine"] == "jnp":
            jnp_ids[kw["beam_width"]] = ids
        else:
            # each pallas variant is checked against its jnp twin (same W)
            row["ids_match_jnp"] = bool(
                (ids == jnp_ids[kw["beam_width"]]).all())
        derived[name] = row
    derived["iter_reduction_w4"] = round(
        derived["jnp_w1"]["iters"] / max(derived["pallas_w4"]["iters"], 1), 2)
    emit("engine_pallas_parity", 0.0, derived)
    return derived

"""Serving frontend (ISSUE 5 tentpole): ragged-batch equivalence against
direct ``AnnIndex.search``, zero-recompile bucket warmup, padded-lane
counter hygiene, admission control (oversized/backpressure/deadline), and
the telemetry digest."""
import time

import numpy as np
import pytest

from repro.core.index import AnnIndex
from repro.core.spec import SearchSpec, SearchStats
from repro.serve import (DeadlineExceeded, QueueFull, RequestRejected,
                         ServeFrontend, bucket_for, pad_to_bucket,
                         validate_buckets)

BUCKETS = (1, 8, 32, 64)
RAGGED = (1, 3, 8, 31, 64)


@pytest.fixture(scope="module")
def built(small_ds):
    return AnnIndex.build(small_ds.base, graph="hnsw", m=12, efc=64)


@pytest.fixture(scope="module")
def queries(small_ds):
    # RAGGED needs up to 64 rows; the fixture dataset ships 40 queries
    q = small_ds.queries
    return np.take(q, np.arange(max(RAGGED)) % len(q), axis=0)


def _frontend(built, spec, **kw):
    kw.setdefault("buckets", BUCKETS)
    return ServeFrontend(built, spec, **kw)


def _assert_stats_equal(a: SearchStats, b: SearchStats):
    for f in ("dist_calls", "est_calls", "rerank_calls", "sq8_calls", "hops"):
        np.testing.assert_array_equal(getattr(a, f), getattr(b, f), err_msg=f)
    for k in set(a.extra) | set(b.extra):
        np.testing.assert_array_equal(a.extra[k], b.extra[k], err_msg=k)


# --------------------------------------------------------------------------
# ragged-batch equivalence suite (satellite): every batch size, bit-identical
# --------------------------------------------------------------------------
def _ragged_sweep(built, queries, engine):
    spec = SearchSpec(k=10, efs=32, router="crouting", engine=engine)
    fe = _frontend(built, spec)
    sess = fe._base
    assert sess.engine.compile_count() == len(BUCKETS), \
        "warmup must pre-jit exactly one executable per rung"
    # direct references FIRST: they share the session's jitted fn and their
    # raw (un-bucketed) shapes 3/31 legitimately add executables to it
    direct = {n: built.search(queries[:n], spec=spec) for n in RAGGED}
    compiles0 = sess.engine.compile_count()
    for n in RAGGED:
        ids_f, d_f, st_f = fe.search(queries[:n])
        ids_d, d_d, st_d = direct[n]
        np.testing.assert_array_equal(ids_f, ids_d, err_msg=f"ids n={n}")
        np.testing.assert_array_equal(d_f, d_d, err_msg=f"dists n={n}")
        assert st_f.dist_calls.shape == (n,)
        _assert_stats_equal(st_f, st_d)
    # the ragged trace itself compiled NOTHING: every dispatch landed on a
    # pre-jitted bucket shape
    assert sess.engine.compile_count() == compiles0
    assert fe.telemetry.recompiles_after_warmup == 0
    summ = fe.telemetry.summary()
    assert all(b["compiles"] == 1 for b in summ["buckets"].values()), summ


def test_ragged_equivalence_jnp(built, queries):
    _ragged_sweep(built, queries, "jnp")


@pytest.mark.slow
def test_ragged_equivalence_pallas(built, queries):
    _ragged_sweep(built, queries, "pallas")


def test_coalesced_dispatch_matches_per_request_search(built, queries):
    """Several queued requests merge into ONE padded dispatch; every
    request's slice must still be bit-identical to its direct search."""
    spec = SearchSpec(k=10, efs=32, router="crouting")
    fe = _frontend(built, spec)
    sizes = (1, 3, 8, 5)
    offs = np.concatenate([[0], np.cumsum(sizes)])
    futs = [fe.submit(queries[offs[i]:offs[i + 1]], k=5 + i)
            for i in range(len(sizes))]
    assert fe.flush() == 1, "17 rows + one cos_theta must be one dispatch"
    for i, f in enumerate(futs):
        q = queries[offs[i]:offs[i + 1]]
        ids_f, d_f, st_f = f.result()
        assert ids_f.shape == (sizes[i], 5 + i)
        ids_d, d_d, st_d = built.search(q, spec=spec.replace(k=5 + i))
        np.testing.assert_array_equal(ids_f, ids_d)
        np.testing.assert_array_equal(d_f, d_d)
        _assert_stats_equal(st_f, st_d)


def test_padded_lanes_contribute_zero_counters(built, queries):
    """Engine-level contract behind the frontend slicing: a bucket-padded
    batch with a valid mask reports bit-equal counters on the real lanes
    and exact zero on the padded ones."""
    import jax.numpy as jnp

    from repro.core.search import build_search_fn, _search_batch
    from repro.core.search import _graph_arrays_cached

    g = built.graph
    spec = SearchSpec(k=10, efs=32, router="crouting",
                      metric=g.metric,
                      use_hierarchy=g.upper_neighbors is not None)
    build_search_fn(g, spec)   # populate the arrays cache
    arrays = _graph_arrays_cached(g)
    ct = jnp.asarray(built.profile.cos_theta_star, jnp.float32)
    qp, valid = pad_to_bucket(queries[:3], 8)
    res = _search_batch(arrays, jnp.asarray(qp), ct, spec,
                        valid=jnp.asarray(valid))
    ref = _search_batch(arrays, jnp.asarray(queries[:3]), ct, spec)
    for f in ("dist_calls", "est_calls", "hops"):
        r = np.asarray(getattr(res, f))
        assert (r[3:] == 0).all(), f"padded lanes leaked into {f}"
        np.testing.assert_array_equal(r[:3], np.asarray(getattr(ref, f)))
    np.testing.assert_array_equal(np.asarray(res.ids[:3]),
                                  np.asarray(ref.ids))


# --------------------------------------------------------------------------
# sessions: request-only overrides reuse the engine, new specs warm anew
# --------------------------------------------------------------------------
def test_request_only_overrides_do_not_recompile(built, queries):
    spec = SearchSpec(k=10, efs=32, router="crouting")
    fe = _frontend(built, spec)
    c0 = fe._base.engine.compile_count()
    fe.search(queries[:4], k=3)
    fe.search(queries[:4], cos_theta=0.55)
    fe.search(queries[:4], spec=spec.replace(k=7, cos_theta=0.9))
    assert fe._base.engine.compile_count() == c0
    assert len(fe._sessions) == 1, "request-only specs must share the session"


def test_engine_shaping_spec_opens_new_session(built, queries):
    spec = SearchSpec(k=10, efs=32, router="crouting")
    fe = _frontend(built, spec)
    fe.search(queries[:2], spec=spec.replace(efs=48))
    assert len(fe._sessions) == 2
    assert fe.telemetry.recompiles_after_warmup == 0, \
        "a fresh session warms its buckets off the request path"


# --------------------------------------------------------------------------
# admission control
# --------------------------------------------------------------------------
def test_oversized_request_rejected_not_truncated(built, queries):
    fe = _frontend(built, SearchSpec(efs=32, router="crouting"),
                   buckets=(1, 8))
    with pytest.raises(RequestRejected, match="exceeds the largest bucket"):
        fe.submit(queries[:9])
    assert fe.telemetry.rejected == 1


def test_k_beyond_session_efs_rejected(built, queries):
    fe = _frontend(built, SearchSpec(efs=32, router="crouting"))
    with pytest.raises(RequestRejected, match="recompile"):
        fe.submit(queries[:2], k=33)


def test_dim_mismatch_rejected(built):
    fe = _frontend(built, SearchSpec(efs=32, router="crouting"))
    with pytest.raises(RequestRejected, match="dim"):
        fe.submit(np.zeros((2, 7), np.float32))


def test_backpressure_queue_full(built, queries):
    fe = _frontend(built, SearchSpec(efs=32, router="crouting"),
                   max_pending_rows=10)
    fe.submit(queries[:8])
    with pytest.raises(QueueFull):
        fe.submit(queries[:8])
    fe.flush()
    fe.submit(queries[:8])    # drained: admitted again
    fe.flush()


def test_expired_deadline_fails_future(built, queries):
    fe = _frontend(built, SearchSpec(efs=32, router="crouting"))
    fut = fe.submit(queries[:2], timeout=1e-4)
    time.sleep(0.01)
    fe.flush()
    with pytest.raises(DeadlineExceeded):
        fut.result()
    assert fe.telemetry.expired == 1


def test_default_timeout_expires_only_stale_requests(built, queries):
    """``default_timeout`` is the admission deadline for every request that
    doesn't set its own: one queued past it fails with ``DeadlineExceeded``
    at dispatch, while a later-admitted request in the SAME flush (with a
    live deadline) resolves normally."""
    fe = _frontend(built, SearchSpec(efs=32, router="crouting"),
                   default_timeout=0.05)
    f_stale = fe.submit(queries[:2])            # inherits the 50ms default
    time.sleep(0.12)
    f_live = fe.submit(queries[:3], timeout=30.0)
    assert fe.flush() == 1, "only the live request dispatches"
    with pytest.raises(DeadlineExceeded):
        f_stale.result(timeout=5)
    ids, _, _ = f_live.result(timeout=5)
    assert ids.shape == (3, 10)
    assert fe.telemetry.expired == 1
    assert fe.telemetry.served == 1


def test_stop_drains_expired_and_live_correctly(built, queries):
    """``stop()``'s final drain applies the same deadline split: expired
    requests fail typed, live ones resolve — nothing is stranded.  The
    state lock (reentrant for this thread) parks the worker's flush so both
    requests are still queued when the deadline passes."""
    fe = _frontend(built, SearchSpec(efs=32, router="crouting"),
                   default_timeout=0.05)
    with fe._lock:
        fe.start(poll_s=0.005)
        f_stale = fe.submit(queries[:2])        # default 50ms deadline
        f_live = fe.submit(queries[:3], timeout=30.0)
        time.sleep(0.12)                        # both still queued
    fe.stop()
    with pytest.raises(DeadlineExceeded):
        f_stale.result(timeout=5)
    ids, _, _ = f_live.result(timeout=5)
    assert ids.shape == (3, 10)
    assert fe.telemetry.expired == 1


def test_admitted_future_always_resolves(built, queries):
    """Once dispatched, a request completes even if its deadline passes
    mid-flight (admission deadline, not a compute kill switch)."""
    fe = _frontend(built, SearchSpec(efs=32, router="crouting"))
    fut = fe.submit(queries[:2], timeout=30.0)
    fe.flush()
    ids, _, _ = fut.result(timeout=5)
    assert ids.shape == (2, 10)


def test_failed_dispatch_only_fails_its_own_batch(built, queries,
                                                  monkeypatch):
    """An engine failure lands on the failing dispatch's futures; requests
    in OTHER dispatch groups (already drained from the queue) still
    resolve — an admitted future always resolves."""
    fe = _frontend(built, SearchSpec(efs=32, router="crouting"))
    sess = fe._base
    orig = sess.engine.search_padded

    def flaky(qp, n_valid, k, ct):
        if ct == 0.123:
            raise RuntimeError("boom")
        return orig(qp, n_valid, k, ct)

    monkeypatch.setattr(sess.engine, "search_padded", flaky)
    f_bad = fe.submit(queries[:2], cos_theta=0.123)   # its own ct group
    f_good = fe.submit(queries[:3], cos_theta=0.9)
    fe.flush()
    with pytest.raises(RuntimeError, match="boom"):
        f_bad.result(timeout=5)
    ids, _, _ = f_good.result(timeout=5)
    assert ids.shape == (3, 10)


# --------------------------------------------------------------------------
# worker thread + telemetry digest
# --------------------------------------------------------------------------
def test_worker_thread_serves(built, queries):
    with _frontend(built, SearchSpec(efs=32, router="crouting")) as fe:
        futs = [fe.submit(queries[:n]) for n in (1, 3, 8)]
        outs = [f.result(timeout=30) for f in futs]
    assert [o[0].shape[0] for o in outs] == [1, 3, 8]


def test_health_reports_frontend_and_backend(built, queries):
    """ISSUE 8 satellite: one structured health() dict for probes."""
    fe = _frontend(built, SearchSpec(efs=32, router="crouting"))
    h = fe.health()
    assert h["stopped"] is False and h["worker_alive"] is False
    assert h["queue_depth_rows"] == 0 and h["queued_requests"] == 0
    assert h["worker_error"] is None and h["worker_errors_total"] == 0
    assert h["backend"]["kind"] == "single"
    assert h["backend"]["degraded"] is False
    fe.submit(queries[:3])                       # queued, worker not running
    h = fe.health()
    assert h["queue_depth_rows"] == 3 and h["queued_requests"] == 1
    with fe:
        assert fe.health()["worker_alive"] is True
    h = fe.health()
    assert h["stopped"] is True and h["worker_alive"] is False


def test_stop_idempotent_and_submit_after_stop_rejected(built, queries):
    from repro.serve import FrontendStopped

    fe = _frontend(built, SearchSpec(efs=32, router="crouting"))
    fe.start()
    fut = fe.submit(queries[:2])
    fe.stop()
    assert fut.result(timeout=30)[0].shape[0] == 2   # drained on stop
    fe.stop()                                        # idempotent: no error
    fe.stop()
    with pytest.raises(FrontendStopped):
        fe.submit(queries[:1])
    # FrontendStopped is a RequestRejected: admission-error handlers catch it
    assert issubclass(FrontendStopped, RequestRejected)
    # start() reopens the frontend
    with fe.start():
        out = fe.submit(queries[:2]).result(timeout=30)
    assert out[0].shape[0] == 2


def test_telemetry_summary_folds_search_stats(built, queries):
    fe = _frontend(built, SearchSpec(efs=32, router="crouting"))
    for n in (1, 3, 8):
        fe.search(queries[:n])
    summ = fe.telemetry.summary()
    assert summ["requests"]["served"] == 3
    assert summ["recompiles_after_warmup"] == 0
    for key in ("p50_ms", "p95_ms", "p99_ms"):
        assert summ["latency"][key] is not None
    assert summ["qps"] > 0
    # the engine counters fold through SearchStats.merge -> one summary()
    # over the whole trace (12 queries -> per-query means)
    assert summ["search"]["router"] == "crouting"
    assert summ["search"]["dist_calls"] > 0
    merged = fe.telemetry.merged_stats()
    assert merged.dist_calls.shape == (12,)


# --------------------------------------------------------------------------
# windowed telemetry (ISSUE 9 satellite): wraparound-correct epochs
# --------------------------------------------------------------------------
def test_telemetry_window_wraparound_percentiles_and_qps():
    """Regression: after the WINDOW-bounded deques wrap, the windowed
    percentiles and QPS must cover exactly the last WINDOW requests —
    early samples roll off instead of poisoning the digest.  Completion
    timestamps are injected so the numbers are exact."""
    from repro.serve.telemetry import WINDOW, ServeTelemetry

    tm = ServeTelemetry()
    # 600 poisoned 100ms samples that must roll off entirely...
    for i in range(600):
        tm.observe_request_done(0.100, 0.0, now=float(i))
    # ...then a full WINDOW of 10ms samples at exactly 1000 QPS
    prev = None
    for i in range(WINDOW):
        if i == WINDOW - 100:
            prev = tm.window_snapshot()
        tm.observe_request_done(0.010, 0.0, now=1000.0 + i * 1e-3)
    snap = tm.window_snapshot()
    assert snap["served"] == 600 + WINDOW        # lifetime counter keeps all
    assert len(snap["_lat_s"]) == WINDOW         # sample window stays bounded
    assert snap["latency"]["p50_ms"] == 10.0     # no 100ms survivor anywhere
    assert snap["latency"]["p99_ms"] == 10.0
    assert snap["window_qps"] == pytest.approx(1000.0, rel=0.01)
    # epoch diff across the wrap: exactly the last 100 requests
    delta = ServeTelemetry.window_delta(prev, snap)
    assert delta["served"] == 100 and not delta["clipped"]
    assert delta["p99_ms"] == 10.0 and delta["qps"] is not None
    # an epoch longer than WINDOW degrades to the window — and says so
    for i in range(WINDOW + 50):
        tm.observe_request_done(0.005, 0.0, now=2000.0 + i * 1e-3)
    delta = ServeTelemetry.window_delta(snap, tm.window_snapshot())
    assert delta["served"] == WINDOW + 50 and delta["clipped"]
    assert delta["p99_ms"] == 5.0


def test_health_exposes_active_spec_window_and_autotune(built, queries):
    """ISSUE 9 satellite: health() carries the active canonical spec, the
    windowed latency digest, and the attached controller's state (None
    when nothing is attached)."""
    import dataclasses as dc

    spec = SearchSpec(k=10, efs=32, router="crouting")
    fe = _frontend(built, spec)
    h = fe.health()
    assert h["autotune"] is None
    assert set(h["active_spec"]) == {f.name for f in dc.fields(SearchSpec)}
    assert h["active_spec"]["efs"] == 32
    assert h["active_spec"]["router"] == "crouting"
    assert h["latency_window"] == {"p99_ms": None, "qps": None, "served": 0}
    for n in (1, 3, 8):
        fe.search(queries[:n])
    h = fe.health()
    assert h["latency_window"]["served"] == 3
    assert h["latency_window"]["p99_ms"] > 0
    # a hot-swap shows up immediately
    fe.activate_spec(spec.replace(efs=48))
    assert fe.health()["active_spec"]["efs"] == 48


def test_hot_swap_mid_trace_completes_every_request(built, queries):
    """ISSUE 9 satellite: a ragged trace concurrent with controller spec
    switches (the ``activate_spec`` promotion path) completes every
    admitted request — no dropped futures, zero request-path recompiles,
    pre-warm strictly off the request path."""
    spec = SearchSpec(k=10, efs=32, router="crouting")
    fe = _frontend(built, spec)
    rich = spec.replace(efs=48)
    sizes = [RAGGED[i % len(RAGGED)] for i in range(30)]
    with fe:
        futs = []
        for i, n in enumerate(sizes):
            futs.append(fe.submit(queries[:n]))
            if i == 10:       # mid-trace upgrade: new session, cold
                assert fe.activate_spec(rich).canonical().efs == 48
            if i == 20:       # and back: old session still warm
                fe.activate_spec(spec)
        outs = [f.result(timeout=60) for f in futs]
    assert [o[0].shape[0] for o in outs] == sizes
    assert fe.telemetry.served == len(sizes)
    assert fe.telemetry.expired == 0 and fe.telemetry.failed == 0
    assert fe.telemetry.recompiles_after_warmup == 0
    assert len(fe._sessions) == 2
    assert fe.active_spec.canonical() == spec.canonical()


# --------------------------------------------------------------------------
# bucketing helpers
# --------------------------------------------------------------------------
def test_bucket_ladder_helpers():
    assert validate_buckets((32, 1, 8, 8)) == (1, 8, 32)
    assert bucket_for(1, (1, 8)) == 1
    assert bucket_for(2, (1, 8)) == 8
    with pytest.raises(ValueError):
        bucket_for(9, (1, 8))
    with pytest.raises(ValueError):
        validate_buckets(())
    q = np.arange(12, dtype=np.float32).reshape(3, 4)
    qp, valid = pad_to_bucket(q, 8)
    assert qp.shape == (8, 4) and valid.sum() == 3 and valid[:3].all()
    np.testing.assert_array_equal(qp[3], q[0])   # pad repeats real rows
    qs, vs = pad_to_bucket(q, 3)
    assert qs is q and vs.all()

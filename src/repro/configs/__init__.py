"""Assigned-architecture registry: ``--arch <id>`` resolution.

Each module defines SPEC (an ArchSpec).  The 10 assigned archs + the paper's
own ANNS serving config.  get_arch(id) / list_archs() are the public API.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Any, Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    shape_id: str
    step: str                 # train | prefill | serve | retrieval
    dims: Dict[str, int]
    notes: str = ""


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    family: str               # lm | gnn | recsys | anns
    model_cfg: Any
    shapes: Tuple[ShapeSpec, ...]
    source: str = ""          # provenance [arXiv / hf]
    smoke_cfg: Optional[Any] = None   # reduced config for CPU smoke tests

    def shape(self, shape_id: str) -> ShapeSpec:
        for s in self.shapes:
            if s.shape_id == shape_id:
                return s
        raise KeyError(f"{self.arch_id}: unknown shape {shape_id!r}")


_MODULES = {
    "granite-8b": "granite_8b",
    "phi4-mini-3.8b": "phi4_mini_3_8b",
    "qwen1.5-4b": "qwen1_5_4b",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "arctic-480b": "arctic_480b",
    "schnet": "schnet",
    "gat-cora": "gat_cora",
    "egnn": "egnn",
    "gin-tu": "gin_tu",
    "dlrm-mlperf": "dlrm_mlperf",
    "crouting-anns": "crouting_paper",
}

_CACHE: Dict[str, ArchSpec] = {}


def get_arch(arch_id: str) -> ArchSpec:
    if arch_id not in _CACHE:
        mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
        _CACHE[arch_id] = mod.SPEC
    return _CACHE[arch_id]


def list_archs(include_anns: bool = False):
    ids = [a for a in _MODULES if a != "crouting-anns"]
    return ids + (["crouting-anns"] if include_anns else [])

"""Deterministic synthetic batches for every family (smoke tests, examples,
and the end-to-end train driver).  All generators are pure functions of seed."""
from __future__ import annotations

from typing import Dict

import numpy as np


# --------------------------------------------------------------------------
# LM token stream
# --------------------------------------------------------------------------
def lm_batch(vocab: int, batch: int, seq: int, seed: int = 0) -> Dict:
    """Markov-ish synthetic tokens (structured enough that loss decreases)."""
    rng = np.random.default_rng(seed)
    base = rng.integers(0, vocab, size=(batch, seq + 1), dtype=np.int32)
    # inject learnable bigram structure: half the positions repeat prev+1
    rep = rng.random((batch, seq)) < 0.5
    nxt = (base[:, :-1] + 1) % vocab
    base[:, 1:][rep] = nxt[rep]
    return {"tokens": base[:, :-1], "labels": base[:, 1:]}


class LMStream:
    """Deterministic, checkpointable token stream (cursor = step index)."""

    def __init__(self, vocab: int, batch: int, seq: int, seed: int = 0):
        self.vocab, self.batch, self.seq, self.seed = vocab, batch, seq, seed
        self.step = 0

    def next(self) -> Dict:
        b = lm_batch(self.vocab, self.batch, self.seq,
                     seed=self.seed * 1_000_003 + self.step)
        self.step += 1
        return b

    def state(self):
        return {"step": self.step, "seed": self.seed}

    def restore(self, state):
        self.step = int(state["step"])
        self.seed = int(state["seed"])


# --------------------------------------------------------------------------
# graphs
# --------------------------------------------------------------------------
def random_graph_batch(n_nodes: int, n_edges: int, d_feat: int,
                       n_classes: int, n_graphs: int = 1, seed: int = 0,
                       task: str = "node_class") -> Dict:
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n_nodes, size=n_edges, dtype=np.int32)
    dst = rng.integers(0, n_nodes, size=n_edges, dtype=np.int32)
    graph_ids = np.sort(rng.integers(0, n_graphs, size=n_nodes)).astype(np.int32) \
        if n_graphs > 1 else np.zeros(n_nodes, np.int32)
    batch = {
        "node_feat": rng.normal(size=(n_nodes, d_feat)).astype(np.float32),
        "pos": rng.normal(size=(n_nodes, 3)).astype(np.float32) * 3.0,
        "atom_z": rng.integers(1, 20, size=n_nodes).astype(np.int32),
        "edge_src": src, "edge_dst": dst,
        "node_mask": np.ones(n_nodes, np.float32),
        "edge_mask": np.ones(n_edges, np.float32),
        "labels": rng.integers(0, n_classes, size=n_nodes).astype(np.int32),
        "label_mask": np.ones(n_nodes, np.float32),
        "graph_ids": graph_ids,
    }
    if task == "graph_class":
        batch["g_labels"] = rng.integers(0, n_classes, size=n_graphs).astype(np.int32)
    else:
        batch["g_labels"] = rng.normal(size=n_graphs).astype(np.float32)
    return batch


def neighbor_sample(adj_src: np.ndarray, adj_dst: np.ndarray, n_nodes: int,
                    seeds: np.ndarray, fanouts, seed: int = 0) -> Dict:
    """Real k-hop uniform neighbor sampler (GraphSAGE-style) over a CSR-ified
    edge list.  Returns the sampled subgraph with node renumbering."""
    rng = np.random.default_rng(seed)
    order = np.argsort(adj_dst, kind="stable")
    sorted_src = adj_src[order]
    starts = np.searchsorted(adj_dst[order], np.arange(n_nodes + 1))
    node_set = list(seeds)
    node_pos = {int(s): i for i, s in enumerate(seeds)}
    sub_src, sub_dst = [], []
    frontier = list(seeds)
    for fan in fanouts:
        nxt = []
        for u in frontier:
            lo, hi = starts[u], starts[u + 1]
            if hi <= lo:
                continue
            cand = sorted_src[lo:hi]
            take = cand if len(cand) <= fan else rng.choice(cand, fan, replace=False)
            for v in take:
                v = int(v)
                if v not in node_pos:
                    node_pos[v] = len(node_set)
                    node_set.append(v)
                    nxt.append(v)
                sub_src.append(node_pos[v])
                sub_dst.append(node_pos[u])
        frontier = nxt
    return {
        "nodes": np.asarray(node_set, np.int64),
        "edge_src": np.asarray(sub_src, np.int32),
        "edge_dst": np.asarray(sub_dst, np.int32),
    }


# --------------------------------------------------------------------------
# recsys (Criteo-like)
# --------------------------------------------------------------------------
def dlrm_batch(n_dense: int, vocab_sizes, batch: int, seed: int = 0) -> Dict:
    rng = np.random.default_rng(seed)
    sparse = np.stack(
        [rng.integers(0, v, size=batch) for v in vocab_sizes], axis=1
    ).astype(np.int32)
    dense = rng.lognormal(size=(batch, n_dense)).astype(np.float32)
    # learnable structure: label correlates with one dense feature
    logit = (dense[:, 0] - np.median(dense[:, 0])) + 0.1 * rng.normal(size=batch)
    return {"dense": dense, "sparse_ids": sparse,
            "labels": (logit > 0).astype(np.float32)}

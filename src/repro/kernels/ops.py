"""Jit'd public wrappers around the Pallas kernels.

Handles padding to block multiples, dtype plumbing, and the CPU/TPU switch:
on this container the kernels execute in interpret mode (Python semantics,
bit-accurate vs the TPU lowering's math); on a real TPU backend set
``interpret=False`` (the default flips automatically off-CPU).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.kernels.l2_distance import l2_distance_pallas
from repro.kernels.crouting_prune import crouting_prune_pallas
from repro.kernels.gather_distance import gather_distance_pallas
from repro.kernels.pool_merge import pool_merge_pallas


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x, mult, axis, value):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def l2_distance(q, x, mode: str = "l2", bq: int = 128, bc: int = 256,
                bd: int = 512, interpret=None):
    """Distance matrix [Q, C]; pads freely, slices back."""
    interpret = _default_interpret() if interpret is None else interpret
    Q, d = q.shape
    C = x.shape[0]
    bq_, bc_, bd_ = min(bq, Q), min(bc, C), min(bd, d)
    qp = _pad_to(q, bq_, 0, 0.0)
    xp = _pad_to(x, bc_, 0, 0.0)
    qp = _pad_to(qp, bd_, 1, 0.0)
    xp = _pad_to(xp, bd_, 1, 0.0)
    out = l2_distance_pallas(qp, xp, bq=bq_, bc=bc_, bd=bd_, mode=mode,
                             interpret=interpret)
    return out[:Q, :C]


def crouting_prune(ed, dcq, bound2, valid, cos_theta, bb: int = 8,
                   interpret=None):
    """Fused estimate + prune mask; pads B to the row-block, M to lanes.

    dcq/bound2 may be [B] (classic one-node expansion, broadcast over lanes)
    or per-lane [B, M] (beam tiles, where each lane's expansion node — and
    for non-L2 metrics its rank-space bound — differs)."""
    interpret = _default_interpret() if interpret is None else interpret
    B, M = ed.shape
    if dcq.ndim == 1:
        dcq = jnp.broadcast_to(dcq[:, None], (B, M))
    if bound2.ndim == 1:
        bound2 = jnp.broadcast_to(bound2[:, None], (B, M))
    edp = _pad_to(_pad_to(ed, 128, 1, jnp.inf), bb, 0, jnp.inf)
    vp = _pad_to(_pad_to(valid.astype(jnp.int8), 128, 1, 0), bb, 0, 0)
    dcqp = _pad_to(_pad_to(dcq, 128, 1, 0.0), bb, 0, 0.0)
    b2p = _pad_to(_pad_to(bound2, 128, 1, 0.0), bb, 0, 0.0)
    est2, mask = crouting_prune_pallas(edp, dcqp, b2p, vp, cos_theta,
                                       bb=bb, interpret=interpret)
    return est2[:B, :M], mask[:B, :M]


def gather_distance(indices, queries, table, interpret=None):
    """Fused gather+distance; prune-masked callers remap lanes to the pad
    row (table's last row, the repo-wide sentinel — see
    core.search.graph_device_arrays)."""
    interpret = _default_interpret() if interpret is None else interpret
    return gather_distance_pallas(indices.astype(jnp.int32), queries, table,
                                  interpret=interpret)


def gather_distance_pruned(nbr_ids, prune_mask, queries, table, interpret=None):
    """CRouting-integrated exact path: pruned lanes fetch the sentinel pad
    row — the table's LAST row, matching the engine's pad-row convention
    (graph_device_arrays appends a zero row at index N) — de-duplicated DMA
    on TPU — and report +inf."""
    pad_row = table.shape[0] - 1
    idx = jnp.where(prune_mask != 0, pad_row, nbr_ids).astype(jnp.int32)
    d2 = gather_distance(idx, queries, table, interpret=interpret)
    return jnp.where(prune_mask != 0, jnp.inf, d2)


def pool_merge(pool_d, pool_i, new_d, new_i, bb: int = 8, interpret=None):
    """Merge new candidates into sorted pools, keep best P."""
    interpret = _default_interpret() if interpret is None else interpret
    B = pool_d.shape[0]
    args = [pool_d, pool_i.astype(jnp.int32), new_d, new_i.astype(jnp.int32)]
    args = [_pad_to(a, bb, 0, v) for a, v in zip(args, (jnp.inf, -1, jnp.inf, -1))]
    d, i = pool_merge_pallas(*args, bb=bb, interpret=interpret)
    return d[:B], i[:B]


def sq8_estimate(nbrs, queries, eval_mask, codes, lo, scale, eps,
                 interpret=None):
    """Stage-1 quantized distance estimate + conservative lower bound over a
    neighbor tile (two-stage engine, core/search.py).

    nbrs [B, L] rows of the uint8 code table; lanes with eval_mask == 0 (or
    out-of-range ids) skip the code-row DMA and report +inf for both
    outputs.  Returns (ad2, lb2) in squared-Euclidean space.
    """
    from repro.kernels.sq8_distance import sq8_distance_pallas
    interpret = _default_interpret() if interpret is None else interpret
    nbrs = nbrs.astype(jnp.int32)
    # same guard as fused_expand: the kernel DMAs row indices unchecked
    in_range = (nbrs < codes.shape[0]).astype(jnp.int8)
    eval_mask = (in_range if eval_mask is None
                 else eval_mask.astype(jnp.int8) & in_range)
    return sq8_distance_pallas(nbrs, queries.astype(jnp.float32), lo, scale,
                               eps, eval_mask, codes, interpret=interpret)


def fused_expand(nbrs, queries, ed, dcq, bound2, cos_theta, table,
                 eval_mask=None, prune_eligible=None, interpret=None):
    """Fused CRouting expansion: estimate + prune + conditional gather +
    exact distance in one kernel (the paper's Alg. 2 inner loop).

    dcq/bound2 may be [B] (broadcast over lanes) or per-lane [B, L] for the
    beam engine's [B, W*M] tiles.  eval_mask marks lanes to evaluate exactly
    when not pruned; prune_eligible marks lanes the estimate test applies
    to.  Both default to "neighbor id in range" (the standalone semantics).
    """
    from repro.kernels.fused_expand import fused_expand_pallas
    interpret = _default_interpret() if interpret is None else interpret
    nbrs = nbrs.astype(jnp.int32)
    B, L = nbrs.shape
    if dcq.ndim == 1:
        dcq = jnp.broadcast_to(dcq[:, None], (B, L))
    if bound2.ndim == 1:
        bound2 = jnp.broadcast_to(bound2[:, None], (B, L))
    # always intersect with in-range: the kernel DMAs nbr row indices
    # unchecked, so an out-of-range id in a caller's mask would be an OOB
    # HBM read on real TPU
    in_range = (nbrs < table.shape[0]).astype(jnp.int8)
    eval_mask = (in_range if eval_mask is None
                 else eval_mask.astype(jnp.int8) & in_range)
    prune_eligible = (in_range if prune_eligible is None
                      else prune_eligible.astype(jnp.int8) & in_range)
    return fused_expand_pallas(nbrs, queries, ed.astype(jnp.float32),
                               dcq.astype(jnp.float32),
                               bound2.astype(jnp.float32), cos_theta,
                               eval_mask, prune_eligible, table,
                               interpret=interpret)

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run driver (deliverable (e)).

For every (architecture x input shape x mesh) cell: build the Cell, lower the
step with the production shardings, .compile(), and record
memory_analysis/cost_analysis/collective schedule + the three roofline terms.
Results append to an incremental JSON cache (reruns skip completed cells).

  PYTHONPATH=src python -m repro.launch.dryrun                 # all cells
  PYTHONPATH=src python -m repro.launch.dryrun --arch granite-8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --mesh multi --include-anns
"""
import argparse
import json
import time
import traceback

import jax

from repro.configs import get_arch, list_archs
from repro.launch.mesh import make_production_mesh
from repro.models.api import build_cell
from repro.roofline.analysis import analyze_compiled

DEFAULT_OUT = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "dryrun_results.json")


def _compile_cell(cell, mesh):
    with mesh:   # ambient mesh so activation shard_hints bind (layers.py)
        jitted = jax.jit(cell.step_fn, in_shardings=cell.in_shardings,
                         out_shardings=cell.out_shardings)
        lowered = jitted.lower(*cell.arg_specs)
        return lowered.compile()


def _raw_terms(compiled):
    from repro.roofline.analysis import parse_collectives
    cost = compiled.cost_analysis()
    return (float(cost.get("flops", 0.0)),
            float(cost.get("bytes accessed", 0.0)),
            parse_collectives(compiled.as_text()).ring_bytes)


def run_cell(arch_id: str, shape_id: str, multi_pod: bool) -> dict:
    from repro.roofline.analysis import roofline_terms

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = 1
    for a in mesh.axis_names:
        n_dev *= mesh.shape[a]
    spec = get_arch(arch_id)
    cell = build_cell(spec, shape_id, mesh)
    t0 = time.time()
    compiled = _compile_cell(cell, mesh)
    t_compile = time.time() - t0
    rec = analyze_compiled(compiled, n_dev, cell.model_flops)

    # ---- loop-corrected accounting (cost_analysis counts scan bodies once;
    # EXPERIMENTS.md §Roofline methodology) ---------------------------------
    flops, nbytes, coll = (rec["hlo_flops_per_dev"], rec["hlo_bytes_per_dev"],
                           rec["collective_wire_bytes"])
    correction = "none"
    if cell.loop_fit is not None:
        L, build = cell.loop_fit
        f1 = _raw_terms(_compile_cell(build(1), mesh))
        f2 = _raw_terms(_compile_cell(build(2), mesh))
        body = tuple(max(b - a, 0.0) for a, b in zip(f1, f2))
        outer = tuple(max(a - d, 0.0) for a, d in zip(f1, body))
        flops, nbytes, coll = (o + L * b for o, b in zip(outer, body))
        correction = f"2pt-fit L={L}"
    elif cell.body_multiplier != 1.0:
        flops *= cell.body_multiplier
        nbytes *= cell.body_multiplier
        coll *= cell.body_multiplier
        correction = f"body x{cell.body_multiplier:.0f}"
    if cell.analytic_extra:
        flops += cell.analytic_extra.get("flops", 0.0)
        nbytes += cell.analytic_extra.get("bytes", 0.0)
        correction += " +analytic(attn,loss)"
    terms = roofline_terms(flops, nbytes, coll,
                           model_flops_per_dev=cell.model_flops / n_dev)
    rec.update(terms)
    rec.update({
        "hlo_flops_per_dev": flops, "hlo_bytes_per_dev": nbytes,
        "collective_wire_bytes": coll,
        "raw_flops_per_dev_body_once": _raw_terms(compiled)[0],
        "loop_correction": correction,
        "arch": arch_id, "shape": shape_id,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": n_dev, "step": cell.step_name,
        "model_flops_total": cell.model_flops,
        "compile_s": round(t_compile, 2),
        "notes": cell.notes, "status": "ok",
    })
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default=os.path.abspath(DEFAULT_OUT))
    ap.add_argument("--include-anns", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    cache = {}
    if os.path.exists(args.out):
        with open(args.out) as f:
            cache = json.load(f)

    archs = [args.arch] if args.arch else list_archs(include_anns=args.include_anns)
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    for arch_id in archs:
        spec = get_arch(arch_id)
        shapes = [args.shape] if args.shape else [s.shape_id for s in spec.shapes]
        for shape_id in shapes:
            for mp in meshes:
                key = f"{arch_id}|{shape_id}|{'2x16x16' if mp else '16x16'}"
                if key in cache and cache[key].get("status") == "ok" and not args.force:
                    print(f"[skip] {key}")
                    continue
                print(f"[run ] {key} ...", flush=True)
                try:
                    rec = run_cell(arch_id, shape_id, mp)
                    print(f"   ok: mem={rec['mem_total_bytes']/1e9:.2f}GB/dev "
                          f"flops={rec['hlo_flops_per_dev']:.3e} "
                          f"dom={rec['dominant']} "
                          f"t=({rec['compute_s']:.2e},{rec['memory_s']:.2e},"
                          f"{rec['collective_s']:.2e})s "
                          f"compile={rec['compile_s']}s", flush=True)
                except Exception as e:   # noqa: BLE001 — sweep survey: record + continue
                    rec = {"arch": arch_id, "shape": shape_id,
                           "mesh": "2x16x16" if mp else "16x16",
                           "status": "error", "error": repr(e),
                           "trace": traceback.format_exc()[-2000:]}
                    print(f"   ERROR: {e!r}", flush=True)
                cache[key] = rec
                with open(args.out, "w") as f:
                    json.dump(cache, f, indent=1)

    n_ok = sum(1 for v in cache.values() if v.get("status") == "ok")
    print(f"\n{n_ok}/{len(cache)} cells ok -> {args.out}")


if __name__ == "__main__":
    main()

"""repolint: repo-specific static analysis for conventions nothing else checks.

The serving stack runs five cooperating thread domains (serve worker,
background merge, WAL group commit, autotune driver, shard pool) whose
correctness rests on *conventions*: which attribute is guarded by which
lock, which ``SearchSpec`` knobs are request-only, which failpoint names
exist, what a ``noqa: BLE001`` handler must do with the failure.  A missed
``with self._lock`` or a traced-value ``if`` inside a jitted path silently
breaks the zero-recompile and crash-safety guarantees the benchmarks
measure — so this package checks the conventions over Python's ``ast``
(DESIGN.md §13).

Usage::

    python -m repro.analysis             # scan src/, text report
    python -m repro.analysis --strict    # exit 1 on any finding (CI)
    python -m repro.analysis --json out.json

Checkers (see ``repro.analysis.checkers``):

* ``guarded-by``     — ``# guarded by: self._lock`` attribute annotations
* ``lock-order``     — declared lock-order table vs nested acquisitions
* ``trace-safety``   — Python control flow on traced values in jit contexts
* ``cache-key``      — SearchSpec field classification + cache-key hygiene
* ``failpoint-sync`` — hit() literals vs registry vs DESIGN.md §10 table
* ``fail-open``      — broad excepts must convert the failure into state

Suppression: ``# repolint: ignore[checker-id] <justification>`` on the
flagged line (or alone on the line above).  A suppression WITHOUT a
justification does not silence anything — it is itself reported (checker
id ``suppression``).
"""
from repro.analysis.core import (CHECKERS, Finding, Project, SourceFile,
                                 register_checker)
from repro.analysis.runner import run_analysis

__all__ = ["CHECKERS", "Finding", "Project", "SourceFile",
           "register_checker", "run_analysis"]

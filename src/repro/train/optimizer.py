"""Pure-JAX optimizers (no optax in the container): AdamW + schedules.

State is a pytree mirroring params, so any param sharding rule applies
verbatim to the optimizer state (ZeRO-style: state shards with the weights).
``state_dtype=bfloat16`` halves optimizer HBM for the largest models
(arctic-480b; EXPERIMENTS.md §Dry-run memory table) at the cost of stochastic
rounding-free moment precision — the standard large-model trade.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_dtype: str = "float32"   # "bfloat16" for memory-tight models
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def lr_schedule(cfg: AdamWConfig, step):
    """Linear warmup + cosine decay to min_lr_ratio."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def global_norm(tree):
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def adamw_init(params, cfg: AdamWConfig) -> AdamWState:
    dt = jnp.dtype(cfg.state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      mu=jax.tree_util.tree_map(zeros, params),
                      nu=jax.tree_util.tree_map(zeros, params))


def adamw_update(grads, state: AdamWState, params, cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    dt = jnp.dtype(cfg.state_dtype)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12)) \
        if cfg.grad_clip > 0 else 1.0
    step = state.step + 1
    lr = lr_schedule(cfg, step)
    bc1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        d = (m32 / bc1) / (jnp.sqrt(v32 / bc2) + cfg.eps)
        newp = p.astype(jnp.float32) - lr * (d + cfg.weight_decay * p.astype(jnp.float32))
        return newp.astype(p.dtype), m32.astype(dt), v32.astype(dt)

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(state.mu)
    flat_v = jax.tree_util.tree_leaves(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    newp = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
    newm = jax.tree_util.tree_unflatten(tdef, [o[1] for o in out])
    newv = jax.tree_util.tree_unflatten(tdef, [o[2] for o in out])
    return newp, AdamWState(step=step, mu=newm, nu=newv), {
        "grad_norm": gnorm, "lr": lr}

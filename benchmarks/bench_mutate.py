"""Live-mutation benchmark (persisted to committed BENCH_mutate.json).

One streaming trace against a ``MutableAnnIndex`` behind the bucketed
``ServeFrontend``: ragged search requests interleaved with insert chunks
and uniform deletes, sized so at least one background merge happens while
requests are in flight.  Reported against a static-rebuild baseline (a
fresh ``AnnIndex`` over the final live rows, same SearchSpec, same trace).

Acceptance (ISSUE 6), all persisted in the JSON:

* ``recall_ratio`` — streaming recall@10 / static-rebuild recall@10,
  must be >= 0.95;
* ``deleted_leaks == 0`` — a result may never contain an id deleted
  before its request was submitted;
* ``recompiles_after_warmup == 0`` with ``merges >= 1`` — the trace spans
  a background merge and no request-path recompile happens (the merge
  pre-warms the fresh snapshot at every noted bucket shape);
* QPS + p50/p99 for the mutable path and the static baseline.

``BENCH_SMOKE=1`` shrinks sizes and diverts the JSON to .cache/.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import (SMOKE, dataset, emit, persist_bench,
                               smoke_scale)
from repro.core.index import AnnIndex
from repro.core.spec import SearchSpec
from repro.data.vectors import recall_at_k
from repro.mutate import MutableAnnIndex, MutateConfig
from repro.serve import ServeFrontend

BUCKETS = (1, 4, 8) if SMOKE else (1, 8, 32, 64)
N_REQUESTS = 8 if SMOKE else 64
HNSW_KW = dict(m=8, efc=48) if SMOKE else dict(m=16, efc=96)


def _gt_live(ds, live: np.ndarray, k: int) -> np.ndarray:
    dist = np.sum((ds.queries[:, None, :].astype(np.float64)
                   - ds.base[None, :, :].astype(np.float64)) ** 2, axis=-1)
    dist[:, ~live] = np.inf
    return np.argsort(dist, axis=1)[:, :k]


def _request_sizes(n_requests: int, top: int, seed: int = 7) -> np.ndarray:
    rng = np.random.default_rng(seed)
    sizes = np.exp(rng.uniform(0, np.log(top + 1), n_requests)).astype(int)
    return np.clip(sizes, 1, top)


def mutate_streaming():
    """Streaming insert+delete trace served without downtime."""
    ds = dataset("sift-synth", n_base=smoke_scale(4000, 600))
    n_total = ds.base.shape[0]
    n0 = int(n_total * 0.75)              # the rest streams in during serve
    spec = SearchSpec(efs=64, k=10, router="crouting")
    cfg = MutateConfig(
        delta_capacity=smoke_scale(256, 48), auto_merge="background",
        graph="hnsw", graph_kw=dict(HNSW_KW))
    mi = MutableAnnIndex.build(ds.base[:n0], config=cfg, **HNSW_KW)
    fe = ServeFrontend(mi, spec, buckets=BUCKETS,
                       max_pending_rows=4 * BUCKETS[-1])

    rng = np.random.default_rng(13)
    sizes = _request_sizes(N_REQUESTS, BUCKETS[-1])
    ins_chunk = max(1, (n_total - n0) // N_REQUESTS)
    live = np.zeros(n_total, bool)
    live[:n0] = True
    next_ins = n0
    dead: set = set()
    futs = []                              # (future, dead-at-submit, query rows)
    for i, sz in enumerate(sizes):
        rows = rng.integers(0, len(ds.queries), int(sz))
        futs.append((fe.submit(ds.queries[rows]), set(dead), rows))
        fe.flush()
        if next_ins < n_total:             # stream the held-out rows in
            hi = min(n_total, next_ins + ins_chunk)
            mi.insert(ds.base[next_ins:hi])
            live[next_ins:hi] = True
            next_ins = hi
        if i % 4 == 3:                     # uniform churn: delete 2 live ids
            kill = rng.choice(np.flatnonzero(live), 2, replace=False)
            mi.delete(kill)
            live[kill] = False
            dead.update(int(x) for x in kill)
    mi.wait_for_merge()
    fe.flush()

    leaks = 0
    for fut, dead_at_submit, _rows in futs:
        ids, _, _ = fut.result(timeout=600)
        leaks += int(np.isin(ids, sorted(dead_at_submit)).sum())
    summ = fe.telemetry.summary()
    assert summ["recompiles_after_warmup"] == 0, summ
    assert mi.merges_completed >= 1, \
        "trace did not span a merge; grow the insert stream"
    assert leaks == 0, f"{leaks} results contained already-deleted ids"

    # final-state recall, streaming index vs from-scratch static rebuild
    gt = _gt_live(ds, live, spec.k)
    m_ids, _, _ = mi.search(ds.queries, spec=spec)
    recall_mut = recall_at_k(m_ids, gt, spec.k)
    static = AnnIndex.build(ds.base[live], graph="hnsw", **HNSW_KW)
    ext_of_row = np.flatnonzero(live)
    s_rows, _, _ = static.search(ds.queries, spec=spec)
    s_ids = np.where(s_rows >= 0,
                     ext_of_row[np.where(s_rows >= 0, s_rows, 0)], -1)
    recall_static = recall_at_k(s_ids, gt, spec.k)
    ratio = recall_mut / max(recall_static, 1e-9)
    assert ratio >= 0.95, (recall_mut, recall_static)

    # static baseline through the same frontend for honest QPS/p99 deltas
    fe_s = ServeFrontend(static, spec, buckets=BUCKETS,
                         max_pending_rows=4 * BUCKETS[-1])
    sfuts = []
    for sz in sizes:
        rows = rng.integers(0, len(ds.queries), int(sz))
        sfuts.append(fe_s.submit(ds.queries[rows]))
        fe_s.flush()
    fe_s.flush()
    for f in sfuts:
        f.result(timeout=600)
    summ_s = fe_s.telemetry.summary()

    payload = {
        "n_base_start": n0, "n_base_total": n_total,
        "n_live_final": int(live.sum()),
        "deletes": len(dead), "merges": mi.merges_completed,
        "epoch_final": mi.epoch,
        "delta_capacity": cfg.delta_capacity,
        "recall_streaming": round(recall_mut, 3),
        "recall_static_rebuild": round(recall_static, 3),
        "recall_ratio": round(ratio, 4),
        "deleted_leaks": leaks,
        "recompiles_after_warmup": summ["recompiles_after_warmup"],
        "streaming": {"qps": summ["qps"], "latency": summ["latency"]},
        "static_baseline": {"qps": summ_s["qps"],
                            "latency": summ_s["latency"]},
        "trace": {"requests": len(sizes), "rows": int(sizes.sum()),
                  "insert_chunk": ins_chunk},
    }
    emit("mutate_streaming", 0.0,
         {"qps": summ["qps"], "p99_ms": summ["latency"]["p99_ms"],
          "recall_ratio": payload["recall_ratio"],
          "merges": mi.merges_completed, "leaks": leaks,
          "recompiles": summ["recompiles_after_warmup"]})
    persist_bench("mutate_streaming", payload, file="BENCH_mutate.json")
    return payload

"""dlrm-mlperf [recsys] — MLPerf DLRM (Criteo 1TB) [arXiv:1906.00091]."""
from repro.configs import ArchSpec
from repro.configs.shapes import RECSYS_SHAPES
from repro.models.dlrm import DlrmConfig

SPEC = ArchSpec(
    arch_id="dlrm-mlperf",
    family="recsys",
    model_cfg=DlrmConfig(),
    shapes=RECSYS_SHAPES,
    source="arXiv:1906.00091; paper (MLPerf reference config)",
    smoke_cfg=DlrmConfig(name="dlrm-smoke", vocab_cap=1000),
)

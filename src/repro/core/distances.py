"""Distance-metric registry for the ANNS engine.

The graph-search engine ranks candidates by a *ranking distance* (smaller is
better).  CRouting's cosine-theorem geometry lives in Euclidean space, so every
metric provides an exact, cheap bidirectional conversion between its ranking
distance and the squared Euclidean distance (paper Eq. 4):

    EuclideanDist(a, b)^2 = |a|^2 + |b|^2 + 2 * IPDist(a, b) - 2
    IPDist(a, b)          = 1 - <a, b>
    CosineDist            = IPDist on unit-normalized vectors.

For ``l2`` the ranking distance *is* the squared Euclidean distance (sqrt is
monotone, so ranking by the square is equivalent and cheaper).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax.numpy as jnp
import numpy as np

METRICS = ("l2", "ip", "cosine")


@dataclasses.dataclass(frozen=True)
class Metric:
    """A ranking distance plus its Euclidean-space conversions.

    Attributes:
      name: one of METRICS.
      needs_norms: whether per-node norms must be stored in the index.
      pairwise: (Q[b,d], X[n,d]) -> ranking distance [b,n].
      point: (q[d], x[d]) -> scalar ranking distance.
      rank_to_eu2: (rank, |a|, |b|) -> squared Euclidean distance.
      eu2_to_rank: (eu2, |a|, |b|) -> ranking distance.
    """

    name: str
    needs_norms: bool
    pairwise: Callable
    point: Callable
    rank_to_eu2: Callable
    eu2_to_rank: Callable


def _l2_pairwise(q, x):
    # |q - x|^2 = |q|^2 + |x|^2 - 2 q.x ; computed via the matmul form so the
    # inner product lands on the MXU at scale (see kernels/l2_distance.py for
    # the Pallas version used on the hot path).
    qn = jnp.sum(q * q, axis=-1, keepdims=True)
    xn = jnp.sum(x * x, axis=-1)
    d2 = qn + xn[None, :] - 2.0 * (q @ x.T)
    return jnp.maximum(d2, 0.0)


def _l2_point(q, x):
    d = q - x
    return jnp.sum(d * d, axis=-1)


def _ip_pairwise(q, x):
    return 1.0 - q @ x.T


def _ip_point(q, x):
    return 1.0 - jnp.sum(q * x, axis=-1)


_L2 = Metric(
    name="l2",
    needs_norms=False,
    pairwise=_l2_pairwise,
    point=_l2_point,
    rank_to_eu2=lambda rank, na, nb: rank,
    eu2_to_rank=lambda eu2, na, nb: eu2,
)

_IP = Metric(
    name="ip",
    needs_norms=True,
    pairwise=_ip_pairwise,
    point=_ip_point,
    # Paper Eq. 4:  eu2 = |a|^2 + |b|^2 + 2*IPDist - 2
    rank_to_eu2=lambda rank, na, nb: jnp.maximum(na * na + nb * nb + 2.0 * rank - 2.0, 0.0),
    eu2_to_rank=lambda eu2, na, nb: (eu2 - na * na - nb * nb + 2.0) / 2.0,
)

# Cosine distance == IP distance on normalized vectors; the index stores the
# normalized vectors (norms == 1), so the conversions collapse to eu2 = 2*rank.
_COS = Metric(
    name="cosine",
    needs_norms=True,
    pairwise=_ip_pairwise,
    point=_ip_point,
    rank_to_eu2=lambda rank, na, nb: jnp.maximum(na * na + nb * nb + 2.0 * rank - 2.0, 0.0),
    eu2_to_rank=lambda eu2, na, nb: (eu2 - na * na - nb * nb + 2.0) / 2.0,
)

_REGISTRY = {"l2": _L2, "ip": _IP, "cosine": _COS}


def get_metric(name: str) -> Metric:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(f"unknown metric {name!r}; choose from {METRICS}")


def preprocess_vectors(x: np.ndarray, metric: str) -> np.ndarray:
    """Dataset-side preprocessing a metric requires (cosine -> normalize)."""
    if metric == "cosine":
        n = np.linalg.norm(x, axis=-1, keepdims=True)
        return (x / np.maximum(n, 1e-12)).astype(x.dtype)
    return x


def pairwise_np(q: np.ndarray, x: np.ndarray, metric: str) -> np.ndarray:
    """NumPy twin of Metric.pairwise (construction-time offline path)."""
    if metric == "l2":
        qn = np.sum(q * q, axis=-1, keepdims=True)
        xn = np.sum(x * x, axis=-1)
        return np.maximum(qn + xn[None, :] - 2.0 * (q @ x.T), 0.0)
    return 1.0 - q @ x.T


def rank_to_eu_np(rank: np.ndarray, na, nb, metric: str) -> np.ndarray:
    """Ranking distance -> Euclidean (non-squared) distance, NumPy."""
    if metric == "l2":
        return np.sqrt(np.maximum(rank, 0.0))
    eu2 = na * na + nb * nb + 2.0 * rank - 2.0
    return np.sqrt(np.maximum(eu2, 0.0))

"""cache-key: SearchSpec field classification + compiled-fn cache hygiene.

Three invariants keep the "zero recompiles after warmup" guarantee honest:

1. **Every ``SearchSpec`` field is classified.**  A field is a tunable knob
   (``KNOB_DOMAINS``), request-only (``REQUEST_ONLY_FIELDS`` — never
   re-traces), or structural (``STRUCTURAL_FIELDS`` — an index property the
   autotuner must not touch).  An unclassified field is invisible to the
   autotune cost model and to ``canonical()`` reasoning; a name classified
   twice (or classifying a non-existent field) has drifted.

2. **``canonical()`` strips exactly the request-only fields.**  The
   ``dataclasses.replace(self, ...)`` call inside ``canonical()`` must
   reset each request-only field and nothing else — resetting an
   engine-shaping field would alias distinct executables under one cache
   key; missing a request-only field re-jits per request.

3. **Jit-cache keys stay hashable and array-free.**  Any key indexed into
   a ``*_CACHE`` dict must not embed list/dict/set displays (unhashable)
   nor ``jnp.*``/``np.*`` call results (device/host arrays: unhashable,
   and a device array in a key pins its buffer for the cache's lifetime)
   nor a request-only spec attribute (``.k``/``.cos_theta`` in a key
   defeats ``canonical()``).
"""
from __future__ import annotations

import ast
import re
from typing import Dict, Iterable, Optional, Set, Tuple

from repro.analysis.core import (Finding, Project, SourceFile, dotted_name,
                                 register_checker)

SPEC_PATH = "src/repro/core/spec.py"
CACHE_NAME_RE = re.compile(r"_CACHE$")
_ARRAY_CALL_HEADS = ("jnp.", "jax.numpy.", "jax.", "np.", "numpy.")


def _tuple_of_strs(node: ast.AST) -> Optional[Tuple[str, ...]]:
    if isinstance(node, (ast.Tuple, ast.List)):
        out = []
        for e in node.elts:
            if not (isinstance(e, ast.Constant) and isinstance(e.value, str)):
                return None
            out.append(e.value)
        return tuple(out)
    return None


class _NamedAssign:
    """Uniform (value, lineno) view over Assign / AnnAssign bindings."""

    def __init__(self, value: ast.AST, lineno: int):
        self.value = value
        self.lineno = lineno


def _module_assign(tree: ast.AST, name: str) -> Optional[_NamedAssign]:
    for node in tree.body:
        if isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Name) and t.id == name:
                    return _NamedAssign(node.value, node.lineno)
        elif isinstance(node, ast.AnnAssign) and node.value is not None \
                and isinstance(node.target, ast.Name) \
                and node.target.id == name:
            return _NamedAssign(node.value, node.lineno)
    return None


def _spec_fields(cls: ast.ClassDef) -> Dict[str, int]:
    """Dataclass field name -> line, from annotated class-body assigns."""
    fields: Dict[str, int] = {}
    for stmt in cls.body:
        if isinstance(stmt, ast.AnnAssign) and isinstance(stmt.target,
                                                          ast.Name):
            fields[stmt.target.id] = stmt.lineno
    return fields


def _check_classification(sf: SourceFile) -> Iterable[Finding]:
    tree = sf.tree
    cls = next((n for n in ast.walk(tree)
                if isinstance(n, ast.ClassDef) and n.name == "SearchSpec"),
               None)
    if cls is None:
        yield Finding(checker="cache-key", path=sf.relpath, line=1,
                      message="SearchSpec class not found in spec module",
                      hint="cache-key analysis needs the dataclass to read "
                           "its fields")
        return
    fields = _spec_fields(cls)

    classes: Dict[str, Tuple[Tuple[str, ...], int]] = {}
    knob = _module_assign(tree, "KNOB_DOMAINS")
    if knob is not None and isinstance(knob.value, ast.Dict):
        keys = tuple(k.value for k in knob.value.keys
                     if isinstance(k, ast.Constant)
                     and isinstance(k.value, str))
        classes["KNOB_DOMAINS"] = (keys, knob.lineno)
    for listing in ("REQUEST_ONLY_FIELDS", "STRUCTURAL_FIELDS"):
        node = _module_assign(tree, listing)
        if node is None:
            yield Finding(
                checker="cache-key", path=sf.relpath, line=cls.lineno,
                message=f"{listing} is not defined in the spec module",
                hint="declare the tuple so every SearchSpec field has "
                     "exactly one cost class")
            continue
        vals = _tuple_of_strs(node.value)
        if vals is None:
            yield Finding(
                checker="cache-key", path=sf.relpath, line=node.lineno,
                message=f"{listing} must be a literal tuple of field-name "
                        "strings",
                hint="the checker (and the autotuner) read it statically")
            continue
        classes[listing] = (vals, node.lineno)

    seen: Dict[str, str] = {}
    for cname, (names, line) in classes.items():
        for n in names:
            if n not in fields:
                yield Finding(
                    checker="cache-key", path=sf.relpath, line=line,
                    message=f"{cname} lists {n!r}, which is not a "
                            "SearchSpec field (stale classification)",
                    hint="remove it or rename it to a real field")
            if n in seen:
                yield Finding(
                    checker="cache-key", path=sf.relpath, line=line,
                    message=f"field {n!r} is classified twice "
                            f"({seen[n]} and {cname})",
                    hint="a field has exactly one cost class")
            seen[n] = cname
    for fname, fline in fields.items():
        if fname not in seen:
            yield Finding(
                checker="cache-key", path=sf.relpath, line=fline,
                message=f"SearchSpec.{fname} is unclassified: not in "
                        "KNOB_DOMAINS, REQUEST_ONLY_FIELDS, or "
                        "STRUCTURAL_FIELDS",
                hint="classify it — unclassified fields are invisible to "
                     "the autotune cost model and canonical() reasoning")

    req = set(classes.get("REQUEST_ONLY_FIELDS", ((), 0))[0])
    yield from _check_canonical(sf, cls, req)


def _check_canonical(sf: SourceFile, cls: ast.ClassDef,
                     request_only: Set[str]) -> Iterable[Finding]:
    canon = next((n for n in cls.body
                  if isinstance(n, ast.FunctionDef)
                  and n.name == "canonical"), None)
    if canon is None:
        yield Finding(
            checker="cache-key", path=sf.relpath, line=cls.lineno,
            message="SearchSpec.canonical() not found",
            hint="canonical() is the compiled-engine cache-key authority")
        return
    replace_call = None
    for node in ast.walk(canon):
        if isinstance(node, ast.Call) and dotted_name(node.func) in (
                "dataclasses.replace", "replace", "self.replace"):
            replace_call = node
    if replace_call is None:
        yield Finding(
            checker="cache-key", path=sf.relpath, line=canon.lineno,
            message="canonical() has no dataclasses.replace(...) call",
            hint="it must reset the request-only fields to defaults")
        return
    reset = {kw.arg for kw in replace_call.keywords if kw.arg}
    for f in sorted(request_only - reset):
        yield Finding(
            checker="cache-key", path=sf.relpath, line=replace_call.lineno,
            message=f"canonical() does not reset request-only field {f!r} "
                    "— two specs differing only in it get distinct cache "
                    "keys (re-jit per request)",
            hint=f"add {f}=<default> to the replace() call")
    for f in sorted(reset - request_only):
        yield Finding(
            checker="cache-key", path=sf.relpath, line=replace_call.lineno,
            message=f"canonical() resets {f!r}, which is not request-only "
                    "— distinct executables would alias one cache key",
            hint="only k/cos_theta-class fields may be stripped; update "
                 "REQUEST_ONLY_FIELDS if the contract changed")


# --- cache-key hygiene at use sites ------------------------------------------
def _key_exprs_in_fn(fn: ast.AST) -> Iterable[Tuple[ast.AST, int]]:
    """Yield (resolved key expression, line) for every ``*_CACHE`` access."""
    env: Dict[str, ast.AST] = {}
    for node in ast.walk(fn):
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name):
            env[node.targets[0].id] = node.value
    for node in ast.walk(fn):
        key = None
        if isinstance(node, ast.Subscript):
            base = dotted_name(node.value)
            if base and CACHE_NAME_RE.search(base.split(".")[-1]):
                key = node.slice
        elif isinstance(node, ast.Call) and isinstance(node.func,
                                                       ast.Attribute):
            base = dotted_name(node.func.value)
            if (base and CACHE_NAME_RE.search(base.split(".")[-1])
                    and node.func.attr in ("get", "pop", "setdefault")
                    and node.args):
                key = node.args[0]
        if key is None:
            continue
        if isinstance(key, ast.Name) and key.id in env:
            key = env[key.id]
        yield key, node.lineno


def _key_hazards(key: ast.AST, line: int, relpath: str,
                 request_only: Set[str]) -> Iterable[Finding]:
    for node in ast.walk(key):
        if isinstance(node, (ast.List, ast.Dict, ast.Set, ast.ListComp,
                             ast.DictComp, ast.SetComp)):
            yield Finding(
                checker="cache-key", path=relpath, line=line,
                message="cache key embeds an unhashable "
                        f"{type(node).__name__.lower()} display",
                hint="use a tuple (or a frozen dataclass) so the key "
                     "hashes")
        elif isinstance(node, ast.Call):
            head = dotted_name(node.func)
            if head and head.startswith(_ARRAY_CALL_HEADS) \
                    and head not in ("np.ndim",):
                yield Finding(
                    checker="cache-key", path=relpath, line=line,
                    message=f"cache key embeds an array value ({head}(...))"
                            " — unhashable, and a device array in a key "
                            "pins its buffer",
                    hint="key on id()/weakref + hashable config instead of "
                         "array contents")
        elif isinstance(node, ast.Attribute) and node.attr in request_only:
            yield Finding(
                checker="cache-key", path=relpath, line=line,
                message=f"cache key reads request-only field .{node.attr} "
                        "— keys must come from canonical() form",
                hint="drop it from the key; request-only fields never "
                     "shape the compiled engine")


@register_checker(
    "cache-key",
    "SearchSpec fields all classified; canonical() strips exactly the "
    "request-only fields; *_CACHE keys hashable, array-free, and free of "
    "request-only fields")
def check_cache_key(project: Project) -> Iterable[Finding]:
    spec_sf = project.find("core/spec.py")
    request_only: Set[str] = {"k", "cos_theta"}
    if spec_sf is not None and spec_sf.tree is not None:
        node = _module_assign(spec_sf.tree, "REQUEST_ONLY_FIELDS")
        vals = _tuple_of_strs(node.value) if node is not None else None
        if vals:
            request_only = set(vals)
        yield from _check_classification(spec_sf)
    for sf in project.files:
        if sf.tree is None:
            continue
        # one whole-file pass: name->value resolution is best-effort (last
        # simple assignment wins), which matches how the caches are used
        for key, line in _key_exprs_in_fn(sf.tree):
            yield from _key_hazards(key, line, sf.relpath, request_only)

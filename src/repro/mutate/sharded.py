"""Per-shard deltas: mutation over a sharded corpus with staggered merges.

``MutableShardedAnnIndex`` is a host-side composition of one
``MutableAnnIndex`` per shard (children run ``auto_merge="off"``; the
parent owns merge policy).  It is NOT the ``shard_map`` data plane of
``ShardedAnnIndex`` — each shard is its own single-device index and the
top-k merge happens host-side, which is exactly what the mutation story
needs: a merge rebuilds ONE shard's graph while every other shard keeps
serving untouched, so the rebuild cost is 1/S of the corpus at a time
(staggering; DESIGN.md §9).

Routing: inserts go to the currently-least-loaded shard (by live count),
so deltas fill — and therefore merge — out of phase with each other.
External ids are allocated globally by the parent and mapped to shards
with a host dict; deletes route through it.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.index import AnnIndex
from repro.core.spec import SearchSpec, SearchStats, resolve_search_spec
from repro.mutate.index import DEFAULT_SEARCH, MutableAnnIndex, MutateConfig


class MutableShardedAnnIndex:
    """S mutable shards behind one insert/delete/search surface."""

    def __init__(self, indexes: List[AnnIndex],
                 config: MutateConfig = MutateConfig(),
                 spec: Optional[SearchSpec] = None):
        if not indexes:
            raise ValueError("need at least one shard")
        child_cfg = dataclasses.replace(config, auto_merge="off")
        self.config = config
        self.default_spec = spec if spec is not None else DEFAULT_SEARCH
        self.shards: List[MutableAnnIndex] = []
        self._ext_to_shard: Dict[int, int] = {}
        self._next_ext = 0
        for s, idx in enumerate(indexes):
            child = MutableAnnIndex(idx, config=child_cfg, spec=spec)
            # children hand out their own ids starting at their local n;
            # the parent overrides allocation so ids are globally unique
            for e in child._state.snapshot.ext_ids:
                ge = self._next_ext
                self._remap_child_ext(child, int(e), ge)
                self._ext_to_shard[ge] = s
                self._next_ext += 1
            self.shards.append(child)

    @staticmethod
    def _remap_child_ext(child: MutableAnnIndex, old: int, new: int):
        snap = child._state.snapshot
        row = snap.ext_to_row.pop(old)
        snap.ext_ids[row] = new
        snap.ext_to_row[new] = row

    # --- mutation ---------------------------------------------------------
    def insert(self, vectors: np.ndarray) -> np.ndarray:
        vectors = np.asarray(vectors, np.float32)
        if vectors.ndim == 1:
            vectors = vectors[None, :]
        # least-loaded shard keeps delta fill (and merges) staggered
        s = int(np.argmin([sh.n_live for sh in self.shards]))
        child = self.shards[s]
        ids = np.arange(self._next_ext, self._next_ext + vectors.shape[0],
                        dtype=np.int64)
        self._next_ext += vectors.shape[0]
        if vectors.shape[0] > child._state.delta.room:
            child.merge()    # children run auto_merge="off"; drain explicitly
        with child._lock:
            child._next_ext = int(ids[0])
            got = child.insert(vectors)
        assert (got == ids).all()
        for e in ids:
            self._ext_to_shard[int(e)] = s
        self.maybe_merge()
        return ids

    def delete(self, ext_ids) -> int:
        if np.ndim(ext_ids) == 0:
            ext_ids = [ext_ids]
        by_shard: Dict[int, List[int]] = {}
        for e in map(int, ext_ids):
            s = self._ext_to_shard.get(e)
            if s is None:
                raise KeyError(f"external id {e} is not live")
            by_shard.setdefault(s, []).append(e)
        removed = 0
        for s, ids in by_shard.items():
            removed += self.shards[s].delete(ids)
        self.maybe_merge()
        return removed

    def maybe_merge(self):
        """Merge AT MOST the single most-pressured shard per call, so shard
        rebuilds stagger instead of stampeding."""
        due = [s for s, sh in enumerate(self.shards) if sh.needs_merge()]
        if not due:
            return
        s = max(due, key=lambda i: self.shards[i]._state.delta.count)
        self.shards[s].merge()

    # --- search -----------------------------------------------------------
    def search(self, queries: np.ndarray,
               spec: Optional[SearchSpec] = None
               ) -> Tuple[np.ndarray, np.ndarray, SearchStats]:
        """Fan out to every shard, host-merge the per-shard top-k."""
        spec = resolve_search_spec(spec, self.default_spec,
                                   "MutableShardedAnnIndex.search")
        k = spec.k
        parts = [sh.search(queries, spec=spec) for sh in self.shards]
        all_ids = np.concatenate([p[0] for p in parts], axis=1)
        all_d = np.concatenate([p[1] for p in parts], axis=1)
        order = np.argsort(all_d, axis=1, kind="stable")[:, :k]
        out_ids = np.take_along_axis(all_ids, order, axis=1)
        out_d = np.take_along_axis(all_d, order, axis=1)
        out_ids = np.where(np.isfinite(out_d), out_ids, -1)
        stats = parts[0][2] if len(parts) == 1 else SearchStats.merge(
            [p[2] for p in parts])
        return out_ids, out_d, stats

    @property
    def n_live(self) -> int:
        return sum(sh.n_live for sh in self.shards)

    @property
    def epochs(self) -> Tuple[int, ...]:
        return tuple(sh.epoch for sh in self.shards)

"""Three-term roofline from a compiled (dry-run) artifact.

  compute    = HLO_FLOPs(per-device) / peak_FLOP/s
  memory     = HLO_bytes(per-device) / HBM_bw
  collective = collective_bytes(per-device, ring-model) / link_bw

cost_analysis() reports per-device (post-SPMD) flops/bytes.  Collective bytes
are NOT in cost_analysis: we parse the partitioned HLO text and apply ring
cost models per op:

  all-reduce      2·X·(n−1)/n   (X = per-device tensor bytes)
  all-gather      X_out·(n−1)/n
  reduce-scatter  X_in ·(n−1)/n
  all-to-all      X·(n−1)/n
  collective-permute  X
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional

from repro.roofline.hw import HwSpec, TPU_V5E

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s+(?:\(?)([a-z0-9\[\],{}\s/)(]+?)\)?\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.I)

_SHAPE_RE = re.compile(r"(f64|f32|f16|bf16|f8e4m3fn|f8e5m2|s64|s32|s16|s8|"
                       r"u64|u32|u16|u8|pred|c64|c128)\[([0-9,]*)\]")

_GROUP_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUP_RE2 = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(shapes_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shapes_str):
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    counts: Dict[str, int]
    raw_bytes: Dict[str, int]       # per-device tensor bytes by op kind
    ring_bytes: float               # ring-model wire bytes per device
    ops: List[dict]


def parse_collectives(hlo_text: str) -> CollectiveStats:
    counts: Dict[str, int] = {}
    raw: Dict[str, int] = {}
    ops: List[dict] = []
    ring_total = 0.0
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        shapes_str, kind = m.group(1), m.group(2).lower()
        if "-done(" in line:
            continue  # avoid double counting start/done pairs
        nbytes = _shape_bytes(shapes_str)
        # group size n for the ring discount
        g = _GROUP_RE.search(line)
        if g:
            n = len(g.group(1).split(","))
        else:
            g2 = _GROUP_RE2.search(line)
            n = int(g2.group(2)) if g2 else 2
        n = max(n, 2)
        disc = (n - 1) / n
        if kind == "all-reduce":
            wire = 2.0 * nbytes * disc
        elif kind == "collective-permute":
            wire = float(nbytes)
        else:
            wire = nbytes * disc
        counts[kind] = counts.get(kind, 0) + 1
        raw[kind] = raw.get(kind, 0) + nbytes
        ring_total += wire
        ops.append({"kind": kind, "bytes": nbytes, "group": n, "wire": wire})
    return CollectiveStats(counts=counts, raw_bytes=raw, ring_bytes=ring_total,
                           ops=ops)


def roofline_terms(flops_per_dev: float, bytes_per_dev: float,
                   coll_wire_bytes: float, hw: HwSpec = TPU_V5E,
                   model_flops_per_dev: Optional[float] = None) -> dict:
    t_comp = flops_per_dev / hw.peak_flops_bf16
    t_mem = bytes_per_dev / hw.hbm_bw
    t_coll = coll_wire_bytes / hw.ici_link_bw
    terms = {"compute_s": t_comp, "memory_s": t_mem, "collective_s": t_coll}
    dom = max(terms, key=terms.get)
    bound = max(t_comp, t_mem, t_coll)
    out = dict(terms)
    out["dominant"] = dom
    out["step_time_lb_s"] = bound
    out["roofline_fraction"] = (t_comp / bound) if bound > 0 else 0.0
    if model_flops_per_dev is not None:
        out["model_flops_per_dev"] = model_flops_per_dev
        out["useful_flop_ratio"] = (model_flops_per_dev / flops_per_dev
                                    if flops_per_dev else 0.0)
        out["model_compute_s"] = model_flops_per_dev / hw.peak_flops_bf16
        out["mfu_upper_bound"] = (out["model_compute_s"] / bound
                                  if bound > 0 else 0.0)
    return out


def analyze_compiled(compiled, n_devices: int, model_flops_total: float = 0.0,
                     hw: HwSpec = TPU_V5E) -> dict:
    """Full §Roofline record for one dry-run cell."""
    cost = compiled.cost_analysis()
    flops = float(cost.get("flops", 0.0))
    nbytes = float(cost.get("bytes accessed", 0.0))
    coll = parse_collectives(compiled.as_text())
    mem = compiled.memory_analysis()
    terms = roofline_terms(flops, nbytes, coll.ring_bytes, hw,
                           model_flops_per_dev=model_flops_total / n_devices
                           if model_flops_total else None)
    return {
        "hlo_flops_per_dev": flops,
        "hlo_bytes_per_dev": nbytes,
        "collective_counts": coll.counts,
        "collective_raw_bytes": coll.raw_bytes,
        "collective_wire_bytes": coll.ring_bytes,
        "mem_args_bytes": int(mem.argument_size_in_bytes),
        "mem_out_bytes": int(mem.output_size_in_bytes),
        "mem_temp_bytes": int(mem.temp_size_in_bytes),
        "mem_total_bytes": int(mem.argument_size_in_bytes
                               + mem.output_size_in_bytes
                               + mem.temp_size_in_bytes
                               - mem.alias_size_in_bytes),
        **terms,
    }

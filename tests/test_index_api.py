"""Public index API (ISSUE 4 satellites, shims retired in ISSUE 6): the
SearchSpec surface, typed SearchStats, the pad-slot distance fix, and the
versioned save/load roundtrip incl. the full angle profile."""
import os

import numpy as np
import pytest

from repro.core.index import AnnIndex, FORMAT_VERSION
from repro.core.spec import SearchSpec, SearchStats
from repro.data.vectors import make_dataset


@pytest.fixture(scope="module")
def built(small_ds):
    return AnnIndex.build(small_ds.base, graph="hnsw", m=12, efc=64)


# --------------------------------------------------------------------------
# legacy call styles are GONE (the ISSUE 4 one-release shim expired): every
# pre-SearchSpec spelling must raise TypeError, never silently misbehave
# --------------------------------------------------------------------------
def test_legacy_kwargs_raise_type_error(small_ds, built):
    with pytest.raises(TypeError):
        built.search(small_ds.queries, k=10, efs=48, router="crouting",
                     beam_width=4)


def test_bare_call_uses_default_spec_without_warning(small_ds, built, recwarn):
    ids, dists, stats = built.search(small_ds.queries[:4])
    assert ids.shape == (4, 10)
    assert stats.router == "crouting"
    assert not [w for w in recwarn.list
                if issubclass(w.category, DeprecationWarning)]


def test_unknown_kwarg_raises(small_ds, built):
    with pytest.raises(TypeError):
        built.search(small_ds.queries[:2], ef_search=32)


def test_spec_positional_typo_raises(small_ds, built):
    with pytest.raises(TypeError, match="SearchSpec"):
        built.search(small_ds.queries[:2], 10)


# --------------------------------------------------------------------------
# typed SearchStats
# --------------------------------------------------------------------------
def test_search_returns_typed_stats(small_ds, built):
    _, _, stats = built.search(small_ds.queries[:4],
                               spec=SearchSpec(k=5, efs=32, router="crouting"))
    assert isinstance(stats, SearchStats)
    assert stats.router == "crouting"
    assert stats.dist_calls.shape == (4,)
    summ = stats.summary()
    assert summ["router"] == "crouting" and summ["dist_calls"] > 0
    # dict-style access was a one-release shim; it's gone
    with pytest.raises(TypeError):
        stats["dist_calls"]


def test_k_and_cos_theta_do_not_retrigger_jit(built):
    """Request-only spec fields must not fragment the compiled-engine
    cache (SearchSpec.canonical)."""
    from repro.core.search import build_search_fn

    g = built.graph
    _, f1 = build_search_fn(g, SearchSpec(k=5, efs=32, cos_theta=0.1))
    _, f2 = build_search_fn(g, SearchSpec(k=7, efs=32, cos_theta=0.9))
    assert f1 is f2
    _, f3 = build_search_fn(g, SearchSpec(k=5, efs=33))
    assert f3 is not f1


# --------------------------------------------------------------------------
# theta*=90deg fallback (ISSUE 5 recall-safety fix): a pruning router on a
# profile-less index must refuse to run, not silently prune at cos_theta=0
# --------------------------------------------------------------------------
@pytest.fixture(scope="module")
def unprofiled(small_ds):
    return AnnIndex.build(small_ds.base[:600], graph="hnsw", m=8, efc=48,
                          profile=False)


def test_pruning_router_without_profile_raises(small_ds, unprofiled):
    assert unprofiled.profile is None
    with pytest.raises(ValueError, match="theta"):
        unprofiled.search(small_ds.queries[:4],
                          spec=SearchSpec(k=5, efs=32, router="crouting"))


def test_non_pruning_router_without_profile_still_works(small_ds, unprofiled):
    ids, dists, stats = unprofiled.search(
        small_ds.queries[:4], spec=SearchSpec(k=5, efs=32, router="none"))
    assert ids.shape == (4, 5)
    assert (stats.est_calls == 0).all()


def test_explicit_cos_theta_without_profile_works(small_ds, unprofiled):
    """An explicit threshold is the documented escape hatch: results match a
    profiled index searched with the same override."""
    ids, dists, stats = unprofiled.search(
        small_ds.queries[:4],
        spec=SearchSpec(k=5, efs=32, router="crouting", cos_theta=0.3))
    assert ids.shape == (4, 5)
    assert (stats.est_calls > 0).any()


# --------------------------------------------------------------------------
# pad-slot masking (satellite fix): ids -1 must never carry a finite dist
# --------------------------------------------------------------------------
def test_empty_result_slots_have_inf_distance():
    ds = make_dataset(n_base=6, n_query=3, dim=8, n_clusters=2, seed=0)
    idx = AnnIndex.build(ds.base, graph="knn", k=4, profile=False)
    ids, dists, _ = idx.search(ds.queries, spec=SearchSpec(k=10, efs=16,
                                                           router="none"))
    assert (ids == -1).any(), "expected pad slots with only 6 base rows"
    assert np.isinf(dists[ids == -1]).all()
    # and real slots stay finite
    assert np.isfinite(dists[ids >= 0]).all()


# --------------------------------------------------------------------------
# save/load roundtrip (satellite fix): hierarchy + FULL angle profile
# --------------------------------------------------------------------------
def test_save_load_roundtrip_hierarchy_and_profile(tmp_path, small_ds):
    idx = AnnIndex.build(small_ds.base[:800], graph="hnsw", m=8, efc=48)
    assert idx.graph.upper_neighbors, "fixture should exercise the hierarchy"
    path = os.path.join(tmp_path, "idx.npz")
    idx.save(path)
    back = AnnIndex.load(path)

    np.testing.assert_array_equal(back.graph.vectors, idx.graph.vectors)
    np.testing.assert_array_equal(back.graph.neighbors, idx.graph.neighbors)
    assert back.graph.entry_point == idx.graph.entry_point
    assert len(back.graph.upper_neighbors) == len(idx.graph.upper_neighbors)
    for a, b in zip(back.graph.upper_neighbors, idx.graph.upper_neighbors):
        np.testing.assert_array_equal(a, b)

    p0, p1 = idx.profile, back.profile
    assert p1 is not None
    np.testing.assert_allclose(p1.theta_star, p0.theta_star)
    np.testing.assert_allclose(p1.cos_theta_star, p0.cos_theta_star)
    assert p1.percentile == p0.percentile
    np.testing.assert_array_equal(p1.samples, p0.samples)
    # regression: these two were silently zeroed on load before ISSUE 4
    assert p1.n_sample_queries == p0.n_sample_queries > 0
    assert p1.sample_secs == pytest.approx(p0.sample_secs)
    # ISSUE 6: corpus size at profile-sample time survives the roundtrip
    # (mutation-staleness detection needs it)
    assert p1.corpus_n == p0.corpus_n == 800

    # and the loaded index searches identically (profile drives cos_theta)
    spec = SearchSpec(k=10, efs=32, router="crouting")
    ids_a, d_a, _ = idx.search(small_ds.queries[:8], spec=spec)
    ids_b, d_b, _ = back.search(small_ds.queries[:8], spec=spec)
    np.testing.assert_array_equal(ids_a, ids_b)
    np.testing.assert_allclose(d_a, d_b, rtol=1e-6)


# --------------------------------------------------------------------------
# payload versioning (ISSUE 6 satellite): save stamps format_version, load
# refuses futures and keeps reading unstamped v1 files
# --------------------------------------------------------------------------
def test_save_stamps_current_format_version(tmp_path, small_ds):
    idx = AnnIndex.build(small_ds.base[:200], graph="knn", k=4, profile=False)
    path = os.path.join(tmp_path, "v.npz")
    idx.save(path)
    z = np.load(path, allow_pickle=False)
    assert int(z["format_version"]) == FORMAT_VERSION == 3
    assert "checksum" in z.files   # v3: content checksum stamped at save


def test_load_rejects_future_format_version(tmp_path, small_ds):
    idx = AnnIndex.build(small_ds.base[:200], graph="knn", k=4, profile=False)
    path = os.path.join(tmp_path, "future.npz")
    idx.save(path)
    z = dict(np.load(path, allow_pickle=False))
    z["format_version"] = np.asarray(FORMAT_VERSION + 1)
    np.savez_compressed(path, **z)
    with pytest.raises(ValueError, match="format_version"):
        AnnIndex.load(path)


def test_load_accepts_unstamped_v1_file(tmp_path, small_ds):
    """Pre-PR4 files carry no stamp and legitimately lack the newer profile
    fields; they must keep loading with the documented defaults."""
    idx = AnnIndex.build(small_ds.base[:300], graph="knn", k=4)
    path = os.path.join(tmp_path, "v1.npz")
    idx.save(path)
    z = dict(np.load(path, allow_pickle=False))
    for key in ("format_version", "theta_nq", "theta_secs", "theta_corpus_n"):
        z.pop(key, None)
    np.savez_compressed(path, **z)
    back = AnnIndex.load(path)
    assert back.profile is not None
    assert back.profile.n_sample_queries == 0
    assert back.profile.corpus_n == 0
    np.testing.assert_allclose(back.profile.theta_star, idx.profile.theta_star)

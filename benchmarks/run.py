# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness: ``PYTHONPATH=src python -m benchmarks.run [--only X]``.

Covers every table/figure of the paper (DESIGN.md §8) plus kernel micros and
the dry-run roofline table.  Scale via BENCH_N / BENCH_Q env vars.
"""
import argparse
import sys
import time
import traceback

from benchmarks import bench_autotune as A
from benchmarks import bench_chaos as C_
from benchmarks import bench_engine as E
from benchmarks import bench_paper as P
from benchmarks import bench_kernels as K
from benchmarks import bench_mutate as M
from benchmarks import bench_recovery as D
from benchmarks import bench_roofline as R
from benchmarks import bench_serve as S

BENCHES = [
    ("engine_beam_sweep", E.engine_beam_sweep),
    ("engine_estimate_sweep", E.engine_estimate_sweep),
    ("engine_router_sweep", E.engine_router_sweep),
    ("engine_pallas_parity", E.engine_pallas_parity),
    ("serve_single", S.serve_single),
    ("serve_sharded", S.serve_sharded),
    ("autotune_two_phase", A.bench_autotune),
    ("mutate_streaming", M.mutate_streaming),
    ("chaos_serving", C_.chaos_serving),
    ("recovery_ingest", D.recovery_ingest),
    ("recovery_replay", D.recovery_replay),
    ("recovery_chaos", D.recovery_chaos),
    ("fig2_time_breakdown", P.fig2_time_breakdown),
    ("fig6_8_angles", P.fig6_8_angles),
    ("fig10_recall_qps", P.fig10_recall_qps),
    ("fig11_recall_speedup", P.fig11_recall_speedup),
    ("table3_efs_ablation", P.table3_efs_ablation),
    ("table4_5_error_analysis", P.table4_5_error_analysis),
    ("fig13_threshold", P.fig13_threshold),
    ("fig14_15_neighbors_k", P.fig14_15_neighbors_k),
    ("fig16_metrics", P.fig16_metrics),
    ("fig17_scalability", P.fig17_scalability),
    ("table6_7_construction", P.table6_7_construction),
    ("fig18_strategies", P.fig18_strategies),
    ("kernels_micro", K.kernels_micro),
    ("roofline_table", R.roofline_table),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="substring filter")
    args = ap.parse_args()
    failed, ran = [], []
    for name, fn in BENCHES:
        if args.only and args.only not in name:
            continue
        t0 = time.time()
        print(f"# === {name} ===", flush=True)
        try:
            fn()
            ran.append(name)
        except Exception as e:   # noqa: BLE001 — harness: one bench must not kill the run
            failed.append(name)
            print(f"{name},nan,{{\"error\": \"{e!r}\"}}")
            traceback.print_exc()
        print(f"#     ({time.time()-t0:.1f}s)", flush=True)
    # stamp the persisted perf trajectories (benchmarks/common.py)
    from benchmarks import common as C
    for prefix, file in (("engine", "BENCH_engine.json"),
                         ("serve", "BENCH_serve.json"),
                         ("autotune", "BENCH_autotune.json"),
                         ("mutate", "BENCH_mutate.json"),
                         ("chaos", "BENCH_chaos.json"),
                         ("recovery", "BENCH_recovery.json")):
        if any(n.startswith(prefix) for n in ran):
            path = C.persist_bench("_meta", {
                "utc": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
                # dataset sizes are per-bench (each section records n_base)
                "bench_q": C.N_QUERY, "smoke": C.SMOKE,
                "benches": [n for n in ran if n.startswith(prefix)],
            }, file=file)
            print(f"# {prefix} results persisted to {path}")
    if failed:
        print(f"# FAILED: {failed}")
        sys.exit(1)
    print("# all benchmarks ok")


if __name__ == '__main__':
    main()

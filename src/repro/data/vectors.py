"""Deterministic synthetic vector datasets + exact ground truth.

The container is offline, so SIFT/DEEP/MSONG/MNIST/GIST are replaced by
synthetic datasets with matched (N, d) and a clustered structure (Gaussian
mixture) that makes graph-ANNS non-trivial.  The angle-concentration property
CRouting exploits is dimension-driven and reproduces on these distributions
(see benchmarks/bench_angles.py).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core import distances as D


@dataclasses.dataclass
class VectorDataset:
    name: str
    base: np.ndarray      # [N, d] float32
    queries: np.ndarray   # [Q, d] float32
    metric: str = "l2"
    gt: Optional[np.ndarray] = None  # [Q, K] exact nearest ids (lazily filled)


def make_dataset(
    name: str = "synth",
    n_base: int = 10_000,
    n_query: int = 100,
    dim: int = 128,
    n_clusters: int = 64,
    cluster_std: float = 0.25,
    metric: str = "l2",
    seed: int = 0,
    heavy_tail: bool = False,
    sub_spread: float = 0.5,
) -> VectorDataset:
    """Hierarchically clustered Gaussian mixture (super-clusters of
    sub-clusters), queries from the same mixture.

    Real descriptor datasets (SIFT/GIST) are hierarchically clustered; flat
    mixtures yield search-path angle distributions centered ~0.39*pi and make
    CRouting's iso-recall gain wash out, while the hierarchical mixture
    reproduces the paper's ~0.5*pi concentration and its 1.2-1.7x
    distance-call speedups (EXPERIMENTS.md §Datasets)."""
    rng = np.random.default_rng(seed)
    n_super = max(1, int(np.sqrt(n_clusters)))
    n_sub = max(1, n_clusters // n_super)
    sup = rng.normal(size=(n_super, dim)).astype(np.float32)
    sup /= np.linalg.norm(sup, axis=1, keepdims=True)
    centers = (sup[:, None, :] + sub_spread
               * rng.normal(size=(n_super, n_sub, dim)).astype(np.float32))
    centers = centers.reshape(n_super * n_sub, dim)
    n_cl = centers.shape[0]

    def _sample(n, salt):
        r = np.random.default_rng(seed * 1_000_003 + salt)
        which = r.integers(0, n_cl, size=n)
        x = centers[which] + cluster_std * r.normal(size=(n, dim)).astype(np.float32)
        if heavy_tail:
            scale = np.exp(0.5 * r.normal(size=(n, 1))).astype(np.float32)
            x = x * scale
        return x.astype(np.float32)

    base = _sample(n_base, 1)
    queries = _sample(n_query, 2)
    base = D.preprocess_vectors(base, metric)
    queries = D.preprocess_vectors(queries, metric)
    return VectorDataset(name=name, base=base, queries=queries, metric=metric)


def exact_ground_truth(ds: VectorDataset, k: int = 10, block: int = 512) -> np.ndarray:
    """Blocked brute-force exact top-k (the oracle for recall)."""
    if ds.gt is not None and ds.gt.shape[1] >= k:
        return ds.gt[:, :k]
    out = np.empty((ds.queries.shape[0], k), dtype=np.int64)
    for s in range(0, ds.queries.shape[0], block):
        q = ds.queries[s : s + block]
        dist = D.pairwise_np(q, ds.base, ds.metric)
        idx = np.argpartition(dist, kth=k - 1, axis=1)[:, :k]
        row = np.take_along_axis(dist, idx, axis=1)
        order = np.argsort(row, axis=1, kind="stable")
        out[s : s + block] = np.take_along_axis(idx, order, axis=1)
    ds.gt = out
    return out


def recall_at_k(found_ids: np.ndarray, gt_ids: np.ndarray, k: int = 10) -> float:
    """Recall@K = |found ∩ true| / K averaged over queries (paper §5.1)."""
    hits = 0
    for f, g in zip(found_ids[:, :k], gt_ids[:, :k]):
        hits += len(set(int(i) for i in f if i >= 0) & set(int(i) for i in g))
    return hits / (len(gt_ids) * k)


# (name, n_base, dim) stand-ins for the paper's Table 2 datasets, scaled down
# to container size but keeping each dataset's dimensionality.
PAPER_DATASETS = {
    "sift-synth": dict(dim=128, n_clusters=64),
    "deep-synth": dict(dim=256, n_clusters=64),
    "msong-synth": dict(dim=420, n_clusters=36),
    "mnist-synth": dict(dim=784, n_clusters=25),
    "gist-synth": dict(dim=960, n_clusters=36),
}


def paper_dataset(name: str, n_base: int = 10_000, n_query: int = 100,
                  metric: str = "l2", seed: int = 0) -> VectorDataset:
    cfg = PAPER_DATASETS[name]
    return make_dataset(name=name, n_base=n_base, n_query=n_query,
                        dim=cfg["dim"], n_clusters=cfg["n_clusters"],
                        metric=metric, seed=seed)

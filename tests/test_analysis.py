"""repolint framework tests: fixtures, suppression semantics, real tree.

Fixture convention: a line comment containing ``expect[id]`` (or
``expect[id-a,id-b]`` for several findings on one line) asserts that the
analyzer produces exactly those findings at that line — no more, no fewer,
nowhere else in the fixture.  Suppression fixtures cannot carry markers
(trailing text after ``ignore[...]`` becomes the justification), so
``tests/fixtures/analysis/suppress.py`` is asserted by explicit line
numbers instead.
"""
import json
import os
import re
import subprocess
import sys

import pytest

from repro.analysis import CHECKERS, run_analysis
from repro.analysis.core import SourceFile
from repro.analysis.runner import render_text

HERE = os.path.dirname(os.path.abspath(__file__))
REPO_ROOT = os.path.dirname(HERE)
FIXTURES = os.path.join(HERE, "fixtures", "analysis")

EXPECT_RE = re.compile(r"expect\[([a-z\-]+(?:\s*,\s*[a-z\-]+)*)\]")


def _markers(root, relpaths):
    """Sorted (relpath, line, checker-id) multiset from expect[] comments."""
    out = []
    for rel in relpaths:
        sf = SourceFile.load(os.path.join(root, rel), root)
        for ln, comment in sf.comments.items():
            m = EXPECT_RE.search(comment)
            if m:
                for cid in m.group(1).split(","):
                    out.append((rel, ln, cid.strip()))
    return sorted(out)


def _found(result):
    return sorted((f.path, f.line, f.checker) for f in result.findings)


def _run_fixture(relpaths, root=FIXTURES):
    result = run_analysis(root=root, paths=relpaths)
    assert result.parse_errors == [], result.parse_errors
    return result


# --- one fixture per checker -------------------------------------------------
def test_locks_fixture_matches_markers():
    rels = ["locks_bad.py"]
    result = _run_fixture(rels)
    assert _found(result) == _markers(FIXTURES, rels)
    # both lock checkers fired (guarded-by accesses + the inversion)
    ids = {f.checker for f in result.findings}
    assert ids == {"guarded-by", "lock-order"}


def test_trace_fixture_matches_markers():
    rels = ["trace_bad.py"]
    result = _run_fixture(rels)
    assert _found(result) == _markers(FIXTURES, rels)
    assert all(f.checker == "trace-safety" for f in result.findings)


def test_failopen_fixture_matches_markers():
    rels = ["failopen_bad.py"]
    result = _run_fixture(rels)
    assert _found(result) == _markers(FIXTURES, rels)
    # the pass-only handler is called out as such
    by_line = {f.line: f for f in result.findings}
    assert "bare `pass`" in by_line[12].message


def test_cachekey_fixture_matches_markers():
    rels = ["cachekey_repo/core/spec.py", "cachekey_repo/core/engine.py"]
    result = _run_fixture(rels)
    assert _found(result) == _markers(FIXTURES, rels)
    msgs = " | ".join(f.message for f in result.findings)
    assert "stale classification" in msgs          # stale_knob
    assert "unclassified" in msgs                  # cos_theta
    assert "does not reset request-only" in msgs   # canonical misses k
    assert "resets 'efs'" in msgs                  # canonical strips a knob
    assert "unhashable list" in msgs               # [1, 2] in key
    assert "array value" in msgs                   # jnp.asarray in key
    assert "request-only field .k" in msgs         # .k in key


def test_failpoint_fixture_matches_markers():
    root = os.path.join(FIXTURES, "failpoint_repo")
    rels = ["svc.py", "fault/failpoints.py"]
    result = _run_fixture(["."], root=root)
    doc = [f for f in result.findings if f.path == "DESIGN.md"]
    rest = sorted((f.path, f.line, f.checker)
                  for f in result.findings if f.path != "DESIGN.md")
    assert rest == _markers(root, rels)
    # the ghost documentation row is flagged at its own table line
    assert [(f.line, f.checker) for f in doc] == [(8, "failpoint-sync")]
    assert "doc.ghost" in doc[0].message and "not declared" in doc[0].message


# --- suppression semantics ---------------------------------------------------
def test_suppressions():
    result = _run_fixture(["suppress.py"])
    # justified suppressions (standalone multi-line + inline) silence the
    # guarded-by findings but keep them visible in the suppressed list
    assert sorted((f.line, f.checker) for f in result.suppressed) == [
        (19, "guarded-by"),     # standalone comment covers next code line
        (22, "guarded-by"),     # inline comment covers its own line
    ]
    # the bare tag silences nothing AND is itself a finding; the typo'd
    # checker id is reported so it cannot silently guard nothing
    assert sorted((f.line, f.checker) for f in result.findings) == [
        (25, "guarded-by"),     # finding survives the bare tag
        (25, "suppression"),    # the bare tag itself
        (29, "suppression"),    # unknown id 'gaurded-by'
    ]
    by = {(f.line, f.checker): f for f in result.findings}
    assert "without a justification" in by[(25, "suppression")].message
    assert "gaurded-by" in by[(29, "suppression")].message


# --- the real tree is clean under --strict -----------------------------------
def test_real_tree_is_clean():
    result = run_analysis()     # root inferred, paths=("src",)
    assert result.parse_errors == []
    assert result.findings == [], "\n" + "\n".join(
        f.text() for f in result.findings)
    assert result.exit_code_strict == 0
    # the justified exceptions stay visible as suppressed, not vanished
    assert result.suppressed, "expected the documented suppressions"
    assert all(f.checker in CHECKERS for f in result.suppressed)


def test_registry_has_the_five_checkers():
    assert set(CHECKERS) == {"guarded-by", "lock-order", "trace-safety",
                             "cache-key", "failpoint-sync", "fail-open"}


def test_unknown_checker_id_rejected():
    with pytest.raises(SystemExit):
        run_analysis(root=FIXTURES, paths=["suppress.py"],
                     checks=["no-such-checker"])


def test_render_text_summary_line():
    result = _run_fixture(["locks_bad.py"])
    text = render_text(result)
    assert "locks_bad.py:23: [guarded-by]" in text
    assert re.search(r"repolint: 1 files, \d+ checkers, 4 finding\(s\), "
                     r"0 suppressed", text)


# --- CLI ---------------------------------------------------------------------
def _cli(args, cwd=REPO_ROOT):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO_ROOT, "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    return subprocess.run([sys.executable, "-m", "repro.analysis"] + args,
                          cwd=cwd, env=env, capture_output=True, text=True)


def test_cli_strict_fails_on_fixture(tmp_path):
    report = tmp_path / "report.json"
    proc = _cli(["--root", FIXTURES, "locks_bad.py", "--strict",
                 "--json", str(report)])
    assert proc.returncode == 1
    assert "[guarded-by]" in proc.stdout and "[lock-order]" in proc.stdout
    data = json.loads(report.read_text())
    assert data["files_scanned"] == 1
    assert len(data["findings"]) == 4
    assert {"checker", "path", "line", "message", "hint"} <= \
        set(data["findings"][0])


def test_cli_non_strict_always_exits_zero():
    proc = _cli(["--root", FIXTURES, "locks_bad.py"])
    assert proc.returncode == 0
    assert "4 finding(s)" in proc.stdout

"""Autotune subsystem (ISSUE 9 tentpole): knob cost classes from
canonical() semantics, the recall proxy's exact ground truth, seeded
deterministic successive-halving + epsilon-greedy decisions, SLO-blowing
candidates quarantined during probing, the pre-warm-then-switch promotion
protocol (zero request-path recompiles across controller switches), and
fail-open behavior under injected controller faults."""
import dataclasses

import numpy as np
import pytest

from repro.autotune import (AutotuneDriver, Controller, Objective,
                            ProbeMeasurement, RecallProxy, TuneSpace,
                            spec_key)
from repro.autotune.space import Knob
from repro.core.index import AnnIndex
from repro.core.spec import (KNOB_DOMAINS, REQUEST_ONLY_FIELDS, SearchSpec,
                             is_request_only)
from repro.fault import failpoints as fault
from repro.serve import ServeFrontend

BUCKETS = (1, 8, 16)


@pytest.fixture(scope="module")
def built(small_ds):
    return AnnIndex.build(small_ds.base, graph="hnsw", m=12, efc=64)


# --------------------------------------------------------------------------
# space: knob domains + cost classes derived from canonical()
# --------------------------------------------------------------------------
def test_cost_classes_follow_canonical_semantics():
    """A knob is request-only exactly when perturbing it leaves the
    compiled-engine cache key unchanged — derived, not hand-listed."""
    for f in REQUEST_ONLY_FIELDS:
        assert is_request_only(f), f
    for f in ("efs", "beam_width", "engine", "estimate", "router",
              "max_hops", "beam_prune"):
        assert not is_request_only(f), f
    with pytest.raises(KeyError):
        is_request_only("not_a_field")
    space = TuneSpace(SearchSpec(), [Knob("efs", (32, 64)), Knob("k", (5, 10))])
    assert space.cost_class("efs") == "engine"
    assert space.cost_class("k") == "request"
    assert [k.name for k in space.engine_knobs] == ["efs"]
    assert [k.name for k in space.request_knobs] == ["k"]


def test_candidate_enumeration_deterministic_and_deduped():
    base = SearchSpec(k=10, efs=32, router="crouting")
    space = TuneSpace.default(base, efs=(8, 32, 64), beam_width=(1, 2))
    cands = space.candidates()
    # efs=8 < k=10 dropped; 2 efs x 2 beam survive, in declaration order
    assert [(c.efs, c.beam_width) for c in cands] == \
        [(32, 1), (32, 2), (64, 1), (64, 2)]
    assert cands == space.candidates()       # stable across calls
    keys = [spec_key(c) for c in cands]
    assert len(set(keys)) == len(keys)
    # request-only knobs collapse onto one engine identity
    space2 = TuneSpace(base, [Knob("efs", (32, 64)),
                              Knob("cos_theta", (0.5, 0.9))])
    assert len(space2.candidates()) == 2
    # domains advertised in core.spec stay importable/enumerable
    assert set(KNOB_DOMAINS) >= {"efs", "beam_width", "estimate"}


# --------------------------------------------------------------------------
# controller: deterministic seeded search over a synthetic system
# --------------------------------------------------------------------------
def _fake_probe(spec, replays=1):
    """Synthetic system: latency ~ efs*W, recall rises with efs."""
    lat_ms = float(spec.efs * spec.beam_width)
    recall = min(1.0, 0.80 + spec.efs / 640.0)
    return ProbeMeasurement(key=spec_key(spec), recall=recall,
                            lat_s=lat_ms * 1e-3, dist_calls=float(spec.efs),
                            replays=replays)


def _make_controller(seed=0, slo_ms=200.0, mode="max_recall"):
    base = SearchSpec(k=10, efs=32, router="crouting")
    space = TuneSpace.default(base, efs=(32, 64, 128), beam_width=(1, 2))
    return Controller(space, Objective(slo_p99_ms=slo_ms, mode=mode),
                      _fake_probe, seed=seed, screen_replays=(1, 2),
                      max_finalists=4, epsilon=0.3)


def _delta(p99_ms, served=64, qps=50.0):
    return {"p99_ms": p99_ms, "served": served, "qps": qps}


def test_screen_quarantines_slo_blowing_probes_and_picks_max_recall():
    ctl = _make_controller()
    d = ctl.screen()
    assert d.kind == "screen"
    # efs=128,W=2 probes at 256ms > 200ms SLO: quarantined during probing
    assert list(ctl.quarantined) == \
        ["efs=128,W=2,router=crouting,estimate=exact,engine=jnp,prune=best"]
    # incumbent = max recall among feasible candidates
    assert ctl.incumbent.startswith("efs=128,W=1")
    assert ctl.by_key[ctl.incumbent].efs == 128


def test_violation_steps_down_then_headroom_steps_back_up():
    ctl = _make_controller()
    ctl.screen()
    # live p99 blows the SLO -> calibrated model picks a cheaper feasible
    d = ctl.step(_delta(400.0))
    assert d.kind == "switch" and "SLO violated" in d.reason
    assert ctl.by_key[ctl.incumbent].efs < 128
    down = ctl.by_key[ctl.incumbent]
    # sustained deep headroom -> upgrade to a higher-recall finalist
    kinds = []
    for _ in range(6):
        kinds.append(ctl.step(_delta(20.0)).kind)
        if ctl.by_key[ctl.incumbent].efs > down.efs:
            break
    assert ctl.by_key[ctl.incumbent].efs > down.efs, kinds


def test_min_p99_mode_respects_recall_floor():
    ctl = _make_controller(mode="min_p99")
    ctl.objective = dataclasses.replace(ctl.objective, recall_floor=0.88)
    ctl.screen()
    inc = ctl.by_key[ctl.incumbent]
    assert ctl.measurements[ctl.incumbent].recall >= 0.88
    # cheapest candidate meeting the floor: efs=64 (recall 0.9), not 32
    assert inc.efs == 64 and inc.beam_width == 1


def test_decision_log_deterministic_per_seed():
    """Same observation trace + same seed -> byte-identical decision log
    (the acceptance property; epsilon exploration draws from the seeded
    PRNG only)."""
    trace = [400.0, 150.0, 20.0, 180.0, 20.0, 20.0, 350.0, 100.0, 20.0,
             190.0, 20.0, 150.0]

    def run(seed):
        ctl = _make_controller(seed=seed)
        ctl.screen()
        for p99 in trace:
            ctl.step(_delta(p99))
        return [d.to_dict() for d in ctl.decisions]

    assert run(7) == run(7)
    # the log replays the full bracket + every epoch
    log = run(7)
    assert log[0]["kind"] == "screen" and len(log) == 1 + len(trace)


def test_idle_window_is_a_noop_decision():
    ctl = _make_controller()
    ctl.screen()
    inc = ctl.incumbent
    d = ctl.step({"p99_ms": None, "served": 0})
    assert d.kind == "idle" and ctl.incumbent == inc


# --------------------------------------------------------------------------
# proxy: attach-time exact ground truth, probe replay correctness
# --------------------------------------------------------------------------
def test_proxy_synthesized_probes_hit_exact_ground_truth(built):
    proxy = RecallProxy.for_index(built, n_probe=12, k=10, seed=3,
                                  buckets=BUCKETS)
    assert proxy.queries.shape == (12, built.graph.dim)
    assert proxy.gt.shape == (12, 10)
    m = proxy.evaluate(SearchSpec(k=10, efs=64, router="crouting"),
                       replays=1)
    assert m.recall >= 0.95          # a rich spec nails near-dup probes
    assert m.lat_s > 0 and m.replays == 1


def test_proxy_explicit_queries_and_gt(built, small_ds, ground_truth):
    proxy = RecallProxy.for_index(built, queries=small_ds.queries[:10],
                                  gt=ground_truth[:10], buckets=BUCKETS)
    m = proxy.evaluate(SearchSpec(k=10, efs=64, router="crouting"))
    # matches direct search recall on the same queries
    from repro.data.vectors import recall_at_k
    ids, _, _ = built.search(small_ds.queries[:10],
                             spec=SearchSpec(k=10, efs=64, router="crouting"))
    assert m.recall == pytest.approx(
        recall_at_k(ids, ground_truth[:10], 10))


def test_proxy_explicit_gt_wider_than_k(built, small_ds):
    with pytest.raises(AssertionError, match="narrower"):
        RecallProxy(built, small_ds.queries[:4], np.zeros((4, 5), np.int64),
                    k=10)


# --------------------------------------------------------------------------
# driver: end-to-end attach/step/promote on a live frontend
# --------------------------------------------------------------------------
@pytest.fixture(scope="module")
def tuned(built, small_ds):
    """One attached frontend+driver shared by the e2e tests (session
    warmup + screening probes are the expensive part)."""
    spec = SearchSpec(k=10, efs=32, router="crouting")
    fe = ServeFrontend(built, spec, buckets=BUCKETS)
    space = TuneSpace.default(spec, efs=(16, 32), beam_width=(1,))
    drv = AutotuneDriver.attach(fe, Objective(slo_p99_ms=60_000.0),
                                space=space, n_probe=8, seed=1)
    return fe, drv


def test_attach_screens_and_promotes_within_slo(tuned):
    fe, drv = tuned
    assert drv.controller.incumbent is not None
    assert spec_key(fe.active_spec) == drv.controller.incumbent
    assert drv.decisions[0].kind == "screen"
    # promotion pre-warmed the ladder: nothing compiled on the request path
    assert fe.telemetry.recompiles_after_warmup == 0


def test_step_consumes_window_delta_and_keeps(tuned, small_ds):
    fe, drv = tuned
    for n in (1, 3, 8):
        fe.search(small_ds.queries[:n])
    d = drv.step()
    # absurdly loose SLO -> never a violation; keep/probe/switch-up only
    assert d.kind in ("keep", "probe", "switch")
    assert d.measured["served"] >= 3
    assert fe.telemetry.recompiles_after_warmup == 0


def test_health_surfaces_controller_state(tuned):
    fe, drv = tuned
    h = fe.health()
    assert h["autotune"]["incumbent"] == drv.controller.incumbent
    assert h["autotune"]["failures"] == drv.failures
    assert h["autotune"]["objective"]["slo_p99_ms"] == 60_000.0
    assert "last_decision" in h["autotune"]
    assert h["active_spec"]["efs"] == fe.active_spec.canonical().efs


def test_fail_open_on_injected_controller_fault(tuned, small_ds):
    """ISSUE 9 acceptance: an injected controller exception leaves the
    frontend serving the last-good spec, recorded as a fail decision."""
    fe, drv = tuned
    active = fe.active_spec
    fails0, n_dec = drv.failures, len(drv.decisions)
    fault.arm("autotune.step", kind="raise")
    try:
        d = drv.step()
    finally:
        fault.disarm("autotune.step")
    assert d.kind == "fail" and "fail-open" in d.reason
    assert drv.failures == fails0 + 1 and drv.last_error is not None
    assert len(drv.decisions) == n_dec + 1
    assert fe.active_spec is active          # untouched
    ids, _, _ = fe.search(small_ds.queries[:2])   # still serving
    assert ids.shape == (2, 10)
    # and the loop recovers on the next (un-faulted) step
    d2 = drv.step()
    assert d2.kind != "fail"


def test_fail_open_on_probe_fault_during_screen(built):
    """A probe-path fault during the screening bracket fails open too:
    the frontend keeps its construction-time spec."""
    spec = SearchSpec(k=10, efs=32, router="crouting")
    fe = ServeFrontend(built, spec, buckets=(1, 8))
    space = TuneSpace.default(spec, efs=(32,), beam_width=(1,))
    fault.arm("autotune.probe", kind="raise")
    try:
        drv = AutotuneDriver.attach(fe, 60_000.0, space=space, n_probe=4,
                                    seed=0)
    finally:
        fault.disarm("autotune.probe")
    assert drv.decisions[-1].kind == "fail"
    assert fe.active_spec.efs == 32          # last-good spec still serving
    assert fe.search(np.asarray(built.graph.vectors[:2]))[0].shape == (2, 10)

"""Per-family parameter/batch sharding rules (DESIGN.md §6).

2-D FSDP x TP scheme for LMs: weight matrices shard (reduction dim -> 'data',
output dim -> 'model'); optimizer state mirrors params (ZeRO-3); activations
shard batch over ('pod','data').  MoE experts shard over 'model' (EP).  GNN
node/edge arrays shard over the data axes.  DLRM embedding tables row-shard
over 'model' (table parallel).

Rules are *name-based* over pytree paths so they apply to params, grads, and
optimizer moments identically.
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.launch.mesh import data_axes


def _ns(mesh, *spec):
    return NamedSharding(mesh, P(*spec))


def _path_str(path) -> str:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
        elif hasattr(p, "name"):
            out.append(str(p.name))
    return "/".join(out)


# --------------------------------------------------------------------------
# LM rules
# --------------------------------------------------------------------------
def lm_param_sharding(mesh: Mesh, params_spec) -> Any:
    """Map a param pytree (of ShapeDtypeStruct or arrays) to NamedShardings."""

    def rule(path, leaf):
        name = _path_str(path)
        nd = len(leaf.shape)
        if "embed" in name:
            return _ns(mesh, "model", None)
        if "lm_head" in name:
            return _ns(mesh, None, "model")
        if name.endswith("final_norm") or "norm" in name:
            return _ns(mesh, *([None] * nd))
        if any(k in name for k in ("we_gate", "we_up")):      # [L, E, D, F]
            return _ns(mesh, None, "model", "data", None)
        if "we_down" in name:                                  # [L, E, F, D]
            return _ns(mesh, None, "model", None, "data")
        if name.endswith("/gate"):
            return _ns(mesh, None, "data", None)               # router [L, D, E]
        if any(k in name for k in ("wq", "wk", "wv", "w_gate", "w_up", "wr_gate", "wr_up")):
            return _ns(mesh, None, "data", "model")            # [L, D, out]
        if any(k in name for k in ("wo", "w_down", "wr_down")):
            return _ns(mesh, None, "model", "data")            # [L, in, D]
        if any(k in name for k in ("bq", "bk", "bv")):
            return _ns(mesh, None, "model")
        return _ns(mesh, *([None] * nd))

    return jax.tree_util.tree_map_with_path(rule, params_spec)


def lm_opt_sharding(mesh: Mesh, opt_spec, param_shardings) -> Any:
    """AdamWState(step, mu, nu): moments mirror the param rule."""
    from repro.train.optimizer import AdamWState
    return AdamWState(step=_ns(mesh),
                      mu=param_shardings, nu=param_shardings)


def lm_batch_sharding(mesh: Mesh) -> Any:
    d = data_axes(mesh)
    return {"tokens": _ns(mesh, d, None), "labels": _ns(mesh, d, None)}


def lm_cache_sharding(mesh: Mesh, batch: int, seq: int) -> Any:
    """KV cache [L, B, T, Hkv, dh]: shard B over data axes when divisible,
    otherwise shard the sequence axis (long-context decode, DESIGN.md §5)."""
    d = data_axes(mesh)
    ndev = 1
    for a in d:
        ndev *= mesh.shape[a]
    if batch % ndev == 0 and batch >= ndev:
        spec = _ns(mesh, None, d, "model", None, None) \
            if seq % mesh.shape["model"] == 0 else _ns(mesh, None, d, None, None, None)
    else:
        spec = _ns(mesh, None, None, d + ("model",), None, None) \
            if seq % (ndev * mesh.shape["model"]) == 0 else _ns(mesh, None, None, d, None, None)
    return {"k": spec, "v": spec}


def lm_token_sharding(mesh: Mesh, batch: int) -> Any:
    d = data_axes(mesh)
    ndev = 1
    for a in d:
        ndev *= mesh.shape[a]
    return _ns(mesh, d, None) if batch % ndev == 0 and batch >= ndev \
        else _ns(mesh, None, None)


# --------------------------------------------------------------------------
# GNN rules
# --------------------------------------------------------------------------
def gnn_param_sharding(mesh: Mesh, params_spec) -> Any:
    # GNN params are tiny (<1M): replicate.
    def rule(path, leaf):
        return _ns(mesh, *([None] * len(leaf.shape)))
    return jax.tree_util.tree_map_with_path(rule, params_spec)


def gnn_batch_sharding(mesh: Mesh, batch_spec) -> Any:
    d = data_axes(mesh)

    def rule(path, leaf):
        name = _path_str(path)
        nd = len(leaf.shape)
        if name.startswith("edge_"):
            return _ns(mesh, d + ("model",))      # edges over every device
        if name in ("node_feat", "pos"):
            return _ns(mesh, d, None)             # nodes over data axes
        if name in ("atom_z", "node_mask", "labels", "label_mask", "graph_ids"):
            return _ns(mesh, d)
        return _ns(mesh, *([None] * nd))

    return jax.tree_util.tree_map_with_path(rule, batch_spec)


# --------------------------------------------------------------------------
# DLRM rules
# --------------------------------------------------------------------------
def dlrm_param_sharding(mesh: Mesh, params_spec) -> Any:
    """Tables row-shard over EVERY mesh axis (§Perf HC1: model-only row
    sharding replicated 96 GB of tables+grads+moments 16x over 'data')."""
    all_axes = tuple(mesh.axis_names)

    def rule(path, leaf):
        name = _path_str(path)
        nd = len(leaf.shape)
        # matches params AND optimizer moments (paths: tables/0, mu/tables/0)
        if "tables/" in name + "/":
            rows = leaf.shape[0]
            ndev = 1
            for a in all_axes:
                ndev *= mesh.shape[a]
            if rows % ndev == 0:
                return _ns(mesh, all_axes, None)
            return _ns(mesh, "model", None)       # small tables: model only
        if nd == 2:
            return _ns(mesh, None, None)          # small MLPs replicated
        return _ns(mesh, *([None] * nd))
    return jax.tree_util.tree_map_with_path(rule, params_spec)


def dlrm_batch_sharding(mesh: Mesh, batch: int) -> Any:
    # §Perf HC1: the batch is REPLICATED — sparse ids must be visible to every
    # table shard for the masked-gather + psum lookup, and the whole batch is
    # ~10 MB vs 96 GB of tables.  The (tiny) MLP compute is replicated too.
    return {"dense": _ns(mesh, None, None),
            "sparse_ids": _ns(mesh, None, None),
            "labels": _ns(mesh, None)}


def replicate(mesh: Mesh, spec) -> Any:
    return jax.tree_util.tree_map(
        lambda l: _ns(mesh, *([None] * len(l.shape))), spec)

"""Graph-construction invariants + recall floors + metric generality."""
import numpy as np
import pytest

from repro.core.graph import validate_graph
from repro.core.index import AnnIndex
from repro.core.search import search_batch
from repro.core.spec import SearchSpec
from repro.data.vectors import make_dataset, exact_ground_truth, recall_at_k


def test_hnsw_structure(hnsw_index):
    validate_graph(hnsw_index)
    assert hnsw_index.kind == "hnsw"
    assert hnsw_index.upper_neighbors is not None
    assert hnsw_index.build_stats["levels"] >= 2


def test_nsg_structure(nsg_index):
    validate_graph(nsg_index)
    assert nsg_index.kind == "nsg"
    # NSG: medoid entry + connectivity guaranteed via spanning tree
    n = nsg_index.n
    seen = np.zeros(n, bool)
    stack = [nsg_index.entry_point]
    seen[nsg_index.entry_point] = True
    while stack:
        u = stack.pop()
        for v in nsg_index.neighbors[u]:
            if v < n and not seen[v]:
                seen[v] = True
                stack.append(int(v))
    assert seen.all(), f"{(~seen).sum()} unreachable nodes"


@pytest.mark.parametrize("which", ["hnsw", "nsg"])
def test_recall_floor(small_ds, hnsw_index, nsg_index, ground_truth, which):
    g = hnsw_index if which == "hnsw" else nsg_index
    res = search_batch(g, small_ds.queries,
                       SearchSpec(efs=48, router="none",
                                    use_hierarchy=g.upper_neighbors is not None))
    rec = recall_at_k(np.asarray(res.ids[:, :10]), ground_truth, 10)
    # NSG floor is lower: our candidate pools use the final search pool only
    # (real NSG unions the visited set), which on strongly clustered data
    # leaves MRNG short of long-range edges (DESIGN.md §7) — recall plateaus
    # ~0.8 at small R on the hierarchical fixture. HNSW is the primary index.
    floor = 0.85 if which == "hnsw" else 0.75
    assert rec > floor, f"{which} recall {rec}"


def test_edge_distances_are_stored_euclidean(hnsw_index):
    """CRouting's extra state: stored d(c,n) must equal true Euclidean."""
    g = hnsw_index
    rng = np.random.default_rng(1)
    for i in rng.integers(0, g.n, size=32):
        nbrs = g.neighbors[i][g.neighbors[i] < g.n]
        d = np.linalg.norm(g.vectors[nbrs] - g.vectors[i], axis=1)
        np.testing.assert_allclose(g.edge_eu_dist[i][: len(nbrs)], d,
                                   rtol=1e-3, atol=1e-4)


@pytest.mark.parametrize("metric", ["cosine", "ip"])
def test_metric_generality(metric):
    """§4.3 / Fig. 16: CRouting works under IP and cosine via Eq. 4."""
    ds = make_dataset(n_base=1200, n_query=30, dim=48, n_clusters=16,
                      metric=metric, seed=2)
    idx = AnnIndex.build(ds.base, graph="hnsw", metric=metric, m=12, efc=64)
    gt = exact_ground_truth(ds, k=10)
    from repro.core.spec import SearchSpec
    ids_p, _, info_p = idx.search(ds.queries, spec=SearchSpec(
        k=10, efs=48, router="none"))
    ids_c, _, info_c = idx.search(ds.queries, spec=SearchSpec(
        k=10, efs=48, router="crouting"))
    rec_p = recall_at_k(ids_p, gt, 10)
    rec_c = recall_at_k(ids_c, gt, 10)
    assert rec_p > 0.8, (metric, rec_p)
    assert rec_c > rec_p - 0.15, (metric, rec_c)
    assert info_c.dist_calls.mean() < info_p.dist_calls.mean()


def test_index_size_accounting(hnsw_index):
    """Table 7: mem_dist is the only CRouting overhead, a few % to ~20%."""
    m = hnsw_index.memory_bytes()
    base = m["total"] - m["mem_dist"]
    overhead = m["mem_dist"] / base
    assert 0.01 < overhead < 0.6, overhead


def test_save_load_roundtrip(tmp_path, small_ds, hnsw_index, hnsw_profile):
    from repro.core.index import AnnIndex
    idx = AnnIndex(graph=hnsw_index, profile=hnsw_profile)
    p = str(tmp_path / "idx.npz")
    idx.save(p)
    idx2 = AnnIndex.load(p)
    from repro.core.spec import SearchSpec
    i1, d1, _ = idx.search(small_ds.queries[:5], spec=SearchSpec(k=5))
    i2, d2, _ = idx2.search(small_ds.queries[:5], spec=SearchSpec(k=5))
    assert np.array_equal(i1, i2)
    np.testing.assert_allclose(d1, d2)
    assert abs(idx2.profile.theta_star - hnsw_profile.theta_star) < 1e-9

"""Production mesh construction (DESIGN.md §6).

A FUNCTION, not a module-level constant: importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before any jax init; smoke tests
see the real single device).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def data_axes(mesh) -> tuple:
    """The axes the batch dimension shards over ('pod' folds into data)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def make_local_mesh(n: int = 1, name: str = "data"):
    """Mesh over whatever devices exist (tests / examples)."""
    n = min(n, len(jax.devices()))
    return jax.make_mesh((n,), (name,),
                         axis_types=(jax.sharding.AxisType.Auto,))

"""Pluggable routing strategies: the ``Router`` protocol + registry.

The paper pitches CRouting as "a plugin to optimize existing graph-based
search with minimal code modifications"; this module is that plugin surface
for the batched engine.  A *router* decides, per candidate lane of the
``[B, W*M]`` expansion tile, whether the exact distance call can be skipped.
Instead of string branches inside ``core/search.py``, each strategy is a
registry entry declaring:

* **flags** the engine consumes (``prunes`` / ``permanent`` /
  ``revisit_pruned`` / ``counts_est`` / ``kernel_estimate``);
* an ``estimate_rank`` hook producing the per-lane estimated ranking
  distance (a lane is pruned when the estimate already beats the frozen
  pool bound) plus any **router-specific counters** it wants surfaced in
  ``SearchStats.extra``;
* a ``prepare`` hook that lazily upgrades the per-graph device-array cache
  with companion tables (mirroring how ``ensure_sq8_arrays`` adds the SQ8
  codes the first time a quantized config runs).

Built-ins: ``none`` (Algorithm 1), ``crouting`` / ``crouting_o`` (paper
Algorithm 2 with/without error correction), ``triangle`` (exact
triangle-inequality lower bound, §3.2) and ``finger`` — an
engine-integrated port of the FINGER baseline (Chen et al., WWW'23,
``core/finger.py``): residual-subspace estimates with sign-LSH signatures,
evaluated tile-wide on device.

Kernel interplay: the edge-angle family (``crouting*``/``triangle``)
evaluates ``est2 = ed^2 + dcq^2 - 2*ed*dcq*cos_theta`` — exactly the
expression the Pallas ``crouting_prune``/``fused_expand`` kernels compute,
so those routers set ``kernel_estimate=True`` and the engine may take the
prune decision inside the kernel (bit-equal f32 math).  Routers with other
estimate forms (``finger``) run their hook on the jnp path under every
engine; the kernels still handle the distance gather/merge.

Adding a strategy is ~a-hundred-line plugin::

    @dataclasses.dataclass(frozen=True)
    class MyRouter(Router):
        def estimate_rank(self, ctx):
            est_rank = ...                       # [B, L], ranking space
            return est_rank, {"my_counter": jnp.sum(ctx.try_prune, axis=1,
                                                    dtype=jnp.int32)}

    register_router(MyRouter(name="mine", prunes=True,
                             extra_counters=("my_counter",)))
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.distances import get_metric
from repro.core.graph import GraphIndex


class RouterContext(NamedTuple):
    """Everything a router's ``estimate_rank`` hook may look at.

    Shapes: B queries, W beam slots, M max degree, L = W*M tile lanes.
    No fp32 *neighbor* row may be read here — the whole point of a router
    is to decide the prune before that DMA happens.  (The W expansion
    nodes' own rows are fair game: their exact distances are already paid.)
    """

    arrays: Dict[str, Any]   # per-graph device tables (see graph_device_arrays)
    queries: jax.Array       # [B, d] f32
    nq: jax.Array            # [B] query norms (ones under l2)
    c: jax.Array             # [B, W] expansion-node ids (pad = n)
    dc: jax.Array            # [B, W] exact ranking distance d(c, q)
    nbrs: jax.Array          # [B, L] neighbor ids (pad = n)
    ed: jax.Array            # [B, L] stored edge Euclidean distances d(c, n)
    dcq: jax.Array           # [B, L] per-lane Euclidean d(c, q)
    nx: jax.Array            # [B, L] neighbor norms
    try_prune: jax.Array     # [B, L] bool — lanes eligible for the prune test
    upper: jax.Array         # [B] frozen pool upper bound (ranking space)
    cos_theta: Any           # traced scalar, cos(theta*) from the profile
    metric: str
    n: int                   # number of real rows (pad row index)
    beam_width: int          # W
    max_degree: int          # M


@dataclasses.dataclass(frozen=True)
class Router:
    """A routing strategy: flags the engine consumes + optional hooks.

    Attributes:
      name: registry key (``SearchSpec.router``).
      prunes: whether the strategy runs an estimate/prune test at all
        (``False`` == plain Algorithm 1).
      permanent: pruned lanes are marked VISITED — final, never revisited.
        Correct for exact bounds (``triangle``) and strategies that prune
        permanently by design (``finger``); estimate-based strategies
        should leave this ``False`` so pruned nodes stay revisitable.
      revisit_pruned: PRUNED lanes may be re-estimated on a later encounter
        (the paper's error correction).  ``crouting_o`` sets ``False``.
        Irrelevant when ``permanent`` (no PRUNED status is ever written).
      counts_est: estimate evaluations increment ``est_calls``
        (``triangle``'s bound is free — it sets ``False``).
      kernel_estimate: the estimate is the edge-angle form the Pallas
        ``crouting_prune``/``fused_expand`` kernels implement, so the prune
        decision may be taken in-kernel.
      extra_counters: names of per-router ``[B]`` int32 counters the
        ``estimate_rank`` hook returns; surfaced as ``SearchStats.extra``.
      companion_tables: keys ``prepare`` adds to the arrays cache.  The
        sharded serving path only supports routers without companion
        tables (per-shard table plumbing is future work).
    """

    name: str
    prunes: bool = False
    permanent: bool = False
    revisit_pruned: bool = True
    counts_est: bool = True
    kernel_estimate: bool = False
    extra_counters: Tuple[str, ...] = ()
    companion_tables: Tuple[str, ...] = ()

    def cos_theta_eff(self, cos_theta):
        """The cos(theta) the edge-angle estimate uses (traced or static)."""
        return cos_theta

    def prepare(self, g: GraphIndex, arrays: Dict[str, Any]) -> Dict[str, Any]:
        """Lazily add companion device tables to the per-graph cache
        (idempotent; mirrors ``ensure_sq8_arrays``)."""
        return arrays

    def estimate_rank(self, ctx: RouterContext):
        """Per-lane estimated ranking distance + extra-counter increments.

        Returns ``(est_rank [B, L], {counter_name: [B] int32 increment})``.
        The engine prunes ``try_prune`` lanes whose estimate already
        reaches the frozen pool bound.
        """
        raise NotImplementedError(
            f"router {self.name!r} declares prunes={self.prunes} but no "
            "estimate_rank hook")


@dataclasses.dataclass(frozen=True)
class EdgeAngleRouter(Router):
    """Cosine-theorem family (paper §3): estimate d(n, q) from the stored
    edge distance d(c, n), the known d(c, q) and an angle threshold.

    ``fixed_cos`` pins the angle term: ``triangle`` uses ``1.0``, turning
    the estimate into the exact lower bound ``(d(c,n) - d(c,q))^2``.
    """

    fixed_cos: Optional[float] = None

    def cos_theta_eff(self, cos_theta):
        return self.fixed_cos if self.fixed_cos is not None else cos_theta

    def estimate_rank(self, ctx: RouterContext):
        ct = self.cos_theta_eff(ctx.cos_theta)
        # identical f32 expression to the Pallas kernels (bit-equal prunes)
        est2 = jnp.maximum(
            ctx.ed * ctx.ed + ctx.dcq * ctx.dcq
            - 2.0 * ctx.ed * ctx.dcq * ct, 0.0)
        est_rank = get_metric(ctx.metric).eu2_to_rank(
            est2, ctx.nq[:, None], ctx.nx)
        return est_rank, {}


# --------------------------------------------------------------------------
# FINGER (engine-integrated port of core/finger.py)
# --------------------------------------------------------------------------
_FINGER_TABLES = ("finger_H", "finger_c2", "finger_hc", "finger_edge_t",
                  "finger_edge_rn", "finger_edge_sig")


def ensure_finger_arrays(g: GraphIndex, arrays: Dict[str, Any],
                         r_bits: int = 64) -> Dict[str, Any]:
    """Add the FINGER companion tables to a packed arrays dict (idempotent).

    Reuses the NumPy construction of ``core/finger.py`` (per-edge
    projection coefficient, residual norm, packed sign-LSH signature;
    per-node |c|^2 and H@c), then appends the pad row (zero vector: t=0,
    |res|=0, empty signature) and re-packs the uint64 signature words into
    little-endian uint32 pairs — x64 is off on device, and
    ``lax.population_count`` handles uint32 natively.
    """
    if "finger_edge_sig" in arrays:
        return arrays
    from repro.core.finger import build_finger

    fi = build_finger(g, r_bits=r_bits, seed=0)
    m = g.max_degree
    c2 = np.concatenate([fi.node_c2, np.ones(1, np.float32)])
    hc = np.concatenate([fi.node_hc, np.zeros((1, r_bits), np.float32)])
    t = np.concatenate([fi.edge_t, np.zeros((1, m), np.float32)])
    rn = np.concatenate([fi.edge_res_norm, np.zeros((1, m), np.float32)])
    sig = np.concatenate(
        [fi.edge_sig, np.zeros((1, m, r_bits // 64), np.uint64)], axis=0)
    # uint64 word w -> uint32 words (2w, 2w+1): bit b of uint32 word j is
    # hyperplane column 32*j + b, matching the query-side packing below
    lo = (sig & np.uint64(0xFFFFFFFF)).astype(np.uint32)
    hi = (sig >> np.uint64(32)).astype(np.uint32)
    sig32 = np.stack([lo, hi], axis=-1).reshape(g.n + 1, m, r_bits // 32)
    arrays["finger_H"] = jnp.asarray(fi.hyperplanes)
    arrays["finger_c2"] = jnp.asarray(c2)
    arrays["finger_hc"] = jnp.asarray(hc)
    arrays["finger_edge_t"] = jnp.asarray(t)
    arrays["finger_edge_rn"] = jnp.asarray(rn)
    arrays["finger_edge_sig"] = jnp.asarray(sig32)
    return arrays


@dataclasses.dataclass(frozen=True)
class FingerRouter(Router):
    """Residual-subspace estimate (FINGER, Chen et al., WWW'23) as a tile
    hook: per expansion node c the query decomposes into a component along
    c and a residual whose angle to each neighbor's residual is estimated
    via sign-LSH hamming distance.  Prunes permanently, like the baseline
    (``finger_search``).  L2-exact; other metrics go through the same
    Euclidean-to-rank conversion as the edge-angle family.
    """

    r_bits: int = 64

    def prepare(self, g, arrays):
        return ensure_finger_arrays(g, arrays, r_bits=self.r_bits)

    def estimate_rank(self, ctx: RouterContext):
        arrays, q, c = ctx.arrays, ctx.queries, ctx.c
        B, L = ctx.nbrs.shape
        H = arrays["finger_H"]                           # [r, d]
        r_bits = H.shape[0]
        cvec = arrays["vectors"][c]                      # [B, W, d]
        c2 = jnp.maximum(arrays["finger_c2"][c], 1e-12)  # [B, W]
        t_q = jnp.einsum("bd,bwd->bw", q, cvec) / c2     # [B, W]
        q2 = jnp.sum(q * q, axis=-1)                     # [B]
        q_res2 = jnp.maximum(q2[:, None] - t_q * t_q * c2, 0.0)
        q_rn = jnp.sqrt(q_res2)                          # [B, W]
        # query-residual signature w.r.t. node c: sign(Hq - t_q * Hc)
        hq = q @ H.T                                     # [B, r]
        hc = arrays["finger_hc"][c]                      # [B, W, r]
        bits = ((hq[:, None, :] - t_q[..., None] * hc) > 0)
        pow2 = jnp.left_shift(jnp.uint32(1),
                              jnp.arange(32, dtype=jnp.uint32))
        sig_q = jnp.sum(
            bits.reshape(bits.shape[:-1] + (r_bits // 32, 32))
            .astype(jnp.uint32) * pow2, axis=-1, dtype=jnp.uint32)
        esig = arrays["finger_edge_sig"][c]              # [B, W, M, words]
        ham = jnp.sum(jax.lax.population_count(esig ^ sig_q[:, :, None, :]),
                      axis=-1)                           # [B, W, M]
        rho = ham.astype(jnp.float32) / r_bits
        t_n = arrays["finger_edge_t"][c]                 # [B, W, M]
        n_rn = arrays["finger_edge_rn"][c]
        # paper Eq. 1: |q-n|^2 ~= (t_q-t_n)^2 |c|^2 + |q_res|^2 + |n_res|^2
        #                         - 2 |q_res||n_res| cos(pi rho)
        est2 = ((t_q[..., None] - t_n) ** 2 * c2[..., None]
                + q_res2[..., None] + n_rn * n_rn
                - 2.0 * q_rn[..., None] * n_rn * jnp.cos(jnp.pi * rho))
        est2 = jnp.maximum(est2, 0.0).reshape(B, L)
        est_rank = get_metric(ctx.metric).eu2_to_rank(
            est2, ctx.nq[:, None], ctx.nx)
        extras = {"finger_est_calls": jnp.sum(ctx.try_prune, axis=1,
                                              dtype=jnp.int32)}
        return est_rank, extras


# --------------------------------------------------------------------------
# registry
# --------------------------------------------------------------------------
_REGISTRY: Dict[str, Router] = {}


def register_router(router: Router, overwrite: bool = False) -> Router:
    """Add a routing strategy to the registry (``SearchSpec.router`` key)."""
    if router.name in _REGISTRY and not overwrite:
        raise ValueError(f"router {router.name!r} already registered; pass "
                         "overwrite=True to replace it")
    _REGISTRY[router.name] = router
    return router


def unregister_router(name: str) -> None:
    """Remove a registry entry (built-ins included — tests use this)."""
    _REGISTRY.pop(name, None)


def get_router(name: str) -> Router:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown router {name!r}; registered: {available_routers()}"
        ) from None


def available_routers() -> Tuple[str, ...]:
    """Registered strategy names, registration order (built-ins first)."""
    return tuple(_REGISTRY)


register_router(Router(name="none", prunes=False))
register_router(EdgeAngleRouter(name="crouting", prunes=True,
                                kernel_estimate=True))
register_router(EdgeAngleRouter(name="crouting_o", prunes=True,
                                revisit_pruned=False, kernel_estimate=True))
register_router(EdgeAngleRouter(name="triangle", prunes=True, permanent=True,
                                counts_est=False, kernel_estimate=True,
                                fixed_cos=1.0))
register_router(FingerRouter(name="finger", prunes=True, permanent=True,
                             extra_counters=("finger_est_calls",),
                             companion_tables=_FINGER_TABLES))

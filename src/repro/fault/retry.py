"""Jittered capped-exponential-backoff retry policy (DESIGN.md §10).

One policy object serves every transient-failure caller in the stack: the
``QueueFull`` backpressure loops in ``launch/serve.py`` and the examples,
and the background-merge retry inside ``MutableAnnIndex``.  Frozen and
seeded: the same policy replays the same backoff sequence, so chaos runs
and tests are deterministic.

Jitter exists to decorrelate retries across many callers (the classic
thundering-herd fix); the cap bounds the worst single wait.
"""
from __future__ import annotations

import dataclasses
import random
import time
from typing import Callable, Iterator, Optional, Tuple, Type, Union

ExcTypes = Union[Type[BaseException], Tuple[Type[BaseException], ...]]


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Capped exponential backoff with deterministic seeded jitter.

    ``max_attempts`` counts calls, not retries: ``max_attempts=1`` never
    retries.  The d-th delay is ``min(base_s * multiplier**d, cap_s)``
    scaled by a jitter factor drawn uniformly from ``[1-jitter, 1+jitter]``
    (a fresh ``random.Random(seed)`` per ``delays()`` walk, so two walks of
    the same policy produce identical sequences).
    """

    max_attempts: int = 8
    base_s: float = 0.01
    cap_s: float = 1.0
    multiplier: float = 2.0
    jitter: float = 0.5
    seed: Optional[int] = None
    # total-budget cap (ISSUE 8 satellite): the SUMMED backoff sleeps never
    # exceed this — the last delay is truncated to fit and the schedule ends
    # there, so a retry loop can't overrun e.g. a quarantine cooldown no
    # matter how many attempts remain.  None = attempts-only bound.
    max_elapsed_s: Optional[float] = None

    def __post_init__(self):
        assert self.max_attempts >= 1, "need at least one attempt"
        assert self.base_s >= 0 and self.cap_s >= 0 and self.multiplier >= 1
        assert 0.0 <= self.jitter < 1.0, "jitter is a fraction of the delay"
        assert self.max_elapsed_s is None or self.max_elapsed_s >= 0

    def delays(self) -> Iterator[float]:
        """The (at most ``max_attempts - 1``) sleeps between attempts, in
        order.  With ``max_elapsed_s`` set the walk ends early once the
        budget is spent (its last delay truncated to exactly exhaust it)."""
        rng = random.Random(self.seed)
        d = self.base_s
        spent = 0.0
        for _ in range(self.max_attempts - 1):
            j = 1.0 + self.jitter * (2.0 * rng.random() - 1.0) \
                if self.jitter else 1.0
            s = min(d, self.cap_s) * j
            if self.max_elapsed_s is not None:
                remaining = self.max_elapsed_s - spent
                if remaining <= 0:
                    return
                s = min(s, remaining)
            spent += s
            yield s
            d = min(d * self.multiplier, self.cap_s)

    def call(self, fn: Callable, *args,
             retry_on: ExcTypes = Exception,
             sleep: Callable[[float], None] = time.sleep,
             on_retry: Optional[Callable[[int, BaseException], None]] = None,
             **kw):
        """Call ``fn`` under this policy, retrying on ``retry_on``.

        The final attempt's exception propagates unwrapped — whether the
        schedule ends on ``max_attempts`` or on an exhausted
        ``max_elapsed_s`` budget.  ``on_retry`` (attempt index, exception)
        observes each failure before its backoff sleep — telemetry's hook.
        ``sleep`` is injectable for tests.
        """
        delays = self.delays()
        for attempt in range(self.max_attempts):
            try:
                return fn(*args, **kw)
            except retry_on as e:
                if attempt == self.max_attempts - 1:
                    raise
                try:
                    delay = next(delays)
                except StopIteration:
                    raise e          # noqa: B904 — budget spent: propagate
                if on_retry is not None:
                    on_retry(attempt, e)
                sleep(delay)
        raise AssertionError("unreachable")

"""gat-cora [gnn] — 2L, d_hidden=8, 8 heads, attn aggregator [arXiv:1710.10903]."""
from repro.configs import ArchSpec
from repro.configs.shapes import GNN_SHAPES
from repro.models.gnn import GnnConfig

SPEC = ArchSpec(
    arch_id="gat-cora",
    family="gnn",
    model_cfg=GnnConfig(name="gat-cora", arch="gat", n_layers=2, d_hidden=8,
                        n_heads=8, task="node_class"),
    shapes=GNN_SHAPES,
    source="arXiv:1710.10903; paper",
    smoke_cfg=GnnConfig(name="gat-smoke", arch="gat", n_layers=2, d_hidden=4,
                        n_heads=2, n_classes=4, task="node_class"),
)

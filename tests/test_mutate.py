"""Live mutation (ISSUE 6 tentpole): delta segment, tombstones, background
merge + snapshot swap, serving across a merge with zero recompiles, cache
hygiene over many merge cycles, and per-shard delta staggering."""
import dataclasses
import warnings

import numpy as np
import pytest

from repro.core.index import AnnIndex
from repro.core.spec import SearchSpec
from repro.data.vectors import make_dataset, recall_at_k
from repro.mutate import (DeltaSegment, MutableAnnIndex,
                          MutableShardedAnnIndex, MutateConfig)

SPEC = SearchSpec(k=10, efs=48, router="crouting")
HNSW_KW = dict(m=12, efc=64)


@pytest.fixture(scope="module")
def mds():
    return make_dataset(n_base=1500, n_query=30, dim=32, n_clusters=12,
                        seed=0)


def _gt_live(ds, live, k=10):
    dist = np.sum((ds.queries[:, None, :] - ds.base[None, :, :]) ** 2,
                  axis=-1)
    dist[:, ~live] = np.inf
    return np.argsort(dist, axis=1)[:, :k]


def _mutable(ds, n0, auto="sync", graph="hnsw", cap=128, **cfg_kw):
    cfg = MutateConfig(delta_capacity=cap, auto_merge=auto, graph=graph,
                       graph_kw=dict(HNSW_KW) if graph == "hnsw" else {},
                       **cfg_kw)
    return MutableAnnIndex.build(ds.base[:n0], config=cfg, **HNSW_KW)


# --------------------------------------------------------------------------
# delta segment unit behavior
# --------------------------------------------------------------------------
def test_delta_segment_insert_delete_topk():
    rng = np.random.default_rng(0)
    seg = DeltaSegment.empty(16, 8, "l2")
    v = rng.normal(size=(5, 8)).astype(np.float32)
    seg2 = seg.insert(v, np.arange(100, 105))
    # copy-on-write: the original is untouched
    assert seg.n_live == 0 and seg2.n_live == 5
    ids, d, scanned = seg2.topk(v[:2], k=3)
    assert ids.shape == (2, 3) and (ids[0, 0] == 100) and (ids[1, 0] == 101)
    assert d[0, 0] == pytest.approx(0.0, abs=1e-5)
    assert (scanned == 5).all()
    seg3, found = seg2.delete(101)
    assert found and seg3.n_live == 4 and seg2.n_live == 5
    ids3, _, _ = seg3.topk(v[1:2], k=1)
    assert ids3[0, 0] != 101
    _, missing = seg3.delete(999)
    assert not missing
    # ask for more than capacity: -1 / +inf pads
    ids4, d4, _ = seg3.topk(v[:1], k=20)
    assert ids4.shape == (1, 20) and (ids4[0, 4:] == -1).all()
    assert np.isinf(d4[0, 4:]).all()


def test_delta_segment_overflow_raises():
    seg = DeltaSegment.empty(4, 8, "l2")
    seg = seg.insert(np.zeros((3, 8), np.float32), np.arange(3))
    with pytest.raises(ValueError, match="delta overflow"):
        seg.insert(np.zeros((2, 8), np.float32), np.arange(10, 12))


def test_delta_segment_sq8_matches_exact_topk():
    rng = np.random.default_rng(1)
    seg = DeltaSegment.empty(64, 16, "l2")
    seg = seg.insert(rng.normal(size=(48, 16)).astype(np.float32),
                     np.arange(48))
    q = rng.normal(size=(4, 16)).astype(np.float32)
    ids_e, d_e, _ = seg.topk(q, k=5)
    ids_q, d_q, _ = seg.topk(q, k=5, use_sq8=True)
    # stage-2 exact rerank makes the quantized path agree on ids + dists
    np.testing.assert_array_equal(ids_e, ids_q)
    np.testing.assert_allclose(d_e, d_q, rtol=1e-5, atol=1e-5)


# --------------------------------------------------------------------------
# engine-level tombstone semantics: dead nodes still route, but masking is
# bit-identical to filtering the no-tombstone pool host-side
# --------------------------------------------------------------------------
def test_engine_tombstone_mask_equals_host_filter(mds):
    import jax.numpy as jnp

    from repro.core.search import build_search_fn

    idx = AnnIndex.build(mds.base[:800], **HNSW_KW)
    g = idx.graph
    cfg = dataclasses.replace(SPEC, metric=g.metric,
                              use_hierarchy=g.upper_neighbors is not None)
    ct = jnp.asarray(idx.profile.cos_theta_star, jnp.float32)
    q = jnp.asarray(mds.queries)
    rng = np.random.default_rng(5)
    tomb = np.zeros(g.n, bool)
    tomb[rng.choice(g.n, 60, replace=False)] = True

    _, f0 = build_search_fn(g, cfg)
    r0 = f0(q, ct)
    ids0, d0 = np.asarray(r0.ids), np.asarray(r0.dists)
    _, f1 = build_search_fn(g, cfg, tombstones=True)
    r1 = f1(q, ct, jnp.asarray(np.concatenate([tomb, [False]])))
    ids1, d1 = np.asarray(r1.ids), np.asarray(r1.dists)

    # identical traversal counters: tombstones must not change routing
    np.testing.assert_array_equal(np.asarray(r0.hops), np.asarray(r1.hops))
    np.testing.assert_array_equal(np.asarray(r0.dist_calls),
                                  np.asarray(r1.dist_calls))
    for b in range(q.shape[0]):
        keep = [(d0[b, j], ids0[b, j]) for j in range(ids0.shape[1])
                if ids0[b, j] < g.n and not tomb[ids0[b, j]]]
        want = [i for _, i in keep]
        got = [i for i in ids1[b] if i < g.n]
        assert got == want[:len(got)] and len(got) == len(want)
        assert np.isinf(d1[b, len(got):]).all()


# --------------------------------------------------------------------------
# mutable index end to end
# --------------------------------------------------------------------------
def test_insert_is_immediately_searchable(mds):
    mi = _mutable(mds, 1400, auto="off")
    new = mds.queries[:3] + 1e-4
    ids = mi.insert(new)
    got, d, stats = mi.search(mds.queries[:3], spec=SPEC)
    assert (got[np.arange(3), 0] == ids).all()
    assert (stats.extra["delta_scanned"] == 3).all()
    assert mi.epoch == 0, "no merge should have happened"


def test_deleted_ids_never_returned_interleaved(mds):
    """Property: across an interleaved trace — including deletes of rows
    still in the delta and deletes racing a merge — no search ever returns
    a dead id."""
    mi = _mutable(mds, 1300, auto="sync", cap=64)
    rng = np.random.default_rng(11)
    live = set(range(1300))
    for step in range(12):
        ids = mi.insert(mds.base[1300 + (step * 10) % 200:][:10]
                        + rng.normal(0, 1e-3, (10, 32)).astype(np.float32))
        live.update(int(i) for i in ids)
        kill = rng.choice(sorted(live), size=6, replace=False)
        mi.delete(kill)
        live.difference_update(int(i) for i in kill)
        got, _, _ = mi.search(mds.queries[:8], spec=SPEC)
        real = got[got >= 0]
        assert set(real.tolist()) <= live, "dead id leaked into results"
    assert mi.merges_completed >= 1
    assert mi.n_live == len(live)
    assert np.array_equal(mi.live_ids(), np.array(sorted(live)))


def test_recall_ratio_vs_static_rebuild(mds):
    """ISSUE 6 acceptance: after an interleaved insert/delete trace,
    recall@10 >= 0.95x a from-scratch static rebuild at equal SearchSpec."""
    mi = _mutable(mds, 1200, auto="sync", cap=96)
    rng = np.random.default_rng(7)
    live = np.zeros(1500, bool)
    live[:1200] = True
    for lo in range(1200, 1500, 75):
        mi.insert(mds.base[lo:lo + 75])
        live[lo:lo + 75] = True
        kill = rng.choice(np.flatnonzero(live), size=20, replace=False)
        mi.delete(kill)
        live[kill] = False
    ids, _, _ = mi.search(mds.queries, spec=SPEC)
    assert not np.isin(ids, np.flatnonzero(~live)).any()
    gt = _gt_live(mds, live)
    rec_mut = recall_at_k(ids, gt, 10)

    static = AnnIndex.build(mds.base[live], graph="hnsw", **HNSW_KW)
    ext_of_row = np.flatnonzero(live)
    sr, _, _ = static.search(mds.queries, spec=SPEC)
    sids = np.where(sr >= 0, ext_of_row[np.where(sr >= 0, sr, 0)], -1)
    rec_static = recall_at_k(sids, gt, 10)
    assert rec_mut >= 0.95 * rec_static, (rec_mut, rec_static)


def test_overflow_triggers_sync_merge_and_off_raises(mds):
    mi = _mutable(mds, 600, auto="sync", cap=32, merge_threshold=2.0,
                  tombstone_threshold=2.0)   # only overflow can merge
    mi.insert(mds.base[600:600 + 30])
    assert mi.epoch == 0
    mi.insert(mds.base[630:630 + 10])        # 30 + 10 > 32: must merge
    assert mi.epoch == 1 and mi.n_live == 640
    off = _mutable(mds, 600, auto="off", cap=16)
    off.insert(mds.base[600:616])
    with pytest.raises(ValueError, match="auto_merge"):
        off.insert(mds.base[616:617])


def test_profile_refresh_policy(mds):
    """Angle profile resamples only once the corpus drifts past the
    configured fraction of its size at sampling time."""
    mi = _mutable(mds, 1000, auto="off", cap=512,
                  profile_refresh_fraction=0.2)
    p0 = mi._state.snapshot.index.profile
    assert p0.corpus_n == 1000
    mi.insert(mds.base[1000:1100])           # +10% < 20%: carried
    mi.merge()
    p1 = mi._state.snapshot.index.profile
    assert p1 is p0 and p1.corpus_n == 1000
    mi.insert(mds.base[1100:1400])           # now 1400 vs 1000: 40% drift
    mi.merge()
    p2 = mi._state.snapshot.index.profile
    assert p2 is not p0 and p2.corpus_n == 1400


def test_save_is_snapshot_only_and_warns(tmp_path, mds):
    """ISSUE 8 satellite: ``save`` persists only the merged snapshot and
    must say so — warning (or raising under ``strict=True``) whenever
    unmerged delta rows / tombstones would be silently dropped."""
    mi = _mutable(mds, 900, auto="off", cap=64)
    mi.insert(mds.base[900:940])
    mi.delete(list(range(0, 20)))
    path = str(tmp_path / "mut.npz")
    with pytest.warns(UserWarning, match="snapshot-only"):
        mi.save(path)
    back = AnnIndex.load(path)
    assert back.graph.n == 900          # pre-merge snapshot: mutations absent
    with pytest.raises(ValueError, match="snapshot-only"):
        mi.save(path, strict=True)
    # after an explicit merge the save is complete — and silent
    mi.merge()
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        mi.save(path)
    back = AnnIndex.load(path)
    assert back.graph.n == 920          # 900 - 20 + 40, delta drained
    assert mi.epoch >= 1


def test_cache_hygiene_across_merge_cycles(mds):
    """ISSUE 6 satellite: N insert->merge cycles must not grow the
    compiled-engine caches beyond one live graph id per spec."""
    from repro.core.search import _ARRAYS_CACHE, _ENGINE_CACHE

    mi = _mutable(mds, 700, auto="off", cap=64)
    mi.search(mds.queries[:4], spec=SPEC)    # warm one engine
    for cycle in range(4):
        mi.insert(mds.base[700 + cycle * 8:][:8])
        mi.merge()
        mi.search(mds.queries[:4], spec=SPEC)
    graph_ids = {id(mi._state.snapshot.index.graph)}
    mine_e = [k for k in _ENGINE_CACHE
              if k[0] in graph_ids or _ENGINE_CACHE[k][0]() is None]
    mine_a = [k for k in _ARRAYS_CACHE
              if k in graph_ids or _ARRAYS_CACHE[k][0]() is None]
    # dead snapshots were purged: nothing but the live graph remains (the
    # weakref check catches any entry whose graph was collected but whose
    # device arrays are still pinned in the cache)
    dead_e = [k for k in mine_e if _ENGINE_CACHE[k][0]() is None]
    dead_a = [k for k in mine_a if _ARRAYS_CACHE[k][0]() is None]
    assert not dead_e, f"dead engine-cache entries survived: {dead_e}"
    assert not dead_a, f"dead arrays-cache entries survived: {dead_a}"
    live_e = [k for k in _ENGINE_CACHE if k[0] in graph_ids]
    assert len(live_e) == 1, "expected exactly one live engine for the spec"


# --------------------------------------------------------------------------
# serving across a background merge: every request completes, zero
# request-path recompiles (the merge pre-warms the fresh snapshot)
# --------------------------------------------------------------------------
@pytest.mark.slow
def test_serve_across_background_merge_zero_recompiles(mds):
    from repro.serve import MutableIndexSession, ServeFrontend, make_session

    cfg = MutateConfig(delta_capacity=48, auto_merge="background",
                       graph="hnsw", graph_kw=dict(HNSW_KW))
    mi = MutableAnnIndex.build(mds.base[:1300], config=cfg, **HNSW_KW)
    assert isinstance(make_session(mi, SPEC), MutableIndexSession)
    fe = ServeFrontend(mi, SPEC, buckets=(1, 8, 32))
    warm = mi.compile_count()
    assert warm > 0 and fe.telemetry.recompiles_after_warmup == 0

    rng = np.random.default_rng(3)
    futs = []
    for step in range(24):
        n = [1, 5, 8, 20][step % 4]
        futs.append(fe.submit(mds.queries[rng.integers(0, 30, n)]))
        fe.flush()
        mi.insert(mds.base[1300 + (step * 6) % 180:][:6]
                  + rng.normal(0, 1e-3, (6, 32)).astype(np.float32))
        if step % 4 == 0:
            mi.delete(rng.choice(mi.live_ids(), 2, replace=False))
    mi.wait_for_merge()
    fe.flush()
    for f in futs:
        ids, d, st = f.result(timeout=120)
        assert ids.shape[1] == SPEC.k
        assert (st.extra["delta_scanned"] >= 0).all()
    assert mi.merges_completed >= 1, "trace was meant to span a merge"
    assert fe.telemetry.recompiles_after_warmup == 0
    assert mi.compile_count() == warm, "swap leaked compiles into telemetry"


# --------------------------------------------------------------------------
# per-shard deltas: merges stagger (one shard at a time)
# --------------------------------------------------------------------------
def test_sharded_mutable_staggered_merges(mds):
    shards = [AnnIndex.build(mds.base[i * 400:(i + 1) * 400], **HNSW_KW)
              for i in range(3)]
    cfg = MutateConfig(delta_capacity=32, merge_threshold=0.5,
                       graph="hnsw", graph_kw=dict(HNSW_KW))
    ms = MutableShardedAnnIndex(shards, config=cfg)
    assert ms.n_live == 1200
    # global external ids are disjoint across shards
    all_ids = np.concatenate([sh._state.snapshot.ext_ids
                              for sh in ms.shards])
    assert len(set(all_ids.tolist())) == 1200

    ids, d, stats = ms.search(mds.queries[:6], spec=SPEC)
    assert ids.shape == (6, 10) and (ids >= 0).all()

    rng = np.random.default_rng(9)
    dead = []
    for step in range(10):
        got = ms.insert(mds.base[1200 + (step * 8) % 300:][:8]
                        + rng.normal(0, 1e-3, (8, 32)).astype(np.float32))
        kill = rng.choice(ms.shards[step % 3]._state.snapshot.ext_ids, 2,
                          replace=False)
        kill = [int(e) for e in kill if int(e) not in dead]
        if kill:
            ms.delete(kill)
            dead.extend(kill)
        # at most one shard merges per trigger: epochs differ by design
        ids, _, _ = ms.search(mds.queries[:4], spec=SPEC)
        assert not np.isin(ids, dead).any()
        assert got.shape == (8,)
    ms.wait_for_merges()   # parent merges run in the background now
    assert sum(e > 0 for e in ms.epochs) >= 1
    # staggering: the trace must never have merged all shards in lockstep
    assert len(set(ms.epochs)) > 1 or min(ms.epochs) == 0

"""Checkpoint/fault-tolerance contracts (DESIGN.md §6)."""
import os

import jax
import numpy as np
import pytest

from repro.data.synthetic import LMStream
from repro.models import transformer as T
from repro.train import checkpoint as C
from repro.train import optimizer as opt
from repro.train.elastic import remesh_plan
from repro.train.trainer import Trainer, TrainerConfig

CFG = T.LMConfig(name="t", n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
                 d_ff=64, vocab=128, dtype="float32", block_q=8, block_k=16,
                 loss_chunk=8)
OCFG = opt.AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=50)


def _fresh():
    params = T.init_params(CFG, jax.random.PRNGKey(0))
    state = opt.adamw_init(params, OCFG)
    stream = LMStream(CFG.vocab, 2, 16, seed=0)
    return params, state, stream


def test_roundtrip_bitexact(tmp_path):
    params, state, stream = _fresh()
    C.save_checkpoint(str(tmp_path), 7, {"params": params, "opt": state},
                      data_cursor=stream.state())
    restored, cursor, step = C.restore_checkpoint(
        str(tmp_path), {"params": params, "opt": state})
    assert step == 7 and cursor == stream.state()
    for a, b in zip(jax.tree_util.tree_leaves(restored["params"]),
                    jax.tree_util.tree_leaves(params)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_corruption_detected(tmp_path):
    params, state, _ = _fresh()
    d = C.save_checkpoint(str(tmp_path), 1, {"params": params, "opt": state})
    shard = os.path.join(d, "shard_0.npz")
    with open(shard, "r+b") as f:
        f.seek(100)
        f.write(b"\xde\xad")
    with pytest.raises(IOError):
        C.restore_checkpoint(str(tmp_path), {"params": params, "opt": state})


def test_crash_resume_bitexact(tmp_path):
    """Kill at step 7, resume, run to 12: losses equal the uninterrupted run."""
    ck = str(tmp_path / "a")

    def make_trainer(ckdir):
        params, state, stream = _fresh()
        return Trainer(TrainerConfig(total_steps=12, ckpt_every=5,
                                     ckpt_dir=ckdir, log_every=100),
                       T.make_train_step(CFG, OCFG), params, state, stream)

    # uninterrupted reference
    t_ref = make_trainer(str(tmp_path / "ref"))
    ref = t_ref.run()

    t1 = make_trainer(ck)
    with pytest.raises(RuntimeError):
        t1.run(crash_at=7)
    t2 = make_trainer(ck)
    assert t2.maybe_resume()
    assert t2.step == 5                    # last checkpoint before the crash
    out = t2.run()
    np.testing.assert_allclose(out["history"][-3:], ref["history"][-3:],
                               rtol=1e-6)


def test_gc_keeps_latest(tmp_path):
    params, state, stream = _fresh()
    for s in (1, 2, 3, 4, 5):
        C.save_checkpoint(str(tmp_path), s, {"params": params, "opt": state})
    C.gc_checkpoints(str(tmp_path), keep=2)
    assert C.latest_step(str(tmp_path)) == 5
    kept = [d for d in os.listdir(str(tmp_path)) if d.startswith("step_")]
    assert len(kept) == 2


def test_elastic_remesh_plan():
    """Global batch preserved across device-count changes."""
    for ndev in (512, 256, 64, 8, 1):
        plan = remesh_plan(global_batch=256, new_devices=ndev)
        assert plan.tokens_per_step_preserved, (ndev, plan)


def test_elastic_restore_different_sharding(tmp_path):
    """Restore under a fresh sharding spec (single device here — the API
    path is identical for a real re-mesh)."""
    params, state, _ = _fresh()
    C.save_checkpoint(str(tmp_path), 3, {"params": params, "opt": state})
    dev = jax.devices()[0]
    sh = jax.tree_util.tree_map(
        lambda _: jax.sharding.SingleDeviceSharding(dev),
        {"params": params, "opt": state})
    restored, _, _ = C.restore_checkpoint(
        str(tmp_path), {"params": params, "opt": state}, shardings=sh)
    leaf = jax.tree_util.tree_leaves(restored["params"])[0]
    assert leaf.sharding == jax.sharding.SingleDeviceSharding(dev)

"""Serving telemetry: latency percentiles, QPS, per-bucket compile counts.

One ``ServeTelemetry`` instance rides a frontend for its lifetime.  Engine
counters are folded through ``SearchStats.merge`` so a single
``SearchStats.summary()`` covers the whole request trace (per-query means on
the single-index path, shard-reduced totals on the sharded path), and the
serving-level numbers — p50/p95/p99 request latency, QPS, per-bucket
dispatch latency and compile counts — wrap around it in ``summary()``.

The compile counters are the serving frontend's key invariant: after
``mark_warm()`` (the explicit bucket warmup) ``recompiles_after_warmup``
must stay 0 across any ragged request trace — a nonzero value means a batch
shape escaped the bucket ladder and paid an XLA compile on the request path
(asserted in benchmarks/bench_serve.py and tests/test_serve.py).

Windowed snapshots (the autotune feed, DESIGN.md §12): the controller
does not read the lifetime digest — it diffs *epochs*.
``window_snapshot()`` captures the cumulative counters plus a copy of the
bounded sample window at one instant; ``window_delta(prev, cur)`` turns
two snapshots into the epoch between them (requests served, epoch QPS,
and p50/p95/p99 over exactly the epoch's own latency samples — valid
while an epoch serves fewer than ``WINDOW`` requests, asserted there).
The observation hooks and snapshots share one lock, so a controller
thread can snapshot mid-trace without tearing a deque.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Deque, Dict, Optional

import numpy as np

from repro.core.spec import SearchStats

# Sliding-window length for the percentile/QPS/engine-stats digests.  The
# cumulative counters (submitted/served/rows/compiles/...) are lifetime
# totals, but the sample lists must stay bounded — a "serve forever" worker
# would otherwise grow one latency float per request and one SearchStats per
# dispatch without limit.
WINDOW = 4096


def _pcts(lat_s) -> Dict[str, Optional[float]]:
    """p50/p95/p99 in milliseconds from an iterable of seconds."""
    lat_s = list(lat_s)
    if not lat_s:
        return {"p50_ms": None, "p95_ms": None, "p99_ms": None}
    ms = np.asarray(lat_s) * 1e3
    return {"p50_ms": round(float(np.percentile(ms, 50)), 3),
            "p95_ms": round(float(np.percentile(ms, 95)), 3),
            "p99_ms": round(float(np.percentile(ms, 99)), 3)}


def _window() -> Deque:
    return deque(maxlen=WINDOW)


@dataclasses.dataclass
class BucketStats:
    """Per-rung accounting (bucket size = the padded batch shape)."""

    dispatches: int = 0
    compiles: int = 0            # executables built for this rung (warmup: 1)
    rows_valid: int = 0          # real query rows served through this rung
    rows_padded: int = 0         # wasted lanes (bucket - valid, summed)
    lat_s: Deque[float] = dataclasses.field(default_factory=_window)

    def summary(self) -> Dict[str, object]:
        pad_total = self.rows_valid + self.rows_padded
        out = {"dispatches": self.dispatches, "compiles": self.compiles,
               "rows": self.rows_valid,
               "pad_overhead": round(self.rows_padded / pad_total, 3)
               if pad_total else 0.0}
        out.update(_pcts(self.lat_s))
        return out


class ServeTelemetry:
    """Latency + throughput + compile accounting for one frontend."""

    def __init__(self):
        self.buckets: Dict[int, BucketStats] = {}
        self.request_lat_s: Deque[float] = _window()  # guarded by: self._obs_lock
        self.queue_wait_s: Deque[float] = _window()   # guarded by: self._obs_lock
        self.submitted = 0
        self.served = 0
        self.rejected = 0           # oversized / backpressure, at submit
        self.expired = 0            # deadline passed before dispatch
        self.failed = 0             # requests resolved with an exception
        self.dispatch_failures = 0  # engine-call failures (whole batches)
        self.worker_errors = 0      # background flush-loop failures
        self.recompiles_after_warmup = 0
        self._warm = False
        self._stats: Deque[SearchStats] = _window()   # guarded by: self._obs_lock
        self._t_first: Optional[float] = None
        self._t_last: Optional[float] = None
        # completion timestamps (same window as request_lat_s): windowed
        # QPS -- guarded by: self._obs_lock
        self._done_t: Deque[float] = _window()
        # guards the sample deques: the dispatch thread appends while a
        # controller thread snapshots (list(deque) during a concurrent
        # append can raise); counters alone would be fine under the GIL
        self._obs_lock = threading.Lock()

    # --- recording hooks (called by the frontend) -------------------------
    def mark_warm(self):
        """All buckets pre-jitted: any later compile is a ladder escape."""
        self._warm = True

    def observe_dispatch(self, bucket: int, n_valid: int, secs: float,
                         compiled: int, stats: Optional[SearchStats]):
        """``stats=None`` marks a warmup probe: it contributes to the
        compile accounting only, never to latency/throughput/pad numbers
        (a probe's latency IS the XLA compile — folding it into the bucket
        percentiles would misreport the served trace)."""
        bs = self.buckets.setdefault(bucket, BucketStats())
        with self._obs_lock:
            bs.compiles += compiled
            if stats is None:
                return
            # a compile during a REAL dispatch after warmup = a batch shape
            # that escaped the ladder and paid XLA on the request path
            # (warmup probes — including a late-created session's — never
            # count)
            if compiled and self._warm:
                self.recompiles_after_warmup += compiled
            bs.dispatches += 1
            bs.rows_valid += n_valid
            bs.rows_padded += bucket - n_valid
            bs.lat_s.append(secs)
            self._stats.append(stats)
            now = time.perf_counter()
            if self._t_first is None:
                self._t_first = now - secs
            self._t_last = now

    def observe_request_done(self, total_s: float, wait_s: float,
                             now: Optional[float] = None):
        """``now`` overrides the completion timestamp (``perf_counter``
        seconds) — the windowed-QPS regression tests inject exact times."""
        with self._obs_lock:
            self.served += 1
            self.request_lat_s.append(total_s)
            self.queue_wait_s.append(wait_s)
            self._done_t.append(time.perf_counter() if now is None else now)

    def observe_dispatch_failure(self, n_requests: int):
        """A whole engine call failed: its requests RESOLVED with the
        error on their futures (admission contract), not results."""
        self.dispatch_failures += 1
        self.failed += n_requests

    # --- windowed snapshots (the autotune epoch feed) ---------------------
    def window_snapshot(self) -> Dict[str, object]:
        """One instant's view: cumulative counters + a copy of the bounded
        sample window.  Two snapshots diff into an epoch via
        ``window_delta``; the latency/QPS entries here are *window*-scoped
        (last ``WINDOW`` requests), the counters lifetime-scoped.
        """
        with self._obs_lock:
            lat = tuple(self.request_lat_s)
            wait = tuple(self.queue_wait_s)
            done_t = tuple(self._done_t)
            snap: Dict[str, object] = {
                "t": time.perf_counter(),
                "served": self.served, "submitted": self.submitted,
                "failed": self.failed, "expired": self.expired,
                "rejected": self.rejected,
                "recompiles_after_warmup": self.recompiles_after_warmup,
            }
        snap["latency"] = _pcts(lat)
        snap["queue_wait"] = _pcts(wait)
        snap["window_qps"] = (
            round(len(done_t) / (done_t[-1] - done_t[0]), 1)
            if len(done_t) >= 2 and done_t[-1] > done_t[0] else None)
        snap["_lat_s"] = lat          # raw samples: window_delta's input
        snap["_done_t"] = done_t
        return snap

    @staticmethod
    def window_delta(prev: Dict[str, object],
                     cur: Dict[str, object]) -> Dict[str, object]:
        """The epoch between two snapshots, JSON-ready.

        Percentiles cover exactly the requests served in the epoch (the
        trailing ``served_delta`` window samples) — correct as long as the
        epoch served fewer than ``WINDOW`` requests; past that the oldest
        epoch samples have rolled off and the digest degrades to the
        window, flagged via ``clipped``.
        """
        served = int(cur["served"]) - int(prev["served"])
        dt = float(cur["t"]) - float(prev["t"])
        lat = cur["_lat_s"]
        n = min(served, len(lat))
        out: Dict[str, object] = {
            "dt_s": round(dt, 4), "served": served,
            "failed": int(cur["failed"]) - int(prev["failed"]),
            "expired": int(cur["expired"]) - int(prev["expired"]),
            "rejected": int(cur["rejected"]) - int(prev["rejected"]),
            "recompiles": (int(cur["recompiles_after_warmup"])
                           - int(prev["recompiles_after_warmup"])),
            "qps": round(served / dt, 1) if dt > 0 and served else None,
            "clipped": served > len(lat),
        }
        out.update(_pcts(lat[len(lat) - n:] if n else ()))
        return out

    # --- reporting --------------------------------------------------------
    def merged_stats(self) -> Optional[SearchStats]:
        """Engine stats folded over the sample window (last WINDOW
        dispatches)."""
        with self._obs_lock:
            stats = list(self._stats)
        return SearchStats.merge(stats) if stats else None

    def qps(self) -> Optional[float]:
        """Real rows served per second of serving wall-clock."""
        if self._t_first is None or self._t_last <= self._t_first:
            return None
        rows = sum(b.rows_valid for b in self.buckets.values())
        return rows / (self._t_last - self._t_first)

    def summary(self) -> Dict[str, object]:
        """JSON-ready digest; ``search`` is ``SearchStats.summary()`` over
        the merged trace — the engine counters fold into the same record the
        benchmarks persist."""
        merged = self.merged_stats()
        qps = self.qps()
        with self._obs_lock:
            lat = tuple(self.request_lat_s)
            wait = tuple(self.queue_wait_s)
        out: Dict[str, object] = {
            "requests": {"submitted": self.submitted, "served": self.served,
                         "rejected": self.rejected, "expired": self.expired,
                         "failed": self.failed},
            "dispatch_failures": self.dispatch_failures,
            "worker_errors": self.worker_errors,
            "latency": _pcts(lat),
            "queue_wait": _pcts(wait),
            "qps": round(qps, 1) if qps else None,
            "compiles_total": sum(b.compiles for b in self.buckets.values()),
            "recompiles_after_warmup": self.recompiles_after_warmup,
            "buckets": {str(b): self.buckets[b].summary()
                        for b in sorted(self.buckets)},
        }
        if merged is not None:
            out["search"] = merged.summary()
        return out

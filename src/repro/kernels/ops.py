"""Jit'd public wrappers around the Pallas kernels.

Handles padding to block multiples, dtype plumbing, and the CPU/TPU switch:
on this container the kernels execute in interpret mode (Python semantics,
bit-accurate vs the TPU lowering's math); on a real TPU backend set
``interpret=False`` (the default flips automatically off-CPU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.l2_distance import l2_distance_pallas
from repro.kernels.crouting_prune import crouting_prune_pallas
from repro.kernels.gather_distance import gather_distance_pallas
from repro.kernels.pool_merge import pool_merge_pallas


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x, mult, axis, value):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def l2_distance(q, x, mode: str = "l2", bq: int = 128, bc: int = 256,
                bd: int = 512, interpret=None):
    """Distance matrix [Q, C]; pads freely, slices back."""
    interpret = _default_interpret() if interpret is None else interpret
    Q, d = q.shape
    C = x.shape[0]
    bq_, bc_, bd_ = min(bq, Q), min(bc, C), min(bd, d)
    qp = _pad_to(q, bq_, 0, 0.0)
    xp = _pad_to(x, bc_, 0, 0.0)
    qp = _pad_to(qp, bd_, 1, 0.0)
    xp = _pad_to(xp, bd_, 1, 0.0)
    out = l2_distance_pallas(qp, xp, bq=bq_, bc=bc_, bd=bd_, mode=mode,
                             interpret=interpret)
    return out[:Q, :C]


def crouting_prune(ed, dcq, bound2, valid, cos_theta, bb: int = 8,
                   interpret=None):
    """Fused estimate + prune mask; pads B to the row-block, M to lanes."""
    interpret = _default_interpret() if interpret is None else interpret
    B, M = ed.shape
    edp = _pad_to(_pad_to(ed, 128, 1, jnp.inf), bb, 0, jnp.inf)
    vp = _pad_to(_pad_to(valid.astype(jnp.int8), 128, 1, 0), bb, 0, 0)
    dcqp = _pad_to(dcq, bb, 0, 0.0)
    b2p = _pad_to(bound2, bb, 0, 0.0)
    est2, mask = crouting_prune_pallas(edp, dcqp, b2p, vp, cos_theta,
                                       bb=bb, interpret=interpret)
    return est2[:B, :M], mask[:B, :M]


def gather_distance(indices, queries, table, interpret=None):
    """Fused gather+distance; prune-masked callers remap lanes to row 0."""
    interpret = _default_interpret() if interpret is None else interpret
    return gather_distance_pallas(indices.astype(jnp.int32), queries, table,
                                  interpret=interpret)


def gather_distance_pruned(nbr_ids, prune_mask, queries, table, interpret=None):
    """CRouting-integrated exact path: pruned lanes fetch the sentinel row 0
    (de-duplicated DMA on TPU) and report +inf."""
    idx = jnp.where(prune_mask != 0, 0, nbr_ids).astype(jnp.int32)
    d2 = gather_distance(idx, queries, table, interpret=interpret)
    return jnp.where(prune_mask != 0, jnp.inf, d2)


def pool_merge(pool_d, pool_i, new_d, new_i, bb: int = 8, interpret=None):
    """Merge new candidates into sorted pools, keep best P."""
    interpret = _default_interpret() if interpret is None else interpret
    B = pool_d.shape[0]
    args = [pool_d, pool_i.astype(jnp.int32), new_d, new_i.astype(jnp.int32)]
    args = [_pad_to(a, bb, 0, v) for a, v in zip(args, (jnp.inf, -1, jnp.inf, -1))]
    d, i = pool_merge_pallas(*args, bb=bb, interpret=interpret)
    return d[:B], i[:B]


def fused_expand(nbrs, queries, ed, dcq, bound2, cos_theta, table,
                 interpret=None):
    """Fused CRouting expansion: estimate + prune + conditional gather +
    exact distance in one kernel (the paper's Alg. 2 inner loop)."""
    from repro.kernels.fused_expand import fused_expand_pallas
    interpret = _default_interpret() if interpret is None else interpret
    return fused_expand_pallas(nbrs.astype(jnp.int32), queries, ed, dcq,
                               bound2, cos_theta, table, interpret=interpret)

"""The durability manifest: one small JSON binding checkpoint + WAL state.

``MANIFEST`` is the root of truth of a durable directory: which checkpoint
file (if any) holds the base state, and which WAL segments — in replay
order — hold the mutations since its boundary.  It is rewritten with the
same temp + fsync + atomic-rename recipe as every other durable artifact
(``repro.durable.atomic``), so readers always see a complete, internally
consistent binding; the state machine (DESIGN.md §11) only ever moves it
between consistent bindings:

* rotation APPENDS the fresh segment before any mutation is acked into it
  (``{ckpt: C, segments: [S1, S2]}``) — a crash before the checkpoint
  publishes replays S1+S2 onto C, exactly the acked history;
* a checkpoint publish REPLACES the binding (``{ckpt: C', segments:
  [S2]}``) only after C' (which covers everything through S1) is durable —
  then the superseded files are garbage and unlinked best-effort.

A ``crc`` stamp over the canonical body catches manifest bit rot
(``CorruptIndexError``), distinct from a future ``format`` (ValueError —
an incompatibility, not damage).  The parent of a sharded deployment
writes the same manifest shape with ``meta.n_shards`` and no segments; the
per-shard truth lives in ``shard-*/MANIFEST``.

Failpoint site: ``manifest.rename`` (crash in the write→publish window —
the previous manifest keeps ruling, which is exactly the recovery
contract).
"""
from __future__ import annotations

import dataclasses
import json
import os
import zlib
from typing import Dict, List, Optional

from repro.fault import CorruptIndexError

from repro.durable.atomic import atomic_write_bytes

MANIFEST_NAME = "MANIFEST"
MANIFEST_FORMAT = 1


@dataclasses.dataclass(frozen=True)
class Manifest:
    """One consistent (checkpoint, active-segments) binding."""

    checkpoint: Optional[str]       # file name within the dir, or None
    segments: List[str]             # WAL segment file names, replay order
    next_lsn: int = 0               # first unassigned LSN at last write
    meta: Dict = dataclasses.field(default_factory=dict)
    format: int = MANIFEST_FORMAT

    def body(self) -> Dict:
        return {"format": self.format, "checkpoint": self.checkpoint,
                "segments": list(self.segments), "next_lsn": self.next_lsn,
                "meta": dict(self.meta)}


def _canonical(body: Dict) -> bytes:
    return json.dumps(body, sort_keys=True, separators=(",", ":")).encode()


def write_manifest(dirname: str, manifest: Manifest) -> None:
    """Atomically publish the manifest (site ``manifest.rename``)."""
    body = manifest.body()
    doc = dict(body, crc=zlib.crc32(_canonical(body)))
    atomic_write_bytes(os.path.join(dirname, MANIFEST_NAME),
                       json.dumps(doc, sort_keys=True, indent=1).encode(),
                       rename_site="manifest.rename")


def read_manifest(dirname: str) -> Manifest:
    """Read + verify the manifest.  Damage raises ``CorruptIndexError``;
    a future ``format`` raises ``ValueError``; a missing file raises
    ``FileNotFoundError`` (no durable state here at all)."""
    path = os.path.join(dirname, MANIFEST_NAME)
    with open(path, "rb") as f:
        raw = f.read()
    try:
        doc = json.loads(raw)
        crc = doc.pop("crc")
        body = {"format": doc["format"], "checkpoint": doc["checkpoint"],
                "segments": list(doc["segments"]),
                "next_lsn": int(doc["next_lsn"]), "meta": dict(doc["meta"])}
    except (json.JSONDecodeError, KeyError, TypeError, ValueError) as e:
        raise CorruptIndexError(
            f"{path}: unreadable manifest ({type(e).__name__}: {e})") from e
    if zlib.crc32(_canonical(body)) != crc:
        raise CorruptIndexError(
            f"{path}: manifest CRC mismatch — the file was damaged after "
            "it was written")
    if body["format"] > MANIFEST_FORMAT:
        raise ValueError(
            f"{path}: manifest format={body['format']} is newer than this "
            f"build understands (max {MANIFEST_FORMAT})")
    return Manifest(checkpoint=body["checkpoint"], segments=body["segments"],
                    next_lsn=body["next_lsn"], meta=body["meta"],
                    format=body["format"])

"""The tunable search space: knobs, cost classes, candidate enumeration.

A ``TuneSpace`` declares which ``SearchSpec`` fields the autotune
controller may move and over which discrete values.  Every knob carries a
*cost class*, derived from ``SearchSpec.canonical()`` semantics rather
than hand-maintained (``repro.core.spec.is_request_only``):

* ``"request"`` — changing the knob leaves the canonical spec unchanged
  (``k``, ``cos_theta``): it retunes instantly, no new executable, no
  pre-warm;
* ``"engine"``  — changing the knob changes the canonical spec
  (``efs``, ``beam_width``, ``estimate``, ``router``, ...): a switch
  creates a new engine session whose every bucket rung MUST be pre-warmed
  off the request path before the atomic active-spec flip
  (``ServeFrontend.activate_spec``) — the zero-recompiles-after-warmup
  invariant survives every controller action.

Candidates are the cartesian product of the knob domains applied to a
base spec, enumerated in a deterministic order (knob declaration order,
then domain order) — the controller's seeded search is reproducible only
because the space underneath it is.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.spec import (BEAM_LADDER, EFS_LADDER, KNOB_DOMAINS,
                             SearchSpec, is_request_only)

COST_CLASSES = ("request", "engine")


def spec_key(spec: SearchSpec) -> str:
    """Stable compact id for a candidate's *engine-shaping* identity (the
    decision log / quarantine key).  Request-only fields are excluded, so
    two candidates differing only in ``k``/``cos_theta`` share a key —
    exactly the specs that share a compiled engine."""
    c = spec.canonical()
    return (f"efs={c.efs},W={c.beam_width},router={c.router},"
            f"estimate={c.estimate},engine={c.engine},prune={c.beam_prune}")


@dataclasses.dataclass(frozen=True)
class Knob:
    """One tunable ``SearchSpec`` field and its discrete domain."""

    name: str
    values: Tuple

    def __post_init__(self):
        object.__setattr__(self, "values", tuple(self.values))
        assert self.values, f"knob {self.name!r} has an empty domain"

    @property
    def cost(self) -> str:
        """``"request"`` or ``"engine"`` — from canonical() semantics."""
        return "request" if is_request_only(self.name) else "engine"


class TuneSpace:
    """A base spec plus the knobs the controller may move."""

    def __init__(self, base: SearchSpec, knobs: Sequence[Knob]):
        self.base = base
        self.knobs = tuple(knobs)
        names = [k.name for k in self.knobs]
        assert len(set(names)) == len(names), f"duplicate knobs: {names}"
        for k in self.knobs:
            k.cost  # validates the field name against SearchSpec

    @classmethod
    def default(cls, base: SearchSpec, *,
                efs: Optional[Sequence[int]] = None,
                beam_width: Optional[Sequence[int]] = None,
                estimate: Optional[Sequence[str]] = None,
                routers: Optional[Sequence[str]] = None) -> "TuneSpace":
        """The stock serving space: efs ladder x beam ladder (+ optional
        estimate mode / router sweeps).  ``efs`` rungs below the base
        ``k`` are dropped — they could not return ``k`` results."""
        knobs = [
            Knob("efs", tuple(v for v in (efs or EFS_LADDER)
                              if v >= base.k)),
            Knob("beam_width", tuple(beam_width or BEAM_LADDER)),
        ]
        if estimate:
            knobs.append(Knob("estimate", tuple(estimate)))
        if routers:
            knobs.append(Knob("router", tuple(routers)))
        return cls(base, knobs)

    def cost_class(self, field: str) -> str:
        """Cost class of one knob (see module docstring)."""
        return "request" if is_request_only(field) else "engine"

    @property
    def engine_knobs(self) -> Tuple[Knob, ...]:
        return tuple(k for k in self.knobs if k.cost == "engine")

    @property
    def request_knobs(self) -> Tuple[Knob, ...]:
        return tuple(k for k in self.knobs if k.cost == "request")

    def candidates(self) -> List[SearchSpec]:
        """Every candidate spec, in deterministic enumeration order
        (knob declaration order, then each knob's domain order)."""
        out: List[SearchSpec] = []
        seen: Dict[str, SearchSpec] = {}
        domains = [k.values for k in self.knobs]
        for combo in itertools.product(*domains):
            spec = self.base.replace(
                **{k.name: v for k, v in zip(self.knobs, combo)})
            if spec.efs < spec.k:
                continue
            key = spec_key(spec)
            if key in seen:       # request-only knobs collapse onto one
                continue          # engine identity; keep the first
            seen[key] = spec
            out.append(spec)
        assert out, "TuneSpace produced no valid candidates"
        return out

    def describe(self) -> Dict[str, Dict[str, object]]:
        """JSON-ready space declaration (persisted with bench results)."""
        return {k.name: {"values": list(k.values), "cost": k.cost}
                for k in self.knobs}

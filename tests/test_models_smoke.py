"""Per-assigned-architecture smoke tests: a REDUCED config of the same family
runs one forward/train step on CPU; output shapes + no NaNs (deliverable (f))."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, list_archs
from repro.models.api import build_smoke

# ~2 min for the full arch sweep — excluded from the fast verify tier
pytestmark = pytest.mark.slow

ALL_ARCHS = list_archs(include_anns=True)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_arch_smoke(arch):
    out = build_smoke(get_arch(arch))()
    for k, v in out.items():
        if hasattr(v, "dtype") and np.asarray(v).dtype.kind == "f":
            assert np.isfinite(np.asarray(v)).all(), (arch, k)
    assert np.isfinite(float(out["loss"]))


def test_decode_matches_forward():
    """KV-cache decode logits == full forward logits at the same position."""
    from repro.models import transformer as T
    cfg = T.LMConfig(name="t", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                     d_ff=128, vocab=256, dtype="float32", block_q=8,
                     block_k=16)
    p = T.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(5), (2, 17), 0, 256)
    S = 16
    _, cache = jax.jit(T.make_prefill_step(cfg))(p, toks[:, :S])
    cache = {k: jnp.pad(v, ((0, 0), (0, 0), (0, 16), (0, 0), (0, 0)))
             for k, v in cache.items()}
    logits, _ = jax.jit(T.make_serve_step(cfg))(p, cache, toks[:, S:S + 1],
                                                jnp.asarray(S, jnp.int32))
    h = T.forward(p, toks, cfg)
    ref = (h[:, S, :] @ p["lm_head"]).astype(jnp.float32)[:, :cfg.vocab]
    np.testing.assert_allclose(np.asarray(logits), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_moe_balance_and_grads():
    """MoE layer: outputs differentiable; capacity dispatch covers most tokens."""
    from repro.models.layers import MoeConfig, moe_layer, moe_dispatch_indices
    key = jax.random.PRNGKey(0)
    T_, D, E, F = 64, 16, 8, 32
    x = jax.random.normal(key, (T_, D))
    gw = jax.random.normal(jax.random.PRNGKey(1), (D, E)) * 0.1
    w1 = jax.random.normal(jax.random.PRNGKey(2), (E, D, F)) * 0.1
    w2 = jax.random.normal(jax.random.PRNGKey(3), (E, D, F)) * 0.1
    w3 = jax.random.normal(jax.random.PRNGKey(4), (E, F, D)) * 0.1
    cfg = MoeConfig(n_experts=E, top_k=2)

    def loss(x):
        return jnp.sum(moe_layer(x, gw, w1, w2, w3, cfg) ** 2)

    g = jax.grad(loss)(x)
    assert np.isfinite(np.asarray(g)).all()
    # dispatch bookkeeping: every kept slot maps back to its token
    logits = x @ gw
    _, idx = jax.lax.top_k(logits, 2)
    cap = max(8, int(1.25 * 2 * T_ / E))
    dest, keep, src = moe_dispatch_indices(idx, E, cap)
    dest, keep, src = np.asarray(dest), np.asarray(keep), np.asarray(src)
    assert keep.mean() > 0.8                      # few capacity drops
    for t in range(T_):
        for j in range(2):
            if keep[t, j]:
                assert src[dest[t, j]] == t


def test_vocab_padding_masked():
    """granite-moe's 49155 vocab pads to /128; pad columns never win."""
    from repro.models import transformer as T
    cfg = T.LMConfig(name="t", n_layers=1, d_model=32, n_heads=2, n_kv_heads=2,
                     d_ff=64, vocab=100, dtype="float32", block_q=8,
                     block_k=8, loss_chunk=8)
    assert cfg.padded_vocab == 128
    p = T.init_params(cfg, jax.random.PRNGKey(0))
    assert p["lm_head"].shape[1] == 128
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 100)
    loss = T.loss_fn(p, {"tokens": toks, "labels": toks}, cfg)
    # masked CE can't exceed log(V) by much at random init
    assert float(loss) < np.log(100) + 1.0


def test_gnn_sampler():
    """minibatch_lg needs a REAL neighbor sampler: check subgraph validity."""
    from repro.data.synthetic import neighbor_sample
    rng = np.random.default_rng(0)
    n, e = 500, 4000
    src = rng.integers(0, n, e).astype(np.int32)
    dst = rng.integers(0, n, e).astype(np.int32)
    seeds = rng.choice(n, 16, replace=False)
    sub = neighbor_sample(src, dst, n, seeds, fanouts=(5, 3), seed=0)
    ns, es, ed = sub["nodes"], sub["edge_src"], sub["edge_dst"]
    assert len(ns) <= 16 * (1 + 5 + 15)
    assert (es < len(ns)).all() and (ed < len(ns)).all()
    # every sampled edge exists in the original graph
    edge_set = set(zip(src.tolist(), dst.tolist()))
    for s_, d_ in zip(ns[es], ns[ed]):
        assert (int(s_), int(d_)) in edge_set


def test_dlrm_interaction_shape():
    from repro.models.dlrm import dot_interaction
    z = jnp.asarray(np.random.default_rng(0).normal(size=(4, 27, 8)),
                    jnp.float32)
    out = dot_interaction(z)
    assert out.shape == (4, 27 * 26 // 2)
    # symmetry check vs manual pair
    zz = np.asarray(z)
    manual = np.einsum("bd,bd->b", zz[:, 1], zz[:, 0])
    np.testing.assert_allclose(np.asarray(out[:, 0]), manual, rtol=1e-5)

"""Durable mutations (ISSUE 8 tentpole): WAL framing + recovery rules,
fsync policies, checkpoint/rotation state machine, sharded persistence,
and the kill-at-every-site chaos suite proving zero acknowledged loss and
zero deleted-id resurrection across crash + recover."""
import os
import threading

import numpy as np
import pytest

from repro import fault
from repro.core.index import AnnIndex
from repro.core.spec import SearchSpec
from repro.durable import (Manifest, SegmentWriter, WalFailedError,
                           damage_file, read_manifest, read_npz_verified,
                           read_segment, write_manifest)
from repro.durable import wal
from repro.fault import CorruptIndexError, FaultInjected
from repro.mutate import MutableAnnIndex, MutableShardedAnnIndex, MutateConfig

SPEC = SearchSpec(k=5, efs=24, router="crouting")
HNSW_KW = dict(m=8, efc=48)


@pytest.fixture(autouse=True)
def _disarm_all():
    yield
    fault.disarm()


@pytest.fixture(scope="module")
def base_index(small_ds):
    return AnnIndex.build(small_ds.base[:400], graph="hnsw", **HNSW_KW)


def _cfg(**kw):
    base = dict(delta_capacity=64, auto_merge="off", graph="hnsw",
                graph_kw=dict(HNSW_KW))
    base.update(kw)
    return MutateConfig(**base)


def _durable(base_index, dirname, **cfg_kw):
    cfg = _cfg(**cfg_kw)
    return MutableAnnIndex(base_index, config=cfg,
                           durable_dir=str(dirname)), cfg


# --------------------------------------------------------------------------
# WAL unit: framing, CRC, torn-tail vs mid-log rules
# --------------------------------------------------------------------------
def test_wal_roundtrip_insert_delete(tmp_path):
    p = str(tmp_path / "w.log")
    w = SegmentWriter(p, fsync="every")
    vecs = np.arange(12, dtype=np.float32).reshape(3, 4)
    l0 = w.append(wal.encode_insert, np.array([7, 8, 9]), vecs)
    l1 = w.append(wal.encode_delete, np.array([8]))
    w.wait_durable(l1)
    w.close()
    recs, valid_len, torn = read_segment(p, final=True)
    assert not torn and valid_len == os.path.getsize(p)
    assert [r.lsn for r in recs] == [l0, l1] == [0, 1]
    np.testing.assert_array_equal(recs[0].ext_ids, [7, 8, 9])
    np.testing.assert_array_equal(recs[0].vectors, vecs)
    np.testing.assert_array_equal(recs[1].ext_ids, [8])


def test_torn_tail_tolerated_only_on_final_segment(tmp_path):
    p = str(tmp_path / "w.log")
    w = SegmentWriter(p, fsync="off")
    w.append(wal.encode_delete, np.array([1]))
    w.append(wal.encode_delete, np.array([2]))
    w.close()
    good = os.path.getsize(p)
    with open(p, "ab") as f:          # half a frame: a torn write
        f.write(wal.frame(wal.encode_delete(2, np.array([3])))[:9])
    recs, valid_len, torn = read_segment(p, final=True)
    assert torn and valid_len == good and len(recs) == 2
    # the SAME bytes in a non-final segment are mid-log corruption
    with pytest.raises(CorruptIndexError, match="non-final"):
        read_segment(p, final=False)


def test_crc_damage_midlog_raises_final_frame_tolerated(tmp_path):
    p = str(tmp_path / "w.log")
    w = SegmentWriter(p, fsync="off")
    for i in range(3):
        w.append(wal.encode_delete, np.array([i]))
    w.close()
    size = os.path.getsize(p)
    frame_len = size // 3
    # flip a payload byte of the LAST frame: torn in-place write -> tolerated
    with open(p, "r+b") as f:
        f.seek(size - 1)
        b = f.read(1)
        f.seek(size - 1)
        f.write(bytes([b[0] ^ 0xFF]))
    recs, valid_len, torn = read_segment(p, final=True)
    assert torn and len(recs) == 2 and valid_len == 2 * frame_len
    # flip a byte of the FIRST frame: valid bytes follow -> corruption
    with open(p, "r+b") as f:
        f.seek(frame_len - 1)
        b = f.read(1)
        f.seek(frame_len - 1)
        f.write(bytes([b[0] ^ 0xFF]))
    with pytest.raises(CorruptIndexError, match="mid-log"):
        read_segment(p, final=True)


@pytest.mark.parametrize("policy", ["every", "interval", "off"])
def test_fsync_policies_ack_and_replay(tmp_path, policy):
    p = str(tmp_path / "w.log")
    w = SegmentWriter(p, fsync=policy, interval_s=0.001)
    lsns = [w.append(wal.encode_delete, np.array([i])) for i in range(5)]
    for lsn in lsns:
        w.wait_durable(lsn)          # the ack point, whatever the policy
    w.close()
    recs, _, torn = read_segment(p, final=True)
    assert not torn and [r.lsn for r in recs] == lsns


def test_group_commit_concurrent_acks(tmp_path):
    """N threads append+ack concurrently; every ack returns and the log
    holds every record exactly once, in LSN order."""
    p = str(tmp_path / "w.log")
    w = SegmentWriter(p, fsync="interval", interval_s=0.002)
    errs = []

    def one(i):
        try:
            w.wait_durable(w.append(wal.encode_delete, np.array([i])))
        except Exception as e:   # noqa: BLE001 — collected for the assert
            errs.append(e)

    threads = [threading.Thread(target=one, args=(i,)) for i in range(16)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    w.close()
    assert not errs
    recs, _, torn = read_segment(p, final=True)
    assert not torn
    assert [r.lsn for r in recs] == list(range(16))


def test_fsync_failure_poisons_writer(tmp_path):
    p = str(tmp_path / "w.log")
    w = SegmentWriter(p, fsync="every")
    lsn = w.append(wal.encode_delete, np.array([1]))
    fault.arm("wal.fsync", kind="raise", hits={0})
    with pytest.raises(FaultInjected):
        w.wait_durable(lsn)
    fault.disarm()
    # poisoned: the in-memory side may be ahead of the log
    with pytest.raises(WalFailedError):
        w.append(wal.encode_delete, np.array([2]))
    with pytest.raises(WalFailedError):
        w.wait_durable(lsn)


# --------------------------------------------------------------------------
# manifest
# --------------------------------------------------------------------------
def test_manifest_roundtrip_and_damage(tmp_path):
    d = str(tmp_path)
    m = Manifest(checkpoint="checkpoint-00000001.npz",
                 segments=["wal-00000001.log"], next_lsn=17,
                 meta={"kind": "mutable-index"})
    write_manifest(d, m)
    back = read_manifest(d)
    assert back == m
    path = os.path.join(d, "MANIFEST")
    raw = open(path, "rb").read()
    with open(path, "wb") as f:       # flip a digit inside the JSON body
        f.write(raw.replace(b"17", b"18"))
    with pytest.raises(CorruptIndexError, match="CRC"):
        read_manifest(d)
    with open(path, "wb") as f:
        f.write(raw[: len(raw) // 2])
    with pytest.raises(CorruptIndexError):
        read_manifest(d)


def test_checkpoint_write_damage_detected(tmp_path, base_index):
    mi, cfg = _durable(base_index, tmp_path / "d")
    name = mi.checkpoint()
    mi.close()
    path = str(tmp_path / "d" / name)
    damage_file(path, "truncate")
    with pytest.raises(CorruptIndexError):
        read_npz_verified(path)
    with pytest.raises(CorruptIndexError):
        MutableAnnIndex.recover(str(tmp_path / "d"), config=cfg)


# --------------------------------------------------------------------------
# recovery basics: roundtrip, torn tail via the truncate failpoint kind,
# double recovery, checkpoint rotation + prune
# --------------------------------------------------------------------------
def test_recover_roundtrip_inserts_deletes(tmp_path, small_ds, base_index):
    mi, cfg = _durable(base_index, tmp_path / "d")
    ids = mi.insert(small_ds.base[400:430])
    mi.delete([0, 5, int(ids[2])])
    mi.close()
    back = MutableAnnIndex.recover(str(tmp_path / "d"), config=cfg)
    assert back.n_live == mi.n_live == 400 + 30 - 3
    np.testing.assert_array_equal(back.live_ids(), mi.live_ids())
    assert back._next_ext == mi._next_ext
    # recovered index searches (and its profile came along)
    out, _, _ = back.search(small_ds.queries[:4], spec=SPEC)
    assert (out >= 0).all()


def test_torn_tail_recovery_via_truncate_failpoint(tmp_path, small_ds,
                                                   base_index):
    """ISSUE 8 satellite: the existing ``truncate`` failpoint kind writes
    half a frame (a torn write) — recovery truncates it away and keeps
    exactly the acked history."""
    mi, cfg = _durable(base_index, tmp_path / "d")
    mi.insert(small_ds.base[400:420])          # acked
    acked = mi.live_ids()
    fault.arm("wal.append", kind="truncate", hits={0})
    with pytest.raises(FaultInjected):
        mi.insert(small_ds.base[420:425])      # torn mid-frame, never acked
    fault.disarm()
    # the writer is poisoned — even in-memory acks now refuse
    with pytest.raises(WalFailedError):
        mi.insert(small_ds.base[425:430])
    back = MutableAnnIndex.recover(str(tmp_path / "d"), config=cfg)
    np.testing.assert_array_equal(back.live_ids(), acked)
    # the torn bytes were truncated off the segment on disk: a second
    # recovery reads a clean log
    back.close()
    again = MutableAnnIndex.recover(str(tmp_path / "d"), config=cfg)
    np.testing.assert_array_equal(again.live_ids(), acked)


def test_double_recovery_idempotence(tmp_path, small_ds, base_index):
    """recover -> mutate -> crash -> recover again replays the combined
    log onto the same checkpoint without duplicating or resurrecting."""
    mi, cfg = _durable(base_index, tmp_path / "d")
    ids = mi.insert(small_ds.base[400:420])
    mi.delete([int(ids[0]), 3])
    mi.close()                                  # "crash" #1
    r1 = MutableAnnIndex.recover(str(tmp_path / "d"), config=cfg)
    ids2 = r1.insert(small_ds.base[420:430])
    r1.delete([int(ids2[1]), int(ids[5]), 9])
    want = r1.live_ids()
    r1.close()                                  # "crash" #2
    r2 = MutableAnnIndex.recover(str(tmp_path / "d"), config=cfg)
    np.testing.assert_array_equal(r2.live_ids(), want)
    assert r2._next_ext == r1._next_ext
    # and the tombstoned ids stay dead
    for e in (int(ids[0]), 3, int(ids2[1]), int(ids[5]), 9):
        with pytest.raises(KeyError):
            r2.delete([e])


def test_checkpoint_rotates_and_prunes(tmp_path, small_ds, base_index):
    mi, cfg = _durable(base_index, tmp_path / "d")
    mi.insert(small_ds.base[400:420])
    name = mi.checkpoint()
    files = set(os.listdir(tmp_path / "d"))
    # exactly one checkpoint + one (fresh) segment survive the prune
    assert files == {"MANIFEST", name, "wal-00000002.log"}
    m = read_manifest(str(tmp_path / "d"))
    assert m.checkpoint == name and m.segments == ["wal-00000002.log"]
    # post-checkpoint mutations land in the new segment and recover fine
    mi.delete([0])
    mi.close()
    back = MutableAnnIndex.recover(str(tmp_path / "d"), config=cfg)
    np.testing.assert_array_equal(back.live_ids(), mi.live_ids())


def test_merge_checkpoints_and_recovers(tmp_path, small_ds, base_index):
    """checkpoint_on_merge: a successful merge rotates + publishes, so
    recovery replays only post-merge mutations onto the merged graph."""
    mi, cfg = _durable(base_index, tmp_path / "d")
    mi.insert(small_ds.base[400:440])
    mi.delete(list(range(10)))
    mi.merge()
    m = read_manifest(str(tmp_path / "d"))
    assert m.checkpoint == "checkpoint-00000002.npz"
    mi.insert(small_ds.base[440:450])
    mi.close()
    back = MutableAnnIndex.recover(str(tmp_path / "d"), config=cfg)
    assert back.epoch == mi.epoch == 1
    np.testing.assert_array_equal(back.live_ids(), mi.live_ids())
    # the recovered delta holds only the post-checkpoint rows
    assert back._state.delta.count == 10


def test_replay_merges_when_delta_overflows(tmp_path, small_ds, base_index):
    """A log longer than the delta capacity replays through mid-recovery
    merges instead of failing."""
    mi, cfg = _durable(base_index, tmp_path / "d", delta_capacity=16,
                       checkpoint_on_merge=False)
    for i in range(5):
        mi.insert(small_ds.base[400 + 10 * i:410 + 10 * i])
        if mi._state.delta.room < 10:
            mi.merge()          # no checkpoint: the log keeps everything
    mi.close()
    back = MutableAnnIndex.recover(str(tmp_path / "d"), config=cfg)
    np.testing.assert_array_equal(back.live_ids(), mi.live_ids())


def test_create_refuses_existing_state(tmp_path, base_index):
    _durable(base_index, tmp_path / "d")
    with pytest.raises(ValueError, match="already holds durable state"):
        _durable(base_index, tmp_path / "d")


def test_mutations_without_durable_dir_unchanged(base_index, small_ds):
    """No durable_dir -> no WAL anywhere near the mutation path."""
    mi = MutableAnnIndex(base_index, config=_cfg())
    mi.insert(small_ds.base[400:410])
    assert mi._durable is None
    with pytest.raises(ValueError, match="durable store"):
        mi.checkpoint()


# --------------------------------------------------------------------------
# kill-at-every-site chaos suite: zero acked loss, zero resurrections
# --------------------------------------------------------------------------
CHAOS_SITES = ["wal.append", "wal.fsync", "wal.rotate", "checkpoint.write",
               "manifest.rename"]


def _chaos_run(site, dirname, small_ds, base_index):
    """Acked mutations -> seeded crash at ``site`` -> recover.  Returns
    (acked_live_ids, deleted_ids, recovered_index)."""
    mi, cfg = _durable(base_index, dirname)
    ids = mi.insert(small_ds.base[400:430])     # acked
    deleted = [int(ids[1]), int(ids[7]), 11]
    mi.delete(deleted)                          # acked
    acked = mi.live_ids()
    fault.arm(site, kind="raise", hits={0})
    crashed = False
    try:
        mi.insert(small_ds.base[430:440])       # never acked if it raises
    except (FaultInjected, WalFailedError):
        crashed = True
    if not crashed:
        # sites on the checkpoint path only fire there
        try:
            mi.checkpoint()
        except (FaultInjected, WalFailedError):
            crashed = True
    assert crashed, f"failpoint {site} never fired"
    fault.disarm()
    back = MutableAnnIndex.recover(str(dirname), config=cfg)
    return acked, deleted, back


@pytest.mark.parametrize("site", CHAOS_SITES)
def test_chaos_kill_site_zero_acked_loss(site, tmp_path, small_ds,
                                         base_index):
    acked, deleted, back = _chaos_run(site, tmp_path / "d", small_ds,
                                      base_index)
    recovered = set(map(int, back.live_ids()))
    # zero acknowledged loss: every acked-live id survives recovery
    missing = set(map(int, acked)) - recovered
    assert not missing, f"{site}: lost acked ids {sorted(missing)}"
    # zero resurrection: every acked delete stays dead
    raised = recovered & set(deleted)
    assert not raised, f"{site}: resurrected deleted ids {sorted(raised)}"
    # the recovered index is fully operational (mutate + search + ack)
    back.insert(small_ds.base[440:442])
    out, _, _ = back.search(small_ds.queries[:2], spec=SPEC)
    assert (out >= 0).all()


def test_chaos_midlog_corruption_refuses_replay(tmp_path, small_ds,
                                                base_index):
    """The ``corrupt`` kind damages a frame while appends continue —
    recovery must refuse the log instead of silently dropping acked
    records."""
    mi, cfg = _durable(base_index, tmp_path / "d", wal_fsync="off")
    mi.insert(small_ds.base[400:410])
    fault.arm("wal.append", kind="corrupt", hits={0})
    mi.insert(small_ds.base[410:415])           # damaged frame
    fault.disarm()
    mi.insert(small_ds.base[415:420])           # valid bytes AFTER it
    mi.close()
    with pytest.raises(CorruptIndexError, match="mid-log|CRC"):
        MutableAnnIndex.recover(str(tmp_path / "d"), config=cfg)


# --------------------------------------------------------------------------
# sharded persistence + sharded chaos
# --------------------------------------------------------------------------
@pytest.fixture(scope="module")
def shard_indexes(small_ds):
    return [AnnIndex.build(small_ds.base[s * 150:(s + 1) * 150],
                           graph="hnsw", **HNSW_KW) for s in range(3)]


def test_sharded_save_load_roundtrip(tmp_path, small_ds, shard_indexes):
    cfg = _cfg()
    ms = MutableShardedAnnIndex(shard_indexes, config=cfg)
    ids = ms.insert(small_ds.base[450:470])
    ms.delete([0, 160, int(ids[3])])
    d = str(tmp_path / "exp")
    ms.save(d)
    back = MutableShardedAnnIndex.load(d, config=cfg)
    assert back.n_live == ms.n_live
    for sh_a, sh_b in zip(ms.shards, back.shards):
        np.testing.assert_array_equal(sh_a.live_ids(), sh_b.live_ids())
        assert sh_b._durable is None           # load does not take the log
    assert back._next_ext == ms._next_ext
    # a loaded index keeps serving and mutating (in memory)
    back.insert(small_ds.base[470:475])
    out, _, _ = back.search(small_ds.queries[:3], spec=SPEC)
    assert (out >= 0).all()


def test_sharded_durable_recover_and_routing(tmp_path, small_ds,
                                             shard_indexes):
    cfg = _cfg()
    d = str(tmp_path / "d")
    ms = MutableShardedAnnIndex(shard_indexes, config=cfg, durable_dir=d)
    ids = ms.insert(small_ds.base[450:480])
    ms.delete([int(ids[0]), 5, 310])
    ms.close()
    back = MutableShardedAnnIndex.recover(d, config=cfg)
    assert back.n_live == ms.n_live
    l1 = np.sort(np.concatenate([sh.live_ids() for sh in ms.shards]))
    l2 = np.sort(np.concatenate([sh.live_ids() for sh in back.shards]))
    np.testing.assert_array_equal(l1, l2)
    # routing state rebuilt: deletes find their shard, allocation resumes
    # globally unique
    back.delete([int(ids[4])])
    new = back.insert(small_ds.base[480:485])
    assert int(new[0]) >= ms._next_ext


@pytest.mark.parametrize("site", ["wal.append", "wal.fsync"])
def test_sharded_chaos_zero_acked_loss(site, tmp_path, small_ds,
                                       shard_indexes):
    cfg = _cfg()
    d = str(tmp_path / "d")
    ms = MutableShardedAnnIndex(shard_indexes, config=cfg, durable_dir=d)
    ids = ms.insert(small_ds.base[450:480])     # acked
    deleted = [int(ids[2]), 7, 320]
    ms.delete(deleted)                          # acked
    acked = np.sort(np.concatenate([sh.live_ids() for sh in ms.shards]))
    fault.arm(site, kind="raise", hits={0})
    with pytest.raises((FaultInjected, WalFailedError)):
        ms.insert(small_ds.base[480:490])       # crashes in one shard's WAL
    fault.disarm()
    back = MutableShardedAnnIndex.recover(d, config=cfg)
    recovered = set(
        int(e) for sh in back.shards for e in sh.live_ids())
    missing = set(map(int, acked)) - recovered
    assert not missing, f"{site}: lost acked ids {sorted(missing)}"
    raised = recovered & set(deleted)
    assert not raised, f"{site}: resurrected deleted ids {sorted(raised)}"

"""Pallas TPU kernel: sorted-pool merge via an in-VMEM bitonic network.

The second hot spot of best-first search: merging M freshly-computed
candidate distances into the sorted size-P result pool each hop.  XLA lowers
the naive concat+argsort to a full sort; here the merge is a fixed
compare-exchange network over a power-of-two padded buffer held in VREGs —
data-independent control flow, exactly what the VPU wants.

Payload trick: ids ride along as the low 32 bits of a float64-free packing —
we sort a single int32 "key" tensor built as (quantized dist, id) pairs?  No:
Pallas TPU has no 64-bit sort lanes; instead we run the compare-exchange on
the distance tensor and apply identical where-swaps to the id tensor.

Grid: one program per batch row block (bb rows), network length L = pow2(P+M).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _next_pow2(x: int) -> int:
    p = 1
    while p < x:
        p *= 2
    return p


def _bitonic_stages(L: int):
    """Yield (stride, block) pairs of a full bitonic sort network of length L."""
    k = 2
    while k <= L:
        j = k // 2
        while j >= 1:
            yield j, k
            j //= 2
        k *= 2


def _merge_kernel(pool_d_ref, pool_i_ref, new_d_ref, new_i_ref,
                  out_d_ref, out_i_ref, *, L: int, P: int):
    d = jnp.concatenate([pool_d_ref[...], new_d_ref[...]], axis=1)  # [bb, P+M]
    i = jnp.concatenate([pool_i_ref[...], new_i_ref[...]], axis=1)
    pad = L - d.shape[1]
    if pad:
        d = jnp.concatenate([d, jnp.full((d.shape[0], pad), jnp.inf, d.dtype)], axis=1)
        i = jnp.concatenate([i, jnp.full((i.shape[0], pad), -1, i.dtype)], axis=1)
    idx = jax.lax.broadcasted_iota(jnp.int32, (1, L), 1)
    for j, k in _bitonic_stages(L):
        partner = idx ^ j
        pd = jnp.take_along_axis(d, jnp.broadcast_to(partner, d.shape), axis=1)
        pi = jnp.take_along_axis(i, jnp.broadcast_to(partner, i.shape), axis=1)
        up = (idx & k) == 0           # ascending block?
        is_lo = partner > idx         # this lane holds the smaller slot
        keep_min = jnp.where(up, is_lo, ~is_lo)
        take_min = jnp.minimum(d, pd)
        take_max = jnp.maximum(d, pd)
        sel_min = jnp.where(d < pd, i, jnp.where(pd < d, pi, jnp.minimum(i, pi)))
        sel_max = jnp.where(d < pd, pi, jnp.where(pd < d, i, jnp.maximum(i, pi)))
        d = jnp.where(keep_min, take_min, take_max)
        i = jnp.where(keep_min, sel_min, sel_max)
    out_d_ref[...] = d[:, :P]
    out_i_ref[...] = i[:, :P]


@functools.partial(jax.jit, static_argnames=("bb", "interpret"))
def pool_merge_pallas(pool_d, pool_i, new_d, new_i, *, bb: int = 8,
                      interpret: bool = True):
    """pool_d/i [B, P] sorted asc, new_d/i [B, M] -> best-P of the union, sorted.

    Ties on distance resolve to the smaller id (deterministic).
    """
    B, P = pool_d.shape
    M = new_d.shape[1]
    bb = min(bb, B)
    assert B % bb == 0
    L = _next_pow2(P + M)
    grid = (B // bb,)
    return pl.pallas_call(
        functools.partial(_merge_kernel, L=L, P=P),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, P), lambda r: (r, 0)),
            pl.BlockSpec((bb, P), lambda r: (r, 0)),
            pl.BlockSpec((bb, M), lambda r: (r, 0)),
            pl.BlockSpec((bb, M), lambda r: (r, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bb, P), lambda r: (r, 0)),
            pl.BlockSpec((bb, P), lambda r: (r, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, P), pool_d.dtype),
            jax.ShapeDtypeStruct((B, P), pool_i.dtype),
        ],
        interpret=interpret,
    )(pool_d, pool_i, new_d, new_i)

"""Sharded, fault-tolerant checkpointing (no orbax in the container).

Layout per step:
    <dir>/step_<N>/
        manifest.json        — pytree structure, shapes, dtypes, data cursor,
                               mesh shape, content hashes
        shard_<i>.npz        — flat arrays (one file per host in multi-host;
                               one file here)
    <dir>/LATEST             — atomic pointer (write tmp + rename)

Fault-tolerance contract (tested in tests/test_checkpoint.py):
  * atomic publish: a crash mid-write never corrupts LATEST;
  * resume restores params/opt state bit-exactly + the data-stream cursor;
  * elastic restore: arrays are re-placed under a *different* mesh/sharding
    (re-sharding happens at device_put, so restart on 2x fewer hosts works);
  * content hashes detect partial/corrupt shard files.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Tuple[list, Any]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _tree_paths(tree):
    return [jax.tree_util.keystr(p)
            for p, _ in jax.tree_util.tree_flatten_with_path(tree)[0]]


def save_checkpoint(ckpt_dir: str, step: int, state: Dict[str, Any],
                    data_cursor: Optional[dict] = None,
                    extra: Optional[dict] = None) -> str:
    """state: pytree dict (e.g. {'params':…, 'opt':…}). Returns the step dir."""
    leaves, treedef = _flatten(state)
    arrays = [np.asarray(l) for l in leaves]
    step_dir = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp_dir = step_dir + ".tmp"
    if os.path.exists(tmp_dir):
        shutil.rmtree(tmp_dir)
    os.makedirs(tmp_dir, exist_ok=True)

    shard_path = os.path.join(tmp_dir, "shard_0.npz")
    np.savez(shard_path, **{f"a{i}": a for i, a in enumerate(arrays)})
    with open(shard_path, "rb") as f:
        digest = hashlib.sha256(f.read()).hexdigest()

    manifest = {
        "step": step,
        "paths": _tree_paths(state),
        "shapes": [list(a.shape) for a in arrays],
        "dtypes": [str(a.dtype) for a in arrays],
        "treedef": str(treedef),
        "n_leaves": len(arrays),
        "data_cursor": data_cursor or {},
        "extra": extra or {},
        "hashes": {"shard_0.npz": digest},
    }
    with open(os.path.join(tmp_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)

    if os.path.exists(step_dir):
        shutil.rmtree(step_dir)
    os.rename(tmp_dir, step_dir)                       # atomic publish
    latest_tmp = os.path.join(ckpt_dir, ".LATEST.tmp")
    with open(latest_tmp, "w") as f:
        f.write(os.path.basename(step_dir))
    os.replace(latest_tmp, os.path.join(ckpt_dir, "LATEST"))
    return step_dir


def latest_step(ckpt_dir: str) -> Optional[int]:
    p = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(p):
        return None
    with open(p) as f:
        name = f.read().strip()
    return int(name.split("_")[1])


def restore_checkpoint(ckpt_dir: str, like: Dict[str, Any],
                       step: Optional[int] = None,
                       shardings: Optional[Any] = None,
                       verify_hash: bool = True):
    """Restore into the structure of `like` (a pytree of arrays or
    ShapeDtypeStructs).  If `shardings` given, device_put each leaf with its
    (possibly new-mesh) sharding — the elastic-rescale path."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    step_dir = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(step_dir, "manifest.json")) as f:
        manifest = json.load(f)
    shard_path = os.path.join(step_dir, "shard_0.npz")
    if verify_hash:
        with open(shard_path, "rb") as f:
            digest = hashlib.sha256(f.read()).hexdigest()
        if digest != manifest["hashes"]["shard_0.npz"]:
            raise IOError(f"checkpoint shard corrupt at step {step}")
    z = np.load(shard_path)
    arrays = [z[f"a{i}"] for i in range(manifest["n_leaves"])]
    _, treedef = _flatten(like)
    leaves = jax.tree_util.tree_leaves(like)
    assert len(leaves) == len(arrays), "checkpoint/model structure mismatch"
    for l, a in zip(leaves, arrays):
        if tuple(l.shape) != a.shape:
            raise ValueError(f"shape mismatch {l.shape} vs {a.shape}")
    if shardings is not None:
        sh_leaves = jax.tree_util.tree_leaves(shardings)
        arrays = [jax.device_put(a, s) for a, s in zip(arrays, sh_leaves)]
    state = jax.tree_util.tree_unflatten(treedef, arrays)
    return state, manifest["data_cursor"], manifest["step"]


def gc_checkpoints(ckpt_dir: str, keep: int = 3):
    """Keep the newest `keep` step dirs (never the one LATEST points at)."""
    if not os.path.isdir(ckpt_dir):
        return
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_")
                   and not d.endswith(".tmp"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d))

"""CRouting applied to recsys retrieval (DESIGN.md §5 Arch-applicability):
the dlrm-mlperf ``retrieval_cand`` shape scores one user query against a
large candidate set.  Brute-force batched-dot is the roofline baseline; the
CRouting-HNSW index answers the same query with a fraction of the exact
distance computations.

    PYTHONPATH=src python examples/dlrm_retrieval.py
"""
import time

import numpy as np
import jax.numpy as jnp

from repro.core.index import AnnIndex
from repro.core.spec import SearchSpec
from repro.kernels import ops
from repro.models.dlrm import DlrmConfig, make_retrieval_step


def main():
    rng = np.random.default_rng(0)
    d = 128
    n_cand = 100_000                     # container-sized; 1e6 in the dry-run
    k = 100
    # item embeddings (as produced by a trained DLRM tower), L2-normalized
    cands = rng.normal(size=(n_cand, d)).astype(np.float32)
    cands /= np.linalg.norm(cands, axis=1, keepdims=True)
    queries = rng.normal(size=(32, d)).astype(np.float32)
    queries /= np.linalg.norm(queries, axis=1, keepdims=True)

    # --- baseline: brute-force batched dot (the dry-run retrieval_step) ----
    step = make_retrieval_step(DlrmConfig(), k=k)
    t0 = time.perf_counter()
    scores, ids_bf = step(jnp.asarray(queries), jnp.asarray(cands))
    ids_bf = np.asarray(ids_bf)
    t_bf = time.perf_counter() - t0
    print(f"brute force: {n_cand} candidates x {len(queries)} queries "
          f"in {t_bf*1e3:.0f}ms (exact)")

    # --- CRouting-ANN retrieval --------------------------------------------
    t0 = time.perf_counter()
    idx = AnnIndex.build(cands, graph="hnsw", metric="ip", m=16, efc=96)
    print(f"ANN index built in {time.perf_counter()-t0:.1f}s")
    ids_ann, _, stats = idx.search(
        queries, spec=SearchSpec(k=k, efs=2 * k, router="crouting"))
    recall = np.mean([len(set(a) & set(b)) / k
                      for a, b in zip(ids_ann, ids_bf)])
    frac = stats.dist_calls.mean() / n_cand
    print(f"CRouting ANN: recall@{k}={recall:.3f}, exact distance calls/query "
          f"= {stats.dist_calls.mean():.0f} ({frac:.2%} of brute force)")

    # --- the Pallas distance kernel is the brute-force hot path -------------
    t0 = time.perf_counter()
    dmat = ops.l2_distance(jnp.asarray(queries[:8]), jnp.asarray(cands[:8192]),
                           mode="ip")
    _ = np.asarray(dmat)
    print(f"pallas l2_distance (interpret): 8x8192 block in "
          f"{(time.perf_counter()-t0)*1e3:.0f}ms")


if __name__ == "__main__":
    main()

"""Gradient compression for cross-pod all-reduce (DESIGN.md §6).

int8 stochastic-rounding quantization with per-tensor scale: quantize ->
all-reduce (psum of int-valued floats is exact up to the shared scale) ->
dequantize.  Cuts the gradient all-reduce wire bytes 4x (fp32) / 2x (bf16);
enable with TrainerConfig.grad_compress for the slow cross-pod hop.

Error feedback (residual carry) keeps the quantization noise from biasing
convergence — the standard 1-bit-Adam/PowerSGD-style correction.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x, key=None):
    """Returns (q int8, scale). Stochastic rounding when key given."""
    amax = jnp.max(jnp.abs(x)) + 1e-12
    scale = amax / 127.0
    y = x / scale
    if key is not None:
        y = jnp.floor(y + jax.random.uniform(key, y.shape))
    else:
        y = jnp.round(y)
    return jnp.clip(y, -127, 127).astype(jnp.int8), scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def compress_tree(grads, key) -> Tuple[Any, Any]:
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    keys = jax.random.split(key, len(leaves))
    qs, scales = [], []
    for l, k in zip(leaves, keys):
        q, s = quantize_int8(l.astype(jnp.float32), k)
        qs.append(q)
        scales.append(s)
    return (jax.tree_util.tree_unflatten(treedef, qs),
            jax.tree_util.tree_unflatten(treedef, scales))


def decompress_tree(qs, scales):
    return jax.tree_util.tree_map(dequantize_int8, qs, scales)


def compressed_psum(grads, axis_name, key):
    """Quantize -> psum -> dequantize, with the scale itself psum-maxed so
    all shards dequantize identically."""
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    keys = jax.random.split(key, len(leaves))
    out = []
    for l, k in zip(leaves, keys):
        x = l.astype(jnp.float32)
        amax = jax.lax.pmax(jnp.max(jnp.abs(x)), axis_name) + 1e-12
        scale = amax / 127.0
        y = jnp.floor(x / scale + jax.random.uniform(k, x.shape))
        y = jnp.clip(y, -127, 127)
        red = jax.lax.psum(y, axis_name)        # int-valued f32: exact sum
        out.append(red * scale)
    return jax.tree_util.tree_unflatten(treedef, out)


def with_error_feedback(grads, residual):
    """Add carried residual; return (to_compress, new_residual_fn)."""
    if residual is None:
        return grads, lambda q_deq: jax.tree_util.tree_map(
            lambda g, d: g - d, grads, q_deq)
    carried = jax.tree_util.tree_map(lambda g, r: g + r, grads, residual)
    return carried, lambda q_deq: jax.tree_util.tree_map(
        lambda g, d: g - d, carried, q_deq)

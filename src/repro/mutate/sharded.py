"""Per-shard deltas: mutation over a sharded corpus with staggered merges.

``MutableShardedAnnIndex`` is a host-side composition of one
``MutableAnnIndex`` per shard (children run ``auto_merge="off"``; the
parent owns merge policy).  It is NOT the ``shard_map`` data plane of
``ShardedAnnIndex`` — each shard is its own single-device index and the
top-k merge happens host-side, which is exactly what the mutation story
needs: a merge rebuilds ONE shard's graph while every other shard keeps
serving untouched, so the rebuild cost is 1/S of the corpus at a time
(staggering; DESIGN.md §9).

Routing: inserts go to the currently-least-loaded shard (by live count),
so deltas fill — and therefore merge — out of phase with each other.
External ids are allocated globally by the parent and mapped to shards
with a host dict; deletes route through it.

Failure domains (DESIGN.md §10): because the top-k composition is
host-side, a shard that fails or stalls can simply be LEFT OUT — the
batch resolves with the survivors' pool and ``SearchStats.shards_failed``
/ ``degraded`` set (partial results are data, not an exception; only when
every shard fails does ``search`` raise ``DegradedSearchError``).  With
``shard_timeout_s`` set, per-shard searches run on a thread pool and a
straggler past the deadline is dropped the same way.  Merge policy is
quarantine-aware: a shard whose merge-retry budget is exhausted sits out
(its pre-merge snapshot serves) and inserts route around it.
"""
from __future__ import annotations

import dataclasses
import os
import threading
from concurrent.futures import ThreadPoolExecutor, wait
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.index import AnnIndex
from repro.core.spec import SearchSpec, SearchStats, resolve_search_spec
from repro.durable.manifest import Manifest, read_manifest, write_manifest
from repro.durable.store import DurableStore
from repro.fault import (CorruptIndexError, DegradedSearchError,
                         MergeQuarantinedError)
from repro.fault import failpoints as fault
from repro.mutate.delta import delta_scan_compile_count
from repro.mutate.index import DEFAULT_SEARCH, MutableAnnIndex, MutateConfig

_SHARD_DIR = "shard-{:d}"


class MutableShardedAnnIndex:
    """S mutable shards behind one insert/delete/search surface."""

    def __init__(self, indexes: List[AnnIndex],
                 config: MutateConfig = MutateConfig(),
                 spec: Optional[SearchSpec] = None, *,
                 shard_timeout_s: Optional[float] = None,
                 durable_dir: Optional[str] = None):
        if not indexes:
            raise ValueError("need at least one shard")
        child_cfg = dataclasses.replace(config, auto_merge="off")
        self._init_common(config, spec, len(indexes), shard_timeout_s)
        for s, idx in enumerate(indexes):
            child = MutableAnnIndex(idx, config=child_cfg, spec=spec)
            # children hand out their own ids starting at their local n;
            # the parent overrides allocation so ids are globally unique
            for e in child._state.snapshot.ext_ids:
                ge = self._next_ext
                self._remap_child_ext(child, int(e), ge)
                self._ext_to_shard[ge] = s
                self._next_ext += 1
            self.shards.append(child)
        if durable_dir is not None:
            # per-shard stores attach AFTER the remap above, so the initial
            # checkpoints capture GLOBAL ids; the parent manifest lands
            # last — its existence implies every shard dir is complete
            for s, child in enumerate(self.shards):
                child._init_durable(
                    os.path.join(durable_dir, _SHARD_DIR.format(s)))
            write_manifest(durable_dir, self._parent_manifest())

    def _init_common(self, config: MutateConfig, spec: Optional[SearchSpec],
                     n_shards: int, shard_timeout_s: Optional[float]):
        """Field setup shared by ``__init__`` and ``recover``."""
        self.config = config
        self.default_spec = spec if spec is not None else DEFAULT_SEARCH
        self.shard_timeout_s = shard_timeout_s
        self.shards: List[MutableAnnIndex] = []
        self._ext_to_shard: Dict[int, int] = {}
        self._next_ext = 0
        self._merge_threads: Dict[int, threading.Thread] = {}
        # pool only when a timeout is configured: the serial path has no
        # per-search executor overhead and identical degradation semantics
        self._pool = (ThreadPoolExecutor(
            max_workers=n_shards, thread_name_prefix="shard-search")
            if shard_timeout_s is not None else None)

    def _parent_manifest(self) -> Manifest:
        """The parent binding: no checkpoint/segments of its own — the
        per-shard truth lives in ``shard-*/MANIFEST``."""
        return Manifest(checkpoint=None, segments=[],
                        meta={"kind": "mutable-sharded",
                              "n_shards": len(self.shards)})

    @staticmethod
    def _remap_child_ext(child: MutableAnnIndex, old: int, new: int):
        snap = child._state.snapshot
        row = snap.ext_to_row.pop(old)
        snap.ext_ids[row] = new
        snap.ext_to_row[new] = row

    # --- mutation ---------------------------------------------------------
    def _pick_shard(self, n_rows: int) -> int:
        """Least-loaded shard that can absorb ``n_rows`` now: a quarantined
        shard with a full delta cannot drain, so inserts route around it.
        Every shard full AND quarantined is typed backpressure."""
        order = sorted(range(len(self.shards)),
                       key=lambda i: self.shards[i].n_live)
        for s in order:
            child = self.shards[s]
            if n_rows <= child._state.delta.room or not child.quarantined:
                return s
        raise MergeQuarantinedError(
            "every shard's delta is full and its merges are quarantined; "
            "retry after a cooldown or clear_quarantine() per shard")

    def insert(self, vectors: np.ndarray) -> np.ndarray:
        vectors = np.asarray(vectors, np.float32)
        if vectors.ndim == 1:
            vectors = vectors[None, :]
        # least-loaded (non-quarantined-full) shard keeps fill staggered
        s = self._pick_shard(vectors.shape[0])
        child = self.shards[s]
        if vectors.shape[0] > child._state.delta.room:
            try:
                # children run auto_merge="off"; drain explicitly (with the
                # child's retry budget — exhaustion quarantines the shard)
                child._merge_with_retry()
            except Exception as e:   # noqa: BLE001 — typed backpressure
                raise MergeQuarantinedError(
                    f"shard delta full and its drain merge failed "
                    f"(shard now quarantined)") from e
        ids = np.arange(self._next_ext, self._next_ext + vectors.shape[0],
                        dtype=np.int64)
        self._next_ext += vectors.shape[0]
        with child._lock:
            child._next_ext = int(ids[0])
            got = child.insert(vectors)
        assert (got == ids).all()
        for e in ids:
            self._ext_to_shard[int(e)] = s
        self.maybe_merge()
        return ids

    def delete(self, ext_ids) -> int:
        if np.ndim(ext_ids) == 0:
            ext_ids = [ext_ids]
        by_shard: Dict[int, List[int]] = {}
        for e in map(int, ext_ids):
            s = self._ext_to_shard.get(e)
            if s is None:
                raise KeyError(f"external id {e} is not live")
            by_shard.setdefault(s, []).append(e)
        removed = 0
        for s, ids in by_shard.items():
            removed += self.shards[s].delete(ids)
        self.maybe_merge()
        return removed

    def maybe_merge(self):
        """Merge AT MOST the single most-pressured, non-quarantined shard
        per call, so shard rebuilds stagger instead of stampeding.  The
        parent owns merge policy: ``sync`` merges inline (failures raise
        after the retry budget), ``background`` rebuilds on a daemon thread
        per shard (failures quarantine the shard silently — the state is
        the record), ``off`` leaves merges to explicit calls."""
        if self.config.auto_merge == "off":
            return
        due = [s for s, sh in enumerate(self.shards)
               if sh.needs_merge() and not sh.quarantined]
        if not due:
            return
        s = max(due, key=lambda i: self.shards[i]._state.delta.count)
        sh = self.shards[s]
        if self.config.auto_merge == "sync":
            sh._merge_with_retry()
            return
        t = self._merge_threads.get(s)
        if t is not None and t.is_alive():
            return

        def run():
            try:
                sh._merge_with_retry()
            # repolint: ignore[fail-open] _merge_with_retry stored the failure
            # (shard merge_error + quarantine) before raising; the wrapper
            # only keeps the daemon thread quiet
            except Exception:   # noqa: BLE001 — recorded as shard quarantine
                pass

        t = threading.Thread(target=run, name=f"shard-merge-{s}", daemon=True)
        self._merge_threads[s] = t
        t.start()

    def wait_for_merges(self):
        """Join outstanding background shard merges.  Does NOT raise:
        failures live on as per-shard quarantine + ``merge_error``."""
        for t in list(self._merge_threads.values()):
            t.join()

    def clear_quarantine(self):
        """Operator override: lift every shard's quarantine."""
        for sh in self.shards:
            sh.clear_quarantine()

    @property
    def quarantined_shards(self) -> Tuple[int, ...]:
        return tuple(s for s, sh in enumerate(self.shards) if sh.quarantined)

    # --- search -----------------------------------------------------------
    def _shard_search(self, s: int, queries: np.ndarray, spec: SearchSpec):
        fault.hit("shard.search", sub=str(s))
        return self.shards[s].search(queries, spec=spec)

    def search(self, queries: np.ndarray,
               spec: Optional[SearchSpec] = None
               ) -> Tuple[np.ndarray, np.ndarray, SearchStats]:
        """Fan out to every shard, host-merge the per-shard top-k.

        Graceful degradation: a shard that raises (or, with
        ``shard_timeout_s``, misses its deadline) is dropped from the
        composition — the batch resolves with the survivors' pool,
        ``stats.shards_failed`` counting the losses and ``stats.degraded``
        set.  Only when EVERY shard fails does the search raise
        (``DegradedSearchError`` chained to the first failure).
        """
        spec = resolve_search_spec(spec, self.default_spec,
                                   "MutableShardedAnnIndex.search")
        k = spec.k
        parts: List[Tuple[np.ndarray, np.ndarray, SearchStats]] = []
        failed = 0
        first_err: Optional[BaseException] = None
        if self._pool is None:
            for s in range(len(self.shards)):
                try:
                    parts.append(self._shard_search(s, queries, spec))
                except Exception as e:   # noqa: BLE001 — degrade, not fail
                    failed += 1
                    if first_err is None:
                        first_err = e
        else:
            futs = {self._pool.submit(self._shard_search, s, queries, spec): s
                    for s in range(len(self.shards))}
            done, not_done = wait(futs, timeout=self.shard_timeout_s)
            for f in futs:
                if f in done:
                    try:
                        parts.append(f.result())
                        continue
                    except Exception as e:   # noqa: BLE001 — degrade
                        err: BaseException = e
                else:
                    # straggler: abandoned (its thread finishes into the
                    # void; results are discarded), the batch moves on
                    f.cancel()
                    err = TimeoutError(
                        f"shard {futs[f]} search missed the "
                        f"{self.shard_timeout_s}s deadline")
                failed += 1
                if first_err is None:
                    first_err = err
        if not parts:
            raise DegradedSearchError(
                f"all {len(self.shards)} shards failed") from first_err
        all_ids = np.concatenate([p[0] for p in parts], axis=1)
        all_d = np.concatenate([p[1] for p in parts], axis=1)
        order = np.argsort(all_d, axis=1, kind="stable")[:, :k]
        out_ids = np.take_along_axis(all_ids, order, axis=1)
        out_d = np.take_along_axis(all_d, order, axis=1)
        out_ids = np.where(np.isfinite(out_d), out_ids, -1)
        stats = parts[0][2] if len(parts) == 1 else SearchStats.merge(
            [p[2] for p in parts])
        if failed:
            stats = dataclasses.replace(
                stats, shards_failed=stats.shards_failed + failed,
                degraded=True)
        return out_ids, out_d, stats

    # --- accounting -------------------------------------------------------
    def compile_count(self) -> int:
        """Graph-engine compiles summed over shards, plus the process-wide
        delta-scan kernels counted ONCE (shards share those jit caches)."""
        return (sum(sh.engine_compile_count() for sh in self.shards)
                + delta_scan_compile_count())

    @property
    def metric(self) -> str:
        return self.shards[0].metric

    @property
    def dim(self) -> int:
        return self.shards[0].dim

    @property
    def n_live(self) -> int:
        return sum(sh.n_live for sh in self.shards)

    @property
    def epochs(self) -> Tuple[int, ...]:
        return tuple(sh.epoch for sh in self.shards)

    # --- persistence (DESIGN.md §11) --------------------------------------
    def save(self, dirname: str):
        """Export the full live state to a fresh durable directory: one
        checkpoint + empty WAL per shard under ``shard-<i>/``, bound by a
        parent ``MANIFEST``.  Unlike ``MutableAnnIndex.save`` this loses
        NOTHING — unmerged deltas and tombstones ride in the checkpoints.
        ``load`` (or ``recover``) reads it back; refuses a directory that
        already holds durable state.
        """
        self.wait_for_merges()
        for s, child in enumerate(self.shards):
            sd = os.path.join(dirname, _SHARD_DIR.format(s))
            store = DurableStore.create(
                sd, fsync=self.config.wal_fsync,
                fsync_interval_s=self.config.wal_fsync_interval_s,
                meta={"kind": "mutable-index"})
            store.publish_checkpoint(child._checkpoint_payload())
            store.close()
        write_manifest(dirname, self._parent_manifest())

    @classmethod
    def load(cls, dirname: str, config: MutateConfig = MutateConfig(),
             spec: Optional[SearchSpec] = None, *,
             shard_timeout_s: Optional[float] = None
             ) -> "MutableShardedAnnIndex":
        """Read a ``save``d (or crashed durable) directory WITHOUT taking
        over its log: the result mutates in memory only."""
        return cls.recover(dirname, config=config, spec=spec,
                           shard_timeout_s=shard_timeout_s, attach=False)

    @classmethod
    def recover(cls, dirname: str, config: MutateConfig = MutateConfig(),
                spec: Optional[SearchSpec] = None, *,
                shard_timeout_s: Optional[float] = None,
                attach: bool = True) -> "MutableShardedAnnIndex":
        """Rebuild every shard from ``shard-<i>/`` (checkpoint + WAL
        replay, see ``MutableAnnIndex.recover``) and re-derive the parent's
        routing state: ``_ext_to_shard`` from each shard's live ids and the
        global id allocator from the max of the shards' allocators.  With
        ``attach=True`` the shards keep logging into their WALs."""
        m = read_manifest(dirname)
        n_shards = int(m.meta.get("n_shards", 0))
        if m.meta.get("kind") != "mutable-sharded" or n_shards <= 0:
            raise CorruptIndexError(
                f"{dirname}: parent manifest is not a mutable-sharded "
                f"binding (meta={m.meta!r})")
        child_cfg = dataclasses.replace(config, auto_merge="off")
        obj = cls.__new__(cls)
        obj._init_common(config, spec, n_shards, shard_timeout_s)
        for s in range(n_shards):
            child = MutableAnnIndex.recover(
                os.path.join(dirname, _SHARD_DIR.format(s)),
                config=child_cfg, spec=spec, attach=attach)
            for e in child.live_ids():
                obj._ext_to_shard[int(e)] = s
            obj._next_ext = max(obj._next_ext, child._next_ext)
            obj.shards.append(child)
        return obj

    def close(self):
        """Release every shard's WAL writer (final fsync included)."""
        for sh in self.shards:
            sh.close()

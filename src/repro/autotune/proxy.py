"""Recall proxy: a small held-out probe set with exact ground truth.

Serving telemetry measures latency but says nothing about result quality,
and true recall needs ground truth no live system has.  The proxy closes
that gap cheaply: at attach time it draws a small probe query set (user
supplied, or synthesized by perturbing sampled base vectors), computes
exact brute-force ground truth against the corpus ONCE, and thereafter
replays the probes through any candidate ``SearchSpec`` on the
controller's background thread — returning a recall@k *proxy* (exact on
the probes, an estimate of serving recall) plus the probe dispatch
latency that feeds the controller's latency model.

Probe batches are padded to a bucket rung of the serving ladder, so a
probe replay compiles (at most) one executable per candidate — the SAME
executable the frontend's warmup would build for that rung, shared
through the compiled-engine cache.  Promotion to active then warms only
the remaining rungs.  Probe replays never touch frontend telemetry: they
are measurement, not traffic.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core import distances as D
from repro.core.spec import SearchSpec
from repro.data.vectors import recall_at_k
from repro.fault import failpoints as fault
from repro.serve.backends import make_session
from repro.serve.bucketing import bucket_for, pad_to_bucket


@dataclasses.dataclass
class ProbeMeasurement:
    """One probe replay through one candidate spec."""

    key: str
    recall: float                # exact recall@k on the probe set
    lat_s: float                 # median timed probe-dispatch latency
    dist_calls: float            # mean exact fp32 calls per probe query
    replays: int                 # timed replays folded into lat_s

    def to_dict(self) -> Dict[str, object]:
        return {"key": self.key, "recall": round(self.recall, 4),
                "lat_ms": round(self.lat_s * 1e3, 3),
                "dist_calls": round(self.dist_calls, 1),
                "replays": self.replays}


def _brute_force_topk(queries: np.ndarray, base: np.ndarray, k: int,
                      metric: str, block: int = 64) -> np.ndarray:
    """Exact top-k ids by the engine's own ranking distance (query-blocked
    — same recipe as ``data.vectors.exact_ground_truth``, over an
    arbitrary corpus matrix)."""
    out = np.empty((queries.shape[0], k), np.int64)
    for s in range(0, queries.shape[0], block):
        dist = D.pairwise_np(queries[s:s + block], base, metric)
        idx = np.argpartition(dist, kth=k - 1, axis=1)[:, :k]
        row = np.take_along_axis(dist, idx, axis=1)
        order = np.argsort(row, axis=1, kind="stable")
        out[s:s + block] = np.take_along_axis(idx, order, axis=1)
    return out


class RecallProxy:
    """Held-out probe set + exact ground truth, reusable across specs."""

    def __init__(self, index, queries: np.ndarray, gt: np.ndarray, *,
                 k: int = 10, buckets: Tuple[int, ...] = (32,)):
        self.index = index
        self.queries = np.ascontiguousarray(queries, np.float32)
        self.gt = np.asarray(gt)
        self.k = int(k)
        assert self.gt.shape[0] == self.queries.shape[0] >= 1
        assert self.gt.shape[1] >= self.k, "ground truth narrower than k"
        # pad probes onto a serving-ladder rung so probe compiles are the
        # warmup's compiles (ladder too short for the probe set: top rung
        # replays it in slices)
        self.bucket = (buckets[-1] if self.queries.shape[0] > buckets[-1]
                       else bucket_for(self.queries.shape[0], buckets))
        self._sessions: Dict[SearchSpec, object] = {}
        self.gt_secs = 0.0        # stamped by for_index / attach paths

    # --- construction -----------------------------------------------------
    @classmethod
    def for_index(cls, index, *, n_probe: int = 32, k: int = 10,
                  seed: int = 0, noise: float = 0.05,
                  buckets: Tuple[int, ...] = (32,),
                  queries: Optional[np.ndarray] = None,
                  gt: Optional[np.ndarray] = None) -> "RecallProxy":
        """Build the probe set + exact ground truth once, at attach time.

        With explicit ``queries`` (a held-out slice the operator trusts),
        ground truth is brute-forced against the index's corpus unless
        also supplied.  Without, probes are synthesized: sample base rows,
        add relative Gaussian noise — near-duplicates whose true neighbors
        are nontrivial but cheap to verify.  Requires an index that
        exposes its corpus (``graph.vectors``); pass explicit
        ``queries``+``gt`` for sharded/composed indexes.
        """
        t0 = time.perf_counter()
        base = cls._corpus(index) if gt is None else None
        if queries is None:
            if base is None:
                raise TypeError(
                    f"cannot synthesize probes for {type(index).__name__}; "
                    "pass explicit queries (and gt for corpus-less indexes)")
            rng = np.random.default_rng(seed)
            rows = rng.choice(base.shape[0], size=min(n_probe, base.shape[0]),
                              replace=False)
            q = base[rows]
            scale = noise * float(np.std(q)) if np.std(q) > 0 else noise
            queries = q + rng.normal(0.0, scale, q.shape)
        queries = np.ascontiguousarray(queries, np.float32)
        if gt is None:
            metric = cls._metric(index)
            qp = D.preprocess_vectors(queries, metric)
            gt = _brute_force_topk(qp, base, k, metric)
        proxy = cls(index, queries, gt, k=k, buckets=buckets)
        proxy.gt_secs = time.perf_counter() - t0
        return proxy

    @staticmethod
    def _corpus(index) -> Optional[np.ndarray]:
        g = getattr(index, "graph", None)
        if g is not None:
            return np.asarray(g.vectors, np.float32)
        state = getattr(index, "_state", None)          # MutableAnnIndex
        if state is not None and hasattr(state, "snapshot"):
            return np.asarray(state.snapshot.index.graph.vectors, np.float32)
        return None

    @staticmethod
    def _metric(index) -> str:
        g = getattr(index, "graph", None)
        if g is not None:
            return g.metric
        state = getattr(index, "_state", None)
        if state is not None and hasattr(state, "snapshot"):
            return state.snapshot.index.graph.metric
        raise TypeError(f"cannot resolve metric for {type(index).__name__}")

    # --- evaluation -------------------------------------------------------
    def _session(self, spec: SearchSpec):
        key = spec.canonical()
        sess = self._sessions.get(key)
        if sess is None:
            sess = self._sessions[key] = make_session(self.index, spec)
        return sess

    def evaluate(self, spec: SearchSpec, replays: int = 1
                 ) -> ProbeMeasurement:
        """Replay the probe set through ``spec``; exact recall + latency.

        The first (untimed) replay absorbs the one-off XLA compile for the
        probe bucket shape; ``replays`` timed replays follow and the
        median is reported.  Failpoint site ``autotune.probe``.
        """
        from repro.autotune.space import spec_key

        fault.hit("autotune.probe")
        sess = self._session(spec)
        k = min(self.k, sess.spec.efs)
        all_ids, lats, calls = None, [], []
        for r in range(max(1, int(replays)) + 1):
            ids_parts = []
            t0 = time.perf_counter()
            for lo in range(0, self.queries.shape[0], self.bucket):
                q = self.queries[lo:lo + self.bucket]
                qp, _ = pad_to_bucket(q, self.bucket)
                ids, _, stats = sess.search_padded(
                    qp, q.shape[0], k, sess.spec.cos_theta)
                ids_parts.append(ids)
                if r == 0:
                    calls.append(float(np.mean(stats.dist_calls)))
            if r == 0:            # untimed: eats the compile
                all_ids = np.concatenate(ids_parts, axis=0)
                continue
            lats.append(time.perf_counter() - t0)
        rec = recall_at_k(all_ids, self.gt[:, :k], k)
        return ProbeMeasurement(
            key=spec_key(spec), recall=float(rec),
            lat_s=float(np.median(lats)),
            dist_calls=float(np.mean(calls)), replays=len(lats))

"""Failure domains: failpoints, retry policy, typed degradation errors.

Public surface (DESIGN.md §10)::

    from repro import fault

    fault.arm("serve.dispatch", kind="raise", hits={3})
    fault.disarm()                       # everything off; hit() is free
    with fault.scoped({"shard.search.1": fault.FaultSpec(p=0.3, seed=7)}):
        ...                              # seeded chaos schedule

    policy = fault.RetryPolicy(max_attempts=6, base_s=0.01, cap_s=0.5)
    fut = policy.call(frontend.submit, queries, retry_on=QueueFull)
"""
from repro.fault.errors import (CorruptIndexError, DegradedSearchError,
                                MergeQuarantinedError)
from repro.fault.failpoints import (FaultInjected, FaultSpec, arm, disarm,
                                    fires, hit, scoped, snapshot)
from repro.fault.retry import RetryPolicy

__all__ = [
    "FaultInjected", "FaultSpec", "arm", "disarm", "fires", "hit",
    "scoped", "snapshot",
    "RetryPolicy",
    "CorruptIndexError", "DegradedSearchError", "MergeQuarantinedError",
]

"""Cell builder: (architecture x input-shape) -> lowerable step + specs.

The dry-run (launch/dryrun.py) and roofline analysis consume Cells; smoke
tests consume build_smoke().  Everything here is ShapeDtypeStruct-based — no
parameter allocation for the full configs (DESIGN.md deliverable (f))."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ArchSpec, ShapeSpec
from repro.launch import sharding as SH
from repro.launch.mesh import data_axes
from repro.train import optimizer as opt


@dataclasses.dataclass
class Cell:
    arch_id: str
    shape_id: str
    step_name: str
    step_fn: Callable
    arg_specs: Tuple
    in_shardings: Any
    out_shardings: Any       # None -> let XLA choose
    model_flops: float       # "useful" flops (6·N·D convention; §Roofline)
    notes: str = ""
    static_argnums: Tuple[int, ...] = ()
    # --- loop-corrected accounting (EXPERIMENTS.md §Roofline methodology):
    # XLA cost_analysis counts each while/scan body ONCE.  For layer-scanned
    # models, `loop_fit` provides (L, build(L) -> Cell) so the dry-run can
    # 2-point-fit the per-layer body cost; `analytic_extra` adds the
    # statically-known inner-scan (attention tiles / loss chunks) shortfall;
    # `body_multiplier` scales all terms for data-dependent while loops
    # (the ANNS best-first search: one body execution == one hop).
    loop_fit: Optional[Tuple[int, Callable]] = None
    analytic_extra: Optional[Dict[str, float]] = None   # per-device adds
    body_multiplier: float = 1.0


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


OCFG = opt.AdamWConfig()
OCFG_BF16 = opt.AdamWConfig(state_dtype="bfloat16")


# ==========================================================================
# LM family
# ==========================================================================
def _lm_analytic_extra(cfg, B, S, mesh, train: bool) -> Dict[str, float]:
    """Per-device flops/bytes the compiled cost analysis misses because the
    attention tile scans and loss-chunk scan are while loops (bodies counted
    once).  Formulas documented in EXPERIMENTS.md §Roofline methodology."""
    H, dh, D = cfg.n_heads, cfg.dh, cfg.d_model
    L = cfg.n_layers
    n_dev = int(np.prod([mesh.shape[a] for a in mesh.axis_names]))
    dp = n_dev // mesh.shape.get("model", 1)
    # attention work replicates over 'model' when H doesn't shard evenly
    attn_div = n_dev if H % mesh.shape.get("model", 1) == 0 else dp
    nq = max(S // min(cfg.block_q, S), 1)
    nk = max(S // min(cfg.block_k, S), 1)
    miss = 1.0 - 1.0 / (nq * nk)
    pass_f = 4.5 if train else 1.0     # fwd + remat-recompute + flash bwd
    attn_flops = pass_f * 4.0 * B * H * S * S * dh * L * miss / attn_div
    attn_bytes = (3.0 if train else 1.0) * nq * nk * B * H * dh \
        * (min(cfg.block_q, S) + 2 * min(cfg.block_k, S)) * 4.0 * L \
        * miss / attn_div
    out = {"flops": attn_flops, "bytes": attn_bytes}
    if train:
        nc = max(S // min(cfg.loss_chunk, S), 1)
        mc = 1.0 - 1.0 / nc
        V = cfg.padded_vocab
        out["flops"] += mc * 6.0 * B * S * D * V / n_dev
        out["bytes"] += mc * nc * (D * V * 2.0 + B * (S // nc) * V * 4.0) \
            * 2.0 / n_dev
    return out


def _lm_cell(spec: ArchSpec, shape: ShapeSpec, mesh, _cfg_override=None) -> Cell:
    from repro.models import transformer as T

    cfg = _cfg_override or spec.model_cfg
    B, S = shape.dims["global_batch"], shape.dims["seq_len"]
    ocfg = OCFG_BF16 if cfg.param_count() > 1e11 else OCFG
    params_spec = jax.eval_shape(lambda: T.init_params(cfg, jax.random.PRNGKey(0)))
    p_sh = SH.lm_param_sharding(mesh, params_spec)
    n_act = cfg.active_param_count()

    def fit_builder(L):
        sub = dataclasses.replace(cfg, n_layers=L, unroll_layers=True)
        return _lm_cell(spec, shape, mesh, _cfg_override=sub)

    loop_fit = None if _cfg_override else (cfg.n_layers, fit_builder)

    if shape.step == "train":
        opt_spec = jax.eval_shape(lambda: opt.adamw_init(params_spec, ocfg))
        o_sh = SH.lm_opt_sharding(mesh, opt_spec, p_sh)
        b_sh = SH.lm_batch_sharding(mesh)
        batch_spec = {"tokens": _sds((B, S), jnp.int32),
                      "labels": _sds((B, S), jnp.int32)}
        return Cell(spec.arch_id, shape.shape_id, "train_step",
                    T.make_train_step(cfg, ocfg),
                    (params_spec, opt_spec, batch_spec),
                    (p_sh, o_sh, b_sh), (p_sh, o_sh, None),
                    model_flops=6.0 * n_act * B * S,
                    notes=f"N_active={n_act:.3e}",
                    loop_fit=loop_fit,
                    analytic_extra=_lm_analytic_extra(cfg, B, S, mesh, True))

    if shape.step == "prefill":
        tok_sh = SH.lm_token_sharding(mesh, B)
        return Cell(spec.arch_id, shape.shape_id, "prefill_step",
                    T.make_prefill_step(cfg),
                    (params_spec, _sds((B, S), jnp.int32)),
                    (p_sh, tok_sh), None,
                    model_flops=2.0 * n_act * B * S,
                    loop_fit=loop_fit,
                    analytic_extra=_lm_analytic_extra(cfg, B, S, mesh, False))

    # serve: one-token decode against a KV cache of length S
    cache_spec = {
        "k": _sds((cfg.n_layers, B, S, cfg.n_kv_heads, cfg.dh), cfg.dtype),
        "v": _sds((cfg.n_layers, B, S, cfg.n_kv_heads, cfg.dh), cfg.dtype),
    }
    c_sh = SH.lm_cache_sharding(mesh, B, S)
    tok_sh = SH.lm_token_sharding(mesh, B)
    attn_flops = 4.0 * B * cfg.n_layers * cfg.n_heads * cfg.dh * S
    return Cell(spec.arch_id, shape.shape_id, "serve_step",
                T.make_serve_step(cfg),
                (params_spec, cache_spec, _sds((B, 1), jnp.int32),
                 _sds((), jnp.int32)),
                (p_sh, c_sh, tok_sh, SH._ns(mesh)), None,
                model_flops=2.0 * n_act * B + attn_flops,
                notes="decode; KV " + ("seq-sharded" if B == 1 else "batch-sharded"),
                loop_fit=loop_fit)


# ==========================================================================
# GNN family
# ==========================================================================
def _pad512(x: int) -> int:
    """Pad node/edge counts to a multiple of 512 (= the largest device count)
    so the arrays shard evenly; pad entries carry zero masks (DESIGN.md §7)."""
    return -(-x // 512) * 512


def _gnn_dims(shape: ShapeSpec):
    d = shape.dims
    if shape.shape_id == "minibatch_lg":
        n, e = d["sub_nodes"], d["sub_edges"]
        f, c, g = d["d_feat"], d.get("n_classes", 16), 1
    elif shape.shape_id == "molecule":
        n, e = d["n_nodes"] * d["batch"], d["n_edges"] * d["batch"]
        f, c, g = d["d_feat"], 16, d["batch"]
    else:
        n, e = d["n_nodes"], d["n_edges"]
        f, c, g = d["d_feat"], d.get("n_classes", 16), 1
    return _pad512(n), _pad512(e), f, c, g


def _gnn_batch_spec(n, e, f, g, task):
    sp = {
        "node_feat": _sds((n, f), jnp.float32),
        "pos": _sds((n, 3), jnp.float32),
        "atom_z": _sds((n,), jnp.int32),
        "edge_src": _sds((e,), jnp.int32),
        "edge_dst": _sds((e,), jnp.int32),
        "node_mask": _sds((n,), jnp.float32),
        "edge_mask": _sds((e,), jnp.float32),
        "labels": _sds((n,), jnp.int32),
        "label_mask": _sds((n,), jnp.float32),
        "graph_ids": _sds((n,), jnp.int32),
        "g_labels": _sds((g,), jnp.int32 if task == "graph_class" else jnp.float32),
    }
    return sp


def _gnn_flops(cfg, n, e, f):
    d = cfg.d_hidden
    if cfg.arch == "gin":
        return cfg.n_layers * (2 * e * d + 4 * n * d * d) + 2 * n * f * d
    if cfg.arch == "gat":
        h = cfg.n_heads
        return cfg.n_layers * (2 * n * f * h * d + 4 * e * h * d)
    if cfg.arch == "schnet":
        return cfg.n_layers * (2 * e * (cfg.n_rbf * d + d * d) + 4 * n * d * d)
    if cfg.arch == "egnn":
        return cfg.n_layers * (2 * e * (2 * d + 1) * d + 2 * e * d * d
                               + 4 * n * d * d)
    return 2.0 * e * d


def _gnn_cell(spec: ArchSpec, shape: ShapeSpec, mesh) -> Cell:
    from repro.models import gnn as G

    n, e, f, ncls, g = _gnn_dims(shape)
    task = spec.model_cfg.task
    if shape.shape_id == "molecule" and task == "node_class":
        task = "graph_class"
    cfg = dataclasses.replace(spec.model_cfg, n_classes=ncls, task=task)
    params_spec = jax.eval_shape(lambda: G.init_gnn(cfg, f, jax.random.PRNGKey(0)))
    p_sh = SH.gnn_param_sharding(mesh, params_spec)
    batch_spec = _gnn_batch_spec(n, e, f, g, task)
    b_sh = SH.gnn_batch_sharding(mesh, batch_spec)
    flops = _gnn_flops(cfg, n, e, f)

    opt_spec = jax.eval_shape(lambda: opt.adamw_init(params_spec, OCFG))
    o_sh = SH.gnn_param_sharding(mesh, opt_spec)
    return Cell(spec.arch_id, shape.shape_id, "train_step",
                G.make_gnn_train_step(cfg, OCFG),
                (params_spec, opt_spec, batch_spec),
                (p_sh, o_sh, b_sh), (p_sh, o_sh, None),
                model_flops=3.0 * flops,
                notes=f"task={task} n={n} e={e}")


# ==========================================================================
# RecSys family
# ==========================================================================
def _dlrm_cell(spec: ArchSpec, shape: ShapeSpec, mesh) -> Cell:
    from repro.models import dlrm as R

    cfg = spec.model_cfg
    params_spec = jax.eval_shape(lambda: R.init_dlrm(cfg, jax.random.PRNGKey(0)))
    p_sh = SH.dlrm_param_sharding(mesh, params_spec)

    if shape.step == "retrieval":
        Bq, Nc = shape.dims["batch"], shape.dims["n_candidates"]
        d = cfg.embed_dim
        # 1e6 rows shard evenly over the data axes (16 / 32), not over model
        cand_sh = SH._ns(mesh, data_axes(mesh), None)
        return Cell(spec.arch_id, shape.shape_id, "retrieval_step",
                    R.make_retrieval_step(cfg),
                    (_sds((Bq, d), jnp.float32), _sds((Nc, d), jnp.float32)),
                    (SH._ns(mesh, None, None), cand_sh), None,
                    model_flops=2.0 * Bq * Nc * d,
                    notes="brute-force scorer; CRouting-ANN variant in examples")

    B = shape.dims["batch"]
    mlp_flops = 0
    dims = (cfg.n_dense,) + cfg.bot_mlp
    mlp_flops += sum(2 * a * b for a, b in zip(dims[:-1], dims[1:]))
    n_int = cfg.n_sparse + 1
    dims = (n_int * (n_int - 1) // 2 + cfg.embed_dim,) + cfg.top_mlp
    mlp_flops += sum(2 * a * b for a, b in zip(dims[:-1], dims[1:]))
    mlp_flops += 2 * n_int * n_int * cfg.embed_dim
    batch_spec = {"dense": _sds((B, cfg.n_dense), jnp.float32),
                  "sparse_ids": _sds((B, cfg.n_sparse), jnp.int32),
                  "labels": _sds((B,), jnp.float32)}
    b_sh = SH.dlrm_batch_sharding(mesh, B)

    if shape.step == "train":
        opt_spec = jax.eval_shape(lambda: opt.adamw_init(params_spec, OCFG))
        o_sh = SH.dlrm_param_sharding(mesh, opt_spec)
        return Cell(spec.arch_id, shape.shape_id, "train_step",
                    R.make_dlrm_train_step(cfg, OCFG),
                    (params_spec, opt_spec, batch_spec),
                    (p_sh, o_sh, b_sh), (p_sh, o_sh, None),
                    model_flops=3.0 * B * mlp_flops)

    del batch_spec["labels"]
    del b_sh["labels"]
    return Cell(spec.arch_id, shape.shape_id, "serve_step",
                R.make_dlrm_serve_step(cfg),
                (params_spec, batch_spec), (p_sh, b_sh), None,
                model_flops=1.0 * B * mlp_flops)


# ==========================================================================
# ANNS family (the paper's own system)
# ==========================================================================
def _anns_cell(spec: ArchSpec, shape: ShapeSpec, mesh) -> Cell:
    from repro.core.sharded_index import make_serve_step
    from repro.core.spec import SearchSpec

    d = shape.dims
    n_shards = int(np.prod([mesh.shape[a] for a in mesh.axis_names]))
    ns = -(-d["n_total"] // n_shards)
    m = d["max_degree"]
    dim, B, efs, k = d["dim"], d["batch"], d["efs"], d["k"]
    cfg = SearchSpec(efs=efs, k=k, router=spec.model_cfg.router, metric="l2",
                     max_hops=2 * efs, use_hierarchy=False)
    # k is request-only: the step merges efs-wide and the host slices to k
    serve, in_sh, out_sh = make_serve_step(mesh, cfg.canonical(), ns)
    vdt = jnp.dtype(getattr(spec.model_cfg, "vec_dtype", "float32"))
    arg_specs = (
        _sds((n_shards, ns + 1, dim), vdt),
        _sds((n_shards, ns + 1, m), jnp.int32),
        _sds((n_shards, ns + 1, m), vdt),      # stored edge dists follow
        _sds((n_shards, ns + 1), jnp.float32),
        _sds((n_shards,), jnp.int32),
        _sds((n_shards,), jnp.int32),
        _sds((n_shards, ns + 1, dim), jnp.uint8),   # SQ8 code table
        _sds((n_shards, dim), jnp.float32),         # SQ8 grid lo
        _sds((n_shards, dim), jnp.float32),         # SQ8 grid scale
        _sds((n_shards, dim), jnp.float32),         # SQ8 error radius
        _sds((B, dim), jnp.float32),
        _sds((), jnp.float32),
        _sds((B,), jnp.bool_),                      # bucket-pad valid mask
    )
    # useful work ~ exact distance evals: efs expansions x m neighbors x 2d
    flops = 2.0 * B * efs * m * dim
    # the best-first while body == ONE expansion (hop) across all query
    # lanes; empirical hops/query ~= 1.5*efs (benchmarks/bench_paper.py)
    hops = 1.5 * efs
    return Cell(spec.arch_id, shape.shape_id, "anns_serve_step", serve,
                arg_specs, in_sh, out_sh, model_flops=flops,
                notes=f"shards={n_shards} ns={ns} router={spec.model_cfg.router} "
                      f"hop_multiplier={hops:.0f}",
                body_multiplier=hops)


# ==========================================================================
# public API
# ==========================================================================
_BUILDERS = {"lm": _lm_cell, "gnn": _gnn_cell, "recsys": _dlrm_cell,
             "anns": _anns_cell}


def build_cell(spec: ArchSpec, shape_id: str, mesh) -> Cell:
    return _BUILDERS[spec.family](spec, spec.shape(shape_id), mesh)


# --------------------------------------------------------------------------
# smoke builders: reduced config + real (tiny) data, runs on one CPU device
# --------------------------------------------------------------------------
def build_smoke(spec: ArchSpec, seed: int = 0):
    """Returns (run_fn, metrics_keys): run_fn() executes one reduced-config
    step on CPU and returns a dict of outputs for assertions."""
    from repro.data import synthetic as syn

    if spec.family == "lm":
        from repro.models import transformer as T
        cfg = spec.smoke_cfg
        key = jax.random.PRNGKey(seed)
        params = T.init_params(cfg, key)
        ocfg = opt.AdamWConfig(lr=1e-3)
        state = opt.adamw_init(params, ocfg)
        batch = jax.tree_util.tree_map(
            jnp.asarray, syn.lm_batch(cfg.vocab, 2, 32, seed))
        ts = jax.jit(T.make_train_step(cfg, ocfg))

        def run():
            p2, s2, m = ts(params, state, batch)
            # one decode step too
            sv = jax.jit(T.make_serve_step(cfg))
            cache = {
                "k": jnp.zeros((cfg.n_layers, 2, 16, cfg.n_kv_heads, cfg.dh), cfg.dtype),
                "v": jnp.zeros((cfg.n_layers, 2, 16, cfg.n_kv_heads, cfg.dh), cfg.dtype),
            }
            logits, _ = sv(p2, cache, batch["tokens"][:, :1], jnp.asarray(0, jnp.int32))
            return {"loss": m["loss"], "logits": logits}
        return run

    if spec.family == "gnn":
        from repro.models import gnn as G
        cfg = spec.smoke_cfg
        task = cfg.task
        b = syn.random_graph_batch(64, 256, 8, cfg.n_classes, n_graphs=4,
                                   seed=seed, task=task)
        b = jax.tree_util.tree_map(jnp.asarray, b)
        params = G.init_gnn(cfg, 8, jax.random.PRNGKey(seed))
        state = opt.adamw_init(params, OCFG)
        ts = jax.jit(G.make_gnn_train_step(cfg, OCFG))

        def run():
            _, _, m = ts(params, state, b)
            out = G.gnn_forward(params, b, cfg)
            return {"loss": m["loss"], "out": out}
        return run

    if spec.family == "recsys":
        from repro.models import dlrm as R
        cfg = spec.smoke_cfg
        params = R.init_dlrm(cfg, jax.random.PRNGKey(seed))
        state = opt.adamw_init(params, OCFG)
        b = jax.tree_util.tree_map(
            jnp.asarray, syn.dlrm_batch(cfg.n_dense, cfg.table_rows(), 64, seed))
        ts = jax.jit(R.make_dlrm_train_step(cfg, OCFG))

        def run():
            _, _, m = ts(params, state, b)
            scores = R.make_dlrm_serve_step(cfg)(params,
                                                 {k: b[k] for k in ("dense", "sparse_ids")})
            return {"loss": m["loss"], "out": scores}
        return run

    # anns
    from repro.core.index import AnnIndex
    from repro.core.spec import SearchSpec
    from repro.data.vectors import make_dataset

    def run():
        ds = make_dataset(n_base=600, n_query=8, dim=32, n_clusters=8, seed=seed)
        idx = AnnIndex.build(ds.base, graph=spec.smoke_cfg.graph,
                             m=spec.smoke_cfg.m, efc=spec.smoke_cfg.efc)
        ids, dists, stats = idx.search(
            ds.queries, spec=SearchSpec(k=5, efs=32,
                                        router=spec.smoke_cfg.router))
        return {"loss": jnp.asarray(0.0), "out": jnp.asarray(dists),
                "ids": ids, "dist_calls": stats.dist_calls}
    return run

"""Decoder-only LM family (dense + MoE) with scan-over-layers.

Covers the five assigned LM architectures (granite-8b, phi4-mini-3.8b,
qwen1.5-4b, granite-moe-1b-a400m, arctic-480b).  Parameters are stacked over
the layer axis and the forward pass is one `lax.scan` (+ per-step remat) so
the HLO stays small enough to compile 36-layer × 512-device programs on the
CPU dry-run host.

Steps exposed (launch/dryrun.py lowers these):
  train_step    causal-LM loss + AdamW update (train_* shapes)
  prefill_step  full-sequence forward that also emits the KV cache (prefill_*)
  serve_step    one-token decode against a KV cache (decode_* / long_*)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L
from repro.train import optimizer as opt


@dataclasses.dataclass(frozen=True)
class MoeSpec:
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25
    dense_residual: bool = False   # arctic: dense FFN in parallel with MoE


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    qkv_bias: bool = False
    moe: Optional[MoeSpec] = None
    rope_theta: float = 10000.0
    dtype: str = "bfloat16"
    remat: bool = True
    block_q: int = 256
    block_k: int = 1024
    loss_chunk: int = 512
    # dry-run accounting only: unroll the layer scan so XLA cost_analysis
    # sees every layer (while bodies are counted once; EXPERIMENTS.md
    # §Roofline methodology). Never set for real training (compile time).
    unroll_layers: bool = False

    @property
    def dh(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded to 128 so embed/lm_head shard over 'model' (=16);
        loss masks the pad columns (granite-moe's 49155 -> 49280)."""
        return -(-self.vocab // 128) * 128

    def param_count(self) -> int:
        D, F, V, H, Hkv, dh = (self.d_model, self.d_ff, self.vocab,
                               self.n_heads, self.n_kv_heads, self.dh)
        attn = D * (H + 2 * Hkv) * dh + H * dh * D
        if self.moe:
            ffn = self.moe.n_experts * 3 * D * F + D * self.moe.n_experts
            if self.moe.dense_residual:
                ffn += 3 * D * F
        else:
            ffn = 3 * D * F
        per_layer = attn + ffn + 2 * D
        return self.n_layers * per_layer + 2 * V * D + D

    def active_param_count(self) -> int:
        """Activated params per token (MoE: top_k of E experts)."""
        if not self.moe:
            return self.param_count()
        D, F = self.d_model, self.d_ff
        dense = self.param_count() - self.n_layers * self.moe.n_experts * 3 * D * F
        act = self.n_layers * self.moe.top_k * 3 * D * F
        return dense + act


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------
def init_params(cfg: LMConfig, key) -> Dict[str, Any]:
    dt = jnp.dtype(cfg.dtype)
    D, F, V = cfg.d_model, cfg.d_ff, cfg.padded_vocab
    H, Hkv, dh, Ln = cfg.n_heads, cfg.n_kv_heads, cfg.dh, cfg.n_layers
    ks = jax.random.split(key, 16)

    def w(k, shape, scale=1.0):
        return L.dense_init(k, shape, dt, scale).astype(dt)

    layer = {
        "wq": w(ks[0], (Ln, D, H * dh)),
        "wk": w(ks[1], (Ln, D, Hkv * dh)),
        "wv": w(ks[2], (Ln, D, Hkv * dh)),
        "wo": w(ks[3], (Ln, H * dh, D)),
        "norm1": jnp.ones((Ln, D), dt),
        "norm2": jnp.ones((Ln, D), dt),
    }
    if cfg.qkv_bias:
        layer["bq"] = jnp.zeros((Ln, H * dh), dt)
        layer["bk"] = jnp.zeros((Ln, Hkv * dh), dt)
        layer["bv"] = jnp.zeros((Ln, Hkv * dh), dt)
    if cfg.moe:
        E = cfg.moe.n_experts
        layer["gate"] = w(ks[4], (Ln, D, E))
        layer["we_gate"] = w(ks[5], (Ln, E, D, F))
        layer["we_up"] = w(ks[6], (Ln, E, D, F))
        layer["we_down"] = w(ks[7], (Ln, E, F, D))
        if cfg.moe.dense_residual:
            layer["wr_gate"] = w(ks[8], (Ln, D, F))
            layer["wr_up"] = w(ks[9], (Ln, D, F))
            layer["wr_down"] = w(ks[10], (Ln, F, D))
    else:
        layer["w_gate"] = w(ks[4], (Ln, D, F))
        layer["w_up"] = w(ks[5], (Ln, D, F))
        layer["w_down"] = w(ks[6], (Ln, F, D))
    return {
        "embed": w(ks[11], (V, D), scale=np.sqrt(D)),  # ~N(0,1) rows
        "layers": layer,
        "final_norm": jnp.ones((D,), dt),
        "lm_head": w(ks[12], (D, V)),
    }


# --------------------------------------------------------------------------
# one transformer block (operating on a single layer's stacked slice)
# --------------------------------------------------------------------------
def _attn(x, lp, cfg: LMConfig, positions, kv_cache=None, kv_mask=None,
          cache_pos=None):
    """Returns (attn_out, (k, v)).  Training/prefill: k/v are the fresh
    per-layer cache slices.  Decode: kv_cache is updated in place at
    cache_pos *before* attending, so the token attends to itself."""
    B, S, D = x.shape
    H, Hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.dh
    q = x @ lp["wq"]
    k = x @ lp["wk"]
    v = x @ lp["wv"]
    if cfg.qkv_bias:
        q, k, v = q + lp["bq"], k + lp["bk"], v + lp["bv"]
    q = q.reshape(B, S, H, dh)
    k = k.reshape(B, S, Hkv, dh)
    v = v.reshape(B, S, Hkv, dh)
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    if kv_cache is None:
        o = L.blockwise_causal_attention(q, k, v, block_q=cfg.block_q,
                                         block_k=cfg.block_k)
        out_kv = (L.shard_hint(k, L.BATCH_AXES, "model", None, None),
                  L.shard_hint(v, L.BATCH_AXES, "model", None, None))
    else:
        kc, vc = kv_cache   # [B, T, Hkv, dh]
        kc = jax.lax.dynamic_update_slice(kc, k, (0, cache_pos, 0, 0))
        vc = jax.lax.dynamic_update_slice(vc, v, (0, cache_pos, 0, 0))
        o = L.decode_attention(q, kc, vc, kv_mask)
        out_kv = (kc, vc)
    return o.reshape(B, S, H * dh) @ lp["wo"], out_kv


def _ffn(x, lp, cfg: LMConfig):
    B, S, D = x.shape
    if cfg.moe:
        m = cfg.moe
        y = L.moe_layer(x.reshape(B * S, D), lp["gate"], lp["we_gate"],
                        lp["we_up"], lp["we_down"],
                        L.MoeConfig(m.n_experts, m.top_k, m.capacity_factor))
        y = y.reshape(B, S, D)
        if m.dense_residual:
            y = y + L.swiglu(x, lp["wr_gate"], lp["wr_up"], lp["wr_down"])
        return y
    return L.swiglu(x, lp["w_gate"], lp["w_up"], lp["w_down"])


def _block(x, lp, cfg: LMConfig, positions, kv_cache=None, kv_mask=None,
           cache_pos=None):
    a, kv = _attn(L.rms_norm(x, lp["norm1"]), lp, cfg, positions, kv_cache,
                  kv_mask, cache_pos)
    x = x + a
    x = x + _ffn(L.rms_norm(x, lp["norm2"]), lp, cfg)
    return x, kv


# --------------------------------------------------------------------------
# forward passes
# --------------------------------------------------------------------------
def forward(params, tokens, cfg: LMConfig, collect_cache: bool = False):
    """tokens [B, S] -> hidden [B, S, D] (and stacked KV cache if asked)."""
    B, S = tokens.shape
    x = params["embed"][tokens]
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))

    def step(x, lp):
        # carry (= the remat-saved residual stack) lives batch- AND
        # sequence-sharded: Megatron-SP layout, [L,B,S,D]/(data*model) per dev
        x = L.shard_hint(x, L.BATCH_AXES, "model", None)
        f = functools.partial(_block, cfg=cfg, positions=positions)
        if cfg.remat:
            f = jax.checkpoint(f)
        x, kv = f(x, lp)
        return x, kv if collect_cache else 0.0

    x, caches = jax.lax.scan(step, x, params["layers"],
                             unroll=cfg.n_layers if cfg.unroll_layers else 1)
    x = L.rms_norm(x, params["final_norm"])
    return (x, caches) if collect_cache else x


def chunked_ce_loss(h, lm_head, labels, chunk: int, vocab: int):
    """Sequence-chunked causal-LM cross entropy (never materializes [B,S,V]);
    pad-vocab columns are masked out of the logsumexp."""
    B, S, D = h.shape
    chunk = min(chunk, S)
    nc = S // chunk
    hc = jnp.moveaxis(h.reshape(B, nc, chunk, D), 1, 0)
    lc = jnp.moveaxis(labels.reshape(B, nc, chunk), 1, 0)
    v_pad = lm_head.shape[1]
    col_ok = (jnp.arange(v_pad) < vocab) if v_pad != vocab else None

    def per_chunk(acc, inp):
        hb, lb = inp
        logits = (hb @ lm_head).astype(jnp.float32)        # [B, chunk, Vpad]
        if col_ok is not None:
            logits = jnp.where(col_ok, logits, -1e30)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lb[..., None], axis=-1)[..., 0]
        return acc + jnp.sum(lse - gold), 0.0

    total, _ = jax.lax.scan(per_chunk, jnp.zeros((), jnp.float32), (hc, lc))
    return total / (B * S)


def loss_fn(params, batch, cfg: LMConfig):
    h = forward(params, batch["tokens"], cfg)
    return chunked_ce_loss(h, params["lm_head"], batch["labels"],
                           cfg.loss_chunk, cfg.vocab)


def make_train_step(cfg: LMConfig, ocfg: opt.AdamWConfig):
    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch, cfg)
        new_params, new_state, metrics = opt.adamw_update(grads, opt_state, params, ocfg)
        metrics["loss"] = loss
        return new_params, new_state, metrics
    return train_step


def make_prefill_step(cfg: LMConfig):
    def prefill_step(params, tokens):
        h, caches = forward(params, tokens, cfg, collect_cache=True)
        logits = (h[:, -1, :] @ params["lm_head"]).astype(jnp.float32)[:, :cfg.vocab]
        kc, vc = caches     # each [L, B, S, Hkv, dh]
        return logits, {"k": kc, "v": vc}
    return prefill_step


def make_serve_step(cfg: LMConfig):
    """One-token decode. cache k/v: [L, B, T, Hkv, dh]; cur_len scalar."""

    def serve_step(params, cache, token, cur_len):
        B = token.shape[0]
        x = params["embed"][token]                         # [B, 1, D]
        positions = jnp.full((B, 1), cur_len, jnp.int32)
        T = cache["k"].shape[2]
        kv_mask = (jnp.arange(T) <= cur_len)[None, :].repeat(B, 0)

        def step(x, inp):
            lp, kc, vc = inp
            x, (kc, vc) = _block(x, lp, cfg, positions, kv_cache=(kc, vc),
                                 kv_mask=kv_mask, cache_pos=cur_len)
            return x, (kc, vc)

        x, (kc, vc) = jax.lax.scan(step, x, (params["layers"], cache["k"], cache["v"]),
                                   unroll=cfg.n_layers if cfg.unroll_layers else 1)
        x = L.rms_norm(x, params["final_norm"])
        logits = (x[:, 0, :] @ params["lm_head"]).astype(jnp.float32)[:, :cfg.vocab]
        return logits, {"k": kc, "v": vc}

    return serve_step

"""§Roofline reporting: read dryrun_results.json, print the per-cell table
(three terms, dominant bottleneck, model-flop ratio) for EXPERIMENTS.md."""
from __future__ import annotations

import json
import os

from benchmarks.common import emit

RESULTS = os.path.join(os.path.dirname(__file__), "..", "dryrun_results.json")


def roofline_table(mesh: str = "16x16"):
    if not os.path.exists(RESULTS):
        emit("roofline_table", 0.0, {"error": "run repro.launch.dryrun first"})
        return {}
    cache = json.load(open(RESULTS))
    rows = []
    for key, r in sorted(cache.items()):
        if r.get("status") != "ok" or r.get("mesh") != mesh:
            continue
        rows.append({
            "cell": f"{r['arch']}/{r['shape']}",
            "step": r["step"],
            "compute_s": f"{r['compute_s']:.3e}",
            "memory_s": f"{r['memory_s']:.3e}",
            "collective_s": f"{r['collective_s']:.3e}",
            "dominant": r["dominant"].replace("_s", ""),
            "useful_flop_ratio": round(r.get("useful_flop_ratio", 0.0), 3),
            "mfu_ub": round(r.get("mfu_upper_bound", 0.0), 3),
            "mem_gb": round(r["mem_total_bytes"] / 1e9, 2),
        })
    dom_counts = {}
    for row in rows:
        dom_counts[row["dominant"]] = dom_counts.get(row["dominant"], 0) + 1
    emit("roofline_table", 0.0, {"mesh": mesh, "cells": len(rows),
                                 "dominant_counts": dom_counts})
    for row in rows:
        print("  " + json.dumps(row))
    return rows

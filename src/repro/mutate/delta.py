"""The delta segment: where freshly-inserted vectors live before a merge.

``DeltaSegment`` is a fixed-capacity, padded, brute-force-scanned side
table (DESIGN.md §9).  New vectors do NOT enter the main graph — linking
into an NSG/HNSW is expensive and would mutate arrays jitted engines close
over — they land in the next free slot here, and every search scans the
segment with one jitted kernel whose shapes never change:

* the vector table is always ``[capacity, d]`` (empty slots hold zeros and
  are masked by ``live``), so the scan compiles ONCE per (batch shape,
  capacity, metric) and a fill-level change never re-traces;
* distances use the same ranking convention as the graph engine (l2:
  squared Euclidean; ip/cosine: ``1 - <q, x>``), so the host-side top-k
  merge with the graph pool compares like with like;
* the segment is IMMUTABLE (copy-on-write): ``insert``/``delete`` return a
  new ``DeltaSegment`` sharing nothing mutable with the old one, which is
  what lets ``MutableAnnIndex.search`` grab a consistent (snapshot, delta)
  state with one reference read and no lock on the query path.

Quantized scan (``use_sq8=True``, mirroring ``ensure_sq8_arrays``): the
segment lazily encodes itself to SQ8 codes on first use; stage 1 scans the
dequantized codes, stage 2 exactly re-ranks only the top
``max(32, 4k)`` candidates host-side.  For the segment's size (hundreds to
a few thousand rows) this is about bandwidth parity with the graph
engine's two-stage path, not a win — it exists so a ``SearchSpec`` with
``estimate="sq8"`` keeps one storage story across graph and delta.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.quant import sq8 as SQ


@partial(jax.jit, static_argnames=("metric",))
def _scan_dists(vectors, live, queries, metric):
    """Ranking distances of every query to every segment slot.

    vectors [cap, d], live [cap] bool, queries [B, d] -> [B, cap] f32 with
    dead/empty slots at +inf.  Fixed shapes: fill level is data, not shape.
    """
    if metric == "l2":
        diff = queries[:, None, :] - vectors[None, :, :]
        d = jnp.sum(diff * diff, axis=-1)
    else:
        d = 1.0 - queries @ vectors.T
    return jnp.where(live[None, :], d, jnp.inf)


@partial(jax.jit, static_argnames=("metric",))
def _scan_dists_sq8(codes, lo, scale, live, queries, metric):
    """Stage-1 approximate ranking distances over the uint8 codes."""
    xhat = SQ.sq8_dequantize_rows(codes, lo, scale)        # [cap, d]
    if metric == "l2":
        diff = queries[:, None, :] - xhat[None, :, :]
        d = jnp.sum(diff * diff, axis=-1)
    else:
        d = 1.0 - queries @ xhat.T
    return jnp.where(live[None, :], d, jnp.inf)


def delta_scan_compile_count() -> int:
    """Total executables behind the jitted scan kernels (all shapes/metrics).

    Feeds ``MutableAnnIndex.compile_count`` so a delta-scan compile on the
    request path is just as visible to serving telemetry as an engine one.
    """
    return _scan_dists._cache_size() + _scan_dists_sq8._cache_size()


@dataclasses.dataclass(frozen=True)
class DeltaSegment:
    """Immutable fixed-capacity segment of freshly-inserted vectors."""

    vectors: np.ndarray      # [capacity, d] f32, preprocessed; empty = 0
    ext_ids: np.ndarray      # [capacity] int64 external ids; -1 = empty slot
    live: np.ndarray         # [capacity] bool; False = empty OR deleted
    count: int               # high-water mark (slots [0, count) were used)
    metric: str

    @classmethod
    def empty(cls, capacity: int, dim: int, metric: str) -> "DeltaSegment":
        assert capacity >= 1, "delta capacity must be >= 1"
        return cls(vectors=np.zeros((capacity, dim), np.float32),
                   ext_ids=np.full((capacity,), -1, np.int64),
                   live=np.zeros((capacity,), bool),
                   count=0, metric=metric)

    @property
    def capacity(self) -> int:
        return self.vectors.shape[0]

    @property
    def dim(self) -> int:
        return self.vectors.shape[1]

    @property
    def n_live(self) -> int:
        return int(self.live.sum())

    @property
    def room(self) -> int:
        return self.capacity - self.count

    def insert(self, vectors: np.ndarray, ext_ids: np.ndarray
               ) -> "DeltaSegment":
        """Append rows (already preprocessed for ``metric``); copy-on-write."""
        vectors = np.asarray(vectors, np.float32)
        ext_ids = np.asarray(ext_ids, np.int64)
        n = vectors.shape[0]
        if n > self.room:
            raise ValueError(
                f"delta overflow: {n} rows into {self.room} free slots "
                f"(capacity {self.capacity}); merge first")
        lo, hi = self.count, self.count + n
        vec = self.vectors.copy()
        vec[lo:hi] = vectors
        ids = self.ext_ids.copy()
        ids[lo:hi] = ext_ids
        live = self.live.copy()
        live[lo:hi] = True
        return dataclasses.replace(self, vectors=vec, ext_ids=ids, live=live,
                                   count=hi)

    def delete(self, ext_id: int) -> Tuple["DeltaSegment", bool]:
        """Mark one external id dead.  Returns (segment, found)."""
        slot = np.flatnonzero((self.ext_ids[:self.count] == ext_id)
                              & self.live[:self.count])
        if slot.size == 0:
            return self, False
        live = self.live.copy()
        live[slot] = False
        return dataclasses.replace(self, live=live), True

    def contains(self, ext_id: int) -> bool:
        return bool(((self.ext_ids[:self.count] == ext_id)
                     & self.live[:self.count]).any())

    def live_rows(self) -> Tuple[np.ndarray, np.ndarray]:
        """(vectors [m, d], ext_ids [m]) of the surviving rows (merge feed)."""
        mask = self.live[:self.count]
        return self.vectors[:self.count][mask], self.ext_ids[:self.count][mask]

    # --- lazy SQ8 sidecar -------------------------------------------------
    def _sq8(self):
        # cached on the (frozen) instance: derived data, not state — each
        # copy-on-write successor re-encodes lazily on first quantized scan
        tables = self.__dict__.get("_sq8_tables")
        if tables is None:
            qp = SQ.sq8_train(self.vectors)
            tables = (jnp.asarray(SQ.sq8_encode(self.vectors, qp)),
                      jnp.asarray(qp.lo), jnp.asarray(qp.scale))
            object.__setattr__(self, "_sq8_tables", tables)
        return tables

    # --- search -----------------------------------------------------------
    def topk(self, queries: np.ndarray, k: int, use_sq8: bool = False
             ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Brute-force top-k over the live slots.

        queries [B, d] (preprocessed) -> (ext_ids [B, k] int64 with -1 pads,
        dists [B, k] ranking distances with +inf pads, scanned [B] int32 =
        live slots each query compared against).  Runs even when the
        segment is empty — the scan's shapes are what serving warms, and an
        "empty" fast path would un-warm them.
        """
        queries = np.ascontiguousarray(queries, np.float32)
        B = queries.shape[0]
        live_dev = jnp.asarray(self.live)
        if use_sq8:
            codes, lo, scale = self._sq8()
            d = np.asarray(_scan_dists_sq8(codes, lo, scale, live_dev,
                                           jnp.asarray(queries), self.metric))
            # stage 2: exact re-rank of the top-m approximate candidates
            m = min(self.capacity, max(32, 4 * k))
            cand = np.argpartition(d, m - 1, axis=1)[:, :m]
            rows = self.vectors[cand]                      # [B, m, d]
            if self.metric == "l2":
                diff = rows - queries[:, None, :]
                exact = np.sum(diff * diff, axis=-1)
            else:
                exact = 1.0 - np.einsum("bmd,bd->bm", rows, queries)
            d = np.full_like(d, np.inf)
            np.put_along_axis(d, cand,
                              np.where(self.live[cand], exact, np.inf),
                              axis=1)
        else:
            d = np.asarray(_scan_dists(jnp.asarray(self.vectors), live_dev,
                                       jnp.asarray(queries), self.metric))
        kk = min(k, self.capacity)
        if kk < self.capacity:
            part = np.argpartition(d, kk - 1, axis=1)[:, :kk]
        else:
            part = np.broadcast_to(np.arange(kk), (B, kk))
        pd = np.take_along_axis(d, part, axis=1)
        order = np.argsort(pd, axis=1, kind="stable")
        idx = np.take_along_axis(part, order, axis=1)
        dists = np.take_along_axis(pd, order, axis=1)
        ids = self.ext_ids[idx]
        ids = np.where(np.isfinite(dists), ids, -1)
        if kk < k:
            ids = np.pad(ids, ((0, 0), (0, k - kk)), constant_values=-1)
            dists = np.pad(dists, ((0, 0), (0, k - kk)),
                           constant_values=np.inf)
        scanned = np.full((B,), self.n_live, np.int32)
        return ids, dists, scanned

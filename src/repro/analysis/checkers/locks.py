"""Lock discipline: ``# guarded by:`` annotations and the lock-order table.

**guarded-by.**  An attribute assigned in ``__init__`` (or at module level)
with a trailing ``# guarded by: self._lock`` comment declares a guard: every
read or write of that attribute in the class's OTHER methods (or, for module
globals, in any module function) must sit lexically inside a ``with`` on the
named lock.  The analysis is intraprocedural and method-level — a method
that runs with the lock already held by its caller states that with an
inline ``# repolint: ignore[guarded-by] caller holds <lock> (...)``, which
doubles as documentation of the calling contract.  ``__init__`` itself is
exempt (the object is unpublished), and a nested ``def`` resets the held
set: a ``with`` in the enclosing scope does NOT protect a closure that runs
later on another thread.

**lock-order.**  ``LOCK_ORDER_TABLE`` declares the acquisition order of
each class's locks (DESIGN.md §13 carries the same table with its
cross-module edges).  Within one function, acquiring lock B while holding
lock A flags an inversion whenever the declared chain puts B before A —
the deadlock shape every one of the five thread domains must avoid.
"""
from __future__ import annotations

import ast
import re
from typing import Dict, Iterable, List, Tuple

from repro.analysis.core import Finding, Project, SourceFile, register_checker

# applied to comment text only (tokenize-extracted), so no '#' anchor —
# prose may precede the marker within the comment
GUARDED_RE = re.compile(r"guarded by:\s*(self\.)?(\w+)")

# Declared acquisition order per class: when two of a chain's locks nest in
# one function, the outer one must come earlier in the tuple.  Cross-module
# edges (mutate _lock -> WAL write lock via append_insert, frontend
# _dispatch_lock -> telemetry _obs_lock) span call boundaries this
# intraprocedural pass cannot see; they are documented in DESIGN.md §13.
LOCK_ORDER_TABLE: Dict[str, Tuple[str, ...]] = {
    "ServeFrontend": ("_lock", "_dispatch_lock"),
    "MutableAnnIndex": ("_merge_lock", "_lock", "_engine_lock"),
    "MutableShardedAnnIndex": ("_merge_lock", "_lock", "_engine_lock"),
    "SegmentWriter": ("_write_lock", "_cond"),
    "DurableStore": ("_lock",),
    "AutotuneDriver": ("_lock",),
    "ServeTelemetry": ("_obs_lock",),
}


def _with_lock_names(node: ast.With, *, selfish: bool) -> List[str]:
    """Lock attribute names acquired by one ``with`` statement.

    ``selfish=True`` matches ``self.X`` context managers (instance locks),
    ``False`` matches bare names (module-level locks)."""
    out = []
    for item in node.items:
        ctx = item.context_expr
        if selfish:
            if (isinstance(ctx, ast.Attribute)
                    and isinstance(ctx.value, ast.Name)
                    and ctx.value.id == "self"):
                out.append(ctx.attr)
        elif isinstance(ctx, ast.Name):
            out.append(ctx.id)
    return out


class _LockWalk:
    """Walk one function body tracking the stack of held locks."""

    def __init__(self, sf: SourceFile, relpath: str, *, selfish: bool,
                 guarded: Dict[str, str], order: Tuple[str, ...],
                 owner: str):
        self.sf = sf
        self.relpath = relpath
        self.selfish = selfish
        self.guarded = guarded          # attr/global -> lock name
        self.order = order
        self.owner = owner              # "Class.method" for messages
        self.findings: List[Finding] = []

    def run(self, fn: ast.AST):
        for stmt in getattr(fn, "body", []):
            self._visit(stmt, held=())

    def _visit(self, node: ast.AST, held: Tuple[str, ...]):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            # a closure runs later, possibly on another thread: the
            # enclosing with-block does not protect it
            for child in ast.iter_child_nodes(node):
                self._visit(child, held=())
            return
        if isinstance(node, ast.With):
            acquired = _with_lock_names(node, selfish=self.selfish)
            for name in acquired:
                self._check_order(node, held, name)
            inner = held + tuple(a for a in acquired if a not in held)
            for item in node.items:
                self._visit(item.context_expr, held)
            for stmt in node.body:
                self._visit(stmt, inner)
            return
        self._check_access(node, held)
        for child in ast.iter_child_nodes(node):
            self._visit(child, held)

    def _check_order(self, node: ast.With, held: Tuple[str, ...],
                     acquiring: str):
        if acquiring not in self.order:
            return
        for h in held:
            if h not in self.order:
                continue
            if self.order.index(h) > self.order.index(acquiring):
                self.findings.append(Finding(
                    checker="lock-order", path=self.relpath,
                    line=node.lineno,
                    message=f"{self.owner} acquires {acquiring!r} while "
                            f"holding {h!r}; the declared order is "
                            f"{' -> '.join(self.order)}",
                    hint="restructure so locks nest in declared order, or "
                         "release the inner lock first (deadlock hazard)"))

    def _check_access(self, node: ast.AST, held: Tuple[str, ...]):
        name = None
        if self.selfish:
            if (isinstance(node, ast.Attribute)
                    and isinstance(node.value, ast.Name)
                    and node.value.id == "self"
                    and node.attr in self.guarded):
                name = node.attr
        elif isinstance(node, ast.Name) and node.id in self.guarded:
            name = node.id
        if name is None:
            return
        lock = self.guarded[name]
        if lock in held:
            return
        ref = f"self.{name}" if self.selfish else name
        lockref = f"self.{lock}" if self.selfish else lock
        self.findings.append(Finding(
            checker="guarded-by", path=self.relpath, line=node.lineno,
            message=f"{self.owner} touches {ref} outside `with {lockref}` "
                    f"(declared '# guarded by: {lockref}')",
            hint=f"wrap the access in `with {lockref}:`, or suppress with "
                 "# repolint: ignore[guarded-by] <why the lock is not "
                 "needed here>"))


def _guard_match(sf: SourceFile, lineno: int):
    """The ``guarded by:`` annotation on an assign: trailing comment on
    the assign's own line, or a comment-only line directly above it."""
    m = GUARDED_RE.search(sf.comment_on(lineno))
    if m is not None:
        return m
    above = sf.comment_on(lineno - 1)
    if above and lineno >= 2 \
            and sf.lines[lineno - 2].lstrip().startswith("#"):
        return GUARDED_RE.search(above)
    return None


def _declared_guards(sf: SourceFile, body: Iterable[ast.stmt], *,
                     selfish: bool) -> Dict[str, str]:
    """attr -> lock from ``# guarded by:`` comments on assigns."""
    guarded: Dict[str, str] = {}
    for stmt in body:
        if not isinstance(stmt, (ast.Assign, ast.AnnAssign)):
            continue
        m = _guard_match(sf, stmt.lineno)
        if not m:
            continue
        targets = (stmt.targets if isinstance(stmt, ast.Assign)
                   else [stmt.target])
        for t in targets:
            if selfish:
                if (isinstance(t, ast.Attribute)
                        and isinstance(t.value, ast.Name)
                        and t.value.id == "self"):
                    guarded[t.attr] = m.group(2)
            elif isinstance(t, ast.Name):
                guarded[t.id] = m.group(2)
    return guarded


def _check_class(sf: SourceFile, cls: ast.ClassDef) -> List[Finding]:
    init = next((n for n in cls.body
                 if isinstance(n, ast.FunctionDef) and n.name == "__init__"),
                None)
    guarded: Dict[str, str] = {}
    # trailing comments can sit on assigns nested under ifs in __init__ too
    if init is not None:
        for stmt in ast.walk(init):
            if isinstance(stmt, (ast.Assign, ast.AnnAssign)):
                guarded.update(_declared_guards(sf, [stmt], selfish=True))
    if not guarded:
        return []
    findings: List[Finding] = []
    for meth in cls.body:
        if not isinstance(meth, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        if meth.name == "__init__":
            continue            # unpublished object: no guard needed yet
        # order=() — inversions are check_lock_order's job (one finding
        # per site, not one per checker)
        walk = _LockWalk(sf, sf.relpath, selfish=True, guarded=guarded,
                         order=(), owner=f"{cls.name}.{meth.name}")
        walk.run(meth)
        findings.extend(walk.findings)
    return findings


def _check_module_globals(sf: SourceFile, tree: ast.Module) -> List[Finding]:
    guarded = _declared_guards(sf, tree.body, selfish=False)
    if not guarded:
        return []
    findings: List[Finding] = []
    for fn in tree.body:
        if isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            walk = _LockWalk(sf, sf.relpath, selfish=False, guarded=guarded,
                             order=(), owner=fn.name)
            walk.run(fn)
            findings.extend(walk.findings)
    return findings


@register_checker(
    "guarded-by",
    "attributes annotated '# guarded by: <lock>' are only touched under "
    "a `with` on that lock (intraprocedural, method-level)")
def check_guarded_by(project: Project) -> Iterable[Finding]:
    for sf in project.files:
        if sf.tree is None:
            continue
        yield from _check_module_globals(sf, sf.tree)
        for node in ast.walk(sf.tree):
            if isinstance(node, ast.ClassDef):
                yield from _check_class(sf, node)


@register_checker(
    "lock-order",
    "nested `with self.<lock>` acquisitions follow the declared per-class "
    "lock-order table (deadlock prevention)")
def check_lock_order(project: Project) -> Iterable[Finding]:
    for sf in project.files:
        if sf.tree is None:
            continue
        for cls in ast.walk(sf.tree):
            if not isinstance(cls, ast.ClassDef):
                continue
            order = LOCK_ORDER_TABLE.get(cls.name, ())
            if len(order) < 2:
                continue
            for meth in cls.body:
                if not isinstance(meth, (ast.FunctionDef,
                                         ast.AsyncFunctionDef)):
                    continue
                walk = _LockWalk(sf, sf.relpath, selfish=True, guarded={},
                                 order=order,
                                 owner=f"{cls.name}.{meth.name}")
                walk.run(meth)
                yield from walk.findings

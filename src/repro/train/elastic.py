"""Elastic scaling: resume the same logical job on a different device count.

The contract (tested in tests/test_checkpoint.py::test_elastic_reshard):
checkpoints are mesh-agnostic (full logical arrays per leaf); on restore,
leaves are device_put with shardings built for the *new* mesh, so a job
checkpointed on 512 chips restarts on 256 (or 8, or 1) without conversion.

remesh_plan() also covers the *data* dimension: global batch stays fixed, so
per-device batch and grad-accumulation factor are re-derived from the new
device count — keeping the optimization trajectory identical (same tokens
per step), which is what makes elastic restarts loss-transparent.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax


@dataclasses.dataclass
class RemeshPlan:
    old_devices: int
    new_devices: int
    global_batch: int
    data_parallel: int       # batch-sharding width (<= new_devices)
    per_device_batch: int
    grad_accum: int

    @property
    def tokens_per_step_preserved(self) -> bool:
        return self.per_device_batch * self.data_parallel * self.grad_accum \
            == self.global_batch


def remesh_plan(global_batch: int, new_devices: int,
                old_devices: Optional[int] = None,
                max_per_device_batch: int = 64) -> RemeshPlan:
    """Re-derive (DP width, per-device batch, grad-accum) for a new device
    count, holding the global batch constant.  When devices > batch, the
    surplus axis becomes model parallelism (DP width caps at the batch)."""
    dp = new_devices
    while dp > 1 and (global_batch % dp or global_batch < dp):
        dp -= 1
    per_dev = global_batch // dp
    accum = 1
    while per_dev > max_per_device_batch and per_dev % 2 == 0:
        per_dev //= 2
        accum *= 2
    return RemeshPlan(old_devices=old_devices or new_devices,
                      new_devices=new_devices, global_batch=global_batch,
                      data_parallel=dp, per_device_batch=per_dev,
                      grad_accum=accum)


def reshard_tree(tree: Any, shardings: Any) -> Any:
    """Place a host-resident pytree under new-mesh shardings."""
    return jax.tree_util.tree_map(
        lambda x, s: jax.device_put(x, s), tree, shardings)

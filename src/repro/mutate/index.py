"""Live index mutation: delta segment + tombstones + background merge.

``MutableAnnIndex`` wraps ``AnnIndex`` with ``insert``/``delete``/``search``
that work while ``ServeFrontend`` keeps answering queries (DESIGN.md §9):

* inserts land in a ``DeltaSegment`` (fixed-shape jit-scanned side table);
  its top-k merges with the main-graph pool host-side;
* deletes become a per-node tombstone mask threaded into the engine
  (``build_search_fn(..., tombstones=True)``): dead nodes still ROUTE —
  their edges stay traversable so recall through a tombstoned region holds
  — but they are masked out of the result pool, so a deleted id is never
  returned;
* when the delta fills past ``MutateConfig.merge_threshold`` (or the dead
  fraction passes ``tombstone_threshold``), a merge re-links survivors +
  delta into a fresh graph and atomically swaps the snapshot under an
  epoch guard.  In-flight searches finish on the old snapshot (they hold a
  reference; the state swap is one pointer write), the compiled-engine
  cache drops the dead graph via ``_purge_dead_cache_entries``, and the
  angle profile refreshes once the corpus drifts past
  ``profile_refresh_fraction`` of its size at sampling time.

External ids: ``insert`` assigns monotonically increasing int64 ids
(the initial wrap takes ids ``[0, n)`` for the base rows), and every search
returns EXTERNAL ids — merges renumber graph rows freely underneath.

Zero request-path recompiles across a swap: the merge thread pre-warms the
fresh snapshot's engines at every (spec, batch shape) the serving layer has
noted (``note_shape``), and ``compile_count`` folds retired engines +
pre-warm discounts so serving telemetry sees a flat count through the swap
(the invariant ``recompiles_after_warmup == 0`` is tested across a merge).

Thread model: ``search`` is lock-free (one volatile read of ``_state``);
``insert``/``delete`` serialize on a mutation lock; merges serialize on a
merge lock and only take the mutation lock for the final
residual-reconcile + swap.

Failure domains (DESIGN.md §10): a failed merge is retried under a capped
exponential backoff (``MutateConfig.merge_retries`` / ``merge_backoff_s``);
when the budget is exhausted the index enters *quarantine* for
``quarantine_cooldown_s`` — the pre-merge snapshot keeps serving, mutations
stay accepted while the delta has room, and ``maybe_merge`` stops
re-attempting until the cooldown lapses (or ``clear_quarantine()``).  The
exhausting error is kept in ``merge_error`` and re-raised by
``wait_for_merge``; a full delta during quarantine surfaces as typed
backpressure (``MergeQuarantinedError``), never a hang.
"""
from __future__ import annotations

import dataclasses
import threading
import time
import warnings
from typing import Dict, Optional, Set, Tuple

import numpy as np

from repro.core import distances as D
from repro.core.angles import sample_angle_profile
from repro.core.index import DEFAULT_SEARCH, GRAPH_BUILDERS, AnnIndex
from repro.core.routers import get_router
from repro.core.search import _purge_dead_cache_entries, build_search_fn
from repro.core.spec import SearchSpec, SearchStats, resolve_search_spec
from repro.durable.store import DurableStore
from repro.durable.wal import FSYNC_POLICIES, InsertRecord
from repro.fault import MergeQuarantinedError, RetryPolicy
from repro.fault import failpoints as fault
from repro.mutate.delta import DeltaSegment, delta_scan_compile_count

# Merge-rebuild graph parameters: modest by default (the merge runs while
# serving; construction quality is recovered by the next merge anyway).
# MutateConfig.graph_kw overrides.
GRAPH_DEFAULTS = {
    "nsg": dict(r=24, c=120, l=32, knn_k=24),
    "hnsw": dict(m=12, efc=80),
}


@dataclasses.dataclass(frozen=True)
class MutateConfig:
    """Policy knobs for the mutation machinery."""

    delta_capacity: int = 1024
    # merge when delta high-water mark passes this fraction of capacity
    merge_threshold: float = 0.75
    # ... or when this fraction of snapshot rows is tombstoned
    tombstone_threshold: float = 0.25
    # resample the angle profile when |corpus_now - corpus_at_sample| /
    # corpus_at_sample exceeds this (profile-staleness policy, DESIGN.md §9)
    profile_refresh_fraction: float = 0.2
    profile_percentile: float = 90.0
    graph: str = "nsg"            # what merges re-link into
    graph_kw: dict = dataclasses.field(default_factory=dict)
    auto_merge: str = "background"   # background | sync | off
    # merge-failure policy (DESIGN.md §10): retries after a failed attempt,
    # backoff between them, and how long the index sits quarantined (no
    # further merge attempts) once the whole budget is exhausted
    merge_retries: int = 3
    merge_backoff_s: float = 0.05
    merge_backoff_cap_s: float = 1.0
    quarantine_cooldown_s: float = 5.0
    seed: int = 0
    # durability (DESIGN.md §11): WAL fsync policy ("every" fsyncs before
    # each ack, "interval" group-commits on a wal_fsync_interval_s window,
    # "off" acks immediately — best-effort), and whether a successful merge
    # also rotates the log and publishes a checkpoint
    wal_fsync: str = "every"
    wal_fsync_interval_s: float = 0.002
    checkpoint_on_merge: bool = True

    def __post_init__(self):
        assert self.graph in GRAPH_BUILDERS, f"unknown graph {self.graph!r}"
        assert self.auto_merge in ("background", "sync", "off")
        assert self.delta_capacity >= 1
        assert self.merge_retries >= 0
        assert self.wal_fsync in FSYNC_POLICIES, \
            f"unknown wal_fsync {self.wal_fsync!r}"


class _Snapshot:
    """One immutable generation of the main graph (+ its engine ledger)."""

    def __init__(self, index: AnnIndex, ext_ids: np.ndarray):
        self.index = index
        self.ext_ids = np.asarray(ext_ids, np.int64)     # row -> external id
        self.ext_to_row: Dict[int, int] = {
            int(e): r for r, e in enumerate(self.ext_ids)}
        # canonical cfg -> jitted fn used on this snapshot, and how many of
        # that fn's executables were compiled OFF the request path by the
        # merge pre-warm (compile_count subtracts them)
        self.engines: Dict[SearchSpec, object] = {}
        self.warm_discount: Dict[SearchSpec, int] = {}


@dataclasses.dataclass(frozen=True)
class _State:
    """What one search sees: grabbed with a single reference read."""

    snapshot: _Snapshot
    tombstone: np.ndarray        # [n] bool, host copy (mutation-side truth)
    tombstone_dev: object        # [n+1] bool device array; pad row False
    n_dead: int
    delta: DeltaSegment
    epoch: int


def _tombstone_dev(tomb: np.ndarray):
    import jax.numpy as jnp

    return jnp.asarray(np.concatenate([tomb, np.zeros(1, bool)]))


class MutableAnnIndex:
    """``AnnIndex`` + insert/delete/background-merge, served without downtime."""

    def __init__(self, index: AnnIndex, config: MutateConfig = MutateConfig(),
                 spec: Optional[SearchSpec] = None, *,
                 durable_dir: Optional[str] = None):
        g = index.graph
        self.config = config
        self.default_spec = spec if spec is not None else DEFAULT_SEARCH
        snap = _Snapshot(index, np.arange(g.n, dtype=np.int64))
        tomb = np.zeros((g.n,), bool)
        self._state = _State(
            snapshot=snap, tombstone=tomb, tombstone_dev=_tombstone_dev(tomb),
            n_dead=0, epoch=0,
            delta=DeltaSegment.empty(config.delta_capacity, g.dim, g.metric))
        self._next_ext = g.n                  # guarded by: self._lock
        self._lock = threading.RLock()        # state swaps + mutation ops
        self._merge_lock = threading.Lock()   # one merge at a time
        self._engine_lock = threading.Lock()  # engine ledger + retired count
        # compiles owned by dead snapshots -- guarded by: self._engine_lock
        self._retired = 0
        # cfg -> batch sizes -- guarded by: self._engine_lock
        self._noted: Dict[SearchSpec, Set[int]] = {}
        self._merge_thread: Optional[threading.Thread] = None  # guarded by: self._lock
        self.merge_error: Optional[BaseException] = None  # guarded by: self._lock
        self.merges_completed = 0
        self.merge_retries_used = 0          # backoff retries ever taken
        # time.monotonic() deadline -- guarded by: self._lock
        self._quarantined_until = 0.0
        self._durable: Optional[DurableStore] = None
        self._replaying = False              # recover() applies, no re-log
        if durable_dir is not None:
            self._init_durable(durable_dir)

    # --- convenience ------------------------------------------------------
    @classmethod
    def build(cls, base: np.ndarray, config: MutateConfig = MutateConfig(),
              spec: Optional[SearchSpec] = None, graph: str = "hnsw",
              **build_kw) -> "MutableAnnIndex":
        return cls(AnnIndex.build(base, graph=graph, **build_kw),
                   config=config, spec=spec)

    @property
    def metric(self) -> str:
        return self._state.snapshot.index.graph.metric

    @property
    def dim(self) -> int:
        return self._state.snapshot.index.graph.dim

    @property
    def epoch(self) -> int:
        return self._state.epoch

    @property
    def n_live(self) -> int:
        s = self._state
        return s.snapshot.index.graph.n - s.n_dead + s.delta.n_live

    def live_ids(self) -> np.ndarray:
        """Sorted external ids currently searchable (test/debug aid)."""
        s = self._state
        main = s.snapshot.ext_ids[~s.tombstone]
        _, d_ids = s.delta.live_rows()
        return np.sort(np.concatenate([main, d_ids]))

    # --- mutation ---------------------------------------------------------
    def _check_merge_error(self):
        # read-and-clear must be atomic against a concurrent merge failure
        # storing a new error between our read and our reset
        with self._lock:
            if self.merge_error is None:
                return
            err, self.merge_error = self.merge_error, None
        raise RuntimeError("background merge failed") from err

    def insert(self, vectors: np.ndarray) -> np.ndarray:
        """Add rows; returns their assigned external ids (int64 [n]).

        Accepted even while merges are failing (quarantine) — the delta
        absorbs writes until it is genuinely full, at which point a
        quarantined index raises ``MergeQuarantinedError`` (typed
        backpressure) rather than attempting a merge it knows is sick.
        """
        vectors = np.asarray(vectors, np.float32)
        if vectors.ndim == 1:
            vectors = vectors[None, :]
        vectors = D.preprocess_vectors(np.ascontiguousarray(vectors),
                                       self.metric)
        n = vectors.shape[0]
        if n > self.config.delta_capacity:
            raise ValueError(
                f"insert of {n} rows exceeds delta_capacity="
                f"{self.config.delta_capacity}; insert in smaller chunks")
        lsn = None
        while True:
            with self._lock:
                state = self._state
                if n <= state.delta.room:
                    ids = np.arange(self._next_ext, self._next_ext + n,
                                    dtype=np.int64)
                    if self._durable is not None and not self._replaying:
                        # write-ahead, inside the mutation lock: LSN order
                        # is mutation order.  A failed append leaves the
                        # in-memory state UNtouched — the caller's error is
                        # the non-acknowledgment.
                        lsn = self._durable.append_insert(ids, vectors)
                    self._next_ext += n
                    self._state = dataclasses.replace(
                        state, delta=state.delta.insert(vectors, ids))
                    break
            # no room: a merge must drain the delta first.  Outside the
            # mutation lock — the merge takes it for the final swap.
            if self.config.auto_merge == "off":
                raise ValueError(
                    "delta segment full and auto_merge='off'; call merge()")
            if self.quarantined:
                with self._lock:
                    left = self._quarantined_until - time.monotonic()
                raise MergeQuarantinedError(
                    "delta segment full while merges are quarantined "
                    f"({left:.1f}s of cooldown left); retry later or "
                    "clear_quarantine()")
            try:
                self._merge_with_retry()
            except Exception as e:   # noqa: BLE001 — typed backpressure
                # the drain itself exhausted its budget (we are quarantined
                # now): callers get one typed error, whatever the cause
                raise MergeQuarantinedError(
                    "delta segment full and the drain merge failed "
                    "(index now quarantined)") from e
        if lsn is not None:
            # acknowledgment point: outside the mutation lock (group commit
            # batches concurrent acks under one fsync), before returning ids
            self._durable.ack(lsn)
        self.maybe_merge()
        return ids

    def delete(self, ext_ids) -> int:
        """Remove external ids from search results; returns count removed.

        Unknown or already-deleted ids raise ``KeyError`` (and the whole
        call applies atomically: either every id dies or none do).
        Accepted during merge quarantine — tombstones are cheap.
        """
        if np.ndim(ext_ids) == 0:
            ext_ids = [ext_ids]
        ext_ids = [int(e) for e in ext_ids]
        lsn = None
        with self._lock:
            state = self._state
            delta = state.delta
            tomb = None
            n_dead = state.n_dead
            for e in ext_ids:
                delta2, found = delta.delete(e)
                if found:
                    delta = delta2
                    continue
                row = state.snapshot.ext_to_row.get(e)
                dead = (tomb if tomb is not None else state.tombstone)
                if row is None or dead[row]:
                    raise KeyError(f"external id {e} is not live")
                if tomb is None:
                    tomb = state.tombstone.copy()
                tomb[row] = True
                n_dead += 1
            if self._durable is not None and not self._replaying:
                # write-ahead AFTER validation (a rejected delete must not
                # log) and BEFORE publishing the new state (log-before-apply)
                lsn = self._durable.append_delete(
                    np.asarray(ext_ids, np.int64))
            if tomb is not None:
                state = dataclasses.replace(
                    state, tombstone=tomb, tombstone_dev=_tombstone_dev(tomb),
                    n_dead=n_dead)
            self._state = dataclasses.replace(state, delta=delta)
            removed = len(ext_ids)
        if lsn is not None:
            self._durable.ack(lsn)
        self.maybe_merge()
        return removed

    # --- search -----------------------------------------------------------
    def _resolve_cos_theta(self, spec: SearchSpec, snap: _Snapshot) -> float:
        if spec.cos_theta is not None:
            return spec.cos_theta
        profile = snap.index.profile
        if profile is not None:
            return profile.cos_theta_star
        if get_router(spec.router).prunes:
            raise ValueError(
                f"router {spec.router!r} prunes on the angle threshold, but "
                "this index has no angle profile and the spec carries no "
                "explicit cos_theta (see AnnIndex.search)")
        return 0.0

    def note_shape(self, cfg: SearchSpec, batch: int):
        """Record a serving (spec, batch shape): merges pre-warm these on
        the fresh snapshot so the swap costs zero request-path compiles."""
        with self._engine_lock:
            self._noted.setdefault(cfg.canonical(), set()).add(int(batch))

    def _engine(self, snap: _Snapshot, cfg: SearchSpec):
        _, fn = build_search_fn(snap.index.graph, cfg, tombstones=True)
        key = cfg.canonical()
        with self._engine_lock:
            if key not in snap.engines:
                snap.engines[key] = fn
        return fn

    def search(self, queries: np.ndarray, spec: Optional[SearchSpec] = None
               ) -> Tuple[np.ndarray, np.ndarray, SearchStats]:
        """Search main graph + delta.  Returns (ext_ids [B,k] int64 with -1
        pads, ranking dists [B,k], SearchStats with a ``delta_scanned``
        extra counter).  Lock-free: the (snapshot, tombstone, delta) triple
        is one immutable state grabbed up front, so a concurrent merge swap
        never tears a search."""
        import jax.numpy as jnp

        state = self._state            # epoch guard: one consistent state
        snap = state.snapshot
        g = snap.index.graph
        spec = resolve_search_spec(spec, self.default_spec,
                                   "MutableAnnIndex.search")
        q = D.preprocess_vectors(np.ascontiguousarray(queries, np.float32),
                                 g.metric)
        cos_theta = self._resolve_cos_theta(spec, snap)
        k = spec.k
        cfg = dataclasses.replace(
            spec, efs=max(spec.efs, k), metric=g.metric,
            use_hierarchy=g.upper_neighbors is not None)
        self.note_shape(cfg, q.shape[0])
        fn = self._engine(snap, cfg)
        res = fn(jnp.asarray(q), jnp.asarray(cos_theta, jnp.float32),
                 state.tombstone_dev)
        rows = np.asarray(res.ids[:, :k]).astype(np.int64)
        g_dists = np.array(res.dists[:, :k])
        pad = rows >= g.n
        g_ids = np.where(pad, -1, snap.ext_ids[np.where(pad, 0, rows)])
        g_dists[pad] = np.inf

        d_ids, d_dists, scanned = state.delta.topk(
            q, k, use_sq8=cfg.estimate in ("sq8", "both"))

        # host-side merge: 2k candidates -> k (ids are disjoint across the
        # graph snapshot and the delta, so no dedup pass is needed)
        all_ids = np.concatenate([g_ids, d_ids], axis=1)
        all_d = np.concatenate([g_dists, d_dists], axis=1)
        order = np.argsort(all_d, axis=1, kind="stable")[:, :k]
        out_ids = np.take_along_axis(all_ids, order, axis=1)
        out_d = np.take_along_axis(all_d, order, axis=1)
        out_ids = np.where(np.isfinite(out_d), out_ids, -1)

        stats = SearchStats.from_result(res, router=spec.router)
        stats.extra["delta_scanned"] = scanned
        return out_ids, out_d, stats

    # --- compile accounting ----------------------------------------------
    def engine_compile_count(self) -> int:
        """Graph-engine executables compiled on behalf of THIS index:
        retired snapshots at their swap-time counts, plus the live
        snapshot's cache sizes minus the merge pre-warm discount.  Excludes
        the delta-scan kernels, which are process-wide — a sharded wrapper
        sums this per shard and adds ``delta_scan_compile_count()`` once."""
        with self._engine_lock:
            snap = self._state.snapshot
            live = sum(fn._cache_size() - snap.warm_discount.get(key, 0)
                       for key, fn in snap.engines.items())
            return self._retired + live

    def compile_count(self) -> int:
        """``engine_compile_count`` + the (process-wide) delta-scan
        kernels — continuous across snapshot swaps."""
        return self.engine_compile_count() + delta_scan_compile_count()

    # --- merge ------------------------------------------------------------
    def needs_merge(self) -> bool:
        s = self._state
        cap = self.config.delta_capacity
        if s.delta.count >= self.config.merge_threshold * cap:
            return True
        n = s.snapshot.index.graph.n
        return n > 0 and s.n_dead >= self.config.tombstone_threshold * n

    # --- merge-failure policy (DESIGN.md §10) ----------------------------
    @property
    def quarantined(self) -> bool:
        """True while the quarantine cooldown from an exhausted merge-retry
        budget is running: no merge attempts, pre-merge snapshot serves."""
        with self._lock:
            return time.monotonic() < self._quarantined_until

    def clear_quarantine(self):
        """Operator override: forget the quarantine and its stored error."""
        with self._lock:
            self._quarantined_until = 0.0
            self.merge_error = None

    def _merge_with_retry(self) -> bool:
        """``merge()`` under the configured backoff; exhaustion quarantines.

        Each failed attempt backs off (capped exponential, seeded jitter)
        and retries; when ``merge_retries`` are all spent the index enters
        quarantine, the exhausting error is stored in ``merge_error``, and
        the error re-raises (background callers swallow it — the state IS
        the record).  Data loss: none — a failed merge never swapped, so
        the pre-merge snapshot + delta keep serving and mutating.
        """
        policy = RetryPolicy(
            max_attempts=self.config.merge_retries + 1,
            base_s=self.config.merge_backoff_s,
            cap_s=self.config.merge_backoff_cap_s,
            # total-budget cap: the whole retry schedule fits inside one
            # quarantine cooldown, so backoff can never outlast the state
            # it would transition into
            max_elapsed_s=self.config.quarantine_cooldown_s,
            seed=self.config.seed)

        def count_retry(_attempt, _exc):
            self.merge_retries_used += 1

        try:
            return policy.call(self.merge, on_retry=count_retry)
        except Exception as e:   # noqa: BLE001 — converted to quarantine state
            with self._lock:
                self.merge_error = e
                self._quarantined_until = (
                    time.monotonic() + self.config.quarantine_cooldown_s)
            raise

    def maybe_merge(self):
        """Apply the configured merge policy (called after every mutation).
        Quarantined: no-op — mutations keep landing in the delta/tombstones
        and the next call after the cooldown retries the merge."""
        if self.config.auto_merge == "off" or not self.needs_merge():
            return
        if self.quarantined:
            return
        if self.config.auto_merge == "sync":
            self._merge_with_retry()
            return
        with self._lock:
            if self._merge_thread is not None and self._merge_thread.is_alive():
                return

            def run():
                try:
                    self._merge_with_retry()
                # repolint: ignore[fail-open] _merge_with_retry stored the
                # failure (merge_error + quarantine cooldown) before raising;
                # this wrapper only keeps the daemon thread quiet
                except Exception:   # noqa: BLE001 — recorded as quarantine
                    pass            # merge_error + cooldown already set

            self._merge_thread = threading.Thread(
                target=run, name="mutate-merge", daemon=True)
            self._merge_thread.start()

    def wait_for_merge(self):
        """Block until a background merge (if any) finishes, then re-raise
        any failure it left behind."""
        # repolint: ignore[guarded-by] volatile read: join() on a stale
        # thread ref is benign (it already finished), and holding the
        # mutation lock across a join would deadlock against the merge swap
        t = self._merge_thread
        if t is not None:
            t.join()
        self._check_merge_error()

    def merge(self) -> bool:
        """Re-link survivors + delta into a fresh graph and swap it in.

        Returns False when there was nothing to merge.  Safe to call
        concurrently (merges serialize); searches continue on the old
        snapshot until the single-reference swap at the end.
        """
        with self._merge_lock:
            base = self._state
            if base.n_dead == 0 and base.delta.count == 0:
                return False
            snap = base.snapshot
            g = snap.index.graph

            # 1) gather survivors + live delta rows (the merge feed)
            keep = ~base.tombstone
            d_vecs, d_ids = base.delta.live_rows()
            new_base = np.concatenate([g.vectors[keep], d_vecs], axis=0)
            new_ext = np.concatenate([snap.ext_ids[keep], d_ids])
            if new_base.shape[0] == 0:
                raise ValueError("merge would leave an empty index")

            # 2) re-link into a fresh graph (the expensive, lock-free part)
            fault.hit("mutate.merge.build")
            kw = dict(GRAPH_DEFAULTS.get(self.config.graph, {}))
            kw.update(self.config.graph_kw)
            new_g = GRAPH_BUILDERS[self.config.graph](
                new_base, metric=g.metric,
                seed=self.config.seed + base.epoch + 1, **kw)

            # 3) profile-refresh policy: resample when the corpus drifted
            # past the configured fraction of its size at sampling time
            profile = snap.index.profile
            if profile is not None:
                ref = profile.corpus_n
                drift = abs(new_g.n - ref) / ref if ref > 0 else np.inf
                if drift > self.config.profile_refresh_fraction:
                    profile = sample_angle_profile(
                        new_g, percentile=self.config.profile_percentile,
                        seed=self.config.seed + base.epoch + 1)
            new_snap = _Snapshot(AnnIndex(graph=new_g, profile=profile),
                                 new_ext)

            # 4) pre-warm every noted (spec, batch shape) on the fresh graph
            # BEFORE the swap: post-swap dispatches hit a full jit cache
            self._prewarm(new_snap)

            # 5) reconcile mutations that raced the build, then swap
            fault.hit("mutate.merge.swap")
            with self._lock:
                cur = self._state
                tomb = np.zeros((new_g.n,), bool)
                n_dead = 0
                # snapshot rows deleted since the merge started
                resid = np.flatnonzero(cur.tombstone & ~base.tombstone)
                dead_ext = [int(snap.ext_ids[r]) for r in resid]
                # delta rows that were merged in but died since
                bc = base.delta.count
                died = base.delta.live[:bc] & ~cur.delta.live[:bc]
                dead_ext += [int(e) for e in base.delta.ext_ids[:bc][died]]
                for e in dead_ext:
                    row = new_snap.ext_to_row.get(e)
                    if row is not None and not tomb[row]:
                        tomb[row] = True
                        n_dead += 1
                # delta rows inserted since the merge started carry over
                # (with their live flags — a delete may have raced in too)
                fresh = DeltaSegment.empty(self.config.delta_capacity,
                                           new_g.dim, new_g.metric)
                nres = cur.delta.count - bc
                if nres > 0:
                    fresh = fresh.insert(cur.delta.vectors[bc:bc + nres],
                                         cur.delta.ext_ids[bc:bc + nres])
                    live = fresh.live.copy()
                    live[:nres] = cur.delta.live[bc:bc + nres]
                    fresh = dataclasses.replace(fresh, live=live)
                with self._engine_lock:
                    # retire the old snapshot's compile ledger so the count
                    # stays continuous across the swap
                    for key, fn in snap.engines.items():
                        self._retired += (fn._cache_size()
                                          - snap.warm_discount.get(key, 0))
                    self._state = _State(
                        snapshot=new_snap, tombstone=tomb,
                        tombstone_dev=_tombstone_dev(tomb), n_dead=n_dead,
                        delta=fresh, epoch=base.epoch + 1)
            if (self._durable is not None and not self._replaying
                    and self.config.checkpoint_on_merge):
                # a merged graph makes the log prefix redundant: rotate +
                # publish so recovery replays only post-merge mutations.
                # Failure here propagates (the merge retry/quarantine
                # machinery owns it) — the swap above already happened and
                # durability is unaffected: the old binding still replays
                # the full acked history.
                self._checkpoint_locked()
            self.merges_completed += 1
        # old snapshot is unreferenced once in-flight searches drain; drop
        # its compiled engines + device arrays (THE _purge_dead_cache_entries
        # scenario: a dead graph id must not pin device buffers)
        _purge_dead_cache_entries()
        return True

    def _prewarm(self, new_snap: _Snapshot):
        import jax
        import jax.numpy as jnp

        g = new_snap.index.graph
        tomb_dev = _tombstone_dev(np.zeros((g.n,), bool))
        ct = jnp.asarray(0.0, jnp.float32)
        with self._engine_lock:
            noted = {key: sorted(bs) for key, bs in self._noted.items()}
        for key, batches in noted.items():
            cfg = dataclasses.replace(
                key, metric=g.metric,
                use_hierarchy=g.upper_neighbors is not None).canonical()
            _, fn = build_search_fn(g, cfg, tombstones=True)
            for b in batches:
                dummy = jnp.zeros((b, g.dim), jnp.float32)
                jax.block_until_ready(fn(dummy, ct, tomb_dev).ids)
            with self._engine_lock:
                new_snap.engines[cfg] = fn
                new_snap.warm_discount[cfg] = fn._cache_size()

    # --- persistence ------------------------------------------------------
    def save(self, path: str, *, strict: bool = False):
        """Persist the current MERGED SNAPSHOT only — a plain ``AnnIndex``
        payload, NOT the live mutation state.

        The trap (ISSUE 8): unmerged delta rows and tombstones are *not* in
        the snapshot, so saving while they exist writes a file that silently
        forgets acknowledged mutations.  When that would happen this method
        warns (or raises ``ValueError`` under ``strict=True``) and still
        writes the snapshot.  For a file that reflects everything, call
        ``merge()`` first; for crash durability of every acknowledged
        mutation, use ``durable_dir=`` / ``checkpoint()`` / ``recover()``
        (DESIGN.md §11) instead of point-in-time saves.
        """
        self.wait_for_merge()
        s = self._state
        if s.delta.count > 0 or s.n_dead > 0:
            msg = (f"MutableAnnIndex.save: snapshot-only save is dropping "
                   f"{s.delta.n_live} unmerged delta row(s) and "
                   f"{s.n_dead} tombstone(s); call merge() first for a "
                   "point-in-time file, or use checkpoint()/durable_dir= "
                   "for crash durability")
            if strict:
                raise ValueError(msg)
            warnings.warn(msg, stacklevel=2)
        s.snapshot.index.save(path)

    # --- durability (DESIGN.md §11) ---------------------------------------
    def _init_durable(self, dirname: str):
        """Create a fresh durable directory: initial checkpoint of the
        current state, then an empty active WAL segment to append into."""
        store = DurableStore.create(
            dirname, fsync=self.config.wal_fsync,
            fsync_interval_s=self.config.wal_fsync_interval_s,
            meta={"kind": "mutable-index"})
        store.publish_checkpoint(self._checkpoint_payload())
        store.attach()
        self._durable = store

    def _checkpoint_payload(self) -> Dict[str, np.ndarray]:
        """Full recoverable state: the snapshot's ``AnnIndex`` payload plus
        the mutation extras (``ckpt_*``).  Dead delta rows are dropped —
        external ids are never reused, so nothing can reference them again.
        """
        with self._lock:
            state = self._state
            next_ext = self._next_ext
        snap = state.snapshot
        d_vecs, d_ids = state.delta.live_rows()
        payload = snap.index._payload()
        payload.update(
            ckpt_ext_ids=snap.ext_ids,
            ckpt_tombstone=state.tombstone,
            ckpt_delta_vectors=d_vecs,
            ckpt_delta_ids=d_ids,
            ckpt_next_ext=np.asarray(next_ext, np.int64),
            ckpt_epoch=np.asarray(state.epoch, np.int64))
        return payload

    def checkpoint(self) -> str:
        """Rotate the WAL and publish a checkpoint of the current state;
        returns the checkpoint file name.  After it lands, recovery loads
        the checkpoint and replays only mutations acked since this call.
        A crash at ANY point leaves a manifest binding that still replays
        the complete acked history (the rotation/publication state machine,
        DESIGN.md §11)."""
        if self._durable is None:
            raise ValueError(
                "index has no durable store; construct with durable_dir= "
                "or via recover()")
        with self._merge_lock:     # serialize with merges (and their ckpts)
            return self._checkpoint_locked()

    def _checkpoint_locked(self) -> str:
        """Checkpoint with the merge lock already held (merge() tail)."""
        with self._lock:
            # the rotate boundary is a mutation-order boundary: capture the
            # state under the SAME lock hold so the checkpoint is exactly
            # "everything before the new segment"
            self._durable.rotate()
            payload = self._checkpoint_payload()
        # the expensive write happens off the mutation lock
        return self._durable.publish_checkpoint(payload)

    @classmethod
    def recover(cls, dirname: str, config: MutateConfig = MutateConfig(),
                spec: Optional[SearchSpec] = None, *,
                attach: bool = True) -> "MutableAnnIndex":
        """Rebuild a ``MutableAnnIndex`` from a durable directory: load the
        manifest's checkpoint, replay the bound WAL segments into delta +
        tombstones, and (with ``attach=True``) keep appending to the log.

        Replay is idempotent — an insert of an already-live id and a delete
        of an already-dead id are skipped — and tolerant of a torn tail on
        the final segment (those records were never acknowledged; they are
        truncated away).  Mid-log corruption raises ``CorruptIndexError``.
        ``attach=False`` opens the state read-write in memory but leaves
        the log alone (export/load semantics).
        """
        store = DurableStore.open(
            dirname, fsync=config.wal_fsync,
            fsync_interval_s=config.wal_fsync_interval_s)
        z = store.load_checkpoint()
        index = AnnIndex._from_payload(z)
        obj = cls(index, config=config, spec=spec)
        snap = _Snapshot(index, np.asarray(z["ckpt_ext_ids"], np.int64))
        tomb = np.ascontiguousarray(z["ckpt_tombstone"], bool)
        obj._state = _State(
            snapshot=snap, tombstone=tomb,
            tombstone_dev=_tombstone_dev(tomb), n_dead=int(tomb.sum()),
            delta=DeltaSegment.empty(config.delta_capacity,
                                     index.graph.dim, index.graph.metric),
            epoch=int(z["ckpt_epoch"]))
        obj._next_ext = int(z["ckpt_next_ext"])
        obj._replaying = True
        try:
            d_vecs = np.ascontiguousarray(z["ckpt_delta_vectors"], np.float32)
            if d_vecs.shape[0]:
                obj._apply_insert(
                    np.asarray(z["ckpt_delta_ids"], np.int64), d_vecs)
            for rec in store.replay():
                if isinstance(rec, InsertRecord):
                    obj._apply_insert(rec.ext_ids, rec.vectors)
                else:
                    obj._apply_delete(rec.ext_ids)
        finally:
            obj._replaying = False
        if attach:
            store.attach()
            obj._durable = store
        else:
            store.close()
        return obj

    def _is_live(self, e: int) -> bool:
        s = self._state
        if s.delta.contains(e):
            return True
        row = s.snapshot.ext_to_row.get(e)
        return row is not None and not s.tombstone[row]

    def _apply_insert(self, ext_ids: np.ndarray, vectors: np.ndarray):
        """Replay-side insert: ids are pre-assigned, vectors already
        preprocessed (they were logged post-preprocessing).  Already-live
        ids are skipped (idempotence); a full delta merges mid-replay."""
        ext_ids = np.asarray(ext_ids, np.int64)
        vectors = np.ascontiguousarray(vectors, np.float32)
        keep = [i for i, e in enumerate(ext_ids) if not self._is_live(int(e))]
        if len(keep) != len(ext_ids):
            ext_ids, vectors = ext_ids[keep], vectors[keep]
        if ext_ids.size == 0:
            return
        i = 0
        while i < ext_ids.size:
            with self._lock:
                room = self._state.delta.room
                if room > 0:
                    j = min(i + room, ext_ids.size)
                    self._state = dataclasses.replace(
                        self._state, delta=self._state.delta.insert(
                            vectors[i:j], ext_ids[i:j]))
                    i = j
                    continue
            self.merge()   # replay-time drain: no checkpoint, no retries
        with self._lock:
            self._next_ext = max(self._next_ext, int(ext_ids.max()) + 1)

    def _apply_delete(self, ext_ids: np.ndarray):
        """Replay-side delete: already-dead / unknown ids are skipped."""
        with self._lock:
            state = self._state
            delta = state.delta
            tomb = None
            n_dead = state.n_dead
            for e in map(int, np.asarray(ext_ids).ravel()):
                delta2, found = delta.delete(e)
                if found:
                    delta = delta2
                    continue
                row = state.snapshot.ext_to_row.get(e)
                dead = (tomb if tomb is not None else state.tombstone)
                if row is None or dead[row]:
                    continue
                if tomb is None:
                    tomb = state.tombstone.copy()
                tomb[row] = True
                n_dead += 1
            if tomb is not None:
                state = dataclasses.replace(
                    state, tombstone=tomb, tombstone_dev=_tombstone_dev(tomb),
                    n_dead=n_dead)
            self._state = dataclasses.replace(state, delta=delta)

    def close(self):
        """Release the WAL writer (final fsync included).  The in-memory
        index stays usable, but further durable mutations raise."""
        if self._durable is not None:
            self._durable.close()

"""Live index mutation (DESIGN.md §9): delta segment + tombstones +
background merge, served without downtime."""
from repro.mutate.delta import DeltaSegment, delta_scan_compile_count
from repro.mutate.index import MutableAnnIndex, MutateConfig
from repro.mutate.sharded import MutableShardedAnnIndex

__all__ = [
    "DeltaSegment",
    "delta_scan_compile_count",
    "MutableAnnIndex",
    "MutableShardedAnnIndex",
    "MutateConfig",
]

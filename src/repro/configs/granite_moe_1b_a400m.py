"""granite-moe-1b-a400m [moe] — 32 experts top-8 [hf:ibm-granite/granite-3.0-1b-a400m-base]."""
from repro.configs import ArchSpec
from repro.configs.shapes import LM_SHAPES
from repro.models.transformer import LMConfig, MoeSpec

SPEC = ArchSpec(
    arch_id="granite-moe-1b-a400m",
    family="lm",
    model_cfg=LMConfig(name="granite-moe-1b-a400m", n_layers=24, d_model=1024,
                       n_heads=16, n_kv_heads=8, d_ff=512, vocab=49155,
                       moe=MoeSpec(n_experts=32, top_k=8)),
    shapes=LM_SHAPES,
    source="hf:ibm-granite/granite-3.0-1b-a400m-base; hf",
    smoke_cfg=LMConfig(name="granite-moe-smoke", n_layers=2, d_model=64,
                       n_heads=4, n_kv_heads=2, d_ff=64, vocab=512,
                       moe=MoeSpec(n_experts=4, top_k=2),
                       dtype="float32", block_q=16, block_k=32, loss_chunk=16),
)

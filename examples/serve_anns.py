"""End-to-end serving driver (deliverable (b)): a dataset-sharded CRouting
index serving batched requests over all local devices, with latency stats and
a straggler-budget demonstration.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/serve_anns.py
"""
import time

import numpy as np
import jax

from repro.core.sharded_index import shard_dataset, ShardedAnnIndex
from repro.core.spec import SearchSpec
from repro.data.vectors import make_dataset, exact_ground_truth, recall_at_k
from repro.launch.mesh import make_local_mesh


def main():
    n_dev = len(jax.devices())
    print(f"serving over {n_dev} device(s)")
    ds = make_dataset(n_base=8000, n_query=512, dim=128, n_clusters=64, seed=0)
    gt = exact_ground_truth(ds, k=10)

    t0 = time.time()
    arrays = shard_dataset(ds.base, n_shards=max(n_dev, 2), graph="hnsw",
                           m=16, efc=96)
    print(f"sharded index built in {time.time()-t0:.1f}s "
          f"({arrays.vectors.shape[0]} shards x {arrays.ns} vectors, "
          f"theta*={np.arccos(arrays.cos_theta)/np.pi:.3f}pi)")
    mesh = make_local_mesh(n_dev, "shards")

    base_spec = SearchSpec(efs=64, k=10, router="crouting", max_hops=2048)
    idx = ShardedAnnIndex(arrays, mesh, spec=base_spec)
    # request loop: batches of 64 queries
    lat, hits = [], []
    for s in range(0, 512, 64):
        q = ds.queries[s:s + 64]
        t0 = time.perf_counter()
        ids, dists, stats = idx.search(q)
        lat.append(time.perf_counter() - t0)
        hits.append(recall_at_k(ids, gt[s // 64 * 64: s + 64], 10))
    lat_ms = np.asarray(lat[1:]) * 1e3       # drop the jit-warmup batch
    print(f"recall@10={np.mean(hits):.3f}  "
          f"p50={np.percentile(lat_ms, 50):.1f}ms "
          f"p99={np.percentile(lat_ms, 99):.1f}ms  "
          f"QPS={64/np.median(lat_ms)*1e3:.0f}")

    # straggler mitigation: a bounded hop budget keeps the merge barrier
    # tail-latency-safe at a controlled recall cost (DESIGN.md §6)
    idx_fast = ShardedAnnIndex(arrays, mesh,
                               spec=base_spec.replace(max_hops=24))
    ids, _, _ = idx_fast.search(ds.queries[:128])
    rec = recall_at_k(ids, gt[:128], 10)
    print(f"bounded-hop (straggler mode): recall@10={rec:.3f}")

    # beam expansion: W frontier nodes per hop amortize the per-iteration
    # fixed cost (candidate select, status scatter, loop overhead) ~W x
    idx_beam = ShardedAnnIndex(arrays, mesh,
                               spec=base_spec.replace(beam_width=4))
    lat = []
    for s in range(0, 256, 64):
        t0 = time.perf_counter()
        ids, _, _ = idx_beam.search(ds.queries[s:s + 64])
        lat.append(time.perf_counter() - t0)
    rec = recall_at_k(ids, gt[192:256], 10)
    print(f"beam W=4: recall@10={rec:.3f} "
          f"p50={np.percentile(np.asarray(lat[1:]) * 1e3, 50):.1f}ms")

    # two-stage quantized distances: stage 1 reads uint8 code rows (4x fewer
    # bytes), stage 2 re-ranks only survivors in fp32 — `dist_calls` counts
    # fp32 evaluations, the row DMAs the SQ8 estimate avoided
    _, _, st_exact = idx_beam.search(ds.queries[:128])
    idx_sq8 = ShardedAnnIndex(
        arrays, mesh,
        spec=base_spec.replace(beam_width=4, estimate="both"))
    ids, _, st_sq8 = idx_sq8.search(ds.queries[:128])
    rec = recall_at_k(ids, gt[:128], 10)
    calls_exact, calls_sq8 = int(st_exact.dist_calls), int(st_sq8.dist_calls)
    print(f"sq8 two-stage: recall@10={rec:.3f} fp32 calls "
          f"{calls_exact} -> {calls_sq8} "
          f"({calls_sq8 / max(calls_exact, 1):.2f}x)")


if __name__ == "__main__":
    main()

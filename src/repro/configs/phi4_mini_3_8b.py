"""phi4-mini-3.8b [dense] — RoPE SwiGLU GQA [arXiv:2412.08905; hf]."""
from repro.configs import ArchSpec
from repro.configs.shapes import LM_SHAPES
from repro.models.transformer import LMConfig

SPEC = ArchSpec(
    arch_id="phi4-mini-3.8b",
    family="lm",
    model_cfg=LMConfig(name="phi4-mini-3.8b", n_layers=32, d_model=3072,
                       n_heads=24, n_kv_heads=8, d_ff=8192, vocab=200064),
    shapes=LM_SHAPES,
    source="arXiv:2412.08905; hf",
    smoke_cfg=LMConfig(name="phi4-smoke", n_layers=2, d_model=48,
                       n_heads=3, n_kv_heads=1, d_ff=128, vocab=512,
                       dtype="float32", block_q=16, block_k=32, loss_chunk=16),
)

"""Scalar quantization: SQ8 table codes + distance bounds, int8 helpers.

This module owns every int8 quantizer in the repo:

* **SQ8 (per-dimension affine, uint8)** — the companion representation of the
  base-vector table used by the two-stage distance engine
  (``SearchSpec.estimate`` in core/search.py).  Each dimension j stores an
  affine grid ``x ~ lo[j] + code * scale[j]`` with ``code in [0, 255]``, so a
  row costs d bytes instead of 4d — the stage-1 estimate reads 4x fewer HBM
  bytes than the fp32 row DMA it replaces.

* **Symmetric per-tensor int8** — ``quantize_int8``/``dequantize_int8``
  (amax/127 scale, optional stochastic rounding), used by gradient
  compression (train/compress.py re-exports them from here).

SQ8 error/bound math (the engine's correctness contract, property-tested in
tests/test_quant.py):

With ``xhat = lo + code * scale`` the reconstruction error per dimension is
``|x_j - xhat_j| <= eps_j`` where ``eps_j = scale_j / 2`` (round-to-nearest)
plus a small float-arithmetic slack.  Writing the true squared Euclidean
distance through ``x = xhat + e``:

    d2(q, x) = |q - xhat|^2 - 2 <q - xhat, e> + |e|^2
             >= ad2 - 2 * sum_j |q_j - xhat_j| * eps_j          =: lb2

because ``|e|^2 >= 0`` and ``|<q - xhat, e>| <= sum_j |delta_j| eps_j``.
``lb2`` is therefore a TRUE lower bound on the squared distance: a candidate
whose ``lb2`` already exceeds the pool bound can skip its fp32 row fetch
without (bound-level) risk.  The per-dimension sum is tighter than the
Cauchy-Schwarz ``|delta| * |eps|`` form and costs one extra VPU accumulate.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

# Relative safety margin on the per-dimension error radius: round-to-nearest
# guarantees scale/2 in real arithmetic; encode/decode/bound evaluation in
# float32 adds ulp-level noise, covered many times over by 2^-10.
EPS_SLACK = 1.0 + 2.0 ** -10


@dataclasses.dataclass(frozen=True)
class SQ8Params:
    """Per-dimension affine grid: x ~ lo + code * scale, code in [0, 255]."""

    lo: np.ndarray      # [d] float32 grid origin (per-dimension min)
    scale: np.ndarray   # [d] float32 grid step, strictly positive
    eps: np.ndarray     # [d] float32 error radius = scale/2 * EPS_SLACK


def sq8_train(x: np.ndarray) -> SQ8Params:
    """Fit the per-dimension grid to the data (min/max range)."""
    x = np.asarray(x, np.float32)
    lo = x.min(axis=0)
    hi = x.max(axis=0)
    # degenerate (constant) dimensions get a tiny step so scale stays > 0
    scale = np.maximum((hi - lo) / 255.0, 1e-12).astype(np.float32)
    eps = (0.5 * scale * EPS_SLACK).astype(np.float32)
    return SQ8Params(lo=lo.astype(np.float32), scale=scale, eps=eps)


def sq8_encode(x: np.ndarray, params: SQ8Params) -> np.ndarray:
    """Rows -> uint8 codes.  Rows outside the trained range clip (their
    reconstruction error exceeds eps — only feed rows the grid was fit on,
    plus sentinel pad rows whose distances are always masked)."""
    x = np.asarray(x, np.float32)
    q = np.rint((x - params.lo[None, :]) / params.scale[None, :])
    return np.clip(q, 0, 255).astype(np.uint8)


def sq8_decode(codes: np.ndarray, params: SQ8Params) -> np.ndarray:
    codes = np.asarray(codes)
    return (params.lo[None, :]
            + codes.astype(np.float32) * params.scale[None, :])


def sq8_estimate(queries, xhat, eps) -> Tuple[jax.Array, jax.Array]:
    """Approximate squared-Euclidean distance + conservative lower bound.

    queries [B, d] f32, xhat [B, L, d] f32 (dequantized rows), eps [d] f32
    -> (ad2 [B, L], lb2 [B, L]).  This is THE bound expression — the Pallas
    kernel (kernels/sq8_distance.py) evaluates the identical f32 math per
    lane, so engine decisions agree bit-for-bit across engines."""
    delta = queries[:, None, :] - xhat
    ad2 = jnp.sum(delta * delta, axis=-1)
    slack = 2.0 * jnp.sum(jnp.abs(delta) * eps[None, None, :], axis=-1)
    lb2 = jnp.maximum(ad2 - slack, 0.0)
    return ad2, lb2


def sq8_dequantize_rows(codes, lo, scale):
    """uint8 codes [..., d] -> f32 rows (jnp, device-side)."""
    return lo + codes.astype(jnp.float32) * scale


# --------------------------------------------------------------------------
# Symmetric per-tensor int8 (gradient compression; train/compress.py
# re-exports these so there is exactly one int8 quantizer implementation).
# --------------------------------------------------------------------------
def quantize_int8_with_scale(x, scale, key=None):
    """x / scale -> int8 in [-127, 127]; stochastic rounding when key given."""
    y = x / scale
    if key is not None:
        y = jnp.floor(y + jax.random.uniform(key, y.shape))
    else:
        y = jnp.round(y)
    return jnp.clip(y, -127, 127).astype(jnp.int8)


def quantize_int8(x, key=None):
    """Returns (q int8, scale) with per-tensor amax/127 scale."""
    amax = jnp.max(jnp.abs(x)) + 1e-12
    scale = amax / 127.0
    return quantize_int8_with_scale(x, scale, key), scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale

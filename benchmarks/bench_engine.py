"""Beam-expansion engine benchmarks.

Four entries (each persists its derived dict into ``BENCH_engine.json``
via ``common.persist_bench`` — the machine-readable perf trajectory):

* ``engine_beam_sweep`` — the tuning sweep behind ``SearchSpec.beam_width``:
  for W in {1, 2, 4, 8} report hop-loop iterations, recall, per-query exact
  distance calls and QPS at equal efs.  The headline number is
  ``iter_reduction``: iterations(W=1) / iterations(W), which should track ~W
  until the frontier is too shallow to fill the beam.
* ``engine_estimate_sweep`` — the two-stage quantized engine
  (``SearchSpec.estimate``): exact vs angle vs sq8 vs both at equal efs.
  The headline: ``exact_rerank_calls`` (fp32 row DMAs on the sq8 path) vs
  the exact baseline's ``dist_calls``, at recall within 0.01.
* ``engine_router_sweep`` — iterates the ROUTER REGISTRY
  (``repro.core.routers.available_routers()``, so a newly registered
  strategy shows up with zero benchmark changes) at fixed efs and stamps
  each entry with the registry name plus the router's own counters
  (``SearchStats.summary()``, e.g. finger's ``finger_est_calls``).
* ``engine_pallas_parity`` — jnp vs Pallas engine on a small graph: asserts
  result parity and reports iterations + dist calls before/after (interpret
  mode — wall-clock here is NOT TPU performance, the parity + counter
  deltas are the point).

``BENCH_SMOKE=1`` (``make bench-smoke``, CI) shrinks every entry to a
seconds-scale run on the same code path.
"""
from __future__ import annotations

import time

from benchmarks.common import (SMOKE, cached_index, dataset, emit,
                               persist_bench, smoke_scale, timed)
from repro.core.routers import available_routers
from repro.core.spec import SearchSpec
from repro.data.vectors import exact_ground_truth, recall_at_k


def engine_beam_sweep():
    ds = dataset("sift-synth", n_base=smoke_scale(4000, 800))
    idx = cached_index(ds)
    gt = exact_ground_truth(ds, k=10)
    derived = {}
    base_iters = {}
    # beam_prune policy only matters for pruning routers (see SearchSpec):
    # "best" holds the W=1 recall profile, "all" holds the W=1 call savings
    variants = (("none", "best"), ("crouting", "best"), ("crouting", "all"))
    widths = (1, 4) if SMOKE else (1, 2, 4, 8)
    for router, pol in variants:
        key = router if router == "none" else f"{router}_{pol}"
        rows = []
        for W in widths:
            spec = SearchSpec(k=10, efs=64, router=router, beam_width=W,
                              beam_prune=pol)
            # warm with the full batch shape — jit caches per shape, so a
            # smaller warm-up batch would leave the compile in the timing
            idx.search(ds.queries, spec=spec)
            t0 = time.perf_counter()
            ids, _, stats = idx.search(ds.queries, spec=spec)
            dt = time.perf_counter() - t0
            rows.append({
                "beam_width": W,
                "iters": stats.iters,
                "recall": round(recall_at_k(ids, gt, 10), 3),
                "dist_calls": round(float(stats.dist_calls.mean()), 1),
                "hops": round(float(stats.hops.mean()), 1),
                "qps": round(len(ds.queries) / dt, 1),
            })
            if W == 1:
                base_iters[key] = stats.iters
        for r in rows:
            r["iter_reduction"] = round(base_iters[key] / max(r["iters"], 1), 2)
        derived[key] = rows
    emit("engine_beam_sweep", 0.0, {
        rt: {f"w{r['beam_width']}": {"iters": r["iters"],
                                     "x": r["iter_reduction"],
                                     "recall": r["recall"],
                                     "calls": r["dist_calls"]}
             for r in rows_}
        for rt, rows_ in derived.items()})
    derived["n_base"] = int(ds.base.shape[0])
    persist_bench("engine_beam_sweep", derived)
    return derived


def engine_estimate_sweep():
    """Two-stage quantized distance engine vs the exact baseline.

    Acceptance tracking (ISSUE 3): ``sq8.recall >= exact.recall - 0.01`` and
    ``sq8.exact_rerank_calls < exact.dist_calls`` — the fp32 row-DMA
    reduction, machine-checked from BENCH_engine.json."""
    ds = dataset("sift-synth", n_base=smoke_scale(4000, 800))
    idx = cached_index(ds)
    gt = exact_ground_truth(ds, k=10)
    variants = (
        ("exact", dict(router="none", estimate="exact")),
        ("angle", dict(router="crouting", estimate="angle")),
        ("sq8", dict(router="none", estimate="sq8")),
        ("both", dict(router="crouting", estimate="both")),
    )
    derived = {}
    for name, kw in variants:
        spec = SearchSpec(k=10, efs=64, beam_width=4, **kw)
        idx.search(ds.queries, spec=spec)        # warm the jit cache
        t0 = time.perf_counter()
        ids, _, stats = idx.search(ds.queries, spec=spec)
        dt = time.perf_counter() - t0
        derived[name] = {
            "recall": round(recall_at_k(ids, gt, 10), 4),
            "dist_calls": round(float(stats.dist_calls.mean()), 1),
            "exact_rerank_calls": round(float(stats.rerank_calls.mean()), 1),
            "sq8_calls": round(float(stats.sq8_calls.mean()), 1),
            "est_calls": round(float(stats.est_calls.mean()), 1),
            "iters": stats.iters,
            "wall_s": round(dt, 4),
        }
    for name in ("sq8", "both"):
        derived[name]["fp32_dma_reduction"] = round(
            derived["exact"]["dist_calls"]
            / max(derived[name]["dist_calls"], 1e-9), 2)
    derived["n_base"] = int(ds.base.shape[0])
    emit("engine_estimate_sweep", 0.0, derived)
    persist_bench("engine_estimate_sweep", derived)
    return derived


def engine_router_sweep():
    """Every registered routing strategy at fixed efs, from the registry.

    Acceptance tracking (ISSUE 4): each entry carries the registry name and
    the router-declared counters via ``SearchStats.summary()``; the
    ``finger`` router must hold recall within 0.01 of ``none`` at efs=64.
    """
    ds = dataset("sift-synth", n_base=smoke_scale(4000, 800))
    idx = cached_index(ds)
    gt = exact_ground_truth(ds, k=10)
    derived = {}
    for name in available_routers():
        spec = SearchSpec(k=10, efs=64, router=name)
        idx.search(ds.queries, spec=spec)        # warm the jit cache
        t0 = time.perf_counter()
        ids, _, stats = idx.search(ds.queries, spec=spec)
        dt = time.perf_counter() - t0
        derived[name] = {
            "recall": round(recall_at_k(ids, gt, 10), 4),
            "wall_s": round(dt, 4),
            **stats.summary(),
        }
    derived["registry"] = list(available_routers())
    derived["n_base"] = int(ds.base.shape[0])
    emit("engine_router_sweep", 0.0,
         {r: {"recall": v["recall"], "calls": v["dist_calls"]}
          for r, v in derived.items() if isinstance(v, dict)})
    persist_bench("engine_router_sweep", derived)
    return derived


def engine_pallas_parity():
    """jnp reference vs kernel-integrated engine: identical results, same
    dist-call counts, iterations cut by the beam."""
    from repro.core.index import AnnIndex

    ds = dataset("sift-synth", n_base=smoke_scale(1200, 600))
    ds_q = ds.queries[:8]
    idx = AnnIndex.build(ds.base, graph="hnsw", m=8, efc=48)
    derived = {}
    jnp_ids = {}
    for name, kw in (
            ("jnp_w1", dict(engine="jnp", beam_width=1)),
            ("jnp_w4", dict(engine="jnp", beam_width=4)),
            ("jnp_w4_sq8", dict(engine="jnp", beam_width=4, estimate="sq8")),
            ("pallas_w1", dict(engine="pallas", beam_width=1)),
            ("pallas_w4", dict(engine="pallas", beam_width=4)),
            ("pallas_w4_sq8", dict(engine="pallas", beam_width=4,
                                   estimate="sq8"))):
        spec = SearchSpec(k=10, efs=48, router="crouting", **kw)
        dt, out = timed(lambda: idx.search(ds_q, spec=spec))
        ids, _, stats = out
        row = {"iters": stats.iters,
               "dist_calls": round(float(stats.dist_calls.mean()), 1),
               "us_per_query": round(dt / len(ds_q) * 1e6, 1)}
        key = (kw["beam_width"], kw.get("estimate", "exact"))
        if kw["engine"] == "jnp":
            jnp_ids[key] = ids
        else:
            # each pallas variant is checked against its jnp twin (same
            # beam width + estimate config)
            row["ids_match_jnp"] = bool((ids == jnp_ids[key]).all())
        derived[name] = row
    derived["iter_reduction_w4"] = round(
        derived["jnp_w1"]["iters"] / max(derived["pallas_w4"]["iters"], 1), 2)
    derived["n_base"] = int(ds.base.shape[0])
    emit("engine_pallas_parity", 0.0, derived)
    persist_bench("engine_pallas_parity", derived)
    return derived

"""SLO-driven knob search: successive halving + epsilon-greedy refinement.

The controller is deliberately *pure*: it owns no threads, reads no
clocks, and touches no frontend.  It consumes (a) probe measurements from
an injected ``probe_fn`` and (b) windowed telemetry deltas handed to
``step()`` by the driver, and emits typed ``Decision`` records.  All
randomness flows from one seeded PRNG, so the decision log is a
deterministic function of (observation sequence, seed) — the property the
regression tests replay twice and diff.

Objective (DESIGN.md §12): ``max_recall`` maximizes the recall proxy
subject to ``p99 <= slo_p99_ms``; ``min_p99`` minimizes predicted p99
subject to ``recall >= recall_floor``.

Search, not a grid sweep:

1. **Screening — successive halving.**  Every candidate gets a cheap
   probe replay; survivors of each rung (top ``1/eta`` by objective
   score) are re-probed with more replays until at most
   ``max_finalists`` remain.  Candidates whose *probe* latency alone
   blows the SLO are quarantined outright — a single dispatch with no
   queueing is a lower bound on served p99, so they cannot possibly
   comply (the ISSUE's "quarantine of candidate specs that blow the SLO
   during probing").
2. **Refinement — epsilon-greedy bandit.**  Each epoch consumes the
   serving window delta for the incumbent: an SLO violation triggers a
   step DOWN to the best predicted-feasible finalist; sustained headroom
   triggers a step UP to a higher-recall finalist; otherwise the epoch
   exploits (keep) or, with probability epsilon, explores by re-probing a
   seeded-random finalist so its measurement cannot go stale.

The latency model is the "model" in model-based: predicted served p99 of
a candidate = its probe latency x a calibration ratio (EMA of the
incumbent's measured p99 over its own probe latency).  Probe latency
orders candidates by engine cost; the ratio maps that ordering onto the
live workload's queueing regime — and re-calibrates each epoch, which is
what lets the controller chase a workload shift.
"""
from __future__ import annotations

import dataclasses
import math
import random
from typing import Callable, Dict, List, Optional

from repro.autotune.proxy import ProbeMeasurement
from repro.autotune.space import TuneSpace, spec_key
from repro.core.spec import SearchSpec

MODES = ("max_recall", "min_p99")


@dataclasses.dataclass(frozen=True)
class Objective:
    """What "better" means, and the hard constraint.

    ``headroom`` is the fraction of the SLO the controller keeps in
    reserve when predicting feasibility (switch targets must project
    under ``slo * (1 - headroom)``); ``upgrade_margin`` is how far under
    the SLO the *measured* p99 must sit before an upgrade is considered
    (hysteresis — without it the controller oscillates at the boundary).
    """

    slo_p99_ms: float
    mode: str = "max_recall"
    recall_floor: float = 0.0
    headroom: float = 0.2
    upgrade_margin: float = 0.5

    def __post_init__(self):
        assert self.mode in MODES, f"unknown objective mode {self.mode!r}"
        assert self.slo_p99_ms > 0

    def to_dict(self) -> Dict[str, object]:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class Decision:
    """One controller action, JSON-ready for the structured decision log."""

    epoch: int
    kind: str            # screen | keep | switch | probe | fail | idle
    key: Optional[str]   # active candidate key after the decision
    reason: str
    measured: Dict[str, object] = dataclasses.field(default_factory=dict)

    def to_dict(self) -> Dict[str, object]:
        return {"epoch": self.epoch, "kind": self.kind, "key": self.key,
                "reason": self.reason, "measured": self.measured}


class Controller:
    """Deterministic seeded search over a ``TuneSpace`` (see module doc)."""

    def __init__(self, space: TuneSpace, objective: Objective,
                 probe_fn: Callable[..., ProbeMeasurement], *,
                 seed: int = 0, eta: int = 2, screen_replays=(1, 2),
                 max_finalists: int = 4, epsilon: float = 0.1,
                 ratio_alpha: float = 0.5):
        self.space = space
        self.objective = objective
        self._probe = probe_fn
        self.rng = random.Random(seed)
        self.seed = seed
        self.eta = max(2, int(eta))
        self.screen_replays = tuple(screen_replays)
        self.max_finalists = max(1, int(max_finalists))
        self.epsilon = float(epsilon)
        self.ratio_alpha = float(ratio_alpha)

        self.candidates: List[SearchSpec] = space.candidates()
        self.by_key: Dict[str, SearchSpec] = {
            spec_key(c): c for c in self.candidates}
        self.measurements: Dict[str, ProbeMeasurement] = {}
        self.quarantined: Dict[str, str] = {}     # key -> reason
        self.finalists: List[str] = []
        self.incumbent: Optional[str] = None
        self.ratio: Optional[float] = None        # served p99 / probe lat
        self.epoch = 0
        self.decisions: List[Decision] = []

    # --- scoring ----------------------------------------------------------
    def predicted_p99_ms(self, key: str) -> float:
        """Latency model: probe latency x calibration ratio (>= 1)."""
        m = self.measurements[key]
        return m.lat_s * 1e3 * max(self.ratio if self.ratio else 1.0, 1.0)

    def _feasible(self, key: str) -> bool:
        o = self.objective
        if o.mode == "min_p99":
            return self.measurements[key].recall >= o.recall_floor
        return self.predicted_p99_ms(key) <= o.slo_p99_ms * (1 - o.headroom)

    def _score(self, key: str):
        """Sort key: larger is better, infeasible always below feasible."""
        m = self.measurements[key]
        if self.objective.mode == "min_p99":
            return (self._feasible(key), -self.predicted_p99_ms(key),
                    m.recall)
        return (self._feasible(key), m.recall, -m.lat_s)

    def _quarantine_check(self, key: str) -> bool:
        """Probe latency alone blows the SLO -> quarantine (True)."""
        lat_ms = self.measurements[key].lat_s * 1e3
        if lat_ms > self.objective.slo_p99_ms:
            self.quarantined[key] = (
                f"probe latency {lat_ms:.1f}ms > SLO "
                f"{self.objective.slo_p99_ms:.1f}ms")
            return True
        return False

    # --- phase 1: successive halving --------------------------------------
    def screen(self) -> Decision:
        """Probe-and-halve the full candidate set down to the finalists;
        install the best as incumbent.  One decision record carries every
        rung's survivors so the log replays the whole bracket."""
        self.epoch += 1
        alive = [spec_key(c) for c in self.candidates]
        rungs: List[Dict[str, object]] = []
        for r, replays in enumerate(self.screen_replays):
            survivors = []
            for key in alive:
                self.measurements[key] = self._probe(
                    self.by_key[key], replays=replays)
                if not self._quarantine_check(key):
                    survivors.append(key)
            survivors.sort(key=self._score, reverse=True)
            if r < len(self.screen_replays) - 1:
                keep = max(1, math.ceil(len(survivors) / self.eta))
                survivors = survivors[:keep]
            rungs.append({"replays": replays, "evaluated": len(alive),
                          "survivors": list(survivors)})
            alive = survivors
            if len(alive) <= self.max_finalists:
                break
        if not alive:
            # every candidate's probe blew the SLO: serve the least-bad one
            # rather than nothing (fail-open all the way down)
            alive = sorted(self.quarantined,
                           key=lambda k: self.measurements[k].lat_s)[:1]
        self.finalists = alive[:self.max_finalists]
        self.incumbent = self.finalists[0]
        d = Decision(
            epoch=self.epoch, kind="screen", key=self.incumbent,
            reason=(f"successive halving over {len(self.candidates)} "
                    f"candidates -> {len(self.finalists)} finalists"),
            measured={
                "rungs": rungs,
                "quarantined": dict(self.quarantined),
                "finalists": {k: self.measurements[k].to_dict()
                              for k in self.finalists},
            })
        self.decisions.append(d)
        return d

    # --- phase 2: epsilon-greedy refinement --------------------------------
    def step(self, delta: Dict[str, object]) -> Decision:
        """One decision epoch from a windowed telemetry delta.

        ``delta`` is ``ServeTelemetry.window_delta`` output for the period
        since the previous decision — measured behavior of the INCUMBENT
        under the live workload.
        """
        if self.incumbent is None:
            return self.screen()
        self.epoch += 1
        o = self.objective
        p99 = delta.get("p99_ms")
        served = int(delta.get("served") or 0)
        meas = {"p99_ms": p99, "served": served, "qps": delta.get("qps")}
        if p99 is None or served == 0:
            d = Decision(self.epoch, "idle", self.incumbent,
                         "no traffic in the window", meas)
            self.decisions.append(d)
            return d

        # re-calibrate the latency model against the live workload
        probe_ms = self.measurements[self.incumbent].lat_s * 1e3
        if probe_ms > 0:
            r = p99 / probe_ms
            self.ratio = (r if self.ratio is None else
                          (1 - self.ratio_alpha) * self.ratio
                          + self.ratio_alpha * r)
            meas["ratio"] = round(self.ratio, 3)

        if p99 > o.slo_p99_ms:
            return self._react_violation(p99, meas)

        recall_now = self.measurements[self.incumbent].recall
        if o.mode == "max_recall" and p99 <= o.slo_p99_ms * o.upgrade_margin:
            best = self._best_feasible(exclude=self.incumbent,
                                       min_recall=recall_now + 1e-9)
            if best is not None:
                self.incumbent = best
                d = Decision(
                    self.epoch, "switch", best,
                    f"headroom: p99 {p99:.1f}ms <= "
                    f"{o.upgrade_margin:.0%} of SLO; upgrading recall "
                    f"{recall_now:.3f} -> "
                    f"{self.measurements[best].recall:.3f}", meas)
                self.decisions.append(d)
                return d

        if self.rng.random() < self.epsilon:
            key = self._explore_pick()
            if key is not None:
                self.measurements[key] = self._probe(self.by_key[key],
                                                     replays=1)
                self._quarantine_check(key)
                meas["probed"] = self.measurements[key].to_dict()
                d = Decision(self.epoch, "probe", self.incumbent,
                             f"epsilon exploration re-probed {key}", meas)
                self.decisions.append(d)
                return d
        d = Decision(self.epoch, "keep", self.incumbent,
                     f"p99 {p99:.1f}ms within SLO {o.slo_p99_ms:.1f}ms",
                     meas)
        self.decisions.append(d)
        return d

    def _react_violation(self, p99: float, meas: Dict[str, object]
                         ) -> Decision:
        o = self.objective
        target = self._best_feasible(exclude=self.incumbent)
        if target is None:
            # nothing projects feasible: fall to the cheapest finalist
            others = [k for k in self.finalists
                      if k != self.incumbent and k not in self.quarantined]
            target = min(others, default=None,
                         key=lambda k: self.measurements[k].lat_s)
        if target is None or target == self.incumbent:
            d = Decision(self.epoch, "keep", self.incumbent,
                         f"SLO violated (p99 {p99:.1f}ms > "
                         f"{o.slo_p99_ms:.1f}ms) but no cheaper candidate "
                         "remains", meas)
            self.decisions.append(d)
            return d
        old = self.incumbent
        self.incumbent = target
        d = Decision(
            self.epoch, "switch", target,
            f"SLO violated: p99 {p99:.1f}ms > {o.slo_p99_ms:.1f}ms; "
            f"stepping {old} -> {target} "
            f"(predicted {self.predicted_p99_ms(target):.1f}ms)", meas)
        self.decisions.append(d)
        return d

    def _best_feasible(self, exclude: Optional[str] = None,
                       min_recall: float = -1.0) -> Optional[str]:
        """Highest-scoring finalist predicted to meet the constraint."""
        pool = [k for k in self.finalists
                if k != exclude and k not in self.quarantined
                and self._feasible(k)
                and self.measurements[k].recall >= min_recall]
        if not pool:
            return None
        return max(pool, key=self._score)

    def _explore_pick(self) -> Optional[str]:
        pool = [k for k in self.finalists if k != self.incumbent]
        return self.rng.choice(pool) if pool else None

    # --- reporting ---------------------------------------------------------
    def health(self) -> Dict[str, object]:
        last = self.decisions[-1].to_dict() if self.decisions else None
        return {
            "epoch": self.epoch,
            "incumbent": self.incumbent,
            "finalists": list(self.finalists),
            "quarantined": dict(self.quarantined),
            "ratio": round(self.ratio, 3) if self.ratio else None,
            "last_decision": last,
        }

"""High-level ANNS index API: build -> profile angles -> search.

This is the user-facing entry point of the CRouting system:

    from repro.core.index import AnnIndex
    from repro.core.spec import SearchSpec

    idx = AnnIndex.build(base, graph="hnsw", metric="l2")
    ids, dists, stats = idx.search(
        queries, spec=SearchSpec(k=10, efs=100, router="crouting"))
    print(stats.dist_calls.mean())          # typed SearchStats, not a dict

``SearchSpec`` is the single request object (router registry name, beam
width, engine, estimate strategy, ...); ``stats`` is a typed
``SearchStats``.  ``search`` accepts a ``SearchSpec`` or ``None`` only —
kwarg-style configuration (``idx.search(q, k=10, router="crouting")``)
raises ``TypeError``.

Index persistence is a plain .npz (content-addressed in benchmarks' cache)
stamped with ``format_version``; ``load`` refuses files newer than it knows
how to read.  A replacement serving node re-pulls only its shard
(DESIGN.md §6).

Crash safety (DESIGN.md §10): ``save`` writes a temp file, fsyncs, stamps a
content checksum, and atomically renames into place — a ``kill -9`` at any
instant leaves either the old version or the new one at ``path``, never a
torn file.  ``load`` verifies the checksum and raises a typed
``CorruptIndexError`` on truncation/corruption instead of surfacing an
opaque ``zipfile``/``zlib`` error.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

from repro.durable.atomic import (atomic_write_npz, read_npz,
                                  verify_checksum)
from repro.fault import CorruptIndexError

from repro.core import distances as D
from repro.core.angles import AngleProfile, sample_angle_profile
from repro.core.graph import GraphIndex
from repro.core.hnsw import build_hnsw
from repro.core.nsg import build_nsg
from repro.core.knn_graph import build_knn_graph
from repro.core.search import SearchResult, build_search_fn
from repro.core.spec import SearchSpec, SearchStats, resolve_search_spec

GRAPH_BUILDERS = {"hnsw": build_hnsw, "nsg": build_nsg, "knn": build_knn_graph}

# What a bare `idx.search(queries)` means (matches the historical kwarg
# defaults; note SearchSpec() itself defaults to router="none").
DEFAULT_SEARCH = SearchSpec(k=10, efs=100, router="crouting")

# .npz payload schema version.  v1 (implicit — no stamp): pre-PR4 files
# missing theta_nq/theta_secs.  v2: format_version + theta_corpus_n stamps.
# v3: content ``checksum`` entry, required and verified on load.
FORMAT_VERSION = 3


@dataclasses.dataclass
class AnnIndex:
    graph: GraphIndex
    profile: Optional[AngleProfile] = None

    # --- construction --------------------------------------------------------
    @classmethod
    def build(cls, base: np.ndarray, graph: str = "hnsw", metric: str = "l2",
              profile_percentile: float = 90.0, seed: int = 0,
              profile: bool = True, **graph_kw) -> "AnnIndex":
        g = GRAPH_BUILDERS[graph](base, metric=metric, seed=seed, **graph_kw) \
            if graph != "knn" else build_knn_graph(base, metric=metric, **graph_kw)
        prof = sample_angle_profile(g, percentile=profile_percentile, seed=seed) \
            if profile else None
        return cls(graph=g, profile=prof)

    # --- search ---------------------------------------------------------------
    def _engine(self, cfg: SearchSpec):
        # build_search_fn memoizes per (graph identity, canonical spec)
        return build_search_fn(self.graph, cfg)

    def search(self, queries: np.ndarray, spec: Optional[SearchSpec] = None
               ) -> Tuple[np.ndarray, np.ndarray, SearchStats]:
        """Batched search.  Returns (ids [B,k], dists [B,k], SearchStats).

        ``spec`` is the one configuration object; its ``metric`` and
        ``use_hierarchy`` fields are overridden from the index's graph, and
        ``cos_theta=None`` resolves to the sampled angle profile.  A pruning
        router with neither a profile nor an explicit ``cos_theta`` raises
        ``ValueError`` — the old silent ``0.0`` fallback made such routers
        prune at theta*=90 degrees and quietly tanked recall; non-pruning
        routers (which never read the threshold) keep the ``0.0``
        placeholder.  Slots with no result carry id -1 and distance +inf.
        Anything other than a ``SearchSpec`` (or ``None``) raises
        ``TypeError``.
        """
        import jax.numpy as jnp

        from repro.core.routers import get_router

        spec = resolve_search_spec(spec, DEFAULT_SEARCH, "AnnIndex.search")
        queries = D.preprocess_vectors(
            np.ascontiguousarray(queries, np.float32), self.graph.metric)
        cos_theta = spec.cos_theta
        if cos_theta is None:
            if self.profile is not None:
                cos_theta = self.profile.cos_theta_star
            elif get_router(spec.router).prunes:
                raise ValueError(
                    f"router {spec.router!r} prunes on the angle threshold, "
                    "but this index was built with profile=False and the "
                    "spec carries no explicit cos_theta — the old fallback "
                    "of cos_theta=0.0 silently pruned at theta*=90deg. "
                    "Build with profile=True, or set SearchSpec.cos_theta.")
            else:
                cos_theta = 0.0   # never read by a non-pruning router
        k = spec.k
        cfg = dataclasses.replace(
            spec, efs=max(spec.efs, k), metric=self.graph.metric,
            use_hierarchy=self.graph.upper_neighbors is not None)
        _, fn = self._engine(cfg)
        res: SearchResult = fn(jnp.asarray(queries),
                               jnp.asarray(cos_theta, jnp.float32))
        ids = np.asarray(res.ids[:, :k]).astype(np.int64)
        dists = np.array(res.dists[:, :k])
        # empty slots resolve to the pad row: mask BOTH columns (an id of -1
        # must never ship with the pad row's finite distance)
        pad = ids >= self.graph.n
        ids[pad] = -1
        dists[pad] = np.inf
        return ids, dists, SearchStats.from_result(res, router=spec.router)

    # --- persistence ----------------------------------------------------------
    def _payload(self) -> Dict[str, np.ndarray]:
        """The v3 .npz payload (sans checksum — the atomic writer stamps
        it).  Shared by ``save`` and the durability checkpoints, which
        embed this payload and extend it with mutation state."""
        g = self.graph
        payload = dict(
            format_version=np.asarray(FORMAT_VERSION),
            vectors=g.vectors, neighbors=g.neighbors, edge_eu_dist=g.edge_eu_dist,
            entry_point=np.asarray(g.entry_point), metric=np.asarray(g.metric),
            kind=np.asarray(g.kind),
        )
        if g.norms is not None:
            payload["norms"] = g.norms
        if g.upper_neighbors:
            payload["n_upper"] = np.asarray(len(g.upper_neighbors))
            for i, (ids, mat) in enumerate(zip(g.upper_ids, g.upper_neighbors)):
                payload[f"upper_ids_{i}"] = ids
                payload[f"upper_nbrs_{i}"] = mat
        if self.profile is not None:
            payload["theta_samples"] = self.profile.samples
            payload["theta_star"] = np.asarray(self.profile.theta_star)
            payload["theta_pct"] = np.asarray(self.profile.percentile)
            payload["theta_nq"] = np.asarray(self.profile.n_sample_queries)
            payload["theta_secs"] = np.asarray(self.profile.sample_secs)
            payload["theta_corpus_n"] = np.asarray(self.profile.corpus_n)
        return payload

    def save(self, path: str):
        """Atomically persist the index (temp file + fsync + rename).

        The payload carries a content checksum; a crash at ANY point leaves
        ``path`` holding either the previous version or the complete new
        one — ``load`` can never silently accept a torn write.  Failpoint
        sites: ``index.save.write`` (raise = crash mid-save; ``corrupt`` /
        ``truncate`` = damage the bytes before publication, exercising the
        ``load`` integrity checks) and ``index.save.rename`` (crash in the
        write→publish window).  The recipe lives in ``repro.durable.atomic``
        and is shared with checkpoints and manifests (DESIGN.md §11).
        """
        atomic_write_npz(path, self._payload(),
                         write_site="index.save.write",
                         rename_site="index.save.rename")

    @classmethod
    def load(cls, path: str) -> "AnnIndex":
        """Load a persisted index, verifying integrity first.

        Truncated or corrupted files — unreadable zip structure, entry
        decompression failures, or (v3+) a content-checksum mismatch —
        raise ``CorruptIndexError``.  A future ``format_version`` raises
        ``ValueError`` (an incompatibility, not damage).
        """
        z = read_npz(path)
        cls._check_version(z, path)
        return cls._from_payload(z)

    @staticmethod
    def _check_version(z: Dict[str, np.ndarray], path: str) -> int:
        """Version + checksum gate shared with the checkpoint reader.

        v1 files predate the stamp; anything NEWER than we know must fail
        loudly instead of silently defaulting fields it doesn't understand.
        v3+ files always carry a checksum (verified here); a missing or
        stale one means the payload was modified after the save stamped it.
        """
        version = int(z["format_version"]) if "format_version" in z else 1
        if version > FORMAT_VERSION:
            raise ValueError(
                f"{path}: index format_version={version} is newer than this "
                f"build understands (max {FORMAT_VERSION}); upgrade the code "
                "or re-save the index with a compatible version")
        if version >= 3:
            verify_checksum(path, z, required=True)
        return version

    @classmethod
    def _from_payload(cls, z: Dict[str, np.ndarray]) -> "AnnIndex":
        """Rebuild graph + profile from a (verified) payload dict.  Extra
        keys (a checkpoint's mutation state) are ignored."""
        version = int(z["format_version"]) if "format_version" in z else 1
        upper_ids = upper_nbrs = None
        if "n_upper" in z:
            k = int(z["n_upper"])
            upper_ids = [z[f"upper_ids_{i}"] for i in range(k)]
            upper_nbrs = [z[f"upper_nbrs_{i}"] for i in range(k)]
        g = GraphIndex(
            vectors=z["vectors"], neighbors=z["neighbors"],
            edge_eu_dist=z["edge_eu_dist"], entry_point=int(z["entry_point"]),
            metric=str(z["metric"]), norms=z.get("norms"),
            upper_ids=upper_ids, upper_neighbors=upper_nbrs, kind=str(z["kind"]))
        prof = None
        if "theta_samples" in z:
            th = float(z["theta_star"])
            if version >= 2:
                # v2 files always carry these; read strictly (a missing key
                # here means corruption, not an old writer)
                nq, secs = int(z["theta_nq"]), float(z["theta_secs"])
                corpus_n = int(z["theta_corpus_n"])
            else:
                # v1 (pre-PR4) files legitimately lack them
                nq = int(z["theta_nq"]) if "theta_nq" in z else 0
                secs = float(z["theta_secs"]) if "theta_secs" in z else 0.0
                corpus_n = 0
            prof = AngleProfile(theta_star=th, cos_theta_star=float(np.cos(th)),
                                percentile=float(z["theta_pct"]),
                                samples=z["theta_samples"],
                                n_sample_queries=nq, sample_secs=secs,
                                corpus_n=corpus_n)
        return cls(graph=g, profile=prof)

"""schnet [gnn] — 3 interactions, d=64, 300 RBF, cutoff 10 [arXiv:1706.08566]."""
from repro.configs import ArchSpec
from repro.configs.shapes import GNN_SHAPES
from repro.models.gnn import GnnConfig

SPEC = ArchSpec(
    arch_id="schnet",
    family="gnn",
    model_cfg=GnnConfig(name="schnet", arch="schnet", n_layers=3, d_hidden=64,
                        n_rbf=300, cutoff=10.0, task="graph_reg"),
    shapes=GNN_SHAPES,
    source="arXiv:1706.08566; paper",
    smoke_cfg=GnnConfig(name="schnet-smoke", arch="schnet", n_layers=2,
                        d_hidden=16, n_rbf=8, cutoff=5.0, task="graph_reg"),
)

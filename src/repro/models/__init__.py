# Model substrate: the 10 assigned architectures as selectable configs.
#   layers.py       transformer blocks (RMSNorm/RoPE/GQA/SwiGLU/MoE)
#   transformer.py  dense + MoE decoder LMs (scan-over-layers)
#   gnn.py          GIN / GAT / SchNet / EGNN via segment ops
#   dlrm.py         DLRM w/ manual EmbeddingBag (take + segment_sum)
#   api.py          (arch x shape) -> lowerable Cell + smoke builders

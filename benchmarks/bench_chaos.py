"""Chaos harness (persisted to committed BENCH_chaos.json).

Replays a SEEDED fault schedule (repro.fault failpoints — deterministic
for a given seed and call order) through the full serving stack: a
``ServeFrontend`` over a 3-shard ``MutableShardedAnnIndex`` taking
inserts/deletes while ragged search requests stream in.  Four phases:

1. **Chaos trace** — intermittent shard-0 kills (``shard.search.0``),
   whole-dispatch faults (``serve.dispatch``) and a bounded merge fault
   (``mutate.merge.build``, ``max_fires=2`` so the retry budget recovers
   it) all armed at once.  Acceptance: EVERY admitted request resolves —
   a result (possibly degraded) or a typed error, never a hang.
2. **Recall under degradation** — controlled A/B: the same queries with
   all shards healthy vs. shard 0 hard-down.  Degraded searches must
   return results from the survivors with ``stats.shards_failed > 0``.
3. **Merge recovery** — a freshly armed ``max_fires=2`` merge fault, then
   a forced delta drain: the shard must recover within the retry budget
   (no quarantine) while serving from its pre-merge snapshot, and the
   wall-clock to the recovered epoch is recorded.
4. **Quarantine round-trip** — an always-firing merge fault exhausts the
   budget: the shard quarantines, searches and mutations keep working,
   and after the fault heals + ``clear_quarantine()`` the next drain
   merges cleanly.

``BENCH_SMOKE=1`` shrinks sizes and diverts the JSON to .cache/.
"""
from __future__ import annotations

import time
from concurrent.futures import TimeoutError as FutureTimeout

import numpy as np

from benchmarks.common import (SMOKE, dataset, emit, persist_bench,
                               smoke_scale)
from repro import fault
from repro.core.index import AnnIndex
from repro.core.spec import SearchSpec
from repro.data.vectors import recall_at_k
from repro.mutate import (MergeQuarantinedError, MutableShardedAnnIndex,
                          MutateConfig)
from repro.serve import ServeFrontend

BUCKETS = (1, 4, 8) if SMOKE else (1, 8, 32)
N_REQUESTS = 12 if SMOKE else 48
N_SHARDS = 3
HNSW_KW = dict(m=8, efc=48) if SMOKE else dict(m=12, efc=64)

# the seeded chaos schedule for phase 1 (recorded verbatim in the JSON)
SCHEDULE = {
    "shard.search.0": dict(kind="raise", p=0.15, seed=113),
    "serve.dispatch": dict(kind="raise", p=0.08, seed=102),
    "mutate.merge.build": dict(kind="raise", max_fires=2, seed=103),
}


def _gt_live(ds, live: np.ndarray, k: int) -> np.ndarray:
    dist = np.sum((ds.queries[:, None, :].astype(np.float64)
                   - ds.base[None, :, :].astype(np.float64)) ** 2, axis=-1)
    dist[:, ~live] = np.inf
    return np.argsort(dist, axis=1)[:, :k]


def _request_sizes(n_requests: int, top: int, seed: int = 7) -> np.ndarray:
    rng = np.random.default_rng(seed)
    sizes = np.exp(rng.uniform(0, np.log(top + 1), n_requests)).astype(int)
    return np.clip(sizes, 1, top)


def chaos_serving():
    """Availability + degradation + recovery under a seeded fault schedule."""
    fault.disarm()
    ds = dataset("sift-synth", n_base=smoke_scale(3000, 600))
    n_total = ds.base.shape[0]
    n0 = int(n_total * 0.8)               # the rest streams in during chaos
    per = n0 // N_SHARDS
    spec = SearchSpec(efs=64, k=10, router="crouting")
    cfg = MutateConfig(
        delta_capacity=smoke_scale(128, 32), auto_merge="background",
        merge_threshold=0.5, graph="hnsw", graph_kw=dict(HNSW_KW),
        merge_retries=3, merge_backoff_s=0.02, merge_backoff_cap_s=0.2,
        quarantine_cooldown_s=30.0)
    shards = [AnnIndex.build(ds.base[i * per:(i + 1) * per], graph="hnsw",
                             **HNSW_KW) for i in range(N_SHARDS)]
    ms = MutableShardedAnnIndex(shards, config=cfg, spec=spec)
    fe = ServeFrontend(ms, spec, buckets=BUCKETS,
                       max_pending_rows=4 * BUCKETS[-1])
    # external id == base row: shards wrap ds.base[:n0] in order and the
    # streaming inserts below append ds.base[n0:] in order
    live = np.zeros(n_total, bool)
    live[:N_SHARDS * per] = True

    # --- phase 1: seeded chaos trace -----------------------------------
    rng = np.random.default_rng(21)
    sizes = _request_sizes(N_REQUESTS, BUCKETS[-1])
    ins_chunk = max(1, (n_total - n0) // N_REQUESTS)
    next_ins = N_SHARDS * per
    futs = []
    with fault.scoped({s: fault.FaultSpec(**kw)
                       for s, kw in SCHEDULE.items()}):
        for i, sz in enumerate(sizes):
            rows = rng.integers(0, len(ds.queries), int(sz))
            futs.append(fe.submit(ds.queries[rows]))
            fe.flush()
            if next_ins < n_total:
                hi = min(n_total, next_ins + ins_chunk)
                ms.insert(ds.base[next_ins:hi])
                live[next_ins:hi] = True
                next_ins = hi
            if i % 5 == 4:
                kill = rng.choice(np.flatnonzero(live), 2, replace=False)
                ms.delete(kill)
                live[kill] = False
        ms.wait_for_merges()
        fe.flush()
        fired = fault.snapshot()          # per-site hit/fire accounting

    resolved_ok = resolved_err = degraded_results = hangs = 0
    error_types: dict = {}
    for f in futs:
        try:
            _ids, _d, st = f.result(timeout=120)
            resolved_ok += 1
            if st.degraded:
                degraded_results += 1
        except (FutureTimeout, TimeoutError):
            hangs += 1                    # an admitted future hung: fatal
        except Exception as e:            # noqa: BLE001 — typed resolution
            resolved_err += 1
            error_types[type(e).__name__] = \
                error_types.get(type(e).__name__, 0) + 1
    admitted = len(futs)
    assert hangs == 0, f"{hangs} admitted futures never resolved"
    availability = (resolved_ok + resolved_err) / admitted
    assert availability == 1.0
    trace_epochs = ms.epochs

    # --- phase 2: recall under controlled degradation -------------------
    ms.wait_for_merges()
    gt = _gt_live(ds, live, spec.k)
    ids0, _, st0 = ms.search(ds.queries, spec=spec)
    recall_base = recall_at_k(ids0, gt, spec.k)
    assert st0.shards_failed == 0 and not st0.degraded
    fault.arm("shard.search.0", kind="raise")     # shard 0 hard-down
    ids1, _, st1 = ms.search(ds.queries, spec=spec)
    fault.disarm()
    recall_degraded = recall_at_k(ids1, gt, spec.k)
    assert st1.degraded and st1.shards_failed == 1, st1
    assert (ids1 >= 0).all(), "survivors must fill the pool"
    s0 = set(int(e) for e in ms.shards[0]._state.snapshot.ext_ids)
    assert not any(int(i) in s0 for i in ids1.ravel()), \
        "a dead shard's ids leaked into a degraded result"
    assert recall_degraded >= 0.25, recall_degraded

    # --- phase 3: merge recovery within the retry budget ----------------
    retries_before = sum(s.merge_retries_used for s in ms.shards)
    epochs_before = ms.epochs
    fault.arm("mutate.merge.build", kind="raise", max_fires=2)
    t0 = time.perf_counter()
    need = int(cfg.merge_threshold * cfg.delta_capacity) + 1
    ms.insert(ds.base[rng.integers(0, n_total, need)]
              + rng.normal(0, 1e-3, (need, ds.base.shape[1]))
              .astype(np.float32))
    # pre-merge snapshot serves while the faulted merge retries
    mid_ids, _, _ = ms.search(ds.queries[:8], spec=spec)
    assert (mid_ids >= 0).all()
    ms.wait_for_merges()
    recovery_s = time.perf_counter() - t0
    fault.disarm()
    retries_used = sum(s.merge_retries_used for s in ms.shards) \
        - retries_before
    assert sum(ms.epochs) > sum(epochs_before), \
        "faulted merge did not recover within the retry budget"
    assert not any(s.quarantined for s in ms.shards)
    assert retries_used >= 2, retries_used

    # --- phase 4: quarantine round-trip ---------------------------------
    fault.arm("mutate.merge.build", kind="raise")  # never heals (until we do)
    q_entered = q_served = False
    try:
        for _ in range(2 * cfg.delta_capacity):
            ms.insert(ds.base[rng.integers(0, n_total, 4)]
                      + rng.normal(0, 1e-3, (4, ds.base.shape[1]))
                      .astype(np.float32))
            ms.wait_for_merges()
            if ms.quarantined_shards:
                q_entered = True
                break
    except MergeQuarantinedError:
        q_entered = True                 # delta filled before we polled
    q_ids, _, _ = ms.search(ds.queries[:8], spec=spec)
    q_served = bool((q_ids >= 0).all())
    assert q_entered and q_served
    fault.disarm()                        # the fault "heals"
    ms.clear_quarantine()
    epochs_q = ms.epochs
    ms.insert(ds.base[rng.integers(0, n_total, need)]
              + rng.normal(0, 1e-3, (need, ds.base.shape[1]))
              .astype(np.float32))
    ms.wait_for_merges()
    assert sum(ms.epochs) > sum(epochs_q), "post-quarantine merge failed"
    assert not ms.quarantined_shards

    summ = fe.telemetry.summary()
    payload = {
        "n_base_start": N_SHARDS * per, "n_shards": N_SHARDS,
        "delta_capacity": cfg.delta_capacity,
        "schedule": SCHEDULE,
        "faults_fired": fired,
        "trace": {
            "admitted": admitted, "rows": int(sizes.sum()),
            "resolved_ok": resolved_ok, "resolved_typed_error": resolved_err,
            "hangs": hangs, "degraded_results": degraded_results,
            "error_types": error_types, "epochs_after_trace": trace_epochs,
        },
        "availability": availability,
        "recall": {
            "healthy": round(recall_base, 3),
            "one_shard_down": round(recall_degraded, 3),
            "ratio": round(recall_degraded / max(recall_base, 1e-9), 4),
        },
        "merge_recovery": {
            "retries_used": retries_used,
            "recovery_s": round(recovery_s, 3),
            "epochs_before": epochs_before, "epochs_after": ms.epochs,
        },
        "quarantine": {"entered": q_entered, "served_during": q_served,
                       "recovered": True},
        "telemetry": {
            "requests": summ["requests"],
            "dispatch_failures": summ["dispatch_failures"],
            "worker_errors": summ["worker_errors"],
            "recompiles_after_warmup": summ["recompiles_after_warmup"],
        },
    }
    emit("chaos_serving", 0.0, {
        "availability": availability, "degraded": degraded_results,
        "typed_errors": resolved_err,
        "recall_ratio": payload["recall"]["ratio"],
        "merge_retries": retries_used,
        "recovery_s": payload["merge_recovery"]["recovery_s"]})
    persist_bench("chaos_serving", payload, file="BENCH_chaos.json")
    return payload

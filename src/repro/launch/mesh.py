"""Production mesh construction (DESIGN.md §6).

A FUNCTION, not a module-level constant: importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before any jax init; smoke tests
see the real single device).
"""
from __future__ import annotations

import jax


def _axis_type_kw(n_axes: int) -> dict:
    """jax.sharding.AxisType landed after 0.4.37; omit the kwarg when the
    installed jax predates it (Auto is the default there anyway)."""
    at = getattr(jax.sharding, "AxisType", None)
    return {"axis_types": (at.Auto,) * n_axes} if at is not None else {}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_type_kw(len(axes)))


def data_axes(mesh) -> tuple:
    """The axes the batch dimension shards over ('pod' folds into data)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def make_local_mesh(n: int = 1, name: str = "data"):
    """Mesh over whatever devices exist (tests / examples)."""
    n = min(n, len(jax.devices()))
    return jax.make_mesh((n,), (name,), **_axis_type_kw(1))

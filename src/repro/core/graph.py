"""Graph-index container shared by HNSW / NSG / KNN-graph builders.

TPU-native representation (DESIGN.md §3): adjacency is a padded int32
``[N, M]`` matrix (pad = N sentinel) with a parallel ``[N, M]`` float32 matrix
of *Euclidean* edge distances — the extra state CRouting keeps from
construction.  A node's neighborhood and its stored distances stream as one
contiguous DMA.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np


@dataclasses.dataclass
class GraphIndex:
    """Layer-0 search graph + optional HNSW upper layers."""

    vectors: np.ndarray          # [N, d] float32 (normalized when metric=cosine)
    neighbors: np.ndarray        # [N, M] int32, pad = N
    edge_eu_dist: np.ndarray     # [N, M] float32 Euclidean dist c->n, pad = +inf
    entry_point: int
    metric: str = "l2"
    norms: Optional[np.ndarray] = None   # [N] float32, required for ip/cosine
    # HNSW hierarchy: per upper layer (top..1), node ids and their adjacency
    # *into global id space*; empty for flat graphs (NSG / KNN).
    upper_ids: Optional[List[np.ndarray]] = None       # each [n_l] int64
    upper_neighbors: Optional[List[np.ndarray]] = None  # each [n_l, M_up] int32 global ids, pad = N
    # Provenance / bookkeeping.
    kind: str = "flat"
    build_stats: Optional[dict] = None

    @property
    def n(self) -> int:
        return self.vectors.shape[0]

    @property
    def dim(self) -> int:
        return self.vectors.shape[1]

    @property
    def max_degree(self) -> int:
        return self.neighbors.shape[1]

    def memory_bytes(self, with_edge_dist: bool = True) -> dict:
        """Index-size accounting (paper Table 7): vectors + graph + mem_dist."""
        out = {
            "vectors": int(self.vectors.nbytes),
            "graph": int(self.neighbors.nbytes),
            "mem_dist": int(self.edge_eu_dist.nbytes) if with_edge_dist else 0,
        }
        if self.upper_neighbors:
            out["graph"] += int(sum(a.nbytes for a in self.upper_neighbors))
        if self.norms is not None:
            out["norms"] = int(self.norms.nbytes)
        out["total"] = sum(v for k, v in out.items() if k != "total")
        return out


def pad_adjacency(adj_lists: List[np.ndarray], dists: List[np.ndarray],
                  n: int, max_degree: int):
    """Lists-of-neighbors -> padded [N, M] matrices (pad id = n, pad dist = inf)."""
    nb = np.full((n, max_degree), n, dtype=np.int32)
    ed = np.full((n, max_degree), np.inf, dtype=np.float32)
    for i, (a, d) in enumerate(zip(adj_lists, dists)):
        m = min(len(a), max_degree)
        nb[i, :m] = a[:m]
        ed[i, :m] = d[:m]
    return nb, ed


def validate_graph(g: GraphIndex, check_dists: bool = True, atol: float = 1e-3):
    """Structural invariants used by property tests."""
    n = g.n
    assert g.neighbors.shape == g.edge_eu_dist.shape
    assert g.neighbors.dtype == np.int32
    valid = g.neighbors < n
    assert (g.neighbors[valid] >= 0).all()
    assert np.isinf(g.edge_eu_dist[~valid]).all(), "pad slots must be +inf"
    if check_dists and n <= 20_000:
        # spot-check stored edge distances against recomputation
        rng = np.random.default_rng(0)
        rows = rng.integers(0, n, size=min(64, n))
        for i in rows:
            nbrs = g.neighbors[i][g.neighbors[i] < n]
            if len(nbrs) == 0:
                continue
            d = np.linalg.norm(g.vectors[nbrs] - g.vectors[i], axis=1)
            s = g.edge_eu_dist[i][: len(nbrs)]
            assert np.allclose(d, s, atol=atol, rtol=1e-3), (i, d[:4], s[:4])

"""Checker modules — importing this package registers all checker ids."""
from repro.analysis.checkers import (cache_key, fail_open, failpoint_sync,
                                     locks, trace_safety)

__all__ = ["cache_key", "fail_open", "failpoint_sync", "locks",
           "trace_safety"]

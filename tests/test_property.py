"""Hypothesis property tests on the system's invariants."""
import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip(
    "hypothesis",
    reason="optional dev dependency (requirements-dev.txt); not in the "
           "baked container image")
from hypothesis import given, settings, strategies as st
import hypothesis.extra.numpy as hnp

pytestmark = pytest.mark.slow

from repro.core import distances as D
from repro.kernels import ref as kref

SET = settings(max_examples=25, deadline=None)

vecs = hnp.arrays(np.float32, st.tuples(st.integers(2, 6), st.just(8)),
                  elements=st.floats(-3, 3, width=32))


@SET
@given(vecs, vecs)
def test_l2_metric_axioms(a, b):
    d_ab = D.pairwise_np(a, b, "l2")
    d_ba = D.pairwise_np(b, a, "l2").T
    assert np.allclose(d_ab, d_ba, atol=1e-4)          # symmetry
    assert (d_ab >= -1e-5).all()                       # non-negativity
    d_aa = np.diag(D.pairwise_np(a, a, "l2"))
    assert np.allclose(d_aa, 0.0, atol=1e-4)           # identity


@SET
@given(vecs)
def test_ip_euclid_conversion_roundtrip(a):
    """Paper Eq. 4 is exact: rank -> eu2 -> rank is the identity."""
    q = a[:1]
    rank = D.pairwise_np(q, a, "ip")[0]
    nq = np.linalg.norm(q)
    na = np.linalg.norm(a, axis=1)
    eu = D.rank_to_eu_np(rank, nq, na, "ip")
    rank2 = (eu**2 - na**2 - nq**2 + 2.0) / 2.0
    # fp32 cancellation: |a|^2+|q|^2-2<a,q> loses ~1e-3 relative precision
    scale = 1.0 + float(nq * na.max())
    assert np.allclose(rank, rank2, atol=1e-3 * scale)
    direct = np.linalg.norm(a - q, axis=1)
    assert np.allclose(eu, direct, atol=5e-3 * np.sqrt(scale))


@SET
@given(st.floats(0.05, 3.0), st.floats(0.05, 3.0), st.floats(0.01, 3.1))
def test_cosine_estimate_exact_at_true_angle(dcq, dcn, theta):
    """If theta* equals the true angle, the estimate is the true distance."""
    true2 = dcn**2 + dcq**2 - 2 * dcn * dcq * np.cos(theta)
    est2, _ = kref.crouting_prune_ref(
        jnp.asarray([[dcn]], jnp.float32), jnp.asarray([dcq], jnp.float32),
        jnp.asarray([1e9], jnp.float32), jnp.asarray([[1]], jnp.int8),
        float(np.cos(theta)))
    assert abs(float(est2[0, 0]) - max(true2, 0)) < 1e-3 * max(true2, 1)


@SET
@given(st.floats(0.05, 2.0), st.floats(0.05, 2.0),
       st.floats(0.1, 1.5), st.floats(0.05, 1.4))
def test_estimate_monotone_in_theta(dcq, dcn, th1, dth):
    """Fig. 13 mechanism: larger theta* -> larger estimate -> more pruning."""
    th2 = th1 + dth
    e1, _ = kref.crouting_prune_ref(
        jnp.asarray([[dcn]], jnp.float32), jnp.asarray([dcq], jnp.float32),
        jnp.asarray([1e9], jnp.float32), jnp.asarray([[1]], jnp.int8),
        float(np.cos(th1)))
    e2, _ = kref.crouting_prune_ref(
        jnp.asarray([[dcn]], jnp.float32), jnp.asarray([dcq], jnp.float32),
        jnp.asarray([1e9], jnp.float32), jnp.asarray([[1]], jnp.int8),
        float(np.cos(th2)))
    assert float(e2[0, 0]) >= float(e1[0, 0]) - 1e-5


@SET
@given(hnp.arrays(np.float32, st.tuples(st.integers(1, 4), st.just(6)),
                  elements=st.floats(0, 10, width=32)),
       hnp.arrays(np.float32, st.tuples(st.integers(1, 4), st.just(4)),
                  elements=st.floats(0, 10, width=32)))
def test_pool_merge_invariants(pool_d, new_d):
    """Merged pool: sorted, size P, equals top-P of the multiset union."""
    b = min(pool_d.shape[0], new_d.shape[0])
    pool_d = np.sort(pool_d[:b], axis=1)
    new_d = new_d[:b]
    pi = np.arange(pool_d.size, dtype=np.int32).reshape(pool_d.shape)
    ni = (np.arange(new_d.size, dtype=np.int32) + 10_000).reshape(new_d.shape)
    d, i = kref.pool_merge_ref(jnp.asarray(pool_d), jnp.asarray(pi),
                               jnp.asarray(new_d), jnp.asarray(ni))
    d = np.asarray(d)
    assert (np.diff(d, axis=1) >= -1e-6).all()
    for r in range(b):
        union = np.sort(np.concatenate([pool_d[r], new_d[r]]))
        assert np.allclose(d[r], union[: pool_d.shape[1]])


@SET
@given(st.integers(1, 40), st.integers(2, 20), st.integers(0, 1_000_000))
def test_embedding_bag_equals_onehot_matmul(n_ids, vocab, seed):
    """EmbeddingBag (take + segment_sum) == one-hot matmul."""
    from repro.models.dlrm import embedding_bag
    rng = np.random.default_rng(seed)
    table = rng.normal(size=(vocab, 8)).astype(np.float32)
    ids = rng.integers(0, vocab, size=n_ids).astype(np.int32)
    bags = np.sort(rng.integers(0, 3, size=n_ids)).astype(np.int32)
    out = embedding_bag(jnp.asarray(table), jnp.asarray(ids),
                        jnp.asarray(bags), 3)
    onehot = np.zeros((3, vocab), np.float32)
    for i, b in zip(ids, bags):
        onehot[b, i] += 1.0
    np.testing.assert_allclose(np.asarray(out), onehot @ table, rtol=1e-4,
                               atol=1e-4)


@SET
@given(st.integers(2, 30), st.integers(0, 10_000))
def test_segment_softmax_equals_dense(n_edges, seed):
    """Edge softmax over dst segments == dense row softmax on the
    materialized adjacency."""
    from repro.models.gnn import segment_softmax
    rng = np.random.default_rng(seed)
    n_nodes = 5
    dst = rng.integers(0, n_nodes, size=n_edges).astype(np.int32)
    scores = rng.normal(size=n_edges).astype(np.float32)
    alpha = np.asarray(segment_softmax(jnp.asarray(scores), jnp.asarray(dst),
                                       n_nodes))
    for v in range(n_nodes):
        m = dst == v
        if m.sum():
            expect = np.exp(scores[m] - scores[m].max())
            expect /= expect.sum()
            np.testing.assert_allclose(alpha[m], expect, rtol=1e-4, atol=1e-5)

"""Pallas TPU kernel: FUSED CRouting expansion step.

One kernel per query lane performs the paper's whole inner loop (Alg. 2,
lines 7-16 minus the pool update) over a flat tile of L neighbor slots —
for the beam-expansion engine L = W*M (W frontier nodes per hop, each with
M neighbor slots; see core/search.py):

    est2 = ed^2 + dcq^2 - 2*ed*dcq*cos(theta*)        (VPU, no vector data)
    prune = prune_eligible & (est2 >= bound2)
    for m in range(L):
        if eval_mask[m] and not prune[m]:
            row = table[nbr[m]]       <-- the point: the HBM row DMA for the
            dist2[m] = |q - row|^2        neighbor vector is *conditionally
        else:                             skipped* for pruned lanes
            dist2[m] = +inf

`ed`, `dcq` and `bound2` are per-lane [B, L]: with a beam each lane belongs
to one of W expansion nodes, so the query distance (and, for non-L2 metrics,
the rank-space bound) varies across the tile.  `eval_mask` marks lanes whose
exact distance the caller wants if not pruned (valid + not-visited, computed
from the status array); `prune_eligible` marks lanes the estimate test
applies to (unvisited + pool-full).  Both default to "nbr id in range" in
the ops wrapper for standalone use.

This is the kernel-level realization of "CRouting skips the distance call":
on TPU the savings are the skipped random HBM reads (DESIGN.md §3).  The
conditional DMA is expressed with lax.cond inside a fori_loop over neighbor
slots; the estimate lives entirely in VMEM/registers.

Grid: (B,).  Per-step VMEM: q (1,d) + one table row (1,d) + the L-wide
scalars — tiny; the table stays in ANY/HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _expand_kernel(nbr_ref, q_ref, ed_ref, dcq_ref, bound2_ref, ct_ref,
                   eval_ref, elig_ref, table_ref, dist_ref, mask_ref, *,
                   m_slots: int, n_rows: int):
    b = pl.program_id(0)
    q = q_ref[0, :].astype(jnp.float32)                # [d]
    dcq = dcq_ref[0, :]                                # [L] per-lane d(c,q)
    b2 = bound2_ref[0, :]                              # [L] per-lane bound
    ct = ct_ref[0]

    ed = ed_ref[0, :]                                  # [L] stored d(c,n)
    est2 = jnp.maximum(ed * ed + dcq * dcq - 2.0 * ed * dcq * ct, 0.0)
    elig = elig_ref[0, :] != 0
    prune = elig & (est2 >= b2)
    evalm = eval_ref[0, :] != 0
    mask_ref[0, :] = prune.astype(jnp.int8)

    def per_slot(m, _):
        def fetch(_):
            row = pl.load(table_ref,
                          (pl.dslice(nbr_ref[b, m], 1), slice(None)))
            diff = q - row[0, :].astype(jnp.float32)
            return jnp.sum(diff * diff)

        def skip(_):
            return jnp.float32(jnp.inf)

        do_fetch = evalm[m] & ~prune[m]
        d2 = jax.lax.cond(do_fetch, fetch, skip, operand=0)
        dist_ref[0, m] = d2
        return 0

    jax.lax.fori_loop(0, m_slots, per_slot, 0)


@functools.partial(jax.jit, static_argnames=("interpret",))
def fused_expand_pallas(nbrs, queries, ed, dcq, bound2, cos_theta,
                        eval_mask, prune_eligible, table, *,
                        interpret: bool = True):
    """nbrs [B,L] int32, queries [B,d], ed/dcq/bound2 [B,L] f32,
    eval_mask/prune_eligible [B,L] int8, table [N,d]
    -> (dist2 [B,L] with +inf for pruned/masked lanes, prune [B,L] int8)."""
    B, L = nbrs.shape
    d = queries.shape[1]
    N = table.shape[0]
    ct = jnp.asarray(cos_theta, jnp.float32).reshape(1)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, d), lambda b, idx: (b, 0)),     # query row
            pl.BlockSpec((1, L), lambda b, idx: (b, 0)),     # edge dists
            pl.BlockSpec((1, L), lambda b, idx: (b, 0)),     # d(c,q) per lane
            pl.BlockSpec((1, L), lambda b, idx: (b, 0)),     # bound^2 per lane
            pl.BlockSpec((1,), lambda b, idx: (0,)),         # cos theta*
            pl.BlockSpec((1, L), lambda b, idx: (b, 0)),     # eval mask
            pl.BlockSpec((1, L), lambda b, idx: (b, 0)),     # prune-eligible
            pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY),  # table in HBM
        ],
        out_specs=[
            pl.BlockSpec((1, L), lambda b, idx: (b, 0)),
            pl.BlockSpec((1, L), lambda b, idx: (b, 0)),
        ],
    )
    return pl.pallas_call(
        functools.partial(_expand_kernel, m_slots=L, n_rows=N),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((B, L), jnp.float32),
                   jax.ShapeDtypeStruct((B, L), jnp.int8)],
        interpret=interpret,
    )(nbrs, queries, ed, dcq, bound2, ct, eval_mask, prune_eligible, table)

"""Pallas TPU kernel: SQ8 quantized distance estimate + lower bound.

Stage 1 of the two-stage distance engine (core/search.py,
``SearchSpec.estimate``): for each candidate lane the kernel DMAs the
neighbor's **uint8 code row** (d bytes — 4x fewer than the fp32 row the
exact path fetches), dequantizes it against the per-dimension affine grid
and emits

    ad2[m] = |q - xhat|^2                     (the quantized estimate)
    lb2[m] = max(ad2 - 2 * sum|q - xhat|*eps, 0)   (conservative lower bound)

per lane — the identical f32 expression as ``repro.quant.sq8.sq8_estimate``
(the jnp oracle), so stage-1 skip decisions agree bit-for-bit between the
jnp and Pallas engines.  Lanes with ``eval_mask == 0`` skip the code-row DMA
entirely (lax.cond, like fused_expand's conditional fetch) and report +inf.

Grid: (B,).  Per-step VMEM: q/lo/scale/eps (1, d) rows + one code row + the
L-wide outputs — tiny; the code table stays in ANY/HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _sq8_kernel(nbr_ref, q_ref, lo_ref, scale_ref, eps_ref, eval_ref,
                codes_ref, ad2_ref, lb2_ref, *, m_slots: int):
    b = pl.program_id(0)
    q = q_ref[0, :].astype(jnp.float32)                # [d]
    lo = lo_ref[0, :]                                  # [d]
    scale = scale_ref[0, :]                            # [d]
    eps = eps_ref[0, :]                                # [d]
    evalm = eval_ref[0, :] != 0                        # [L]

    def per_slot(m, _):
        def fetch(_):
            row = pl.load(codes_ref,
                          (pl.dslice(nbr_ref[b, m], 1), slice(None)))
            xhat = lo + row[0, :].astype(jnp.float32) * scale
            delta = q - xhat
            ad2 = jnp.sum(delta * delta)
            slack = 2.0 * jnp.sum(jnp.abs(delta) * eps)
            return ad2, jnp.maximum(ad2 - slack, 0.0)

        def skip(_):
            return jnp.float32(jnp.inf), jnp.float32(jnp.inf)

        ad2, lb2 = jax.lax.cond(evalm[m], fetch, skip, operand=0)
        ad2_ref[0, m] = ad2
        lb2_ref[0, m] = lb2
        return 0

    jax.lax.fori_loop(0, m_slots, per_slot, 0)


@functools.partial(jax.jit, static_argnames=("interpret",))
def sq8_distance_pallas(nbrs, queries, lo, scale, eps, eval_mask, codes, *,
                        interpret: bool = True):
    """nbrs [B,L] int32, queries [B,d] f32, lo/scale/eps [d] f32,
    eval_mask [B,L] int8, codes [N,d] uint8
    -> (ad2 [B,L] f32, lb2 [B,L] f32), +inf for masked lanes."""
    B, L = nbrs.shape
    d = queries.shape[1]
    lo2 = lo.reshape(1, d).astype(jnp.float32)
    scale2 = scale.reshape(1, d).astype(jnp.float32)
    eps2 = eps.reshape(1, d).astype(jnp.float32)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, d), lambda b, idx: (b, 0)),     # query row
            pl.BlockSpec((1, d), lambda b, idx: (0, 0)),     # grid lo
            pl.BlockSpec((1, d), lambda b, idx: (0, 0)),     # grid scale
            pl.BlockSpec((1, d), lambda b, idx: (0, 0)),     # error radius
            pl.BlockSpec((1, L), lambda b, idx: (b, 0)),     # eval mask
            pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY),  # codes/HBM
        ],
        out_specs=[
            pl.BlockSpec((1, L), lambda b, idx: (b, 0)),
            pl.BlockSpec((1, L), lambda b, idx: (b, 0)),
        ],
    )
    return pl.pallas_call(
        functools.partial(_sq8_kernel, m_slots=L),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((B, L), jnp.float32),
                   jax.ShapeDtypeStruct((B, L), jnp.float32)],
        interpret=interpret,
    )(nbrs, queries, lo2, scale2, eps2, eval_mask, codes)

# Quantized vector representations for the distance engine.
#
#   sq8.py   per-dimension affine int8 scalar quantization (SQ8) of the base
#            vector table + the conservative distance lower bound the
#            two-stage engine prunes with (core/search.py,
#            SearchSpec.estimate), and the per-tensor symmetric int8
#            helpers shared with gradient compression (train/compress.py) —
#            ONE quantization implementation repo-wide.

from repro.quant import sq8  # noqa: F401

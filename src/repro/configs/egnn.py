"""egnn [gnn] — 4L, d=64, E(n)-equivariant [arXiv:2102.09844]."""
from repro.configs import ArchSpec
from repro.configs.shapes import GNN_SHAPES
from repro.models.gnn import GnnConfig

SPEC = ArchSpec(
    arch_id="egnn",
    family="gnn",
    model_cfg=GnnConfig(name="egnn", arch="egnn", n_layers=4, d_hidden=64,
                        task="graph_reg"),
    shapes=GNN_SHAPES,
    source="arXiv:2102.09844; paper",
    smoke_cfg=GnnConfig(name="egnn-smoke", arch="egnn", n_layers=2,
                        d_hidden=16, task="graph_reg"),
)

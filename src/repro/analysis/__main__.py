"""CLI: ``python -m repro.analysis [paths...] [--strict] [--json FILE]``."""
from __future__ import annotations

import argparse
import sys

from repro.analysis.core import CHECKERS
from repro.analysis.runner import render_text, run_analysis, write_json


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="repolint: repo-specific static analysis "
                    "(DESIGN.md §13)")
    ap.add_argument("paths", nargs="*", default=["src"],
                    help="files/directories to scan (default: src)")
    ap.add_argument("--root", default=None,
                    help="repo root anchoring relative paths and "
                         "DESIGN.md (default: inferred)")
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 on any active finding (CI mode)")
    ap.add_argument("--json", metavar="FILE", default=None,
                    help="also write the full report as JSON")
    ap.add_argument("--checks", default=None,
                    help="comma-separated checker ids (default: all)")
    ap.add_argument("--show-suppressed", action="store_true",
                    help="list suppressed findings in the text report")
    ap.add_argument("--list-checkers", action="store_true",
                    help="print the checker catalog and exit")
    args = ap.parse_args(argv)

    # the registry fills on import of repro.analysis.checkers (via runner)
    import repro.analysis.checkers  # noqa: F401

    if args.list_checkers:
        width = max(len(c) for c in CHECKERS)
        for cid, (_, desc) in CHECKERS.items():
            print(f"{cid:<{width}}  {desc}")
        return 0

    checks = ([c.strip() for c in args.checks.split(",") if c.strip()]
              if args.checks else None)
    result = run_analysis(root=args.root, paths=args.paths, checks=checks)
    print(render_text(result, show_suppressed=args.show_suppressed))
    if args.json:
        write_json(result, args.json)
    return result.exit_code_strict if args.strict else 0


if __name__ == "__main__":
    sys.exit(main())

"""Batched best-first graph search in JAX (the TPU-native serving hot path).

Re-derivation of the paper's Algorithm 1/2 for fixed-shape SPMD execution
(DESIGN.md §3):

* the candidate queue C and result queue T collapse into ONE sorted pool of
  size ``efs`` with per-slot expanded flags — provably equivalent to the
  two-heap formulation for expansion/termination decisions;
* per-node state is a dense uint8 status array (0 unvisited / 1 visited /
  2 pruned) — the pruned state doubles as CRouting's error-correction flag;
* one `lax.while_loop` iteration expands one node per query lane; all M
  neighbors are processed vector-wide: estimate + prune on the VPU path,
  exact distances on the MXU path, pool merge as a static sort.

Semantic note (tested in tests/test_engine_equivalence.py): within one
expansion the batched engine evaluates all M neighbors against the
*expansion-start* upper bound ("frozen bound"), whereas the scalar Algorithm 1
updates the bound after every insertion.  The final pool per expansion is
identical either way (merge-then-truncate == insert-with-evolving-bound); only
CRouting prune decisions can differ, strictly toward *fewer* prunes (frozen
bound >= evolving bound), i.e. toward accuracy.  The NumPy oracle exposes
``stale_bound=True`` to check exact equivalence, and live-vs-frozen deltas are
measured in benchmarks.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import distances as D
from repro.core.graph import GraphIndex

STATUS_UNVISITED = 0
STATUS_VISITED = 1
STATUS_PRUNED = 2


class SearchResult(NamedTuple):
    ids: jax.Array        # [B, efs] int32, N = empty
    dists: jax.Array      # [B, efs] ranking distance
    dist_calls: jax.Array  # [B] int32 exact distance evaluations
    est_calls: jax.Array   # [B] int32 cosine-theorem estimates
    hops: jax.Array        # [B] int32 node expansions


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    efs: int = 100
    router: str = "none"          # none | crouting | crouting_o | triangle
    metric: str = "l2"
    max_hops: int = 4096
    use_hierarchy: bool = True


def graph_device_arrays(g: GraphIndex) -> Dict[str, Any]:
    """Pack a GraphIndex into device arrays with a sentinel pad row at index N."""
    n, d = g.n, g.dim
    vecs = np.concatenate([g.vectors, np.zeros((1, d), np.float32)], axis=0)
    nbrs = np.concatenate([g.neighbors, np.full((1, g.max_degree), n, np.int32)], axis=0)
    ed = np.concatenate([g.edge_eu_dist, np.full((1, g.max_degree), np.inf, np.float32)], axis=0)
    norms = g.norms if g.norms is not None else np.linalg.norm(g.vectors, axis=1)
    norms = np.concatenate([norms.astype(np.float32), np.ones(1, np.float32)])
    out = {
        "vectors": jnp.asarray(vecs),
        "neighbors": jnp.asarray(nbrs),
        "edge_eu": jnp.asarray(ed),
        "norms": jnp.asarray(norms),
        "entry": jnp.asarray(g.entry_point, jnp.int32),
        "n": n,
    }
    # HNSW hierarchy: id->row maps + per-layer adjacency (top..1).
    if g.upper_neighbors:
        pos_maps, layer_nbrs = [], []
        for ids, mat in zip(g.upper_ids, g.upper_neighbors):
            pos = np.full(n + 1, -1, dtype=np.int32)
            pos[ids] = np.arange(len(ids), dtype=np.int32)
            pos_maps.append(jnp.asarray(pos))
            layer_nbrs.append(jnp.asarray(np.concatenate(
                [mat, np.full((1, mat.shape[1]), n, np.int32)], axis=0)))
        out["upper_pos"] = pos_maps
        out["upper_nbrs"] = layer_nbrs
    return out


def _rank_many(q, X, metric):
    """q [d], X [m, d] -> ranking distances [m]."""
    if metric == "l2":
        diff = X - q[None, :]
        return jnp.sum(diff * diff, axis=-1)
    return 1.0 - X @ q


def _rank_to_eu(rank, nq, nx, metric):
    if metric == "l2":
        return jnp.sqrt(jnp.maximum(rank, 0.0))
    return jnp.sqrt(jnp.maximum(nx * nx + nq * nq + 2.0 * rank - 2.0, 0.0))


def _eu2_to_rank(eu2, nq, nx, metric):
    if metric == "l2":
        return eu2
    return (eu2 - nx * nx - nq * nq + 2.0) / 2.0


def _descend(arrays, q, cfg: EngineConfig):
    """Greedy 1-NN descent through HNSW upper layers. Returns (entry, dist_calls)."""
    metric = cfg.metric
    cur = arrays["entry"]
    d_cur = _rank_many(q, arrays["vectors"][cur][None, :], metric)[0]
    calls = jnp.asarray(1, jnp.int32)
    if "upper_nbrs" not in arrays:
        return cur, d_cur, calls
    n = arrays["n"]
    for pos_map, lnbrs in zip(arrays["upper_pos"], arrays["upper_nbrs"]):
        def cond(s):
            cur, d_cur, calls, improved = s
            return improved

        def body(s):
            cur, d_cur, calls, _ = s
            row = pos_map[cur]
            nbrs = lnbrs[jnp.where(row >= 0, row, lnbrs.shape[0] - 1)]
            valid = nbrs < n
            dists = _rank_many(q, arrays["vectors"][nbrs], metric)
            dists = jnp.where(valid, dists, jnp.inf)
            calls = calls + jnp.sum(valid.astype(jnp.int32))
            j = jnp.argmin(dists)
            better = dists[j] < d_cur
            return (jnp.where(better, nbrs[j], cur).astype(jnp.int32),
                    jnp.where(better, dists[j], d_cur), calls, better)

        cur, d_cur, calls, _ = jax.lax.while_loop(
            cond, body, (cur, d_cur, calls, jnp.asarray(True)))
    return cur, d_cur, calls


def _search_one(arrays, q, cos_theta, cfg: EngineConfig):
    """Single-query Algorithm 1/2; vmapped over the query batch."""
    metric, efs, n = cfg.metric, cfg.efs, arrays["n"]
    router = cfg.router
    nq = jnp.linalg.norm(q) if metric != "l2" else jnp.asarray(1.0, jnp.float32)

    if cfg.use_hierarchy:
        entry, d_entry, calls0 = _descend(arrays, q, cfg)
    else:
        entry = arrays["entry"]
        d_entry = _rank_many(q, arrays["vectors"][entry][None, :], metric)[0]
        calls0 = jnp.asarray(1, jnp.int32)

    pool_d = jnp.full((efs,), jnp.inf, jnp.float32).at[0].set(d_entry)
    pool_id = jnp.full((efs,), n, jnp.int32).at[0].set(entry)
    pool_exp = jnp.zeros((efs,), bool)
    status = jnp.zeros((n + 1,), jnp.uint8).at[entry].set(STATUS_VISITED)

    State = (pool_d, pool_id, pool_exp, status, calls0,
             jnp.asarray(0, jnp.int32),  # est_calls
             jnp.asarray(0, jnp.int32),  # hops
             jnp.asarray(False))         # done

    def cond(s):
        *_, hops, done = s
        return (~done) & (hops < cfg.max_hops)

    def body(s):
        pool_d, pool_id, pool_exp, status, dcalls, ecalls, hops, done = s
        cand = (~pool_exp) & (pool_id < n)
        cand_d = jnp.where(cand, pool_d, jnp.inf)
        best = jnp.argmin(cand_d)
        has = jnp.any(cand)
        dc = pool_d[best]
        pool_full = pool_id[efs - 1] < n
        upper = jnp.where(pool_full, pool_d[efs - 1], jnp.inf)
        stop = (~has) | (dc > upper)
        live = ~stop

        c = pool_id[best]
        pool_exp = pool_exp.at[best].set(pool_exp[best] | live)

        nbrs = arrays["neighbors"][c]                 # [M]
        # stored edge distances may be bf16 (§Perf HC3); estimate math in f32
        ed = arrays["edge_eu"][c].astype(jnp.float32)  # [M]  Euclidean d(c, n)
        st = status[nbrs]                             # [M]
        in_range = nbrs < n
        valid = in_range & (st != STATUS_VISITED) & live

        # --- router: estimate + prune (no vector fetch on this path) -------
        if router in ("crouting", "crouting_o"):
            d_cq_eu = _rank_to_eu(dc, nq, arrays["norms"][c], metric)
            est2 = ed * ed + d_cq_eu * d_cq_eu - 2.0 * ed * d_cq_eu * cos_theta
            est_rank = _eu2_to_rank(jnp.maximum(est2, 0.0), nq, arrays["norms"][nbrs], metric)
            try_prune = valid & (st == STATUS_UNVISITED) & pool_full
            prune = try_prune & (est_rank >= upper)
            ecalls = ecalls + jnp.sum(try_prune.astype(jnp.int32))
            if router == "crouting_o":
                # no error correction: previously-pruned lanes stay skipped
                valid = valid & (st != STATUS_PRUNED)
            compute = valid & ~prune
        elif router == "triangle":
            d_cq_eu = _rank_to_eu(dc, nq, arrays["norms"][c], metric)
            lb = jnp.abs(ed - d_cq_eu)
            lb_rank = _eu2_to_rank(lb * lb, nq, arrays["norms"][nbrs], metric)
            try_prune = valid & (st == STATUS_UNVISITED) & pool_full
            prune = try_prune & (lb_rank >= upper)
            # exact lower bound => discard is permanent (mark visited below)
            compute = valid & ~prune
        else:
            prune = jnp.zeros_like(valid)
            compute = valid

        # --- exact distances (masked; the Pallas gather kernel skips the
        # HBM row fetch for ~compute lanes on real TPU) ----------------------
        gathered = arrays["vectors"][jnp.where(compute, nbrs, n)]
        exact = _rank_many(q, gathered, metric)
        dcalls = dcalls + jnp.sum(compute.astype(jnp.int32))

        # --- status scatter --------------------------------------------------
        if router == "triangle":
            new_st = jnp.where(compute | prune, STATUS_VISITED, st).astype(jnp.uint8)
        else:
            new_st = jnp.where(compute, STATUS_VISITED,
                               jnp.where(prune, STATUS_PRUNED, st)).astype(jnp.uint8)
        status = status.at[jnp.where(in_range & live, nbrs, n)].set(
            jnp.where(in_range & live, new_st, status[n]))

        # --- pool merge (merge-then-truncate == evolving-bound insertion) ---
        new_d = jnp.where(compute, exact, jnp.inf)
        new_id = jnp.where(compute, nbrs, n).astype(jnp.int32)
        md = jnp.concatenate([pool_d, new_d])
        mi = jnp.concatenate([pool_id, new_id])
        me = jnp.concatenate([pool_exp, jnp.zeros_like(compute)])
        order = jnp.argsort(md, stable=True)[:efs]
        pool_d, pool_id, pool_exp = md[order], mi[order], me[order]

        hops = hops + live.astype(jnp.int32)
        done = done | stop
        return (pool_d, pool_id, pool_exp, status, dcalls, ecalls, hops, done)

    pool_d, pool_id, pool_exp, status, dcalls, ecalls, hops, done = \
        jax.lax.while_loop(cond, body, State)
    return SearchResult(ids=pool_id, dists=pool_d, dist_calls=dcalls,
                        est_calls=ecalls, hops=hops)


def build_search_fn(g: GraphIndex, cfg: EngineConfig):
    """Returns (arrays, jitted fn(queries [B,d], cos_theta) -> SearchResult)."""
    arrays = graph_device_arrays(g)

    @functools.partial(jax.jit, static_argnames=())
    def run(queries, cos_theta):
        queries = queries.astype(jnp.float32)
        return jax.vmap(lambda q: _search_one(arrays, q, cos_theta, cfg))(queries)

    return arrays, run


def search_batch(g: GraphIndex, queries: np.ndarray, cfg: EngineConfig,
                 cos_theta: float = 0.0, k: Optional[int] = None) -> SearchResult:
    """Convenience one-shot batched search (jit per (graph, cfg))."""
    _, fn = build_search_fn(g, cfg)
    res = fn(jnp.asarray(queries), jnp.asarray(cos_theta, jnp.float32))
    if k is not None:
        res = SearchResult(ids=res.ids[:, :k], dists=res.dists[:, :k],
                           dist_calls=res.dist_calls, est_calls=res.est_calls,
                           hops=res.hops)
    return res

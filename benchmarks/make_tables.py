"""Format dryrun_results.json into the EXPERIMENTS.md §Dry-run / §Roofline
markdown tables.

    PYTHONPATH=src python -m benchmarks.make_tables [--mesh 16x16]
"""
from __future__ import annotations

import argparse
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "dryrun_results.json")


def fmt(x):
    return f"{x:.2e}"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--results", default=RESULTS)
    args = ap.parse_args()
    cache = json.load(open(args.results))
    rows = [r for r in cache.values()
            if r.get("status") == "ok" and r.get("mesh") == args.mesh]
    rows.sort(key=lambda r: (r["arch"], r["shape"]))

    print(f"### Roofline — mesh {args.mesh} "
          f"(TPU v5e: 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link)\n")
    print("| cell | step | compute s | memory s | collective s | dominant | "
          "MODEL/HLO flops | MFU ub | mem GB/dev | correction |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for r in rows:
        print(f"| {r['arch']}/{r['shape']} | {r['step'].replace('_step','')} "
              f"| {fmt(r['compute_s'])} | {fmt(r['memory_s'])} "
              f"| {fmt(r['collective_s'])} | {r['dominant'].replace('_s','')} "
              f"| {r.get('useful_flop_ratio', 0):.2f} "
              f"| {r.get('mfu_upper_bound', 0):.3f} "
              f"| {r['mem_total_bytes']/1e9:.2f} "
              f"| {r.get('loop_correction','-')} |")

    print("\n### Collective schedule summary\n")
    print("| cell | all-reduce | all-gather | reduce-scatter | all-to-all | "
          "permute | wire GB/dev |")
    print("|---|---|---|---|---|---|---|")
    for r in rows:
        c = r.get("collective_counts", {})
        print(f"| {r['arch']}/{r['shape']} | {c.get('all-reduce', 0)} "
              f"| {c.get('all-gather', 0)} | {c.get('reduce-scatter', 0)} "
              f"| {c.get('all-to-all', 0)} | {c.get('collective-permute', 0)} "
              f"| {r['collective_wire_bytes']/1e9:.2f} |")


if __name__ == "__main__":
    main()

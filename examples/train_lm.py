"""Train a ~100M-parameter LM for a few hundred steps on synthetic data with
checkpoint/restart (deliverable (b): end-to-end train driver).

    PYTHONPATH=src python examples/train_lm.py --steps 300
    PYTHONPATH=src python examples/train_lm.py --steps 300 --resume   # restart

The config is a scaled-down granite (same family as the assigned arch).
~100M params: 12L x d=512 x ff=2048 x vocab=8192.
"""
import argparse

import jax

from repro.data.synthetic import LMStream
from repro.models import transformer as T
from repro.train import optimizer as opt
from repro.train.trainer import Trainer, TrainerConfig

CFG_100M = T.LMConfig(name="granite-100m", n_layers=16, d_model=576,
                      n_heads=9, n_kv_heads=3, d_ff=2304, vocab=16384,
                      dtype="float32", block_q=64, block_k=128, loss_chunk=64)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_100m")
    args = ap.parse_args()

    cfg = CFG_100M
    print(f"{cfg.name}: {cfg.param_count()/1e6:.1f}M params")
    ocfg = opt.AdamWConfig(lr=3e-4, warmup_steps=30, total_steps=args.steps)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    state = opt.adamw_init(params, ocfg)
    stream = LMStream(cfg.vocab, args.batch, args.seq, seed=0)

    tr = Trainer(TrainerConfig(total_steps=args.steps, ckpt_every=100,
                               ckpt_dir=args.ckpt_dir, log_every=10,
                               step_deadline_s=60.0),
                 T.make_train_step(cfg, ocfg), params, state, stream)
    if args.resume and tr.maybe_resume():
        print(f"resumed at step {tr.step}")
    out = tr.run()
    print(f"loss {out['history'][0]:.3f} -> {out['final_loss']:.3f} "
          f"({len(out['stragglers'])} straggler events)")


if __name__ == "__main__":
    main()

"""Transformer building blocks: RMSNorm, RoPE, GQA attention (blockwise
train path + KV-cache decode path), SwiGLU, and the token-sorted MoE layer.

Everything is pure jnp + lax (SPMD-partitionable under pjit); parameters are
plain pytrees (no flax).  Shapes follow [batch, seq, heads, head_dim].
"""
from __future__ import annotations

import dataclasses
import functools
import jax
import jax.numpy as jnp
import numpy as np


# --------------------------------------------------------------------------
# init helpers
# --------------------------------------------------------------------------
def dense_init(key, shape, dtype, scale: float = 1.0):
    fan_in = shape[0] if len(shape) >= 2 else 1
    return (scale / np.sqrt(fan_in)) * jax.random.normal(key, shape, dtype=jnp.float32)


# --------------------------------------------------------------------------
# activation-sharding hints (MaxText-style). No-ops without a mesh context,
# and silently drop axes that are absent or don't divide the dimension —
# so the same model code runs in smoke tests (1 device) and the 512-chip
# dry-run unchanged.
# --------------------------------------------------------------------------
BATCH_AXES = ("pod", "data")


def shard_hint(x, *spec):
    try:
        from jax._src.mesh import thread_resources
        mesh = thread_resources.env.physical_mesh
    # repolint: ignore[fail-open] internal-API probe at trace time: no mesh
    # means hints are no-ops by contract, there is no state to record
    except Exception:   # noqa: BLE001 — jax-internal API probe; no-mesh fallback
        return x
    if mesh.empty:
        return x
    names = set(mesh.axis_names)

    def clean(dim, entry):
        if entry is None:
            return None
        axes = (entry,) if isinstance(entry, str) else tuple(entry)
        axes = tuple(a for a in axes if a in names)
        if not axes:
            return None
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        if dim % size != 0 or dim < size:
            return None
        return axes if len(axes) > 1 else axes[0]

    assert len(spec) == x.ndim, (spec, x.shape)
    pspec = jax.sharding.PartitionSpec(
        *[clean(d, e) for d, e in zip(x.shape, spec)])
    return jax.lax.with_sharding_constraint(x, pspec)


def rms_norm(x, gamma, eps: float = 1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)).astype(x.dtype) * gamma


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float = 10000.0):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float = 10000.0):
    """x [B, S, H, dh], positions [B, S] -> rotated x."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                                  # [dh/2]
    ang = positions[..., None].astype(jnp.float32) * freqs         # [B, S, dh/2]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# attention
# --------------------------------------------------------------------------
def _gqa_scores(q, k):
    """q [B, S, Hkv, G, dh], k [B, T, Hkv, dh] -> scores [B, Hkv, G, S, T]."""
    return jnp.einsum("bshgd,bthd->bhgst", q, k)


def _fa_fwd_core(q, k, v, block_q: int, block_k: int):
    """Causal flash forward. q/k/v [B, S, H, dh] (kv pre-repeated to H).
    Returns (o [B,S,H,dh], lse [B,S,H] fp32).  Double scan over (q x kv)
    blocks with an online-softmax carry; largest temp is one
    [B, H, bq, bk] tile."""
    B, S, H, dh = q.shape
    scale = 1.0 / np.sqrt(dh)
    nq, nk = S // block_q, S // block_k
    qb = jnp.moveaxis(q.reshape(B, nq, block_q, H, dh), 1, 0)
    kb = jnp.moveaxis(k.reshape(B, nk, block_k, H, dh), 1, 0)
    vb = jnp.moveaxis(v.reshape(B, nk, block_k, H, dh), 1, 0)
    qpos = jnp.arange(block_q)
    kpos = jnp.arange(block_k)

    def per_qblock(_, inp):
        qi, iq = inp                                      # [B, bq, H, dh]
        qi32 = qi.astype(jnp.float32) * scale
        m0 = jnp.full((B, H, block_q), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, H, block_q), jnp.float32)
        o0 = jnp.zeros((B, H, block_q, dh), jnp.float32)

        def per_kblock(carry, kin):
            m, l, o = carry
            ki, vi, ik = kin
            s = jnp.einsum("bshd,bthd->bhst", qi32, ki.astype(jnp.float32))
            causal = (iq * block_q + qpos)[:, None] >= (ik * block_k + kpos)[None, :]
            s = jnp.where(causal[None, None], s, -jnp.inf)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            p = jnp.exp(s - m_safe[..., None])
            corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
            l = l * corr + jnp.sum(p, axis=-1)
            o = o * corr[..., None] + jnp.einsum("bhst,bthd->bhsd", p,
                                                 vi.astype(jnp.float32))
            return (m_new, l, o), 0.0

        (m, l, o), _ = jax.lax.scan(per_kblock, (m0, l0, o0),
                                    (kb, vb, jnp.arange(nk)))
        o = o / jnp.maximum(l, 1e-30)[..., None]
        lse = jnp.where(l > 0, jnp.log(jnp.maximum(l, 1e-30))
                        + jnp.where(jnp.isfinite(m), m, 0.0), -jnp.inf)
        return 0, (jnp.moveaxis(o, 2, 1), jnp.moveaxis(lse, 2, 1))

    _, (ob, lseb) = jax.lax.scan(per_qblock, 0, (qb, jnp.arange(nq)))
    o = jnp.moveaxis(ob, 0, 1).reshape(B, S, H, dh).astype(q.dtype)
    lse = jnp.moveaxis(lseb, 0, 1).reshape(B, S, H)
    return o, lse


def _fa_bwd_core(q, k, v, o, lse, do, block_q: int, block_k: int):
    """Flash backward: recompute p per (q,kv) tile from lse; never stores the
    probability stack (the memory fix the custom_vjp exists for)."""
    B, S, H, dh = q.shape
    scale = 1.0 / np.sqrt(dh)
    nq, nk = S // block_q, S // block_k
    delta = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32), axis=-1)  # [B,S,H]
    qb = jnp.moveaxis(q.reshape(B, nq, block_q, H, dh), 1, 0)
    dob = jnp.moveaxis(do.reshape(B, nq, block_q, H, dh), 1, 0)
    lseb = jnp.moveaxis(lse.reshape(B, nq, block_q, H), 1, 0)
    deltab = jnp.moveaxis(delta.reshape(B, nq, block_q, H), 1, 0)
    kb = jnp.moveaxis(k.reshape(B, nk, block_k, H, dh), 1, 0)
    vb = jnp.moveaxis(v.reshape(B, nk, block_k, H, dh), 1, 0)
    qpos = jnp.arange(block_q)
    kpos = jnp.arange(block_k)

    def per_kvblock(dq_acc, kin):
        ki, vi, ik = kin
        ki32 = ki.astype(jnp.float32)
        vi32 = vi.astype(jnp.float32)

        def per_qblock(carry, qin):
            dk, dv = carry
            qi, doi, lsei, di, iq = qin
            qi32 = qi.astype(jnp.float32) * scale
            s = jnp.einsum("bshd,bthd->bhst", qi32, ki32)
            causal = (iq * block_q + qpos)[:, None] >= (ik * block_k + kpos)[None, :]
            lsei_safe = jnp.where(jnp.isfinite(lsei), lsei, 0.0)
            p = jnp.where(causal[None, None],
                          jnp.exp(s - jnp.moveaxis(lsei_safe, 2, 1)[..., None]), 0.0)
            do32 = doi.astype(jnp.float32)
            dv = dv + jnp.einsum("bhst,bshd->bthd", p, do32)
            dp = jnp.einsum("bshd,bthd->bhst", do32, vi32)
            ds = p * (dp - jnp.moveaxis(di, 2, 1)[..., None])
            dq_i = jnp.einsum("bhst,bthd->bshd", ds, ki32) * scale
            dk = dk + jnp.einsum("bhst,bshd->bthd", ds, qi32)
            return (dk, dv), dq_i

        zer = jnp.zeros((B, block_k, H, dh), jnp.float32)
        (dk, dv), dq_stack = jax.lax.scan(
            per_qblock, (zer, zer), (qb, dob, lseb, deltab, jnp.arange(nq)))
        return dq_acc + dq_stack, (dk, dv)

    dq0 = jnp.zeros((nq, B, block_q, H, dh), jnp.float32)
    dq_stack, (dkb, dvb) = jax.lax.scan(per_kvblock, dq0,
                                        (kb, vb, jnp.arange(nk)))
    dq = jnp.moveaxis(dq_stack, 0, 1).reshape(B, S, H, dh)
    # dk carried the *scaled* q contribution; undo nothing (ds@q uses scaled q
    # => dk already includes the 1/sqrt(dh) factor exactly once).
    dk = jnp.moveaxis(dkb, 0, 1).reshape(B, S, H, dh)
    dv = jnp.moveaxis(dvb, 0, 1).reshape(B, S, H, dh)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def _flash_attention(q, k, v, block_q: int, block_k: int):
    return _fa_fwd_core(q, k, v, block_q, block_k)[0]


def _fa_fwd_rule(q, k, v, block_q, block_k):
    o, lse = _fa_fwd_core(q, k, v, block_q, block_k)
    return o, (q, k, v, o, lse)


def _fa_bwd_rule(block_q, block_k, res, do):
    q, k, v, o, lse = res
    return _fa_bwd_core(q, k, v, o, lse, do, block_q, block_k)


_flash_attention.defvjp(_fa_fwd_rule, _fa_bwd_rule)


def blockwise_causal_attention(q, k, v, *, block_q: int = 256,
                               block_k: int = 1024) -> jax.Array:
    """Causal GQA flash attention (custom-VJP, DESIGN.md §6).

    q [B, S, H, dh]; k/v [B, S, Hkv, dh].  KV heads are repeated to H (the
    flat-H layout keeps the head axis shardable over 'model' when H divides);
    the custom VJP saves only (q, k, v, o, lse) and recomputes probability
    tiles in the backward — the [nq*nk, ...] tile stack never materializes.
    """
    B, S, H, dh = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    if G > 1:
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    s_orig = S
    lcm = int(np.lcm(block_q, block_k))
    pad = (-S) % lcm
    if pad:
        # pad keys land at positions > any real query => causally masked out
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        S = S + pad
    q = shard_hint(q, BATCH_AXES, None, "model", None)
    k = shard_hint(k, BATCH_AXES, None, "model", None)
    v = shard_hint(v, BATCH_AXES, None, "model", None)
    out = _flash_attention(q, k, v, block_q, block_k)
    return out[:, :s_orig]


def decode_attention(q, k_cache, v_cache, kv_len_mask) -> jax.Array:
    """Single-token decode: q [B, 1, H, dh], caches [B, T, Hkv, dh].

    kv_len_mask [B, T] marks valid cache slots.  Softmax reductions over T
    partition cleanly when the cache is sequence-sharded (flash-decoding
    semantics emerge from SPMD partial reductions; DESIGN.md §5 long_500k).
    """
    B, _, H, dh = q.shape
    Hkv = k_cache.shape[2]
    G = H // Hkv
    scale = 1.0 / np.sqrt(dh)
    qg = q.reshape(B, 1, Hkv, G, dh) * scale
    scores = jnp.einsum("bshgd,bthd->bhgst", qg, k_cache).astype(jnp.float32)
    scores = jnp.where(kv_len_mask[:, None, None, None, :], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bhgst,bthd->bshgd", p.astype(v_cache.dtype), v_cache)
    return o.reshape(B, 1, H, dh)


# --------------------------------------------------------------------------
# FFN / SwiGLU
# --------------------------------------------------------------------------
def swiglu(x, w_gate, w_up, w_down):
    h = jax.nn.silu(x @ w_gate) * (x @ w_up)
    return h @ w_down


# --------------------------------------------------------------------------
# MoE: token-sorted dispatch with static capacity (DESIGN.md §6)
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class MoeConfig:
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25


def moe_dispatch_indices(top_idx, n_experts: int, capacity: int):
    """top_idx [T, k] expert choices -> (dest [T, k], keep [T, k], src [E*C]).

    dest = e*C + position-within-expert; src is the inverse map (gather list
    for building the per-expert token buffers), pad slots point at T (callers
    append a zero row).
    """
    T, k = top_idx.shape
    flat_e = top_idx.reshape(-1)                                     # [T*k]
    onehot = jax.nn.one_hot(flat_e, n_experts, dtype=jnp.int32)      # [T*k, E]
    pos = jnp.cumsum(onehot, axis=0) - onehot                        # rank within expert
    pos = jnp.sum(pos * onehot, axis=-1)                             # [T*k]
    keep = pos < capacity
    dest = jnp.where(keep, flat_e * capacity + pos, n_experts * capacity)
    src = jnp.full((n_experts * capacity + 1,), T, dtype=jnp.int32)
    token_of = jnp.arange(T * k, dtype=jnp.int32) // k
    src = src.at[dest].set(jnp.where(keep, token_of, T))
    return dest.reshape(T, k), keep.reshape(T, k), src[:-1]


def moe_layer(x, gate_w, w_gate, w_up, w_down, cfg: MoeConfig):
    """x [T, D]; expert weights [E, D, F] / [E, F, D]. Returns [T, D].

    Token-sorted static-capacity dispatch: gather tokens into [E, C, D]
    buffers, batched per-expert SwiGLU einsum, weighted combine.  With experts
    sharded over 'model' and tokens over 'data', XLA inserts the dispatch
    all-to-all (EP); hillclimbed in EXPERIMENTS.md §Perf.
    """
    T, Dm = x.shape
    E, k = cfg.n_experts, cfg.top_k
    cap = max(8, int(cfg.capacity_factor * k * T / E))
    x = shard_hint(x, ("pod", "data", "model"), None)
    logits = (x @ gate_w).astype(jnp.float32)                        # [T, E]
    top_val, top_idx = jax.lax.top_k(logits, k)
    probs = jax.nn.softmax(top_val, axis=-1).astype(x.dtype)         # [T, k]

    dest, keep, src = moe_dispatch_indices(top_idx, E, cap)
    x_pad = jnp.concatenate([x, jnp.zeros((1, Dm), x.dtype)], axis=0)
    # §Perf HC2: gather with EP-sharded *indices* so the dispatched buffer is
    # born sharded over 'model' (an unsharded [E*cap, D] gather output was
    # the arctic-480b memory blow-up; EXPERIMENTS.md §Perf)
    src2 = shard_hint(src.reshape(E, cap), "model", None)
    xe = x_pad[src2]                                                 # [E, cap, D]
    xe = shard_hint(xe, "model", None, None)                         # EP layout
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, w_gate)) \
        * jnp.einsum("ecd,edf->ecf", xe, w_up)
    h = shard_hint(h, "model", None, None)
    ye = jnp.einsum("ecf,efd->ecd", h, w_down)                       # [E, cap, D]
    # combine via slot-indexed scatter-add: per expert slot we already know
    # its source token (`src`) — scatter ye rows into token space directly.
    # The gather-combine formulation all-gathers ye when its rows are
    # model-sharded (37 GB/dev at arctic scale); the scatter keeps the
    # updates expert-sharded (§Perf HC2 iter 2).
    wslot = jnp.zeros((E * cap + 1,), ye.dtype).at[
        jnp.where(keep.reshape(-1), dest.reshape(-1), E * cap)].set(
        (probs * keep).reshape(-1).astype(ye.dtype))                 # [E*cap]
    upd = ye.reshape(E * cap, Dm) * wslot[:-1, None]
    upd = shard_hint(upd.reshape(E, cap, Dm), "model", None, None)
    y = jnp.zeros((T + 1, Dm), ye.dtype).at[src.reshape(E, cap)].add(
        upd.reshape(E, cap, Dm))
    return y[:T].astype(x.dtype)

"""TOGG-KDT baseline (Xu et al., KBS'21) — two-stage routing with per-node
KD-trees for directional neighbor filtering.

Stage S1 (far from query): at each expansion, descend the node's KD-tree
(built over its neighbors' vectors at construction) to the leaf containing the
query — only those direction-aligned neighbors are evaluated.  Stage S2 (near
the query, triggered when S1 stops improving): full greedy expansion with the
constraint relaxed to two-hop neighborhoods.

The accuracy loss from S1's hard filtering (paper Fig. 3: nodes like n3 are
unrecoverable) is the phenomenon the comparison reproduces.
"""
from __future__ import annotations

import dataclasses
import heapq
import time
from typing import List, Tuple

import numpy as np

from repro.core.graph import GraphIndex
from repro.core.kdtree import KDTree, build_kdtree, descend
from repro.core.ref_search import SearchStats, STATUS_VISITED


@dataclasses.dataclass
class ToggIndex:
    graph: GraphIndex
    trees: List[KDTree]
    build_secs: float = 0.0

    def extra_bytes(self) -> int:
        tot = 0
        for t in self.trees:
            tot += (t.axis.nbytes + t.thresh.nbytes + t.left.nbytes
                    + t.right.nbytes + t.leaf_start.nbytes + t.leaf_end.nbytes
                    + t.items.nbytes)
        return int(tot)


def build_togg(g: GraphIndex, leaf_size: int = 8) -> ToggIndex:
    t0 = time.time()
    n = g.n
    trees: List[KDTree] = []
    for i in range(n):
        nbrs = g.neighbors[i]
        ids = nbrs[nbrs < n].astype(np.int64)
        if len(ids) == 0:
            trees.append(build_kdtree(np.zeros((1, g.dim), np.float32),
                                      np.asarray([i]), leaf_size))
            continue
        trees.append(build_kdtree(g.vectors[ids], ids, leaf_size))
    return ToggIndex(graph=g, trees=trees, build_secs=time.time() - t0)


def togg_search(ti: ToggIndex, q: np.ndarray, entry: int, efs: int,
                max_hops: int = 10**9, s1_patience: int = 3,
                ) -> Tuple[np.ndarray, np.ndarray, SearchStats]:
    g = ti.graph
    n = g.n
    vecs = g.vectors
    status = np.zeros(n, np.uint8)
    stats = SearchStats()

    def exact(i):
        stats.dist_calls += 1
        d = q - vecs[i]
        return float(np.dot(d, d))

    d0 = exact(entry)
    status[entry] = STATUS_VISITED
    C = [(d0, entry)]
    T = [(-d0, entry)]
    stage2 = False
    best_seen = d0
    stalls = 0

    while C and stats.hops < max_hops:
        dc, c = heapq.heappop(C)
        upper = -T[0][0]
        if dc > upper and len(T) >= efs:
            break
        stats.hops += 1

        if not stage2:
            cand_ids = [int(x) for x in descend(ti.trees[c], q)]  # S1: leaf only
        else:
            # S2: thorough near-query expansion. Full one-hop, plus two-hop
            # through the closest unvisited neighbor only (the unrestricted
            # two-hop of the original bloats distance calls at our scales).
            one_hop = [int(x) for x in g.neighbors[c] if x < n]
            cand_ids = list(one_hop)
            fresh = [h for h in one_hop if status[h] != STATUS_VISITED]
            if fresh:
                h0 = fresh[0]
                cand_ids.extend(int(x) for x in g.neighbors[h0] if x < n)

        improved = False
        for nid in cand_ids:
            if status[nid] == STATUS_VISITED:
                continue
            status[nid] = STATUS_VISITED
            dn = exact(nid)
            if dn < best_seen:
                best_seen = dn
                improved = True
            if dn < upper or len(T) < efs:
                heapq.heappush(C, (dn, nid))
                heapq.heappush(T, (-dn, nid))
                if len(T) > efs:
                    heapq.heappop(T)
                upper = -T[0][0]
        if not stage2:
            stalls = 0 if improved else stalls + 1
            if stalls >= s1_patience:
                stage2 = True   # switch to thorough near-query exploration

    out = sorted(((-d, i) for d, i in T))
    ids_out = np.full(efs, -1, np.int64)
    ds_out = np.full(efs, np.inf, np.float32)
    for j, (d, i) in enumerate(out[:efs]):
        ids_out[j] = i
        ds_out[j] = d
    return ids_out, ds_out, stats

"""Bucket-ladder batch shaping (DESIGN.md §6, serving frontend).

The jitted engines trace once per input *shape*: a ragged stream of request
sizes (1, 7, 3, 19, ...) would trigger a fresh XLA compile per new batch
size — seconds of latency on the request path.  The frontend instead rounds
every micro-batch up to a fixed ladder of bucket sizes (default 1/8/32/128),
pads the query matrix, and passes a ``valid`` mask so padded lanes never
pollute results or counters (``repro.core.search._search_batch``).  After a
one-time warmup of every rung, any request mix replays against at most
``len(buckets)`` compiled executables.

Padding repeats real query rows rather than inserting zeros: a duplicated
row provably changes nothing (per-query lanes are independent and its
counters are masked), while an all-zero query could run the hop loop longer
than any real lane and stretch the batch's iteration count.
"""
from __future__ import annotations

from typing import Sequence, Tuple

import numpy as np

DEFAULT_BUCKETS = (1, 8, 32, 128)


def validate_buckets(buckets: Sequence[int]) -> Tuple[int, ...]:
    """Normalize a bucket ladder: sorted, unique, positive ints."""
    out = tuple(sorted({int(b) for b in buckets}))
    if not out or out[0] < 1:
        raise ValueError(f"bucket ladder must be positive ints, got {buckets}")
    return out


def bucket_for(n: int, buckets: Sequence[int]) -> int:
    """Smallest rung >= n.  Raises for n beyond the ladder (the frontend
    rejects oversized requests instead of silently splitting them)."""
    for b in buckets:
        if n <= b:
            return b
    raise ValueError(
        f"batch of {n} rows exceeds the largest bucket {buckets[-1]}")


def pad_to_bucket(queries: np.ndarray, bucket: int
                  ) -> Tuple[np.ndarray, np.ndarray]:
    """Pad [n, d] -> [bucket, d] by cycling real rows; returns (padded, valid).

    ``valid`` is the [bucket] bool mask the engines use to zero padded
    lanes' counters; callers slice results back to ``[:n]``.
    """
    n = queries.shape[0]
    if n > bucket:
        raise ValueError(f"{n} rows do not fit bucket {bucket}")
    if n == bucket:
        return queries, np.ones((n,), bool)
    reps = np.take(queries, np.arange(bucket - n) % n, axis=0)
    padded = np.concatenate([queries, reps], axis=0)
    valid = np.zeros((bucket,), bool)
    valid[:n] = True
    return padded, valid

"""The paper's own system config: sharded CRouting-HNSW serving + the five
Table-2 dataset stand-ins (see repro.data.vectors.PAPER_DATASETS)."""
import dataclasses
from repro.configs import ArchSpec
from repro.configs.shapes import ANNS_SHAPES


@dataclasses.dataclass(frozen=True)
class AnnsConfig:
    name: str = "crouting-hnsw"
    graph: str = "hnsw"
    m: int = 32            # paper §5.1: HNSW M=32, efc=256
    efc: int = 256
    router: str = "crouting"
    percentile: float = 90.0   # paper §5.5: best at the 90th percentile
    vec_dtype: str = "float32"  # "bfloat16" = beyond-paper storage (§Perf HC3)


SPEC = ArchSpec(
    arch_id="crouting-anns",
    family="anns",
    model_cfg=AnnsConfig(),
    shapes=ANNS_SHAPES,
    source="this paper (CRouting, CS.DB 2025)",
    smoke_cfg=AnnsConfig(name="crouting-smoke", m=8, efc=32),
)

"""Failure domains (ISSUE 7): the failpoint registry and RetryPolicy units,
crash-safe persistence (an interrupted ``save()`` never leaves a file
``load()`` accepts silently), partial sharded results + ``shard_timeout_s``
stragglers, merge retry/backoff -> quarantine -> recovery, and fault
containment through the serving frontend (``serve.dispatch`` failures and
``WorkerFailure`` surfacing)."""
import glob
import time

import numpy as np
import pytest

from repro.core.index import AnnIndex
from repro.core.spec import SearchSpec
from repro.fault import (CorruptIndexError, DegradedSearchError,
                         FaultInjected, FaultSpec, MergeQuarantinedError,
                         RetryPolicy)
from repro.fault import failpoints as fault
from repro.mutate import MutableAnnIndex, MutableShardedAnnIndex, MutateConfig
from repro.serve import ServeFrontend, WorkerFailure

SPEC = SearchSpec(k=5, efs=24, router="crouting")


@pytest.fixture(autouse=True)
def _disarm_all():
    """No fault schedule may leak between tests."""
    yield
    fault.disarm()


@pytest.fixture(scope="module")
def tiny_index(small_ds):
    return AnnIndex.build(small_ds.base[:400], graph="hnsw", m=8, efc=48)


@pytest.fixture(scope="module")
def shard_indexes(small_ds):
    return [AnnIndex.build(small_ds.base[s * 200:(s + 1) * 200],
                           graph="hnsw", m=8, efc=48) for s in range(3)]


def _sharded(shard_indexes, **kw):
    cfg = MutateConfig(delta_capacity=32, auto_merge="off")
    return MutableShardedAnnIndex(shard_indexes, config=cfg, spec=SPEC, **kw)


# --------------------------------------------------------------------------
# failpoint registry
# --------------------------------------------------------------------------
def test_disarmed_hit_is_none():
    assert fault.hit("no.such.site") is None
    assert fault.fires("no.such.site") == 0


def test_explicit_hit_schedule():
    fault.arm("x", hits={1, 3})
    fired = []
    for i in range(5):
        try:
            fault.hit("x")
        except FaultInjected as e:
            fired.append(i)
            assert e.hit_index == i
    assert fired == [1, 3]
    assert fault.fires("x") == 2
    assert fault.snapshot()["x"] == {"hits": 5, "fires": 2}


def test_seeded_probability_is_deterministic():
    def trace():
        fault.arm("p", kind="raise", p=0.4, seed=7)
        out = []
        for _ in range(30):
            try:
                fault.hit("p")
                out.append(0)
            except FaultInjected:
                out.append(1)
        return out

    a, b = trace(), trace()
    assert a == b
    assert 0 < sum(a) < 30, "p=0.4 over 30 hits must fire sometimes"


def test_max_fires_caps_the_schedule():
    fault.arm("cap", kind="raise", p=1.0, max_fires=2)
    n_raised = 0
    for _ in range(6):
        try:
            fault.hit("cap")
        except FaultInjected:
            n_raised += 1
    assert n_raised == 2 and fault.fires("cap") == 2


def test_sub_targeting_most_specific_wins():
    fault.arm("shard.search.1", kind="raise")
    fault.hit("shard.search", sub="0")          # other children untouched
    with pytest.raises(FaultInjected, match="shard.search.1"):
        fault.hit("shard.search", sub="1")
    fault.disarm("shard.search.1")
    fault.arm("shard.search", kind="raise")     # bare site: every child
    with pytest.raises(FaultInjected):
        fault.hit("shard.search", sub="0")


def test_delay_and_data_kinds_return_not_raise():
    fault.arm("slow", kind="delay", delay_s=0.01)
    t0 = time.perf_counter()
    assert fault.hit("slow") == "delay"
    assert time.perf_counter() - t0 >= 0.01
    fault.arm("bytes", kind="corrupt")
    assert fault.hit("bytes") == "corrupt"


def test_scoped_arms_and_disarms():
    with fault.scoped({"a": FaultSpec(kind="raise")}):
        with pytest.raises(FaultInjected):
            fault.hit("a")
    assert fault.hit("a") is None


# --------------------------------------------------------------------------
# RetryPolicy
# --------------------------------------------------------------------------
def test_retry_delays_deterministic_and_capped():
    p = RetryPolicy(max_attempts=6, base_s=0.01, cap_s=0.05, jitter=0.5,
                    seed=3)
    a, b = list(p.delays()), list(p.delays())
    assert a == b and len(a) == 5
    assert all(d <= 0.05 * 1.5 for d in a)
    assert list(RetryPolicy(max_attempts=1).delays()) == []


def test_retry_call_recovers_then_propagates():
    sleeps = []
    calls = {"n": 0}

    def flaky(fail_times):
        calls["n"] += 1
        if calls["n"] <= fail_times:
            raise ValueError("transient")
        return "ok"

    p = RetryPolicy(max_attempts=4, base_s=0.0, seed=0)
    assert p.call(flaky, 2, sleep=sleeps.append) == "ok"
    assert calls["n"] == 3 and len(sleeps) == 2

    calls["n"] = 0
    with pytest.raises(ValueError, match="transient"):
        p.call(flaky, 99, sleep=sleeps.append)     # budget exhausted: raw error
    assert calls["n"] == 4


def test_retry_max_elapsed_truncates_budget():
    """ISSUE 8 satellite: the summed backoff sleeps never exceed
    ``max_elapsed_s`` — the last delay is truncated to exactly exhaust
    the budget, then the schedule stops."""
    p = RetryPolicy(max_attempts=10, base_s=0.04, cap_s=0.04, jitter=0.0,
                    max_elapsed_s=0.10, seed=0)
    ds = list(p.delays())
    assert ds == [0.04, 0.04, pytest.approx(0.02)]
    assert sum(ds) == pytest.approx(0.10)
    # deterministic: the schedule replays identically
    assert list(p.delays()) == ds

    # the budget also bounds call(): attempts stop once sleeps exhaust it
    sleeps, calls = [], {"n": 0}

    def always_fails():
        calls["n"] += 1
        raise ValueError("transient")

    with pytest.raises(ValueError, match="transient"):
        p.call(always_fails, sleep=sleeps.append)
    assert calls["n"] == 4 and sum(sleeps) == pytest.approx(0.10)

    # a zero budget degenerates to a single attempt, raw error out
    p0 = RetryPolicy(max_attempts=10, base_s=0.04, max_elapsed_s=0.0)
    assert list(p0.delays()) == []
    calls["n"] = 0
    with pytest.raises(ValueError):
        p0.call(always_fails, sleep=sleeps.append)
    assert calls["n"] == 1


def test_retry_on_filters_exception_types():
    def bad():
        raise KeyError("not transient")

    p = RetryPolicy(max_attempts=5, base_s=0.0)
    calls = []
    with pytest.raises(KeyError):
        p.call(lambda: (calls.append(1), bad()), retry_on=ValueError,
               sleep=lambda s: None)
    assert len(calls) == 1, "a non-matching exception must not retry"


# --------------------------------------------------------------------------
# crash-safe persistence: interrupted save() never leaves a file load()
# accepts silently (ISSUE 7 acceptance)
# --------------------------------------------------------------------------
def _no_tmp_litter(path):
    assert glob.glob(f"{path}.tmp.*") == [], "temp files must be cleaned up"


def test_save_load_roundtrip_with_checksum(tiny_index, tmp_path):
    path = str(tmp_path / "idx.npz")
    tiny_index.save(path)
    back = AnnIndex.load(path)
    np.testing.assert_array_equal(back.graph.vectors,
                                  tiny_index.graph.vectors)
    assert back.profile is not None
    _no_tmp_litter(path)


def test_interrupted_save_leaves_old_version(tiny_index, small_ds, tmp_path):
    path = str(tmp_path / "idx.npz")
    tiny_index.save(path)
    newer = AnnIndex.build(small_ds.base[:300], graph="hnsw", m=8, efc=48)
    for site in ("index.save.write", "index.save.rename"):
        fault.arm(site, kind="raise")
        with pytest.raises(FaultInjected):
            newer.save(path)
        fault.disarm(site)
        back = AnnIndex.load(path)       # the OLD version, fully intact
        assert back.graph.n == tiny_index.graph.n
        _no_tmp_litter(path)


@pytest.mark.parametrize("kind", ["corrupt", "truncate"])
def test_damaged_bytes_never_load_silently(tiny_index, tmp_path, kind):
    path = str(tmp_path / f"idx_{kind}.npz")
    fault.arm("index.save.write", kind=kind)
    tiny_index.save(path)                # publishes damaged bytes
    fault.disarm("index.save.write")
    with pytest.raises(CorruptIndexError):
        AnnIndex.load(path)


def test_checksum_catches_post_publish_tamper(tiny_index, tmp_path):
    path = str(tmp_path / "idx.npz")
    tiny_index.save(path)
    with np.load(path, allow_pickle=False) as npz:
        z = {k: npz[k] for k in npz.files}
    v = z["vectors"].copy()
    v[0, 0] += 1.0                       # one flipped value, stale checksum
    z["vectors"] = v
    np.savez(path, **z)
    with pytest.raises(CorruptIndexError, match="checksum"):
        AnnIndex.load(path)
    del z["checksum"]                    # v3 file missing its checksum
    np.savez(path, **z)
    with pytest.raises(CorruptIndexError, match="checksum"):
        AnnIndex.load(path)


def test_v2_files_without_checksum_still_load(tiny_index, tmp_path):
    path = str(tmp_path / "idx.npz")
    tiny_index.save(path)
    with np.load(path, allow_pickle=False) as npz:
        z = {k: npz[k] for k in npz.files}
    del z["checksum"]
    z["format_version"] = np.asarray(2)
    np.savez(path, **z)
    assert AnnIndex.load(path).graph.n == tiny_index.graph.n


# --------------------------------------------------------------------------
# partial sharded results: a dead shard degrades, it does not fail
# --------------------------------------------------------------------------
def test_one_dead_shard_degrades_with_survivor_results(shard_indexes,
                                                       small_ds):
    ms = _sharded(shard_indexes)
    q = small_ds.queries[:4]
    ids0, _, st0 = ms.search(q)
    assert st0.shards_failed == 0 and not st0.degraded

    fault.arm("shard.search.1", kind="raise")
    ids, _, st = ms.search(q)
    assert st.degraded and st.shards_failed == 1
    assert (ids >= 0).all(), "3 surviving shards fill k=5 easily"
    dead = (ids >= 200) & (ids < 400)    # shard 1 owns global ids [200, 400)
    assert not dead.any(), "a dropped shard's ids must not appear"


def test_all_shards_dead_raises_degraded_error(shard_indexes, small_ds):
    ms = _sharded(shard_indexes)
    fault.arm("shard.search", kind="raise")     # bare site: every child
    with pytest.raises(DegradedSearchError, match="all 3 shards"):
        ms.search(small_ds.queries[:2])


def test_shard_timeout_drops_straggler(shard_indexes, small_ds):
    q = small_ds.queries[:2]
    # compile every shard engine OFF the deadline clock (the serving stack
    # pre-warms; a cold XLA compile inside the pool would miss any deadline)
    _sharded(shard_indexes).search(q)
    ms = _sharded(shard_indexes, shard_timeout_s=0.75)
    _, _, st0 = ms.search(q)              # pooled warm pass
    assert not st0.degraded
    fault.arm("shard.search.2", kind="delay", delay_s=2.0)
    ids, _, st = ms.search(q)
    assert st.degraded and st.shards_failed == 1
    assert (ids >= 0).all()
    assert not ((ids >= 400) & (ids < 600)).any(), \
        "the straggler's ids must be dropped, not merged late"


# --------------------------------------------------------------------------
# merge retry/backoff -> quarantine -> recovery
# --------------------------------------------------------------------------
def _mutable(small_ds, **cfg_kw):
    cfg = MutateConfig(delta_capacity=8, merge_threshold=0.5, graph="hnsw",
                       graph_kw=dict(m=8, efc=48), merge_backoff_s=0.001,
                       merge_backoff_cap_s=0.002, **cfg_kw)
    return MutableAnnIndex(
        AnnIndex.build(small_ds.base[:200], graph="hnsw", m=8, efc=48),
        config=cfg, spec=SPEC)


def test_merge_retry_recovers_within_budget(small_ds):
    m = _mutable(small_ds, auto_merge="sync", merge_retries=3)
    fault.arm("mutate.merge.build", kind="raise", max_fires=2)
    m.insert(small_ds.base[200:205])      # past threshold: sync merge
    assert m.epoch == 1, "the 3rd attempt must land the merge"
    assert m.merge_retries_used == 2
    assert not m.quarantined and m.merge_error is None


def test_exhausted_retries_quarantine_not_poison(small_ds):
    m = _mutable(small_ds, auto_merge="background", merge_retries=1,
                 quarantine_cooldown_s=60.0)
    fault.arm("mutate.merge.build", kind="raise", p=1.0)
    m.insert(small_ds.base[200:205])      # spawns the failing merge
    m._merge_thread.join()
    assert m.quarantined and isinstance(m.merge_error, FaultInjected)
    assert m.epoch == 0, "a failed merge must never swap"

    # quarantined =/= down: searching and mutating both still work
    ids, _, _ = m.search(small_ds.queries[:2])
    assert (ids >= 0).all()
    m.delete(int(ids[0, 0]))
    m.insert(small_ds.base[205:208])      # delta still has room
    with pytest.raises(MergeQuarantinedError, match="quarantined"):
        m.insert(small_ds.base[208:216])  # genuinely full: typed backpressure

    # operator heals the fault and lifts the quarantine: merges resume
    fault.disarm("mutate.merge.build")
    m.clear_quarantine()
    assert m.merge_error is None
    m.maybe_merge()
    m.wait_for_merge()
    assert m.epoch == 1
    m.insert(small_ds.base[208:216])      # the refused write now lands


def test_sharded_inserts_route_around_quarantined_shard(shard_indexes,
                                                        small_ds):
    cfg = MutateConfig(delta_capacity=8, merge_threshold=0.9, graph="hnsw",
                       graph_kw=dict(m=8, efc=48), auto_merge="background",
                       merge_retries=0, merge_backoff_s=0.001,
                       quarantine_cooldown_s=60.0)
    ms = MutableShardedAnnIndex(shard_indexes, config=cfg, spec=SPEC)
    far = time.monotonic() + 60.0
    # shard 0: quarantined AND full (cannot drain) — yet least loaded
    ms.shards[0]._quarantined_until = far
    ms.shards[0].insert(small_ds.base[600:608])    # fills its delta
    ms.delete(list(range(40)))                     # 0 is least loaded now
    assert ms.quarantined_shards == (0,)
    before0 = ms.shards[0].n_live
    ids = ms.insert(small_ds.base[608:612])
    assert ms.shards[0].n_live == before0, \
        "inserts must route around a full quarantined shard"
    assert all(ms._ext_to_shard[int(e)] != 0 for e in ids)
    ms.clear_quarantine()
    assert ms.quarantined_shards == ()
    # every shard full + quarantined: typed backpressure, never a hang
    for sh in ms.shards:
        room = sh._state.delta.room
        if room:
            sh.insert(small_ds.base[612:612 + room])
        sh._quarantined_until = far
    with pytest.raises(MergeQuarantinedError, match="every shard"):
        ms.insert(small_ds.base[700:701])


# --------------------------------------------------------------------------
# fault containment through the serving frontend
# --------------------------------------------------------------------------
def test_dispatch_fault_fails_only_its_batch(tiny_index, small_ds):
    fe = ServeFrontend(tiny_index, SPEC, buckets=(1, 4))
    q = small_ds.queries
    fault.arm("serve.dispatch", hits={0})
    f_bad = fe.submit(q[:2], cos_theta=0.111)   # group 1 -> first dispatch
    f_good = fe.submit(q[:2], cos_theta=0.999)  # group 2 -> second dispatch
    fe.flush()
    with pytest.raises(FaultInjected):
        f_bad.result(timeout=5)
    ids, _, _ = f_good.result(timeout=5)
    assert ids.shape == (2, 5)
    assert fe.telemetry.dispatch_failures == 1
    assert fe.telemetry.summary()["requests"]["failed"] == 1
    # the frontend is not poisoned: the next request serves normally
    ids, _, _ = fe.search(q[:1])
    assert ids.shape == (1, 5)


def test_degraded_shard_search_resolves_through_frontend(shard_indexes,
                                                         small_ds):
    ms = _sharded(shard_indexes)
    fe = ServeFrontend(ms, SPEC, buckets=(1, 4))
    fault.arm("shard.search.0", kind="raise")
    ids, _, st = fe.search(small_ds.queries[:2])
    assert st.degraded and st.shards_failed == 1 and (ids >= 0).all()
    assert fe.telemetry.recompiles_after_warmup == 0


def test_worker_failure_surfaces_on_next_submit(tiny_index, small_ds):
    """Satellite: a background-worker failure must not die silently — it
    raises ``WorkerFailure`` from the next caller-thread ``submit()``/
    ``flush()`` and counts in ``worker_errors``."""
    fe = ServeFrontend(tiny_index, SPEC, buckets=(1, 4))
    fault.arm("serve.worker", hits={0})
    fe.start(poll_s=0.005)
    deadline = time.time() + 5
    while fe.telemetry.worker_errors == 0 and time.time() < deadline:
        time.sleep(0.005)
    assert fe.telemetry.worker_errors == 1
    with pytest.raises(WorkerFailure) as ei:
        fe.submit(small_ds.queries[:1])
    assert isinstance(ei.value.__cause__, FaultInjected)
    assert fe.telemetry.summary()["worker_errors"] == 1
    # the error is consumed and the worker loop survived: serving resumes
    fut = fe.submit(small_ds.queries[:2])
    ids, _, _ = fut.result(timeout=10)
    assert ids.shape == (2, 5)
    fe.stop()

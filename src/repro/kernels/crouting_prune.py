"""Pallas TPU kernel: fused CRouting estimate + prune decision (paper Alg. 2).

One VPU pass over a batch of neighbor lists — this is the cosine-theorem inner
loop, and by design it never touches vector data (that is the whole point of
CRouting on TPU: the pruned lanes skip their HBM vector fetch):

    est2[b, m]  = ed[b, m]^2 + dcq[b]^2 - 2 * ed[b, m] * dcq[b] * cos_theta
    prune[b, m] = valid[b, m] & (est2 >= bound2[b])

Inputs stream from the adjacency-side arrays only: stored edge distances
(float32 [B, M]), the expansion node's query distance [B], and the per-lane
pool bound [B].  Output is the estimate and an int8 prune mask.

Tiling: grid over B; M lives in the lane dimension (callers pad M to a
multiple of 128; ops.crouting_prune handles it).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _prune_kernel(ed_ref, dcq_ref, bound2_ref, valid_ref, ct_ref, est_ref, mask_ref):
    ed = ed_ref[...]                    # [bb, M]
    dcq = dcq_ref[...]                  # [bb, M] per-lane (beam tiles)
    b2 = bound2_ref[...]                # [bb, M]
    ct = ct_ref[0]
    est2 = ed * ed + dcq * dcq - 2.0 * ed * dcq * ct
    est2 = jnp.maximum(est2, 0.0)
    mask = (valid_ref[...] != 0) & (est2 >= b2)
    est_ref[...] = est2
    mask_ref[...] = mask.astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=("bb", "interpret"))
def crouting_prune_pallas(ed, dcq, bound2, valid, cos_theta, *,
                          bb: int = 8, interpret: bool = True):
    """ed [B, M], dcq [B, M], bound2 [B, M], valid [B, M] int8, cos_theta
    scalar -> (est2 [B, M] f32, prune [B, M] int8).

    dcq/bound2 are per-lane: the beam engine packs W expansion nodes per
    query into one [B, W*M] tile, so the expansion-node query distance (and
    for non-L2 metrics the rank-space bound) differs lane to lane.  The ops
    wrapper broadcasts 1-D [B] inputs for the classic single-node case.
    """
    B, M = ed.shape
    bb = min(bb, B)
    assert B % bb == 0, "pad batch to a block multiple (ops wrapper pads)"
    ct = jnp.asarray(cos_theta, jnp.float32).reshape(1)
    grid = (B // bb,)
    return pl.pallas_call(
        _prune_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, M), lambda i: (i, 0)),
            pl.BlockSpec((bb, M), lambda i: (i, 0)),
            pl.BlockSpec((bb, M), lambda i: (i, 0)),
            pl.BlockSpec((bb, M), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((bb, M), lambda i: (i, 0)),
            pl.BlockSpec((bb, M), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, M), jnp.float32),
            jax.ShapeDtypeStruct((B, M), jnp.int8),
        ],
        interpret=interpret,
    )(ed, dcq, bound2, valid, ct)

"""Fault-tolerant training loop (DESIGN.md §6).

Features exercised by tests/test_trainer.py and examples/train_lm.py:
  * gradient accumulation (microbatching) via lax.scan inside the step;
  * periodic sharded checkpoints w/ deterministic data cursor;
  * crash/restart resume that is BIT-EXACT vs an uninterrupted run;
  * elastic restore onto a different mesh (re-shard at device_put);
  * straggler/heartbeat hook: a step-deadline watchdog that records
    slow steps and (in multi-host deployments) triggers re-scheduling.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.train import checkpoint as ckpt
from repro.train import optimizer as opt


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 25
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep_ckpts: int = 3
    grad_accum: int = 1
    log_every: int = 10
    step_deadline_s: float = 0.0     # >0: watchdog flags stragglers
    grad_compress: bool = False      # int8 all-reduce on the pod axis


def make_accum_train_step(loss_fn, ocfg: opt.AdamWConfig, n_accum: int):
    """Gradient-accumulation step: batch [A, b, ...] microbatches scanned."""

    def train_step(params, opt_state, batch):
        def micro(g_acc, mb):
            loss, g = jax.value_and_grad(loss_fn)(params, mb)
            return jax.tree_util.tree_map(jnp.add, g_acc, g), loss

        zeros = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        g_sum, losses = jax.lax.scan(micro, zeros, batch)
        grads = jax.tree_util.tree_map(lambda g: g / n_accum, g_sum)
        new_p, new_s, metrics = opt.adamw_update(grads, opt_state, params, ocfg)
        metrics["loss"] = jnp.mean(losses)
        return new_p, new_s, metrics

    return train_step


class Trainer:
    def __init__(self, cfg: TrainerConfig, train_step: Callable,
                 params, opt_state, data_stream,
                 shardings: Optional[Any] = None):
        self.cfg = cfg
        self.step_fn = jax.jit(train_step, donate_argnums=(0, 1))
        self.params = params
        self.opt_state = opt_state
        self.stream = data_stream
        self.shardings = shardings
        self.step = 0
        self.history: list = []
        self.straggler_events: list = []

    # ------------------------------------------------------------------
    def maybe_resume(self) -> bool:
        last = ckpt.latest_step(self.cfg.ckpt_dir)
        if last is None:
            return False
        state, cursor, step = ckpt.restore_checkpoint(
            self.cfg.ckpt_dir,
            {"params": self.params, "opt": self.opt_state},
            shardings=self.shardings)
        self.params, self.opt_state = state["params"], state["opt"]
        self.stream.restore(cursor)
        self.step = step
        return True

    def _checkpoint(self):
        ckpt.save_checkpoint(
            self.cfg.ckpt_dir, self.step,
            {"params": self.params, "opt": self.opt_state},
            data_cursor=self.stream.state())
        ckpt.gc_checkpoints(self.cfg.ckpt_dir, self.cfg.keep_ckpts)

    # ------------------------------------------------------------------
    def run(self, n_steps: Optional[int] = None,
            crash_at: Optional[int] = None) -> Dict:
        """crash_at: raise after that step (fault-injection for tests)."""
        target = self.step + (n_steps or self.cfg.total_steps - self.step)
        while self.step < target:
            batch = jax.tree_util.tree_map(jnp.asarray, self.stream.next())
            t0 = time.time()
            self.params, self.opt_state, metrics = self.step_fn(
                self.params, self.opt_state, batch)
            loss = float(metrics["loss"])
            dt = time.time() - t0
            if self.cfg.step_deadline_s and dt > self.cfg.step_deadline_s:
                # straggler watchdog: in a multi-host deployment this is the
                # signal to preempt/reschedule the slow host
                self.straggler_events.append({"step": self.step, "secs": dt})
            self.step += 1
            self.history.append(loss)
            if self.step % self.cfg.log_every == 0:
                print(f"step {self.step}: loss={loss:.4f} ({dt:.2f}s)")
            if self.step % self.cfg.ckpt_every == 0:
                self._checkpoint()
            if crash_at is not None and self.step >= crash_at:
                raise RuntimeError(f"injected crash at step {self.step}")
        self._checkpoint()
        return {"final_loss": self.history[-1], "history": self.history,
                "stragglers": self.straggler_events}

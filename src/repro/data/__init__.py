from repro.data import vectors  # noqa: F401

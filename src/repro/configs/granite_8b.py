"""granite-8b [dense] — llama-arch code model [arXiv:2405.04324; hf]."""
from repro.configs import ArchSpec
from repro.configs.shapes import LM_SHAPES
from repro.models.transformer import LMConfig

SPEC = ArchSpec(
    arch_id="granite-8b",
    family="lm",
    model_cfg=LMConfig(name="granite-8b", n_layers=36, d_model=4096,
                       n_heads=32, n_kv_heads=8, d_ff=14336, vocab=49152),
    shapes=LM_SHAPES,
    source="arXiv:2405.04324; hf",
    smoke_cfg=LMConfig(name="granite-8b-smoke", n_layers=2, d_model=64,
                       n_heads=4, n_kv_heads=2, d_ff=160, vocab=512,
                       dtype="float32", block_q=16, block_k=32, loss_chunk=16),
)

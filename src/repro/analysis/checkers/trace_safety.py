"""trace-safety: Python control flow on traced values inside jit contexts.

Inside a function that JAX traces — a ``@jax.jit`` body, a ``pallas_call``
kernel, a ``lax.while_loop``/``cond``/``scan`` branch — the arguments are
abstract tracers.  ``if x > 0:``, ``while n:``, ``bool(x)`` or ``int(x)``
on such a value raises ``ConcretizationTypeError`` at trace time, or worse,
silently bakes one Python-level branch into the compiled artifact and
recompiles per distinct value.  This checker finds those sites.

Taint model (intraprocedural, per traced function):

* taint sources: the traced function's parameters (minus names listed in
  ``static_argnames=``/``static_argnums``-exempted positions are NOT
  tracked — any name in ``static_argnames`` is clean), and any value built
  from ``jnp.*`` / ``lax.*`` / ``pl.load`` / ``pl.dot`` calls;
* taint propagates through arithmetic/subscripts/calls and simple
  ``name = expr`` assignment;
* sanitizers (shape-level facts are concrete under tracing): ``.shape``,
  ``.ndim``, ``.dtype``, ``.size``, ``len()``, ``isinstance()``, and
  ``x is None`` / ``x is not None`` comparisons.

Flagged sinks on tainted values: ``if``/``while``/``assert`` tests,
``bool()`` / ``int()`` / ``float()`` casts, and ``and``/``or``/``not``
(which call ``__bool__``).
"""
from __future__ import annotations

import ast
from typing import Iterable, List, Optional, Set

from repro.analysis.core import (Finding, Project, dotted_name,
                                 register_checker)

# call heads whose nth argument (or fn= kwarg) is traced
_TRACING_CALLS = {
    "jax.jit": [0],
    "jit": [0],
    "pl.pallas_call": [0],
    "pallas_call": [0],
    "lax.while_loop": [0, 1],
    "jax.lax.while_loop": [0, 1],
    "lax.cond": [1, 2],
    "jax.lax.cond": [1, 2],
    "lax.scan": [0],
    "jax.lax.scan": [0],
    "lax.fori_loop": [2],
    "jax.lax.fori_loop": [2],
    "jax.vmap": [0],
    "vmap": [0],
}

_JIT_DECORATORS = ("jax.jit", "jit", "pl.pallas_call", "pallas_call")

_ARRAY_NAMESPACES = ("jnp.", "jax.numpy.", "lax.", "jax.lax.", "pl.")

_SHAPE_ATTRS = {"shape", "ndim", "dtype", "size"}

_SANITIZER_CALLS = {"len", "isinstance", "type", "id", "repr", "str"}


def _static_names(call: Optional[ast.Call]) -> Set[str]:
    """Names listed in ``static_argnames=`` of a jit/partial call."""
    out: Set[str] = set()
    if call is None:
        return out
    for kw in call.keywords:
        if kw.arg in ("static_argnames", "static_argnums"):
            for node in ast.walk(kw.value):
                if (isinstance(node, ast.Constant)
                        and isinstance(node.value, str)):
                    out.add(node.value)
    return out


def _decorator_jit_call(dec: ast.AST) -> Optional[ast.Call]:
    """The jit-ish Call of a decorator, if the decorator makes fn traced.

    Handles ``@jax.jit``, ``@jax.jit(...)``, and
    ``@functools.partial(jax.jit, static_argnames=...)``."""
    if isinstance(dec, ast.Call):
        head = dotted_name(dec.func)
        if head in _JIT_DECORATORS:
            return dec
        if head in ("functools.partial", "partial") and dec.args:
            inner = dotted_name(dec.args[0])
            if inner in _JIT_DECORATORS:
                return dec
    return None


def _is_jit_decorated(fn: ast.AST) -> Optional[ast.Call]:
    for dec in getattr(fn, "decorator_list", []):
        if dotted_name(dec) in _JIT_DECORATORS:
            return ast.Call(func=dec, args=[], keywords=[])  # no kwargs
        call = _decorator_jit_call(dec)
        if call is not None:
            return call
    return None


class _TaintWalk:
    """Track tainted names through one traced function body."""

    def __init__(self, relpath: str, fn_name: str, tainted: Set[str]):
        self.relpath = relpath
        self.fn_name = fn_name
        self.tainted = set(tainted)
        self.findings: List[Finding] = []

    # -- taint query -------------------------------------------------------
    def is_tainted(self, node: ast.AST) -> bool:
        if isinstance(node, ast.Name):
            return node.id in self.tainted
        if isinstance(node, ast.Attribute):
            if node.attr in _SHAPE_ATTRS:
                return False                      # shape facts are concrete
            dn = dotted_name(node)
            if dn is not None and dn.startswith(_ARRAY_NAMESPACES):
                return False                      # e.g. jnp.inf, jnp.float32
            return self.is_tainted(node.value)
        if isinstance(node, ast.Call):
            head = dotted_name(node.func)
            if head in _SANITIZER_CALLS:
                return False
            if head is not None and head.startswith(_ARRAY_NAMESPACES):
                return True                       # jnp.* returns a tracer
            if (isinstance(node.func, ast.Attribute)
                    and node.func.attr in ("item", "tolist")):
                # .item() inside a traced fn is itself a concretization
                # hazard, but that is the sink's job to flag, not taint's
                return self.is_tainted(node.func.value)
            return any(self.is_tainted(a) for a in node.args) or \
                any(self.is_tainted(k.value) for k in node.keywords)
        if isinstance(node, ast.BinOp):
            return self.is_tainted(node.left) or self.is_tainted(node.right)
        if isinstance(node, ast.UnaryOp):
            return self.is_tainted(node.operand)
        if isinstance(node, ast.Compare):
            if all(isinstance(op, (ast.Is, ast.IsNot))
                    for op in node.ops):
                return False                      # `x is None` is concrete
            return (self.is_tainted(node.left)
                    or any(self.is_tainted(c) for c in node.comparators))
        if isinstance(node, ast.Subscript):
            return self.is_tainted(node.value)
        if isinstance(node, (ast.Tuple, ast.List)):
            return any(self.is_tainted(e) for e in node.elts)
        if isinstance(node, ast.IfExp):
            return self.is_tainted(node.body) or self.is_tainted(node.orelse)
        if isinstance(node, ast.BoolOp):
            return any(self.is_tainted(v) for v in node.values)
        if isinstance(node, ast.Starred):
            return self.is_tainted(node.value)
        return False

    # -- walk --------------------------------------------------------------
    def run(self, fn: ast.AST):
        body = getattr(fn, "body", [])
        if isinstance(body, ast.expr):        # Lambda: body is one expr
            self._visit_expr(body)
            return
        for stmt in body:
            self._visit(stmt)

    def _assign_targets(self, target: ast.AST, tainted: bool):
        if isinstance(target, ast.Name):
            if tainted:
                self.tainted.add(target.id)
            else:
                self.tainted.discard(target.id)
        elif isinstance(target, (ast.Tuple, ast.List)):
            for e in target.elts:
                self._assign_targets(e, tainted)
        elif isinstance(target, ast.Starred):
            self._assign_targets(target.value, tainted)

    def _flag(self, node: ast.AST, what: str):
        self.findings.append(Finding(
            checker="trace-safety", path=self.relpath, line=node.lineno,
            message=f"{what} on a traced value inside {self.fn_name} "
                    "(ConcretizationError / silent-recompile hazard)",
            hint="branch with lax.cond/lax.select or jnp.where, loop with "
                 "lax.while_loop/fori_loop, or hoist the value out of the "
                 "traced function (static_argnames)"))

    def _check_test(self, test: ast.AST, kind: str) -> bool:
        if self.is_tainted(test):
            self._flag(test, f"Python `{kind}` test")
            return True
        return False

    def _visit(self, node: ast.AST):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            return          # nested defs get their own context if traced
        if isinstance(node, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
            value = node.value
            tainted = value is not None and self.is_tainted(value)
            targets = (node.targets if isinstance(node, ast.Assign)
                       else [node.target])
            if isinstance(node, ast.AugAssign):
                tainted = tainted or self.is_tainted(node.target)
            if value is not None:
                self._visit_expr(value)
            for t in targets:
                self._assign_targets(t, tainted)
            return
        if isinstance(node, ast.If):
            if not self._check_test(node.test, "if"):
                self._visit_expr(node.test)
            for s in node.body + node.orelse:
                self._visit(s)
            return
        if isinstance(node, ast.While):
            if not self._check_test(node.test, "while"):
                self._visit_expr(node.test)
            for s in node.body + node.orelse:
                self._visit(s)
            return
        if isinstance(node, ast.Assert):
            if not self._check_test(node.test, "assert"):
                self._visit_expr(node.test)
            return
        for child in ast.iter_child_nodes(node):
            if isinstance(child, ast.expr):
                self._visit_expr(child)
            else:
                self._visit(child)

    def _visit_expr(self, node: ast.AST):
        if isinstance(node, ast.Call):
            head = dotted_name(node.func)
            if head in ("bool", "int", "float") and node.args \
                    and self.is_tainted(node.args[0]):
                self._flag(node, f"`{head}()` cast")
        if isinstance(node, ast.IfExp) and self.is_tainted(node.test):
            self._flag(node, "conditional expression test")
        if isinstance(node, ast.BoolOp) and self.is_tainted(node):
            self._flag(node, "`and`/`or` (implicit __bool__)")
            return          # don't double-report on operands
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            return
        for child in ast.iter_child_nodes(node):
            self._visit_expr(child)


def _fn_params(fn: ast.AST) -> List[str]:
    args = fn.args
    names = [a.arg for a in args.posonlyargs + args.args + args.kwonlyargs]
    if args.vararg:
        names.append(args.vararg.arg)
    if args.kwarg:
        names.append(args.kwarg.arg)
    return names


def _traced_functions(tree: ast.AST):
    """Yield (fn_node, static_names) for every traced function in a file.

    Sources: jit/pallas decorators, and function references passed to the
    tracing call heads in ``_TRACING_CALLS`` (by Name, resolved lexically
    to a sibling/nearby ``def``, or as an inline ``lambda``)."""
    defs = {}
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            defs.setdefault(node.name, node)
    seen = set()
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            call = _is_jit_decorated(node)
            if call is not None and id(node) not in seen:
                seen.add(id(node))
                yield node, _static_names(call if call.keywords else None)
        if isinstance(node, ast.Call):
            head = dotted_name(node.func)
            if head not in _TRACING_CALLS:
                continue
            statics = _static_names(node)
            for idx in _TRACING_CALLS[head]:
                if idx >= len(node.args):
                    continue
                arg = node.args[idx]
                target = None
                if isinstance(arg, ast.Name):
                    target = defs.get(arg.id)
                elif isinstance(arg, ast.Lambda):
                    target = arg
                if target is not None and id(target) not in seen:
                    seen.add(id(target))
                    yield target, statics


@register_checker(
    "trace-safety",
    "no Python if/while/bool()/int() on traced values inside jit, "
    "pallas_call, or lax control-flow bodies")
def check_trace_safety(project: Project) -> Iterable[Finding]:
    for sf in project.files:
        if sf.tree is None:
            continue
        for fn, statics in _traced_functions(sf.tree):
            params = [p for p in _fn_params(fn)
                      if p not in statics and p != "self"]
            name = getattr(fn, "name", "<lambda>")
            walk = _TaintWalk(sf.relpath, f"traced fn {name!r}",
                              set(params))
            walk.run(fn)
            yield from walk.findings

"""Quickstart: build a CRouting-HNSW index and see the distance-call savings.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core.index import AnnIndex
from repro.core.spec import SearchSpec
from repro.data.vectors import make_dataset, exact_ground_truth, recall_at_k


def main():
    # 1. a clustered synthetic dataset (stands in for SIFT; dim matches)
    ds = make_dataset(n_base=5000, n_query=100, dim=128, n_clusters=64, seed=0)

    # 2. build the graph index; CRouting keeps the construction-time edge
    #    distances and samples the dataset's angle distribution (paper §4.1)
    idx = AnnIndex.build(ds.base, graph="hnsw", m=16, efc=128)
    print(f"index built: {idx.graph.n} nodes, "
          f"theta* = {idx.profile.theta_star/np.pi:.3f}*pi "
          f"(90th pct of {len(idx.profile.samples)} sampled angles)")

    # 3. search with and without routing plugins — any registry entry works
    #    (repro.core.routers: none | crouting | crouting_o | triangle | finger)
    gt = exact_ground_truth(ds, k=10)
    for router in ("none", "crouting", "finger"):
        ids, dists, stats = idx.search(
            ds.queries, spec=SearchSpec(k=10, efs=96, router=router))
        rec = recall_at_k(ids, gt, 10)
        print(f"router={router:9s} recall@10={rec:.3f} "
              f"dist_calls/query={stats.dist_calls.mean():7.1f} "
              f"estimates/query={stats.est_calls.mean():7.1f}")

    # 4. the paper's headline: same accuracy, far fewer exact distance calls
    _, _, plain = idx.search(ds.queries, spec=SearchSpec(k=10, efs=96,
                                                         router="none"))
    _, _, cr = idx.search(ds.queries, spec=SearchSpec(k=10, efs=96,
                                                      router="crouting"))
    saved = 1 - cr.dist_calls.mean() / plain.dist_calls.mean()
    print(f"CRouting skipped {saved:.1%} of exact distance computations")


if __name__ == "__main__":
    main()

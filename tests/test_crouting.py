"""Paper-behavior validation: the claims of CRouting reproduce qualitatively
on synthetic data (quantitative table in EXPERIMENTS.md)."""
import numpy as np

from repro.core.angles import sample_angle_profile, theoretical_angle_pdf
from repro.core.ref_search import search_ref
from repro.core.search import search_batch
from repro.core.spec import SearchSpec
from repro.data.vectors import recall_at_k


def test_angle_concentration_near_half_pi(hnsw_profile):
    """§3.3: theta concentrates near 0.5*pi (slightly below, since the search
    moves toward the query)."""
    med = np.median(hnsw_profile.samples)
    assert 0.3 * np.pi < med < 0.6 * np.pi
    # skew: the distribution has mass on both sides but a single mode
    assert hnsw_profile.samples.std() < 0.2 * np.pi


def test_angle_distribution_graph_invariant(small_ds, hnsw_index, nsg_index):
    """Fig. 7: the angle distribution is a property of the DATASET, not of the
    graph algorithm."""
    p1 = sample_angle_profile(hnsw_index, n_sample=10, efs=48, seed=3)
    p2 = sample_angle_profile(nsg_index, n_sample=10, efs=48, seed=3)
    assert abs(np.median(p1.samples) - np.median(p2.samples)) < 0.06 * np.pi


def test_user_queries_not_truncated_to_default_n_sample(hnsw_index):
    """ISSUE 5 regression: 50 held-out queries against a graph whose default
    n_sample is smaller (0.1%·1500 -> 8) must ALL be searched, and
    n_sample_queries must record the count actually used."""
    rng = np.random.default_rng(11)
    held_out = rng.standard_normal((50, hnsw_index.dim)).astype(np.float32)
    prof = sample_angle_profile(hnsw_index, efs=32, queries=held_out)
    assert prof.n_sample_queries == 50
    # sanity: 50 queries collect far more angle samples than 8 would
    prof8 = sample_angle_profile(hnsw_index, efs=32, queries=held_out,
                                 n_sample=8)
    assert prof8.n_sample_queries == 8
    assert prof.samples.size > prof8.samples.size


def test_explicit_n_sample_still_caps_user_queries(hnsw_index):
    """Passing BOTH queries and n_sample keeps the cap (the old default-cap
    behavior is now opt-in), and the random path records its true count."""
    rng = np.random.default_rng(12)
    held_out = rng.standard_normal((20, hnsw_index.dim)).astype(np.float32)
    capped = sample_angle_profile(hnsw_index, efs=32, queries=held_out,
                                  n_sample=5)
    assert capped.n_sample_queries == 5
    rand = sample_angle_profile(hnsw_index, efs=32, n_sample=7, seed=2)
    assert rand.n_sample_queries == 7


def test_theoretical_pdf_integrates_to_one():
    eta = np.linspace(1e-3, np.pi - 1e-3, 4001)
    for d in (16, 128, 960):
        pdf = theoretical_angle_pdf(eta, d)
        area = np.trapezoid(pdf, eta)
        assert abs(area - 1.0) < 1e-3, (d, area)


def test_crouting_reduces_distance_calls(small_ds, hnsw_index, hnsw_profile):
    """Headline claim: substantially fewer exact distance calls at the same efs."""
    g = hnsw_index
    plain = search_batch(g, small_ds.queries, SearchSpec(efs=48, router="none"))
    cr = search_batch(g, small_ds.queries, SearchSpec(efs=48, router="crouting"),
                      cos_theta=hnsw_profile.cos_theta_star)
    reduction = 1 - np.mean(cr.dist_calls) / np.mean(plain.dist_calls)
    assert reduction > 0.20, f"only {reduction:.1%} fewer distance calls"


def test_error_correction_recovers_recall(small_ds, hnsw_index, hnsw_profile,
                                          ground_truth):
    """Table 3: CRouting_O collapses recall; error correction recovers most
    of it while still saving calls."""
    g = hnsw_index
    ct = hnsw_profile.cos_theta_star
    # efs=16 keeps the pool under pressure so the prune-only collapse shows
    # (at large efs this tiny dataset saturates recall for every router)
    cfgs = {r: search_batch(g, small_ds.queries, SearchSpec(efs=16, router=r),
                            cos_theta=ct)
            for r in ("none", "crouting", "crouting_o")}
    rec = {r: recall_at_k(np.asarray(v.ids[:, :10]), ground_truth, 10)
           for r, v in cfgs.items()}
    assert rec["crouting_o"] < rec["crouting"] - 0.1, rec
    # at FIXED efs the paper itself shows a gap (Table 3: 0.954 vs 0.842 at
    # efs=60); iso-recall speedup is asserted in test_system.py
    assert rec["crouting"] > rec["none"] - 0.16, rec
    assert np.mean(cfgs["crouting"].dist_calls) < np.mean(cfgs["none"].dist_calls)
    assert np.mean(cfgs["crouting_o"].dist_calls) < np.mean(cfgs["crouting"].dist_calls)


def test_triangle_inequality_barely_prunes(small_ds, hnsw_index):
    """§3.2: the triangle lower bound is too loose to prune (~0.08% on SIFT)."""
    g = hnsw_index
    plain = search_batch(g, small_ds.queries, SearchSpec(efs=48, router="none"))
    tri = search_batch(g, small_ds.queries, SearchSpec(efs=48, router="triangle"))
    reduction = 1 - np.mean(tri.dist_calls) / np.mean(plain.dist_calls)
    assert reduction < 0.05, f"triangle pruned {reduction:.1%} (too much?)"


def test_relative_estimation_error_small(small_ds, hnsw_index, hnsw_profile):
    """Table 4: mean relative error of the cosine-theorem estimate ~6%."""
    g = hnsw_index
    errs = []
    for q in small_ds.queries[:15]:
        _, _, st = search_ref(g, q, efs=48, router="crouting",
                              cos_theta=hnsw_profile.cos_theta_star,
                              record_est_error=True)
        for est, true in st.est_pairs:
            if true > 1e-9:
                errs.append(abs(true - est) / true)
    assert np.mean(errs) < 0.20, f"mean rel err {np.mean(errs):.3f}"


def test_incorrect_prune_ratio_bounded(small_ds, hnsw_index, hnsw_profile,
                                       ground_truth):
    """Table 5: pruned nodes that were actually positive stay a small
    fraction (paper <6%; we allow <15% on tiny synthetic graphs)."""
    g = hnsw_index
    ct = hnsw_profile.cos_theta_star
    bad = tot = 0
    for i, q in enumerate(small_ds.queries[:15]):
        _, _, st_p = search_ref(g, q, efs=48)          # ground-truth positives
        ids, _, st_c = search_ref(g, q, efs=48, router="crouting", cos_theta=ct)
        positives = st_p.visited_ids
        tot += max(len(st_c.pruned_ids), 1)
        bad += len(st_c.pruned_ids & set(int(x) for x in ids if x >= 0))
    assert bad / tot < 0.15, f"incorrect prune ratio {bad/tot:.3f}"


def test_higher_percentile_prunes_more(small_ds, hnsw_index, hnsw_profile):
    """Fig. 13: larger theta* (higher percentile) => more pruning."""
    g = hnsw_index
    calls = []
    for pct in (50, 90, 99):
        prof = hnsw_profile.at_percentile(pct)
        r = search_batch(g, small_ds.queries[:16],
                         SearchSpec(efs=48, router="crouting_o"),
                         cos_theta=prof.cos_theta_star)
        calls.append(float(np.mean(r.dist_calls)))
    assert calls[0] >= calls[1] >= calls[2], calls

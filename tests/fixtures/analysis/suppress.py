"""Suppression semantics: justified silences, bare does not, typos flagged.

This fixture is asserted with explicit line numbers in
tests/test_analysis.py (a bare tag cannot carry an inline marker —
trailing text would become its justification).  Keep the layout stable.
"""
import threading


class Box:
    def __init__(self):
        self._lock = threading.Lock()
        self.val = 0    # guarded by: self._lock

    def silenced(self):
        # a justified suppression silences the finding on the next code line
        # repolint: ignore[guarded-by] read-only snapshot for logs; a stale
        # value is acceptable here
        return self.val

    def silenced_inline(self):
        return self.val  # repolint: ignore[guarded-by] monitoring read, staleness ok

    def bare_tag_does_not_silence(self):
        return self.val  # repolint: ignore[guarded-by]

    def unknown_id(self):
        with self._lock:
            # repolint: ignore[gaurded-by] typo'd checker id
            return self.val

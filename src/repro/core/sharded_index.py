"""Dataset-sharded distributed ANNS serving (DESIGN.md §6).

The billion-vector layout: every device owns one shard of the base vectors
plus a search graph built *over that shard*.  A query batch is replicated,
each device runs the batched CRouting engine on its shard, and the global
top-k is a cheap merge of per-shard top-k lists (k x n_shards candidates —
one small all-gather, not a vector-data collective).

Straggler mitigation: the per-shard search runs a *fixed hop budget*
(SearchSpec.max_hops), so one slow shard cannot stall the merge barrier —
quality degrades gracefully instead of latency (tested in
tests/test_sharded_index.py).

`serve_step` is the function the multi-pod dry-run lowers for the ANNS
configs; it is pure pjit (shard_map inside) and scales to any mesh by
flattening all mesh axes into the shard axis.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core import distances as D
from repro.core.angles import sample_angle_profile
from repro.core.graph import GraphIndex
from repro.core.routers import get_router
from repro.core.search import _search_batch
from repro.core.spec import SearchSpec, SearchStats, resolve_search_spec
from repro.fault import failpoints as fault
from repro.quant import sq8 as SQ


@dataclasses.dataclass
class ShardedIndexArrays:
    """Stacked per-shard device arrays (leading axis = shard)."""

    vectors: np.ndarray      # [S, ns+1, d]
    neighbors: np.ndarray    # [S, ns+1, M]
    edge_eu: np.ndarray      # [S, ns+1, M]
    norms: np.ndarray        # [S, ns+1]
    entries: np.ndarray      # [S]
    offsets: np.ndarray      # [S] global id of local id 0
    ns: int                  # local shard capacity (excl. pad row)
    metric: str
    cos_theta: float
    # SQ8 companion tables (per-shard grids; SearchSpec.estimate="sq8")
    sq8_codes: np.ndarray = None   # [S, ns+1, d] uint8
    sq8_lo: np.ndarray = None      # [S, d]
    sq8_scale: np.ndarray = None   # [S, d]
    sq8_eps: np.ndarray = None     # [S, d]


def shard_dataset(base: np.ndarray, n_shards: int, metric: str = "l2",
                  graph: str = "hnsw", seed: int = 0,
                  profile_percentile: float = 90.0, **graph_kw
                  ) -> ShardedIndexArrays:
    """Round-robin-partition the base set; build one sub-graph per shard."""
    from repro.core.hnsw import build_hnsw
    from repro.core.nsg import build_nsg

    base = D.preprocess_vectors(np.ascontiguousarray(base, np.float32), metric)
    n, d = base.shape
    ns = (n + n_shards - 1) // n_shards
    builder = {"hnsw": build_hnsw, "nsg": build_nsg}[graph]

    graphs: List[GraphIndex] = []
    offsets = []
    cos_thetas = []
    for s in range(n_shards):
        lo, hi = s * ns, min((s + 1) * ns, n)
        sub = base[lo:hi]
        g = builder(sub, metric=metric, seed=seed + s, **graph_kw)
        graphs.append(g)
        offsets.append(lo)
        prof = sample_angle_profile(g, percentile=profile_percentile, seed=seed)
        cos_thetas.append(prof.cos_theta_star)

    m = max(g.max_degree for g in graphs)
    vecs = np.zeros((n_shards, ns + 1, d), np.float32)
    nbrs = np.full((n_shards, ns + 1, m), ns, np.int32)
    ed = np.full((n_shards, ns + 1, m), np.inf, np.float32)
    norms = np.ones((n_shards, ns + 1), np.float32)
    entries = np.zeros((n_shards,), np.int32)
    codes = np.zeros((n_shards, ns + 1, d), np.uint8)
    sq_lo = np.zeros((n_shards, d), np.float32)
    sq_scale = np.full((n_shards, d), 1e-12, np.float32)
    sq_eps = np.zeros((n_shards, d), np.float32)
    for s, g in enumerate(graphs):
        k = g.n
        vecs[s, :k] = g.vectors
        # remap pad ids (== k) to the stacked pad slot (== ns)
        nb = g.neighbors.copy()
        nb[nb >= k] = ns
        nbrs[s, :k, : g.max_degree] = nb
        ed[s, :k, : g.max_degree] = g.edge_eu_dist
        norms[s, :k] = g.norms if g.norms is not None else np.linalg.norm(g.vectors, axis=1)
        entries[s] = g.entry_point
        # per-shard SQ8 grid (fit on the shard's real rows; pad rows encode
        # the zero vector and are always masked)
        qp = SQ.sq8_train(g.vectors)
        codes[s] = SQ.sq8_encode(vecs[s], qp)
        sq_lo[s], sq_scale[s], sq_eps[s] = qp.lo, qp.scale, qp.eps
    return ShardedIndexArrays(
        vectors=vecs, neighbors=nbrs, edge_eu=ed, norms=norms, entries=entries,
        offsets=np.asarray(offsets, np.int64), ns=ns, metric=metric,
        cos_theta=float(np.median(cos_thetas)),
        sq8_codes=codes, sq8_lo=sq_lo, sq8_scale=sq_scale, sq8_eps=sq_eps)


def _backfill_sq8(arrays: ShardedIndexArrays) -> ShardedIndexArrays:
    """Fill missing SQ8 tables on a pre-existing ShardedIndexArrays."""
    S, _, d = arrays.vectors.shape
    codes = np.zeros(arrays.vectors.shape, np.uint8)
    lo = np.zeros((S, d), np.float32)
    scale = np.full((S, d), 1e-12, np.float32)
    eps = np.zeros((S, d), np.float32)
    for s in range(S):
        qp = SQ.sq8_train(arrays.vectors[s])
        codes[s] = SQ.sq8_encode(arrays.vectors[s], qp)
        lo[s], scale[s], eps[s] = qp.lo, qp.scale, qp.eps
    return dataclasses.replace(arrays, sq8_codes=codes, sq8_lo=lo,
                               sq8_scale=scale, sq8_eps=eps)


def make_serve_step(mesh: Mesh, cfg: SearchSpec, ns: int,
                    shard_axes: Optional[Tuple[str, ...]] = None):
    """Build the pjit-able distributed serve step.

    shard_axes: mesh axes flattened into the shard dimension (default: all).
    Returns (serve_step, in_shardings, out_shardings) ready for jit/lower.
    The step takes ``(*10 data arrays, queries, cos_theta, valid)`` where
    ``valid`` [B] bool marks the real lanes of a bucket-padded batch
    (padded lanes are born done inside the engine and contribute zero to
    every counter — see ``_search_batch``).

    The merge is ``efs``-wide: each shard contributes its whole result pool
    and the host slices to the request's ``k``, so ``k`` is request-only
    (canonical-spec contract — sweeping ``k`` or ``cos_theta`` never
    re-jits).  The third output is the aggregate counter vector
    ``[dist_calls, est_calls, rerank_calls, sq8_calls, hops, iters,
    *Router.extra_counters]`` (sums across shards and queries; ``iters`` is
    the max over shards — the straggler's iteration count) that
    ``ShardedAnnIndex.search`` wraps into a typed ``SearchStats``.
    """
    axes = tuple(shard_axes or mesh.axis_names)
    extra_names = get_router(cfg.router).extra_counters
    kk = cfg.efs              # merge width; k slices host-side

    def local_search(vectors, neighbors, edge_eu, norms, entries, offsets,
                     sq8_codes, sq8_lo, sq8_scale, sq8_eps,
                     queries, cos_theta, valid):
        # shard_map gives the local shard with a leading axis of size 1
        arrays = {
            "vectors": vectors[0], "neighbors": neighbors[0],
            "edge_eu": edge_eu[0], "norms": norms[0],
            "entry": entries[0], "n": ns,
            "sq8_codes": sq8_codes[0], "sq8_lo": sq8_lo[0],
            "sq8_scale": sq8_scale[0], "sq8_eps": sq8_eps[0],
        }
        res = _search_batch(arrays, queries, cos_theta, cfg, valid=valid)
        loc_d, loc_i = res.dists[:, :kk], res.ids[:, :kk]
        # int32 global ids (enable_x64 is off; fine below 2^31 vectors/shard set)
        glob_i = jnp.where(loc_i < ns, loc_i + offsets[0].astype(jnp.int32), -1)
        # merge: gather per-shard pools along the shard axis, then re-top-k
        all_d = jax.lax.all_gather(loc_d, axes, tiled=False)   # [S, B, efs]
        all_i = jax.lax.all_gather(glob_i, axes, tiled=False)
        S = all_d.shape[0]
        flat_d = jnp.moveaxis(all_d, 0, 1).reshape(queries.shape[0], S * kk)
        flat_i = jnp.moveaxis(all_i, 0, 1).reshape(queries.shape[0], S * kk)
        neg, pos = jax.lax.top_k(-flat_d, kk)
        ids = jnp.take_along_axis(flat_i, pos, axis=1)
        sums = jax.lax.psum(jnp.stack(
            [jnp.sum(res.dist_calls), jnp.sum(res.est_calls),
             jnp.sum(res.rerank_calls), jnp.sum(res.sq8_calls),
             jnp.sum(res.hops)]
            + [jnp.sum(res.extra[nm]) for nm in extra_names]), axes)
        iters = jax.lax.pmax(res.iters, axes)
        stats_vec = jnp.concatenate([sums[:5], iters[None], sums[5:]])
        return -neg, ids, stats_vec

    pspec_data = P(axes)      # shard leading axis over all shard axes
    pspec_rep = P()           # queries / cos_theta / valid replicated

    serve = shard_map(
        local_search, mesh=mesh,
        in_specs=(pspec_data,) * 10 + (pspec_rep,) * 3,
        out_specs=(pspec_rep, pspec_rep, pspec_rep),
        check_rep=False,
    )
    in_sh = tuple(NamedSharding(mesh, s) for s in
                  (pspec_data,) * 10 + (pspec_rep,) * 3)
    out_sh = tuple(NamedSharding(mesh, s) for s in (pspec_rep,) * 3)
    return serve, in_sh, out_sh


class ShardedAnnIndex:
    """Runtime wrapper: place shards on a mesh and serve batched queries.

    ``spec`` is the same ``SearchSpec`` the single-index path takes
    (``metric``/``use_hierarchy`` are overridden from the shard arrays);
    anything else — including the retired legacy kwargs and the pre-parity
    positional ``cos_theta`` scalar — raises ``TypeError``, for API parity
    with ``AnnIndex.search``.  Per-call specs that differ only
    in the request-only fields (``k``/``cos_theta``) reuse the jitted serve
    step (canonical-spec contract: ``k`` slices the ``efs``-wide merge
    host-side, ``cos_theta`` is a traced scalar); engine-shaping changes
    compile one new step, cached per canonical spec.  Routers that need
    per-graph companion tables (``Router.companion_tables``, e.g.
    ``finger``) are not yet plumbed through the stacked per-shard arrays
    and are rejected here.
    """

    DEFAULT_SEARCH = SearchSpec(k=10, efs=100, router="crouting",
                                max_hops=2048)

    def __init__(self, arrays: ShardedIndexArrays, mesh: Mesh,
                 spec: Optional[SearchSpec] = None):
        spec = resolve_search_spec(spec, self.DEFAULT_SEARCH,
                                   "ShardedAnnIndex")
        spec = dataclasses.replace(spec, metric=arrays.metric,
                                   use_hierarchy=False)
        self.arrays = arrays
        self.mesh = mesh
        self.spec = spec
        self.k = spec.k        # back-compat alias
        self.cfg = spec        # back-compat alias
        if arrays.sq8_codes is None:
            # arrays predating the SQ8 tables (direct construction, old
            # persisted shards): backfill per-shard grids from the stacked
            # vectors — the zero pad rows only widen the grid, so the
            # lower-bound contract is unaffected
            arrays = _backfill_sq8(arrays)
            self.arrays = arrays
        self._steps = {}       # canonical spec -> jitted serve step
        self._placed = None    # device-placed data arrays (fixed shardings)
        self._step(spec)       # validate + pre-jit the construction spec

    def _step(self, spec: SearchSpec):
        """The jitted serve step for ``spec``, cached per canonical form."""
        key = spec.canonical()
        fn = self._steps.get(key)
        if fn is not None:
            return fn
        rt = get_router(spec.router)
        if rt.companion_tables:
            raise NotImplementedError(
                f"router {spec.router!r} needs companion tables "
                f"{rt.companion_tables} which the sharded arrays do not "
                "carry yet; use the single-index path")
        serve, in_sh, _ = make_serve_step(self.mesh, key, self.arrays.ns)
        fn = jax.jit(serve, in_shardings=in_sh)
        if self._placed is None:
            dev = lambda a, sh: jax.device_put(a, sh)
            self._placed = tuple(
                dev(getattr(self.arrays, f), s) for f, s in
                zip(("vectors", "neighbors", "edge_eu", "norms", "entries",
                     "offsets", "sq8_codes", "sq8_lo", "sq8_scale",
                     "sq8_eps"), in_sh[:10]))
        self._steps[key] = fn
        return fn

    def search(self, queries: np.ndarray, spec=None, *,
               valid: Optional[np.ndarray] = None
               ) -> Tuple[np.ndarray, np.ndarray, SearchStats]:
        """Returns (ids [B,k], dists [B,k], SearchStats).

        ``spec`` overrides the construction spec for this call (same
        contract as ``AnnIndex.search``; non-``SearchSpec`` values raise
        ``TypeError``).  ``valid`` [B] bool marks the real lanes of a
        bucket-padded batch — padded lanes contribute zero to the counters.
        The stats fields are batch TOTALS reduced across shards (``iters``
        is the straggler's count), not per-query arrays — the per-shard
        engines ran behind one collective merge.
        """
        spec = resolve_search_spec(spec, self.spec, "ShardedAnnIndex.search")
        spec = dataclasses.replace(spec, metric=self.arrays.metric,
                                   use_hierarchy=False)
        # the device data plane is one collective: no partial results here —
        # a fault fails the whole dispatch, and the serving frontend
        # contains it per-batch (DESIGN.md §10 documents the asymmetry
        # with MutableShardedAnnIndex's host-side composition)
        fault.hit("sharded.search")
        fn = self._step(spec)
        q = D.preprocess_vectors(np.ascontiguousarray(queries, np.float32),
                                 self.arrays.metric)
        # precedence: spec override > profiled shard median
        ct = spec.cos_theta
        if ct is None:
            ct = self.arrays.cos_theta
        v = (jnp.ones((q.shape[0],), bool) if valid is None
             else jnp.asarray(valid, bool))
        d, i, sv = fn(*self._placed, jnp.asarray(q),
                      jnp.asarray(ct, jnp.float32), v)
        sv = np.asarray(sv)
        extra_names = get_router(spec.router).extra_counters
        stats = SearchStats(
            dist_calls=int(sv[0]), est_calls=int(sv[1]),
            rerank_calls=int(sv[2]), sq8_calls=int(sv[3]), hops=int(sv[4]),
            iters=int(sv[5]), router=spec.router,
            extra={nm: int(sv[6 + j]) for j, nm in enumerate(extra_names)})
        k = spec.k
        return np.asarray(i[:, :k]), np.asarray(d[:, :k]), stats

"""Autotune: an online SLO-driven controller over the serving knobs.

Public surface::

    from repro.autotune import AutotuneDriver, Objective, TuneSpace

    fe = ServeFrontend(index, spec)
    drv = AutotuneDriver.attach(fe, Objective(slo_p99_ms=250.0))
    with fe, drv:                    # serve + tune on background threads
        ... submit traffic ...
    print(drv.decision_log())        # structured, deterministic per seed

See DESIGN.md §12 (self-tuning serving) and the README Autotune section.
"""
from repro.autotune.controller import Controller, Decision, Objective
from repro.autotune.driver import AutotuneDriver
from repro.autotune.proxy import ProbeMeasurement, RecallProxy
from repro.autotune.space import Knob, TuneSpace, spec_key

__all__ = [
    "AutotuneDriver", "Controller", "Decision", "Objective",
    "Knob", "TuneSpace", "spec_key",
    "RecallProxy", "ProbeMeasurement",
]

"""Engine-session adapters: one serving interface over both index types.

The frontend speaks one protocol — ``search_padded(q, n_valid, k,
cos_theta)`` plus a compile counter — and these adapters bind it to the two
engine stacks:

* ``SingleIndexSession`` — ``AnnIndex`` over the compiled-engine cache of
  ``repro.core.search`` (one jitted fn per canonical spec; one executable
  per batch shape inside it).  Stats are per-query arrays, so a dispatch's
  stats slice exactly per request.
* ``ShardedIndexSession`` — ``ShardedAnnIndex`` over its per-canonical-spec
  serve-step cache.  The bucket ``valid`` mask rides to the device so the
  shard-reduced counter totals exclude padded lanes; stats are batch totals
  behind one collective merge and cannot be split per request (each request
  of a dispatch sees the dispatch's totals).
* ``MutableIndexSession`` — ``MutableAnnIndex`` (delta + tombstones +
  background merge, DESIGN.md §9).  The session does NOT pin a graph or a
  jitted fn: every dispatch resolves the index's current snapshot, so a
  concurrent merge swap is invisible to the request path.  Warmup notes
  each bucket shape with the index (``note_shape``), merges pre-warm those
  shapes on the fresh graph before swapping, and ``compile_count`` folds
  retired + pre-warmed engines — so ``recompiles_after_warmup`` stays 0
  across snapshot swaps.
* ``MutableShardedIndexSession`` — ``MutableShardedAnnIndex`` (host-side
  per-shard composition, DESIGN.md §9/§10).  Stats are the dispatch's
  shard-merged record (no per-request split), which is what carries the
  graceful-degradation fields: a dispatch that lost shards resolves its
  futures with ``stats.degraded``/``shards_failed`` set rather than an
  exception.

Request-only fields (``k``/``cos_theta``) never recompile — the canonical-
spec contract from ``repro.core.spec`` — so a session's compile count is
exactly one per warmed bucket shape.  ``k`` is capped at the session's
``efs``: a larger ``k`` would widen the result pool and so the trace.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

from repro.core.index import AnnIndex, DEFAULT_SEARCH
from repro.core.sharded_index import ShardedAnnIndex
from repro.core.spec import SearchSpec, SearchStats


class SingleIndexSession:
    """``AnnIndex`` behind the serving protocol (per-query stats)."""

    splits_stats = True   # per-request stats slices are exact

    def __init__(self, index: AnnIndex, spec: SearchSpec):
        from repro.core.search import build_search_fn

        self.index = index
        g = index.graph
        self.spec = dataclasses.replace(
            spec, efs=max(spec.efs, spec.k), metric=g.metric,
            use_hierarchy=g.upper_neighbors is not None)
        self.dim = g.dim
        # the SAME cache entry AnnIndex.search resolves to: its _cache_size
        # counts every executable (one per batch shape) this session compiles
        _, self._fn = build_search_fn(g, self.spec)

    def compile_count(self) -> int:
        return self._fn._cache_size()

    def health(self) -> dict:
        return {"kind": "single", "n": int(self.index.graph.n),
                "degraded": False}

    def sample_query(self) -> np.ndarray:
        return np.asarray(self.index.graph.vectors[0], np.float32)

    def search_padded(self, queries: np.ndarray, n_valid: int, k: int,
                      cos_theta: Optional[float]
                      ) -> Tuple[np.ndarray, np.ndarray, SearchStats]:
        ids, dists, stats = self.index.search(
            queries, spec=self.spec.replace(k=k, cos_theta=cos_theta))
        return (ids[:n_valid], dists[:n_valid],
                self.stats_for_rows(stats, 0, n_valid))

    def stats_for_rows(self, stats: SearchStats, lo: int, hi: int
                       ) -> SearchStats:
        s = slice(lo, hi)
        return dataclasses.replace(
            stats, dist_calls=stats.dist_calls[s], est_calls=stats.est_calls[s],
            rerank_calls=stats.rerank_calls[s], sq8_calls=stats.sq8_calls[s],
            hops=stats.hops[s],
            extra={kk: v[s] for kk, v in stats.extra.items()})


class ShardedIndexSession:
    """``ShardedAnnIndex`` behind the serving protocol (batch-total stats)."""

    splits_stats = False  # shard-reduced totals: per-request stats = dispatch

    def __init__(self, index: ShardedAnnIndex, spec: SearchSpec):
        self.index = index
        self.spec = dataclasses.replace(
            spec, efs=max(spec.efs, spec.k), metric=index.arrays.metric,
            use_hierarchy=False)
        self.dim = index.arrays.vectors.shape[-1]
        self._fn = index._step(self.spec)   # pre-jit + router validation

    def compile_count(self) -> int:
        return self._fn._cache_size()

    def health(self) -> dict:
        return {"kind": "sharded",
                "n_shards": int(self.index.arrays.vectors.shape[0]),
                "degraded": False}

    def sample_query(self) -> np.ndarray:
        return np.asarray(self.index.arrays.vectors[0, 0], np.float32)

    def search_padded(self, queries: np.ndarray, n_valid: int, k: int,
                      cos_theta: Optional[float]
                      ) -> Tuple[np.ndarray, np.ndarray, SearchStats]:
        valid = np.zeros((queries.shape[0],), bool)
        valid[:n_valid] = True
        ids, dists, stats = self.index.search(
            queries, spec=self.spec.replace(k=k, cos_theta=cos_theta),
            valid=valid)
        return ids[:n_valid], dists[:n_valid], stats

    def stats_for_rows(self, stats: SearchStats, lo: int, hi: int
                       ) -> SearchStats:
        return stats


class MutableIndexSession:
    """``MutableAnnIndex`` behind the serving protocol (per-query stats).

    Snapshot-agnostic: holds only the user spec.  Graph-dependent spec
    fields (``metric``/``use_hierarchy``) are resolved inside
    ``MutableAnnIndex.search`` against whatever snapshot is live at
    dispatch time, so bucket sessions survive a merge swap with zero
    request-path recompiles (the merge pre-warms every shape this session
    warmed, via ``note_shape``).
    """

    splits_stats = True   # per-request stats slices are exact

    def __init__(self, index, spec: SearchSpec):
        self.index = index
        self.spec = dataclasses.replace(spec, efs=max(spec.efs, spec.k))

    @property
    def dim(self) -> int:
        return self.index.dim

    def compile_count(self) -> int:
        # engines across every snapshot generation + the delta-scan kernels
        return self.index.compile_count()

    def health(self) -> dict:
        idx = self.index
        return {"kind": "mutable", "n_live": int(idx.n_live),
                "epoch": int(idx.epoch),
                "quarantined": bool(idx.quarantined),
                "degraded": bool(idx.quarantined),
                "merge_error": (repr(idx.merge_error)
                                if idx.merge_error is not None else None),
                "durable": idx._durable is not None}

    def sample_query(self) -> np.ndarray:
        g = self.index._state.snapshot.index.graph
        return np.asarray(g.vectors[0], np.float32)

    def search_padded(self, queries: np.ndarray, n_valid: int, k: int,
                      cos_theta: Optional[float]
                      ) -> Tuple[np.ndarray, np.ndarray, SearchStats]:
        ids, dists, stats = self.index.search(
            queries, spec=self.spec.replace(k=k, cos_theta=cos_theta))
        return (ids[:n_valid], dists[:n_valid],
                self.stats_for_rows(stats, 0, n_valid))

    stats_for_rows = SingleIndexSession.stats_for_rows


class MutableShardedIndexSession:
    """``MutableShardedAnnIndex`` behind the serving protocol.

    The host-side top-k composition means per-shard failures degrade the
    dispatch instead of failing it (``MutableShardedAnnIndex.search``);
    the shard-merged stats carry ``shards_failed``/``degraded`` to every
    request of the dispatch.  Stats are batch-level (per-query arrays from
    S shards concatenate under ``SearchStats.merge``, so a per-request row
    slice would be meaningless) — each request sees the dispatch's record,
    like the device-sharded session.
    """

    splits_stats = False

    def __init__(self, index, spec: SearchSpec):
        self.index = index
        self.spec = dataclasses.replace(spec, efs=max(spec.efs, spec.k))

    @property
    def dim(self) -> int:
        return self.index.dim

    def compile_count(self) -> int:
        # per-shard engines across snapshot generations + the (shared)
        # delta-scan kernels counted once
        return self.index.compile_count()

    def health(self) -> dict:
        idx = self.index
        quarantined = list(idx.quarantined_shards)
        return {"kind": "mutable-sharded", "n_live": int(idx.n_live),
                "n_shards": len(idx.shards),
                "epochs": [int(e) for e in idx.epochs],
                "quarantined_shards": quarantined,
                "degraded": bool(quarantined),
                "durable": any(sh._durable is not None for sh in idx.shards)}

    def sample_query(self) -> np.ndarray:
        g = self.index.shards[0]._state.snapshot.index.graph
        return np.asarray(g.vectors[0], np.float32)

    def search_padded(self, queries: np.ndarray, n_valid: int, k: int,
                      cos_theta: Optional[float]
                      ) -> Tuple[np.ndarray, np.ndarray, SearchStats]:
        ids, dists, stats = self.index.search(
            queries, spec=self.spec.replace(k=k, cos_theta=cos_theta))
        return ids[:n_valid], dists[:n_valid], stats

    def stats_for_rows(self, stats: SearchStats, lo: int, hi: int
                       ) -> SearchStats:
        return stats


def make_session(index, spec: Optional[SearchSpec] = None):
    """Bind an index to the serving protocol (dispatch on index type)."""
    from repro.mutate.index import MutableAnnIndex
    from repro.mutate.sharded import MutableShardedAnnIndex

    if isinstance(index, AnnIndex):
        return SingleIndexSession(index, spec or DEFAULT_SEARCH)
    if isinstance(index, ShardedAnnIndex):
        return ShardedIndexSession(index, spec or index.spec)
    if isinstance(index, MutableAnnIndex):
        return MutableIndexSession(index, spec or index.default_spec)
    if isinstance(index, MutableShardedAnnIndex):
        return MutableShardedIndexSession(index, spec or index.default_spec)
    raise TypeError(
        f"cannot serve {type(index).__name__}; expected AnnIndex, "
        "ShardedAnnIndex, MutableAnnIndex, or MutableShardedAnnIndex")

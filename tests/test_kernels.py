"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(0)


@pytest.mark.parametrize("q_n,c_n,d", [(8, 16, 32), (70, 130, 96),
                                       (128, 256, 128), (33, 257, 200)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("mode", ["l2", "ip"])
def test_l2_distance_sweep(q_n, c_n, d, dtype, mode):
    q = jnp.asarray(RNG.normal(size=(q_n, d)), dtype)
    x = jnp.asarray(RNG.normal(size=(c_n, d)), dtype)
    out = ops.l2_distance(q, x, mode=mode, bq=32, bc=64, bd=64)
    exp = ref.l2_distance_ref(q, x, mode=mode)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=tol, atol=tol * d)


@pytest.mark.parametrize("b,m", [(1, 7), (13, 37), (32, 64), (5, 130)])
def test_crouting_prune_sweep(b, m):
    ed = jnp.asarray(RNG.uniform(0.1, 2.0, size=(b, m)), jnp.float32)
    dcq = jnp.asarray(RNG.uniform(0.1, 2.0, size=(b,)), jnp.float32)
    b2 = jnp.asarray(RNG.uniform(0.5, 4.0, size=(b,)), jnp.float32)
    valid = jnp.asarray(RNG.integers(0, 2, size=(b, m)), jnp.int8)
    for ct in (-0.3, 0.0, 0.156, 0.9):
        e1, m1 = ops.crouting_prune(ed, dcq, b2, valid, ct)
        e2, m2 = ref.crouting_prune_ref(ed, dcq, b2, valid, ct)
        np.testing.assert_allclose(np.asarray(e1), np.asarray(e2), rtol=1e-5)
        assert (np.asarray(m1) == np.asarray(m2)).all()


@pytest.mark.parametrize("b,m,n,d", [(2, 5, 50, 16), (7, 31, 300, 64),
                                     (4, 16, 128, 128)])
def test_gather_distance_sweep(b, m, n, d):
    table = jnp.asarray(RNG.normal(size=(n, d)), jnp.float32)
    idx = jnp.asarray(RNG.integers(0, n, size=(b, m)), jnp.int32)
    qs = jnp.asarray(RNG.normal(size=(b, d)), jnp.float32)
    out = ops.gather_distance(idx, qs, table)
    exp = ref.gather_distance_ref(idx, qs, table)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), rtol=1e-5,
                               atol=1e-5)


def test_gather_distance_pruned_lanes():
    table = jnp.asarray(RNG.normal(size=(64, 32)), jnp.float32)
    idx = jnp.asarray(RNG.integers(0, 64, size=(3, 8)), jnp.int32)
    qs = jnp.asarray(RNG.normal(size=(3, 32)), jnp.float32)
    mask = jnp.asarray(RNG.integers(0, 2, size=(3, 8)), jnp.int8)
    out = ops.gather_distance_pruned(idx, mask, qs, table)
    exp = ref.gather_distance_ref(idx, qs, table)
    m = np.asarray(mask) != 0
    assert np.isinf(np.asarray(out)[m]).all()
    np.testing.assert_allclose(np.asarray(out)[~m], np.asarray(exp)[~m],
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("p,m", [(8, 4), (16, 12), (50, 30), (64, 64)])
def test_pool_merge_sweep(p, m):
    b = 6
    pd = jnp.sort(jnp.asarray(RNG.uniform(0, 5, size=(b, p)), jnp.float32), axis=1)
    pi = jnp.asarray(RNG.permutation(10_000)[: b * p].reshape(b, p), jnp.int32)
    nd = jnp.asarray(RNG.uniform(0, 5, size=(b, m)), jnp.float32)
    ni = jnp.asarray((RNG.permutation(10_000)[: b * m] + 20_000).reshape(b, m),
                     jnp.int32)
    d1, i1 = ops.pool_merge(pd, pi, nd, ni)
    d2, i2 = ref.pool_merge_ref(pd, pi, nd, ni)
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2))
    assert (np.asarray(i1) == np.asarray(i2)).all()


def test_pool_merge_randomized_with_ties():
    """Quantized distances force ties across pool/new; the kernel must agree
    with an explicit np.sort of the union under the (dist, id) order."""
    rng = np.random.default_rng(11)
    for _ in range(5):
        b, p, m = 4, 12, 9
        pd = np.sort(rng.integers(0, 6, size=(b, p)).astype(np.float32) / 2.0,
                     axis=1)
        nd = rng.integers(0, 6, size=(b, m)).astype(np.float32) / 2.0
        perm = rng.permutation(5000)
        pi = perm[: b * p].reshape(b, p).astype(np.int32)
        ni = (perm[b * p: b * (p + m)] + 10_000).reshape(b, m).astype(np.int32)
        d1, i1 = ops.pool_merge(jnp.asarray(pd), jnp.asarray(pi),
                                jnp.asarray(nd), jnp.asarray(ni))
        for r in range(b):
            union = sorted(zip(np.concatenate([pd[r], nd[r]]),
                               np.concatenate([pi[r], ni[r]])))
            exp_d = np.asarray([u[0] for u in union[:p]], np.float32)
            exp_i = np.asarray([u[1] for u in union[:p]], np.int32)
            np.testing.assert_array_equal(np.asarray(d1)[r], exp_d)
            np.testing.assert_array_equal(np.asarray(i1)[r], exp_i)


@pytest.mark.parametrize("b,m", [(3, 17), (6, 64)])
def test_crouting_prune_per_lane_dcq(b, m):
    """Beam tiles carry a per-lane expansion-node distance and bound."""
    ed = jnp.asarray(RNG.uniform(0.1, 2.0, size=(b, m)), jnp.float32)
    dcq = jnp.asarray(RNG.uniform(0.1, 2.0, size=(b, m)), jnp.float32)
    b2 = jnp.asarray(RNG.uniform(0.5, 4.0, size=(b, m)), jnp.float32)
    valid = jnp.asarray(RNG.integers(0, 2, size=(b, m)), jnp.int8)
    e1, m1 = ops.crouting_prune(ed, dcq, b2, valid, 0.3)
    e2, m2 = ref.crouting_prune_ref(ed, dcq, b2, valid, 0.3)
    np.testing.assert_allclose(np.asarray(e1), np.asarray(e2), rtol=1e-5)
    assert (np.asarray(m1) == np.asarray(m2)).all()


def test_fused_expand_masks_and_per_lane():
    """eval/prune-eligible masks + per-lane dcq/bound2 (the beam-engine
    calling convention)."""
    b, m, n, d = 4, 12, 120, 16
    table = jnp.asarray(RNG.normal(size=(n, d)), jnp.float32)
    nbrs = jnp.asarray(RNG.integers(0, n + 2, size=(b, m)), jnp.int32)
    qs = jnp.asarray(RNG.normal(size=(b, d)), jnp.float32)
    ed = jnp.asarray(RNG.uniform(0.5, 3.0, size=(b, m)), jnp.float32)
    dcq = jnp.asarray(RNG.uniform(0.5, 3.0, size=(b, m)), jnp.float32)
    b2 = jnp.asarray(RNG.uniform(2.0, 9.0, size=(b, m)), jnp.float32)
    evalm = jnp.asarray(RNG.integers(0, 2, size=(b, m)), jnp.int8) \
        & (nbrs < n).astype(jnp.int8)
    elig = evalm & jnp.asarray(RNG.integers(0, 2, size=(b, m)), jnp.int8)
    d1, m1 = ops.fused_expand(nbrs, qs, ed, dcq, b2, 0.2, table,
                              eval_mask=evalm, prune_eligible=elig)
    d2, m2 = ref.fused_expand_ref(nbrs, qs, ed, dcq, b2, 0.2, table,
                                  eval_mask=evalm, prune_eligible=elig)
    assert (np.asarray(m1) == np.asarray(m2)).all()
    fin = np.isfinite(np.asarray(d2))
    assert (np.isfinite(np.asarray(d1)) == fin).all()
    np.testing.assert_allclose(np.asarray(d1)[fin], np.asarray(d2)[fin],
                               rtol=1e-5, atol=1e-5)


def test_gather_distance_pruned_uses_pad_row_sentinel():
    """Pruned lanes must remap to the table's LAST row (the engine pad row),
    not row 0 — unified sentinel convention (graph_device_arrays)."""
    table = jnp.asarray(RNG.normal(size=(32, 8)), jnp.float32)
    qs = jnp.asarray(RNG.normal(size=(2, 8)), jnp.float32)
    idx = jnp.full((2, 4), 31, jnp.int32)   # all lanes point at the pad row
    mask = jnp.asarray([[1, 1, 0, 1], [1, 0, 1, 1]], jnp.int8)
    out = np.asarray(ops.gather_distance_pruned(idx, mask, qs, table))
    exp = np.asarray(ref.gather_distance_ref(idx, qs, table))
    m = np.asarray(mask) != 0
    assert np.isinf(out[m]).all()
    np.testing.assert_allclose(out[~m], exp[~m], rtol=1e-5, atol=1e-5)


def _sq8_fixture(b, m, n, d, seed=0):
    from repro.quant import sq8 as SQ

    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, d)).astype(np.float32)
    p = SQ.sq8_train(x)
    codes = jnp.asarray(SQ.sq8_encode(x, p))
    nbrs = jnp.asarray(rng.integers(0, n + 2, size=(b, m)), jnp.int32)
    qs = jnp.asarray(rng.normal(size=(b, d)), jnp.float32)
    evalm = jnp.asarray(rng.integers(0, 2, size=(b, m)), jnp.int8)
    return (nbrs, qs, evalm, codes, jnp.asarray(p.lo), jnp.asarray(p.scale),
            jnp.asarray(p.eps))


@pytest.mark.parametrize("b,m,n,d", [(3, 8, 100, 16), (5, 16, 400, 64),
                                     (2, 33, 128, 128)])
def test_sq8_estimate_kernel_matches_oracle(b, m, n, d):
    """Stage-1 SQ8 kernel (uint8 row gather + dequantized accumulate +
    lower-bound emit) == the repro.quant.sq8 oracle, bit-for-bit masks."""
    args = _sq8_fixture(b, m, n, d, seed=b)
    d1, l1 = ops.sq8_estimate(*args)
    d2, l2 = ref.sq8_estimate_ref(*args)
    fin = np.isfinite(np.asarray(d2))
    assert (np.isfinite(np.asarray(d1)) == fin).all()
    np.testing.assert_allclose(np.asarray(d1)[fin], np.asarray(d2)[fin],
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(l1)[fin], np.asarray(l2)[fin],
                               rtol=1e-5, atol=1e-5)


def test_sq8_estimate_masked_lanes_report_inf():
    nbrs, qs, _, codes, lo, scale, eps = _sq8_fixture(4, 12, 64, 32)
    evalm = jnp.zeros((4, 12), jnp.int8).at[:, ::3].set(1)
    d1, l1 = ops.sq8_estimate(nbrs, qs, evalm, codes, lo, scale, eps)
    dead = ~(np.asarray(evalm) != 0) | ~(np.asarray(nbrs) < 64)
    assert np.isinf(np.asarray(d1)[dead]).all()
    assert np.isinf(np.asarray(l1)[dead]).all()


def test_sq8_estimate_lower_bound_holds_on_true_rows():
    """lb2 from the kernel never exceeds the true fp32 distance."""
    from repro.quant import sq8 as SQ

    rng = np.random.default_rng(5)
    n, d, b, m = 150, 48, 4, 20
    x = rng.normal(size=(n, d)).astype(np.float32)
    p = SQ.sq8_train(x)
    codes = jnp.asarray(SQ.sq8_encode(x, p))
    nbrs = jnp.asarray(rng.integers(0, n, size=(b, m)), jnp.int32)
    qs = jnp.asarray(rng.normal(size=(b, d)), jnp.float32)
    _, lb2 = ops.sq8_estimate(nbrs, qs, jnp.ones((b, m), jnp.int8), codes,
                              jnp.asarray(p.lo), jnp.asarray(p.scale),
                              jnp.asarray(p.eps))
    true = np.asarray(ref.gather_distance_ref(nbrs, qs, jnp.asarray(x)))
    assert (np.asarray(lb2) <= true + 1e-4 * (1 + true)).all()


def test_pool_merge_with_inf_padding():
    pd = jnp.asarray([[0.1, 0.5, jnp.inf, jnp.inf]], jnp.float32)
    pi = jnp.asarray([[3, 7, -1, -1]], jnp.int32)
    nd = jnp.asarray([[0.3, jnp.inf]], jnp.float32)
    ni = jnp.asarray([[9, -1]], jnp.int32)
    d, i = ops.pool_merge(pd, pi, nd, ni)
    assert list(np.asarray(i)[0][:3]) == [3, 9, 7]


@pytest.mark.parametrize("b,m,n,d", [(3, 8, 100, 16), (5, 16, 400, 64)])
def test_fused_expand_sweep(b, m, n, d):
    """Fused estimate+prune+conditional-gather kernel == composed oracle."""
    table = jnp.asarray(RNG.normal(size=(n, d)), jnp.float32)
    nbrs = jnp.asarray(RNG.integers(0, n + 2, size=(b, m)), jnp.int32)  # some pads
    qs = jnp.asarray(RNG.normal(size=(b, d)), jnp.float32)
    ed = jnp.asarray(RNG.uniform(0.5, 3.0, size=(b, m)), jnp.float32)
    dcq = jnp.asarray(RNG.uniform(0.5, 3.0, size=(b,)), jnp.float32)
    b2 = jnp.asarray(RNG.uniform(2.0, 9.0, size=(b,)), jnp.float32)
    d1, m1 = ops.fused_expand(nbrs, qs, ed, dcq, b2, 0.156, table)
    d2, m2 = ref.fused_expand_ref(nbrs, qs, ed, dcq, b2, 0.156, table)
    assert (np.asarray(m1) == np.asarray(m2)).all()
    fin = np.isfinite(np.asarray(d2))
    assert (np.isfinite(np.asarray(d1)) == fin).all()
    np.testing.assert_allclose(np.asarray(d1)[fin], np.asarray(d2)[fin],
                               rtol=1e-5, atol=1e-5)

"""DLRM (Naumov et al., arXiv:1906.00091) — the MLPerf recsys benchmark config.

JAX has no nn.EmbeddingBag: the lookup is implemented as ``jnp.take`` +
``jax.ops.segment_sum`` (multi-hot capable; Criteo features are single-hot).
The 26 sparse tables (~188M rows x 128) are the hot path; tables row-shard
over the 'model' mesh axis (classic table-parallel layout, DESIGN.md §6).

Steps: train_step (BCE), serve_step (scores), retrieval_step (1 query vs 1M
candidate embeddings — the shape where CRouting applies directly; see
examples/dlrm_retrieval.py for the ANN-served variant).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.train import optimizer as opt

# Criteo-1TB per-feature vocabulary sizes (MLPerf reference, max-ind-range=40M)
CRITEO_VOCAB_SIZES = [
    39884406, 39043, 17289, 7420, 20263, 3, 7120, 1543, 63, 38532951,
    2953546, 403346, 10, 2208, 11938, 155, 4, 976, 14, 39979771,
    25641295, 39664984, 585935, 12972, 108, 36,
]


@dataclasses.dataclass(frozen=True)
class DlrmConfig:
    name: str = "dlrm-mlperf"
    n_dense: int = 13
    n_sparse: int = 26
    embed_dim: int = 128
    bot_mlp: Tuple[int, ...] = (512, 256, 128)
    top_mlp: Tuple[int, ...] = (1024, 1024, 512, 256, 1)
    vocab_sizes: Tuple[int, ...] = tuple(CRITEO_VOCAB_SIZES)
    vocab_cap: int = 0          # >0: cap rows per table (smoke tests)
    dtype: str = "float32"

    def table_rows(self) -> List[int]:
        rows = [min(v, self.vocab_cap) if self.vocab_cap else v
                for v in self.vocab_sizes]
        # pad rows so sharding is even (pad rows are never looked up):
        # big tables to /512 (row-shard over EVERY device, §Perf HC1),
        # small tables to /16 ('model'-axis only)
        return [-(-r // 512) * 512 if r > 512 else -(-r // 16) * 16
                for r in rows]

    def param_count(self) -> int:
        rows = sum(self.table_rows())
        n = rows * self.embed_dim
        dims = (self.n_dense,) + self.bot_mlp
        n += sum(a * b + b for a, b in zip(dims[:-1], dims[1:]))
        n_int = self.n_sparse + 1
        d_int = n_int * (n_int - 1) // 2 + self.embed_dim
        dims = (d_int,) + self.top_mlp
        n += sum(a * b + b for a, b in zip(dims[:-1], dims[1:]))
        return n


def _mlp_init(key, dims, dt):
    ks = jax.random.split(key, len(dims) - 1)
    return [{"w": (jax.random.normal(k, (a, b)) / np.sqrt(a)).astype(dt),
             "b": jnp.zeros((b,), dt)} for k, a, b in zip(ks, dims[:-1], dims[1:])]


def _mlp(params, x, final_act=None):
    for i, l in enumerate(params):
        x = x @ l["w"] + l["b"]
        if i < len(params) - 1:
            x = jax.nn.relu(x)
        elif final_act is not None:
            x = final_act(x)
    return x


def init_dlrm(cfg: DlrmConfig, key) -> Dict[str, Any]:
    dt = jnp.dtype(cfg.dtype)
    rows = cfg.table_rows()
    ks = jax.random.split(key, len(rows) + 2)
    tables = [
        (jax.random.normal(ks[i], (r, cfg.embed_dim))
         / np.sqrt(cfg.embed_dim)).astype(dt)
        for i, r in enumerate(rows)
    ]
    n_int = cfg.n_sparse + 1
    d_int = n_int * (n_int - 1) // 2 + cfg.embed_dim
    return {
        "tables": tables,
        "bot": _mlp_init(ks[-2], (cfg.n_dense,) + cfg.bot_mlp, dt),
        "top": _mlp_init(ks[-1], (d_int,) + cfg.top_mlp, dt),
    }


# --------------------------------------------------------------------------
# EmbeddingBag: take + segment_sum (JAX-native; DESIGN.md §2 table)
# --------------------------------------------------------------------------
def embedding_bag(table, ids, bag_ids, n_bags, combiner: str = "sum"):
    """Multi-hot lookup: ids [L] rows of table, bag_ids [L] -> [n_bags, dim]."""
    rows = jnp.take(table, ids, axis=0)
    out = jax.ops.segment_sum(rows, bag_ids, num_segments=n_bags)
    if combiner == "mean":
        cnt = jax.ops.segment_sum(jnp.ones_like(ids, jnp.float32), bag_ids,
                                  num_segments=n_bags)
        out = out / jnp.maximum(cnt, 1.0)[:, None]
    return out


def table_parallel_lookup(tables, ids):
    """Explicit table-parallel embedding lookup (§Perf HC1).

    XLA's SPMD gather over row-sharded tables chooses to ALL-GATHER the whole
    table (~96 GB fp32) to every device; this shard_map does the classic
    layout instead: each device masked-gathers the rows it owns and a psum
    (batch-sized, not table-sized) combines.  Tables whose rows don't divide
    the device count stay replicated (they are tiny).  Falls back to plain
    takes without a mesh (smoke tests / single device)."""
    import numpy as np
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    try:
        from jax._src.mesh import thread_resources
        mesh = thread_resources.env.physical_mesh
    except Exception:   # noqa: BLE001 — jax-internal API probe; no-mesh fallback
        mesh = None
    if mesh is None or mesh.empty:
        return [jnp.take(t, ids[:, i], axis=0) for i, t in enumerate(tables)]

    axes = tuple(mesh.axis_names)
    ndev = int(np.prod([mesh.shape[a] for a in axes]))
    big = [t.shape[0] % ndev == 0 and t.shape[0] >= ndev for t in tables]

    def local(tables_loc, ids_rep):
        pos = jnp.int32(0)
        for a in axes:
            pos = pos * mesh.shape[a] + jax.lax.axis_index(a)
        parts, direct = [], {}
        for i, t in enumerate(tables_loc):
            if big[i]:
                rows_loc = t.shape[0]
                idx = ids_rep[:, i] - pos * rows_loc
                ok = (idx >= 0) & (idx < rows_loc)
                safe = jnp.clip(idx, 0, rows_loc - 1)
                parts.append(jnp.take(t, safe, axis=0)
                             * ok[:, None].astype(t.dtype))
            else:
                direct[i] = jnp.take(t, ids_rep[:, i], axis=0)
        if parts:
            summed = jax.lax.psum(jnp.stack(parts), axes)   # ONE batch-sized psum
        out, j = [], 0
        for i in range(len(tables_loc)):
            if big[i]:
                out.append(summed[j])
                j += 1
            else:
                out.append(direct[i])
        return tuple(out)

    in_specs = ([P(axes, None) if b else P(None, None) for b in big],
                P(None, None))
    out_specs = tuple(P(None, None) for _ in tables)
    return list(shard_map(local, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=False)(tables, ids))


def dot_interaction(vectors):
    """vectors [B, n, d] -> lower-triangle pairwise dots [B, n(n-1)/2]."""
    B, n, d = vectors.shape
    z = jnp.einsum("bnd,bmd->bnm", vectors, vectors)
    iu, ju = np.tril_indices(n, k=-1)
    return z[:, iu, ju]


def dlrm_forward(params, batch, cfg: DlrmConfig):
    """batch: dense [B, 13] float, sparse_ids [B, 26] int32 (single-hot)."""
    dense, sparse = batch["dense"], batch["sparse_ids"]
    B = dense.shape[0]
    x = _mlp(params["bot"], dense)                       # [B, 128]
    embs = table_parallel_lookup(params["tables"], sparse)  # single-hot bags
    z = jnp.stack([x] + embs, axis=1)                    # [B, 27, 128]
    inter = dot_interaction(z)                           # [B, 351]
    feat = jnp.concatenate([x, inter], axis=-1)
    return _mlp(params["top"], feat)[:, 0]               # logits [B]


def dlrm_loss(params, batch, cfg: DlrmConfig):
    logits = dlrm_forward(params, batch, cfg).astype(jnp.float32)
    y = batch["labels"].astype(jnp.float32)
    return jnp.mean(jnp.maximum(logits, 0) - logits * y
                    + jnp.log1p(jnp.exp(-jnp.abs(logits))))


def make_dlrm_train_step(cfg: DlrmConfig, ocfg: opt.AdamWConfig):
    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(dlrm_loss)(params, batch, cfg)
        newp, news, metrics = opt.adamw_update(grads, opt_state, params, ocfg)
        metrics["loss"] = loss
        return newp, news, metrics
    return train_step


def make_dlrm_serve_step(cfg: DlrmConfig):
    def serve_step(params, batch):
        return jax.nn.sigmoid(dlrm_forward(params, batch, cfg).astype(jnp.float32))
    return serve_step


def make_retrieval_step(cfg: DlrmConfig, k: int = 100):
    """Score one user query against n_candidates item embeddings (batched dot
    — never a loop) and return top-k.  The CRouting-ANN alternative to this
    brute-force scorer lives in examples/dlrm_retrieval.py."""

    def retrieval_step(query, candidates):
        # query [Bq, d], candidates [Nc, d] -> (scores [Bq, k], ids [Bq, k])
        scores = query @ candidates.T                    # MXU batched dot
        top, idx = jax.lax.top_k(scores, k)
        return top, idx

    return retrieval_step

"""Mini failpoint registry (failpoint-sync fixture)."""

DECLARED_SITES = frozenset({
    "svc.ok",
    "svc.dead",     # expect[failpoint-sync,failpoint-sync] dead + undocumented
})


def hit(site, sub=None):
    return None

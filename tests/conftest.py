import pytest

from repro.data.vectors import make_dataset, exact_ground_truth
from repro.core.hnsw import build_hnsw
from repro.core.nsg import build_nsg
from repro.core.angles import sample_angle_profile


@pytest.fixture(scope="session")
def small_ds():
    return make_dataset(n_base=1500, n_query=40, dim=48, n_clusters=24, seed=0)


@pytest.fixture(scope="session")
def hnsw_index(small_ds):
    return build_hnsw(small_ds.base, m=12, efc=80, seed=0)


@pytest.fixture(scope="session")
def nsg_index(small_ds):
    return build_nsg(small_ds.base, r=24, c=120, l=32, knn_k=24)


@pytest.fixture(scope="session")
def hnsw_profile(hnsw_index):
    return sample_angle_profile(hnsw_index, n_sample=12, efs=48, seed=1)


@pytest.fixture(scope="session")
def ground_truth(small_ds):
    return exact_ground_truth(small_ds, k=10)

"""Distributed serving: sharded search must merge to (near-)single-device
results; straggler hop-budget degrades gracefully.  Runs in a subprocess so
the 8 host devices don't leak into other tests."""
import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import json
import numpy as np, jax
from repro.core.sharded_index import shard_dataset, ShardedAnnIndex
from repro.core.index import AnnIndex
from repro.core.spec import SearchSpec
from repro.data.vectors import make_dataset, exact_ground_truth, recall_at_k
from repro.launch.mesh import make_local_mesh

ds = make_dataset(n_base=3000, n_query=40, dim=48, n_clusters=24, seed=0)
gt = exact_ground_truth(ds, k=10)
arrays = shard_dataset(ds.base, n_shards=8, graph="hnsw", m=12, efc=64)
mesh = make_local_mesh(8, "shards")
out = {}

spec = SearchSpec(k=10, efs=48, router="crouting", max_hops=2048)
idx = ShardedAnnIndex(arrays, mesh, spec=spec)
ids, d, stats = idx.search(ds.queries)
out["recall_sharded"] = recall_at_k(ids, gt, 10)
out["calls"] = int(stats.dist_calls)
# the typed stats carry the registry router name + aggregate counters
out["stats_ok"] = bool(stats.router == "crouting"
                       and int(stats.est_calls) > 0
                       and int(stats.iters) > 0)

# global ids must be valid and deduplicated per query
ok = True
for row in ids:
    real = [i for i in row if i >= 0]
    ok &= len(set(real)) == len(real) and all(0 <= i < 3000 for i in real)
out["ids_valid"] = bool(ok)

# single- index reference (same total data, one graph)
ref = AnnIndex.build(ds.base, graph="hnsw", m=12, efc=64)
rids, _, _ = ref.search(ds.queries, spec=SearchSpec(k=10, efs=48,
                                                    router="crouting"))
out["recall_single"] = recall_at_k(rids, gt, 10)

# straggler mitigation: tiny hop budget must still return (degraded) results
idx2 = ShardedAnnIndex(arrays, mesh, spec=spec.replace(max_hops=8))
ids2, _, stats2 = idx2.search(ds.queries)
out["recall_budget"] = recall_at_k(ids2, gt, 10)
out["calls_budget"] = int(stats2.dist_calls)

# a plugin router's extra counters must survive the shard psum (review
# finding: the serve step used to drop SearchResult.extra silently)
import dataclasses
import jax.numpy as jnp
from repro.core.routers import EdgeAngleRouter, register_router

@dataclasses.dataclass(frozen=True)
class CountingRouter(EdgeAngleRouter):
    def estimate_rank(self, ctx):
        est_rank, _ = super().estimate_rank(ctx)
        return est_rank, {"my_tests": jnp.sum(ctx.try_prune, axis=1,
                                              dtype=jnp.int32)}

register_router(CountingRouter(name="counting", prunes=True,
                               extra_counters=("my_tests",)))
idx3 = ShardedAnnIndex(arrays, mesh, spec=spec.replace(router="counting"))
_, _, stats3 = idx3.search(ds.queries[:8])
out["extra_counter"] = int(stats3.extra["my_tests"])

# --- ISSUE 5 spec parity: per-call spec routes through resolve_search_spec
# and request-only fields (k / cos_theta) reuse the jitted serve step
step0 = idx._step(idx.spec)
n_cache0 = step0._cache_size()
ids_k, d_k, _ = idx.search(ds.queries, spec=spec.replace(k=5, cos_theta=0.6))
out["k_override_shape_ok"] = bool(ids_k.shape == (40, 5))
out["k_override_no_rejit"] = bool(
    idx._step(idx.spec) is step0 and step0._cache_size() == n_cache0
    and len(idx._steps) == 1)
# legacy kwargs and the pre-parity positional scalar are retired: both
# spellings must raise TypeError now (ISSUE 6 shim removal)
def _raises_type_error(fn):
    try:
        fn()
    except TypeError:
        return True
    return False

out["legacy_kwarg_raises"] = _raises_type_error(
    lambda: idx.search(ds.queries, cos_theta=0.6, k=5))
out["positional_scalar_raises"] = _raises_type_error(
    lambda: idx.search(ds.queries, 0.6))
out["ctor_kwarg_raises"] = _raises_type_error(
    lambda: ShardedAnnIndex(arrays, mesh, k=5))

# --- ISSUE 5 valid mask: padded lanes contribute ZERO to the shard-reduced
# counter totals (the serving frontend's bucket-padding contract)
qpad = np.concatenate([ds.queries[:10], np.repeat(ds.queries[:1], 6, 0)])
vmask = np.arange(16) < 10
ids_p, d_p, st_pad = idx.search(qpad, valid=vmask)
_, _, st_ref = idx.search(ds.queries[:10])
out["padded_counters_zero"] = bool(
    int(st_pad.dist_calls) == int(st_ref.dist_calls)
    and int(st_pad.hops) == int(st_ref.hops)
    and int(st_pad.est_calls) == int(st_ref.est_calls))

# --- ISSUE 5 frontend over the sharded backend: ragged trace, results
# bit-identical to direct search, zero compiles on the request path
from repro.serve import ServeFrontend
fe = ServeFrontend(idx, spec, buckets=(1, 8, 16, 40))
ok = True
for n in (1, 3, 8, 16, 40):
    fut = fe.submit(ds.queries[:n]); fe.flush()
    f_ids, f_d, f_st = fut.result()
    r_ids, r_d, r_st = idx.search(ds.queries[:n])
    ok &= (f_ids == r_ids).all() and np.allclose(f_d, r_d)
    ok &= int(f_st.dist_calls) == int(r_st.dist_calls)
out["frontend_matches_direct"] = bool(ok)
out["frontend_recompiles"] = int(fe.telemetry.recompiles_after_warmup)
print("RESULT " + json.dumps(out))
"""


@pytest.mark.slow
def test_sharded_index_subprocess():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stderr[-3000:]
    line = [l for l in r.stdout.splitlines() if l.startswith("RESULT ")][-1]
    out = json.loads(line[len("RESULT "):])
    assert out["ids_valid"]
    assert out["stats_ok"]
    # sharded top-k merge over 8 sub-indexes should beat one global graph at
    # equal efs (it runs efs per shard) — require >= single-graph - 2%
    assert out["recall_sharded"] >= out["recall_single"] - 0.02, out
    assert out["recall_sharded"] > 0.9, out
    # bounded-hop straggler mode: returns, degraded but nonzero
    assert out["calls_budget"] < out["calls"], out
    assert out["recall_budget"] > 0.2, out
    # plugin-router extra counters round-trip through the shard reduction
    assert out["extra_counter"] > 0, out
    # ISSUE 5 spec parity: request-only overrides reuse the serve step, the
    # legacy shims warn and agree, padded lanes stay out of the counters,
    # and the serving frontend is bit-identical to direct sharded search
    assert out["k_override_shape_ok"], out
    assert out["k_override_no_rejit"], out
    assert out["legacy_kwarg_raises"], out
    assert out["positional_scalar_raises"], out
    assert out["ctor_kwarg_raises"], out
    assert out["padded_counters_zero"], out
    assert out["frontend_matches_direct"], out
    assert out["frontend_recompiles"] == 0, out

"""arctic-480b [moe] — 128 experts top-2 + dense residual [hf:Snowflake/snowflake-arctic-base]."""
from repro.configs import ArchSpec
from repro.configs.shapes import LM_SHAPES
from repro.models.transformer import LMConfig, MoeSpec

SPEC = ArchSpec(
    arch_id="arctic-480b",
    family="lm",
    model_cfg=LMConfig(name="arctic-480b", n_layers=35, d_model=7168,
                       n_heads=56, n_kv_heads=8, d_ff=4864, vocab=32000,
                       moe=MoeSpec(n_experts=128, top_k=2, dense_residual=True)),
    shapes=LM_SHAPES,
    source="hf:Snowflake/snowflake-arctic-base; hf",
    smoke_cfg=LMConfig(name="arctic-smoke", n_layers=2, d_model=56,
                       n_heads=7, n_kv_heads=1, d_ff=64, vocab=512,
                       moe=MoeSpec(n_experts=8, top_k=2, dense_residual=True),
                       dtype="float32", block_q=16, block_k=32, loss_chunk=16),
)

"""Deliberately broken lock discipline (guarded-by + lock-order).

Lines carrying an ``expect[checker-id]`` comment are asserted to produce
exactly that finding (see tests/test_analysis.py::fixture_expectations).
"""
import threading


class ServeFrontend:          # name is in the declared lock-order table
    def __init__(self):
        self._lock = threading.RLock()
        self._dispatch_lock = threading.Lock()
        self._pending_rows = 0    # guarded by: self._lock
        # spec -> session -- guarded by: self._lock
        self._sessions = {}
        self._unguarded = 0       # no annotation: never checked

    def ok_read(self):
        with self._lock:
            return self._pending_rows + len(self._sessions)

    def bad_read(self):
        return self._pending_rows          # expect[guarded-by]

    def bad_write(self):
        self._sessions = {}                # expect[guarded-by]

    def closure_leak(self):
        with self._lock:
            def worker():
                self._pending_rows += 1    # expect[guarded-by]
            return worker

    def inverted(self):
        with self._dispatch_lock:
            with self._lock:               # expect[lock-order]
                return self._pending_rows

    def unannotated_ok(self):
        return self._unguarded

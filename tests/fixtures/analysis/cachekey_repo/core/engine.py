"""Unhashable / array-bearing / request-only cache keys (cache-key fixture)."""
import jax.numpy as jnp

_ENGINE_CACHE = {}


def lookup(spec, arr):
    key = (spec.efs, [1, 2], jnp.asarray(arr), spec.k)
    return _ENGINE_CACHE.get(key)   # expect[cache-key,cache-key,cache-key]


def store(spec, fn):
    _ENGINE_CACHE[(spec.efs, spec.metric)] = fn   # hashable scalars: clean

"""Serving frontend: bucketed dynamic batching over the jitted engines.

Public surface::

    from repro.serve import ServeFrontend

    fe = ServeFrontend(index, SearchSpec(efs=64, router="crouting"))
    fut = fe.submit(queries)          # any [n<=top_bucket, d] batch
    fe.flush()                        # or fe.start() for the worker thread
    ids, dists, stats = fut.result()
    print(fe.telemetry.summary())     # p50/p95/p99, QPS, per-bucket compiles

See DESIGN.md §6 (serving frontend) and the README "Serving" section.
"""
from repro.serve.backends import (MutableIndexSession,
                                  MutableShardedIndexSession,
                                  SingleIndexSession, ShardedIndexSession,
                                  make_session)
from repro.serve.bucketing import (DEFAULT_BUCKETS, bucket_for, pad_to_bucket,
                                   validate_buckets)
from repro.serve.frontend import (DeadlineExceeded, FrontendStopped,
                                  QueueFull, RequestRejected, ServeFrontend,
                                  WorkerFailure)
from repro.serve.telemetry import BucketStats, ServeTelemetry

__all__ = [
    "ServeFrontend", "ServeTelemetry", "BucketStats",
    "RequestRejected", "QueueFull", "DeadlineExceeded", "WorkerFailure",
    "FrontendStopped",
    "DEFAULT_BUCKETS", "bucket_for", "pad_to_bucket", "validate_buckets",
    "SingleIndexSession", "ShardedIndexSession", "MutableIndexSession",
    "MutableShardedIndexSession",
    "make_session",
]

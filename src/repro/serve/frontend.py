"""The serving frontend: futures queue -> bucketed micro-batches -> engines.

``ServeFrontend`` is the piece between "a numpy array of queries" and the
jitted engines (DESIGN.md §6): callers ``submit()`` arbitrary-size query
batches and get ``concurrent.futures.Future``s; the micro-batcher coalesces
pending requests, rounds each dispatch up the bucket ladder (pad +
``valid`` mask — padded lanes never pollute results or counters), and runs
the session's pre-jitted executable, so a ragged request stream hits zero
XLA compiles after warmup.

Sessions: one engine session per *canonical* ``SearchSpec`` (the
compiled-engine cache key of PR 4) — requests override only the
request-only fields ``k``/``cos_theta``, which never re-jit.  Submitting a
spec whose canonical form is new creates (and warms) a new session.

Admission control, not silent degradation:

* a request larger than the top bucket raises ``RequestRejected`` — it is
  never truncated or split behind the caller's back;
* ``k`` beyond the session's ``efs`` raises — it would widen the trace;
* a full queue raises ``QueueFull`` (backpressure to the caller);
* a request whose deadline passes while queued fails its future with
  ``DeadlineExceeded`` at dispatch time (admission deadline: once a request
  makes it into a dispatch it always completes).

Dispatch grouping: requests sharing a session and an effective
``cos_theta`` coalesce (the threshold is one traced scalar per engine
call); ``k`` mixes freely — the dispatch searches ``max(k)`` and each
request slices its own ``k`` from the pool.

Threading: ``flush()`` is synchronous and deterministic (tests, benchmarks
drive it directly).  ``start()`` spawns a daemon worker that flushes
whenever requests are pending — the launcher's "serve forever" mode.  Both
may run concurrently; the queue and dispatch path are lock-protected.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from concurrent.futures import Future
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.spec import SearchSpec
from repro.fault import failpoints as fault
from repro.serve.backends import make_session
from repro.serve.bucketing import (DEFAULT_BUCKETS, bucket_for,
                                   pad_to_bucket, validate_buckets)
from repro.serve.telemetry import ServeTelemetry


class RequestRejected(RuntimeError):
    """Admission control refused the request (oversized, bad k, ...)."""


class QueueFull(RequestRejected):
    """Backpressure: the pending-row budget is exhausted; retry later."""


class DeadlineExceeded(RequestRejected):
    """The request's deadline passed while it waited in the queue."""


class FrontendStopped(RequestRejected):
    """``submit()`` after ``stop()``: the frontend is no longer accepting
    requests.  ``start()`` reopens it."""


class WorkerFailure(RuntimeError):
    """The background flush loop itself failed (NOT a per-batch engine
    error — those resolve onto their batch's futures).  Stored on the
    frontend and re-raised, wrapped, from the next ``submit()``/``flush()``
    on a caller thread, so a silent worker death cannot strand a trace."""


@dataclasses.dataclass
class _Request:
    queries: np.ndarray          # [n, d] f32, preprocessed by the engine
    n: int
    k: int
    cos_theta: Optional[float]   # None -> the index's profile
    deadline: Optional[float]    # absolute perf_counter() time
    t_submit: float
    future: Future


class _Session:
    """One canonical SearchSpec: engine binding + its own FIFO queue."""

    def __init__(self, index, spec: Optional[SearchSpec]):
        self.engine = make_session(index, spec)
        self.spec = self.engine.spec
        self.queue: deque = deque()
        self.warmed = False


class ServeFrontend:
    """Bucketed dynamic batcher over ``AnnIndex`` / ``ShardedAnnIndex``."""

    def __init__(self, index, spec: Optional[SearchSpec] = None, *,
                 buckets=DEFAULT_BUCKETS, max_pending_rows: int = 1024,
                 default_timeout: Optional[float] = None, warmup: bool = True):
        self.index = index
        self.buckets = validate_buckets(buckets)
        self.max_pending_rows = int(max_pending_rows)
        self.default_timeout = default_timeout
        self.telemetry = ServeTelemetry()
        self._lock = threading.RLock()          # queue + session state
        self._dispatch_lock = threading.Lock()  # serializes engine calls
        self._pending_rows = 0                  # guarded by: self._lock
        # spec -> session -- guarded by: self._lock
        self._sessions: Dict[SearchSpec, _Session] = {}
        self._worker: Optional[threading.Thread] = None
        self._stop = threading.Event()
        self._wake = threading.Event()
        self._stopped = False                   # stop() called, no start() yet
        self.worker_error: Optional[BaseException] = None
        self.autotune = None        # AutotuneDriver.attach registers itself
        self._base = self._session(spec)
        if warmup:
            self.warmup()

    # --- sessions ---------------------------------------------------------
    def _session(self, spec: Optional[SearchSpec]) -> _Session:
        """The session for ``spec`` (created on first use).  Request-only
        field differences map to the same session."""
        with self._lock:
            if spec is None:
                sess = getattr(self, "_base", None)
                if sess is not None:
                    return sess
            s = _Session(self.index, spec)
            key = s.spec.canonical()
            if key in self._sessions:
                return self._sessions[key]
            self._sessions[key] = s
            return s

    def activate_spec(self, spec: SearchSpec) -> SearchSpec:
        """Hot-swap the default session: pre-warm, THEN atomically switch.

        The autotune controller's promotion path (DESIGN.md §12).  The new
        spec's session compiles every bucket rung off the request path
        (under the dispatch lock only — concurrent submits keep flowing
        into the old default), and only then does the default-session
        pointer flip, under the state lock.  Requests already queued on the
        old session still dispatch through it — an admitted future always
        resolves — and the old session stays warm for an instant switch
        back.  Returns the activated session's resolved spec.
        """
        if spec is None:
            raise TypeError("activate_spec requires an explicit SearchSpec")
        sess = self._session(spec)
        self._warm_session(sess)                # no-op if already warm
        with self._lock:
            self._base = sess
        return sess.spec

    @property
    def active_spec(self) -> SearchSpec:
        """The default session's resolved spec (what ``spec=None`` gets)."""
        return self._base.spec

    def warmup(self):
        """Pre-jit every bucket rung of every session (compile off the
        request path).  Idempotent; new sessions warm on creation via
        ``submit``."""
        with self._lock:
            sessions = list(self._sessions.values())
        for sess in sessions:
            self._warm_session(sess)
        self.telemetry.mark_warm()

    def _warm_session(self, sess: _Session):
        """Compile every rung for one session.  Runs under the DISPATCH
        lock only: multi-second XLA compiles must never hold the state lock
        (they would block every concurrent submit and queue drain)."""
        if sess.warmed:
            return
        with self._dispatch_lock:
            if sess.warmed:           # lost the race: another thread warmed
                return
            q1 = sess.engine.sample_query()[None, :]
            for b in self.buckets:
                qb, _ = pad_to_bucket(q1, b)
                c0 = sess.engine.compile_count()
                t0 = time.perf_counter()
                sess.engine.search_padded(qb, 1, sess.spec.k,
                                          sess.spec.cos_theta)
                self.telemetry.observe_dispatch(
                    b, 0, time.perf_counter() - t0,
                    sess.engine.compile_count() - c0, None)
            sess.warmed = True

    # --- submission -------------------------------------------------------
    def submit(self, queries: np.ndarray, *, spec: Optional[SearchSpec] = None,
               k: Optional[int] = None, cos_theta: Optional[float] = None,
               timeout: Optional[float] = None) -> Future:
        """Enqueue one request; returns a Future of (ids, dists, stats).

        ``spec`` selects/creates the engine session; ``k``/``cos_theta``
        override its request-only fields.  ``timeout`` (seconds) is the
        admission deadline.  Raises ``RequestRejected``/``QueueFull``
        synchronously — an admitted future always resolves.
        """
        if self._stopped:
            raise FrontendStopped(
                "frontend is stopped; call start() to accept requests again")
        self._raise_worker_error()
        with self._lock:
            self.telemetry.submitted += 1
        q = np.ascontiguousarray(queries, np.float32)
        if q.ndim == 1:
            q = q[None, :]
        if q.ndim != 2 or q.shape[0] == 0:
            self._reject(f"expected [n>=1, d] queries, got {q.shape}")
        sess = self._session(spec)
        if q.shape[1] != sess.engine.dim:
            self._reject(
                f"query dim {q.shape[1]} != index dim {sess.engine.dim}")
        n = q.shape[0]
        if n > self.buckets[-1]:
            self._reject(
                f"batch of {n} rows exceeds the largest bucket "
                f"{self.buckets[-1]}; split the request or widen the ladder")
        kk = sess.spec.k if k is None else int(k)
        if not 1 <= kk <= sess.spec.efs:
            self._reject(
                f"k={kk} outside [1, efs={sess.spec.efs}] — a wider pool "
                "would recompile the engine; open a session with larger efs")
        if not sess.warmed:
            # first use of a late-created session: compile its rungs off
            # the request path, WITHOUT holding the state lock
            self._warm_session(sess)
        timeout = self.default_timeout if timeout is None else timeout
        now = time.perf_counter()
        with self._lock:
            if self._pending_rows + n > self.max_pending_rows:
                self.telemetry.rejected += 1
                raise QueueFull(
                    f"{self._pending_rows} rows pending >= budget "
                    f"{self.max_pending_rows}; retry after a flush")
            req = _Request(
                queries=q, n=n, k=kk,
                cos_theta=cos_theta if cos_theta is not None
                else sess.spec.cos_theta,
                deadline=None if timeout is None else now + timeout,
                t_submit=now, future=Future())
            sess.queue.append(req)
            self._pending_rows += n
        self._wake.set()
        return req.future

    def _reject(self, msg: str):
        with self._lock:
            self.telemetry.rejected += 1
        raise RequestRejected(msg)

    def search(self, queries: np.ndarray, **kw
               ) -> Tuple[np.ndarray, np.ndarray, object]:
        """Blocking convenience: submit + flush + result."""
        fut = self.submit(queries, **kw)
        if self._worker is None:
            self.flush()
        return fut.result()

    # --- dispatch ---------------------------------------------------------
    def flush(self) -> int:
        """Drain every session queue once; returns the dispatch count.

        The queue pop (fast) runs under the state lock; the engine calls
        (slow) run under a separate dispatch lock, so concurrent
        ``submit()``s are never blocked behind a running search.
        """
        with self._lock:
            work = [(sess, self._drain(sess))
                    for sess in list(self._sessions.values())]
        n_dispatched = 0
        with self._dispatch_lock:
            for sess, admitted in work:
                n_dispatched += self._dispatch_admitted(sess, admitted)
        # AFTER the drain: queued futures resolve first, then a stored
        # worker failure surfaces to the calling thread
        self._raise_worker_error()
        return n_dispatched

    def _raise_worker_error(self):
        """Surface a background-worker failure on a CALLER thread (the
        worker itself flushes too — re-raising there would just loop)."""
        if self.worker_error is None:
            return
        if threading.current_thread() is self._worker:
            return
        err, self.worker_error = self.worker_error, None
        raise WorkerFailure(
            "background serve worker hit an unexpected error; queued "
            "requests were drained — call start() again to resume") from err

    def _drain(self, sess: _Session) -> List[_Request]:
        """Pop the session queue (state lock held); fail expired futures."""
        now = time.perf_counter()
        admitted: List[_Request] = []
        while sess.queue:
            r = sess.queue.popleft()
            # repolint: ignore[guarded-by] calling contract (see docstring):
            # flush() and the worker loop invoke _drain under self._lock
            self._pending_rows -= r.n
            if r.deadline is not None and now > r.deadline:
                self.telemetry.expired += 1
                r.future.set_exception(DeadlineExceeded(
                    f"deadline passed after {now - r.t_submit:.3f}s in queue"))
                continue
            admitted.append(r)
        return admitted

    def _dispatch_admitted(self, sess: _Session,
                           admitted: List[_Request]) -> int:
        # group by effective cos_theta (one traced scalar per engine call),
        # FIFO within each group
        groups: Dict[object, List[_Request]] = {}
        for r in admitted:
            groups.setdefault(r.cos_theta, []).append(r)
        n_dispatched = 0
        for ct, reqs in groups.items():
            batch, rows = [], 0
            for r in reqs:
                if rows + r.n > self.buckets[-1]:
                    self._dispatch(sess, batch, rows, ct)
                    n_dispatched += 1
                    batch, rows = [], 0
                batch.append(r)
                rows += r.n
            if batch:
                self._dispatch(sess, batch, rows, ct)
                n_dispatched += 1
        return n_dispatched

    def _dispatch(self, sess: _Session, batch: List[_Request], rows: int,
                  cos_theta: Optional[float]):
        bucket = bucket_for(rows, self.buckets)
        q = (batch[0].queries if len(batch) == 1
             else np.concatenate([r.queries for r in batch], axis=0))
        qp, _ = pad_to_bucket(q, bucket)
        k_d = max(r.k for r in batch)
        c0 = sess.engine.compile_count()
        t0 = time.perf_counter()
        try:
            fault.hit("serve.dispatch")
            ids, dists, stats = sess.engine.search_padded(
                qp, rows, k_d, cos_theta)
        except Exception as e:                     # noqa: BLE001
            # the failure belongs to THIS batch's futures only: callers see
            # it via result(), and the flush loop keeps dispatching the
            # other groups/sessions (an admitted future always resolves)
            self.telemetry.observe_dispatch_failure(len(batch))
            for r in batch:
                r.future.set_exception(e)
            return
        t1 = time.perf_counter()
        self.telemetry.observe_dispatch(
            bucket, rows, t1 - t0, sess.engine.compile_count() - c0, stats)
        lo = 0
        for r in batch:
            hi = lo + r.n
            r_stats = sess.engine.stats_for_rows(stats, lo, hi)
            r.future.set_result(
                (ids[lo:hi, :r.k], dists[lo:hi, :r.k], r_stats))
            self.telemetry.observe_request_done(
                t1 - r.t_submit, t0 - r.t_submit)
            lo = hi

    # --- health -----------------------------------------------------------
    def health(self) -> dict:
        """Operational state as a plain dict (launcher/monitoring surface):
        acceptance + worker liveness, queue depth, any stored worker error,
        the active canonical spec + windowed p99 (what the autotune loop
        acts on), the attached controller's own state, and the backend
        session's degraded/quarantined state."""
        with self._lock:
            base = self._base
            h = {
                "stopped": self._stopped,
                "worker_alive": (self._worker is not None
                                 and self._worker.is_alive()),
                "queue_depth_rows": self._pending_rows,
                "queued_requests": sum(len(s.queue)
                                       for s in self._sessions.values()),
                "sessions": len(self._sessions),
                "worker_error": (repr(self.worker_error)
                                 if self.worker_error is not None else None),
                "worker_errors_total": self.telemetry.worker_errors,
            }
        h["active_spec"] = dataclasses.asdict(base.spec.canonical())
        snap = self.telemetry.window_snapshot()
        h["latency_window"] = {
            "p99_ms": snap["latency"]["p99_ms"],
            "qps": snap["window_qps"],
            "served": snap["served"],
        }
        h["autotune"] = (self.autotune.health()
                         if self.autotune is not None else None)
        h["backend"] = base.engine.health()
        return h

    # --- background worker --------------------------------------------------
    def start(self, poll_s: float = 0.05) -> "ServeFrontend":
        """Spawn the daemon flush loop ("serve forever" mode).  Also
        reopens a ``stop()``ed frontend for submissions."""
        self._stopped = False
        if self._worker is not None:
            return self
        self._stop.clear()

        def loop():
            while not self._stop.is_set():
                self._wake.wait(timeout=poll_s)
                self._wake.clear()
                try:
                    fault.hit("serve.worker")
                    self.flush()
                except Exception as e:             # noqa: BLE001
                    # per-batch failures land on their futures inside
                    # _dispatch; anything reaching here is unexpected — keep
                    # the worker alive and surface it on the frontend
                    self.worker_error = e
                    self.telemetry.worker_errors += 1

        self._worker = threading.Thread(target=loop, daemon=True,
                                        name="serve-frontend")
        self._worker.start()
        return self

    def stop(self):
        """Stop accepting requests, stop the worker, and drain what is
        still queued (an admitted future always resolves).  Idempotent —
        a second ``stop()`` is a no-op; ``submit()`` afterwards raises
        ``FrontendStopped`` until ``start()`` reopens the frontend."""
        if self._stopped:
            return
        self._stopped = True
        if self._worker is not None:
            self._stop.set()
            self._wake.set()
            self._worker.join()
            self._worker = None
        self.flush()

    def __enter__(self) -> "ServeFrontend":
        return self.start()

    def __exit__(self, *exc):
        self.stop()

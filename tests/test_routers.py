"""Router protocol + registry (ISSUE 4 tentpole): the four built-ins are
registry entries with unchanged behavior (oracle/counter parity lives in
test_engine_equivalence/test_crouting), the engine-integrated ``finger``
router runs under all three engines, and a custom strategy registers as a
small plugin with its own counters."""
import dataclasses

import numpy as np
import pytest

from repro.core.routers import (EdgeAngleRouter, Router, available_routers,
                                get_router, register_router,
                                unregister_router)
from repro.core.search import search_batch
from repro.core.spec import SearchSpec


@pytest.fixture(scope="module")
def tiny(small_ds, hnsw_index, hnsw_profile):
    return small_ds, hnsw_index, hnsw_profile.cos_theta_star


def test_builtin_routers_are_registry_entries():
    names = available_routers()
    for expected in ("none", "crouting", "crouting_o", "triangle", "finger"):
        assert expected in names, names
    cr = get_router("crouting")
    assert cr.prunes and cr.revisit_pruned and not cr.permanent
    assert not get_router("crouting_o").revisit_pruned
    tri = get_router("triangle")
    assert tri.permanent and not tri.counts_est
    assert tri.cos_theta_eff(0.123) == 1.0     # exact lower bound
    assert not get_router("none").prunes
    fi = get_router("finger")
    assert fi.permanent and fi.extra_counters == ("finger_est_calls",)
    assert fi.companion_tables                  # sharded path must reject it


def test_unknown_router_name_raises_with_available_list(tiny):
    ds, g, _ = tiny
    with pytest.raises(ValueError, match="crouting"):
        search_batch(g, ds.queries[:2], SearchSpec(efs=16, router="bogus"))


def test_register_router_refuses_silent_overwrite():
    with pytest.raises(ValueError, match="already registered"):
        register_router(Router(name="none"))


def test_finger_router_prunes_and_counts(tiny):
    ds, g, ct = tiny
    plain = search_batch(g, ds.queries, SearchSpec(efs=48, router="none"))
    fing = search_batch(g, ds.queries, SearchSpec(efs=48, router="finger"),
                        cos_theta=ct)
    assert float(np.mean(fing.dist_calls)) < float(np.mean(plain.dist_calls))
    assert int(np.asarray(fing.est_calls).sum()) > 0
    # the router-declared extra counter rides the engine state
    assert set(fing.extra) == {"finger_est_calls"}
    np.testing.assert_array_equal(np.asarray(fing.extra["finger_est_calls"]),
                                  np.asarray(fing.est_calls))


def test_finger_recall_within_0_01_of_none(tiny, ground_truth):
    from repro.data.vectors import recall_at_k

    ds, g, ct = tiny
    plain = search_batch(g, ds.queries, SearchSpec(efs=64, router="none"))
    fing = search_batch(g, ds.queries, SearchSpec(efs=64, router="finger"),
                        cos_theta=ct)
    rec_p = recall_at_k(np.asarray(plain.ids[:, :10]), ground_truth, 10)
    rec_f = recall_at_k(np.asarray(fing.ids[:, :10]), ground_truth, 10)
    assert rec_f >= rec_p - 0.01, (rec_p, rec_f)


@pytest.mark.parametrize("engine,W", [("pallas", 1), ("pallas", 4),
                                      ("pallas_unfused", 2)])
def test_finger_router_matches_jnp_under_pallas_engines(engine, W):
    """The finger estimate runs on the jnp path under every engine (its
    form is not the kernels' edge-angle expression), but the kernel
    engines' gathers/merges must still reproduce the jnp engine exactly."""
    from repro.core.hnsw import build_hnsw
    from repro.data.vectors import make_dataset

    ds = make_dataset(n_base=600, n_query=6, dim=24, n_clusters=12, seed=3)
    g = build_hnsw(ds.base, m=8, efc=48, seed=0)
    a = search_batch(g, ds.queries, SearchSpec(efs=20, router="finger",
                                               beam_width=W))
    b = search_batch(g, ds.queries, SearchSpec(efs=20, router="finger",
                                               beam_width=W, engine=engine))
    np.testing.assert_array_equal(np.asarray(a.ids), np.asarray(b.ids))
    np.testing.assert_allclose(np.asarray(a.dists), np.asarray(b.dists),
                               rtol=1e-6, atol=1e-6)
    assert (np.asarray(a.dist_calls) == np.asarray(b.dist_calls)).all()
    assert (np.asarray(a.est_calls) == np.asarray(b.est_calls)).all()
    assert (np.asarray(a.extra["finger_est_calls"])
            == np.asarray(b.extra["finger_est_calls"])).all()


def test_finger_companion_tables_upgrade_arrays_cache_lazily(tiny):
    """Like ensure_sq8_arrays: the per-graph arrays dict gains the finger
    tables only when a finger config first touches the graph, in place."""
    from repro.core.hnsw import build_hnsw
    from repro.core.search import build_search_fn
    from repro.data.vectors import make_dataset

    ds = make_dataset(n_base=400, n_query=2, dim=16, n_clusters=8, seed=2)
    g = build_hnsw(ds.base, m=6, efc=24, seed=0)
    arrays, _ = build_search_fn(g, SearchSpec(efs=12, router="none"))
    assert "finger_edge_sig" not in arrays
    arrays2, _ = build_search_fn(g, SearchSpec(efs=12, router="finger"))
    assert arrays2 is arrays and "finger_edge_sig" in arrays
    sig = arrays["finger_edge_sig"]
    assert sig.shape == (g.n + 1, g.max_degree, 2)   # r_bits=64 -> 2 words
    assert not np.asarray(sig[-1]).any()             # pad row: empty sigs


def test_reregistering_a_router_invalidates_the_compiled_engine(tiny):
    """Regression (review finding): the jitted engine bakes the router's
    hooks in, so the compiled-fn cache is keyed on the resolved Router
    INSTANCE — swapping the registry entry under the same name must miss
    the cache, not silently serve the old strategy."""
    ds, g, ct = tiny
    name = "_test_swap"
    register_router(Router(name=name, prunes=False))      # behaves like none
    try:
        spec = SearchSpec(efs=32, router=name)
        v1 = search_batch(g, ds.queries[:8], spec, cos_theta=ct)
        assert int(np.asarray(v1.est_calls).sum()) == 0
        register_router(EdgeAngleRouter(name=name, prunes=True,
                                        kernel_estimate=True),
                        overwrite=True)                   # now == crouting
        v2 = search_batch(g, ds.queries[:8], spec, cos_theta=ct)
        assert int(np.asarray(v2.est_calls).sum()) > 0, \
            "stale compiled engine served after re-registration"
        twin = search_batch(g, ds.queries[:8],
                            SearchSpec(efs=32, router="crouting"),
                            cos_theta=ct)
        assert (np.asarray(v2.dist_calls) == np.asarray(twin.dist_calls)).all()
    finally:
        unregister_router(name)


def test_custom_router_is_a_small_plugin(tiny):
    """The plugin story: a strategy registered from user code — here an
    edge-angle variant with its own counter — runs through the engine with
    no engine changes, and its counter lands in SearchResult.extra."""
    import jax.numpy as jnp

    @dataclasses.dataclass(frozen=True)
    class CountingRouter(EdgeAngleRouter):
        def estimate_rank(self, ctx):
            est_rank, _ = super().estimate_rank(ctx)
            return est_rank, {"my_tests": jnp.sum(ctx.try_prune, axis=1,
                                                  dtype=jnp.int32)}

    register_router(CountingRouter(name="_test_counting", prunes=True,
                                   extra_counters=("my_tests",)))
    try:
        ds, g, ct = tiny
        twin = search_batch(g, ds.queries, SearchSpec(efs=32,
                                                      router="crouting"),
                            cos_theta=ct)
        mine = search_batch(g, ds.queries,
                            SearchSpec(efs=32, router="_test_counting"),
                            cos_theta=ct)
        # same flags + same estimate expression == crouting bit-for-bit
        np.testing.assert_array_equal(np.asarray(mine.ids),
                                      np.asarray(twin.ids))
        assert (np.asarray(mine.dist_calls)
                == np.asarray(twin.dist_calls)).all()
        assert (np.asarray(mine.extra["my_tests"])
                == np.asarray(twin.est_calls)).all()
    finally:
        unregister_router("_test_counting")

"""qwen1.5-4b [dense] — QKV bias [hf:Qwen/Qwen1.5-0.5B family; hf]."""
from repro.configs import ArchSpec
from repro.configs.shapes import LM_SHAPES
from repro.models.transformer import LMConfig

SPEC = ArchSpec(
    arch_id="qwen1.5-4b",
    family="lm",
    model_cfg=LMConfig(name="qwen1.5-4b", n_layers=40, d_model=2560,
                       n_heads=20, n_kv_heads=20, d_ff=6912, vocab=151936,
                       qkv_bias=True),
    shapes=LM_SHAPES,
    source="hf:Qwen/Qwen1.5-0.5B; hf",
    smoke_cfg=LMConfig(name="qwen-smoke", n_layers=2, d_model=40,
                       n_heads=4, n_kv_heads=4, d_ff=96, vocab=512,
                       qkv_bias=True, head_dim=10,
                       dtype="float32", block_q=16, block_k=32, loss_chunk=16),
)

"""ANNS serving launcher: build (or load) a CRouting index sharded over the
local devices and serve batched queries.

  PYTHONPATH=src python -m repro.launch.serve --n-base 20000 --batches 10

On a multi-chip slice this is the production layout of DESIGN.md §6 (one
shard per device); here it runs over however many devices exist.
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax

from repro.core.sharded_index import shard_dataset, ShardedAnnIndex
from repro.core.spec import SearchSpec
from repro.data.vectors import make_dataset, exact_ground_truth, recall_at_k
from repro.launch.mesh import make_local_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-base", type=int, default=20000)
    ap.add_argument("--dim", type=int, default=128)
    ap.add_argument("--graph", default="hnsw", choices=["hnsw", "nsg"])
    ap.add_argument("--router", default="crouting")
    ap.add_argument("--efs", type=int, default=100)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--batches", type=int, default=5)
    ap.add_argument("--m", type=int, default=16)
    ap.add_argument("--efc", type=int, default=128)
    args = ap.parse_args()

    n_dev = len(jax.devices())
    print(f"devices: {n_dev}")
    ds = make_dataset(n_base=args.n_base, n_query=args.batch * args.batches,
                      dim=args.dim, seed=0)
    t0 = time.time()
    arrays = shard_dataset(ds.base, n_shards=max(n_dev, 1), graph=args.graph,
                           m=args.m, efc=args.efc)
    print(f"index built in {time.time()-t0:.1f}s "
          f"(theta*={np.arccos(arrays.cos_theta)/np.pi:.3f}pi)")
    mesh = make_local_mesh(n_dev, "shards")
    idx = ShardedAnnIndex(arrays, mesh,
                          spec=SearchSpec(efs=args.efs, k=args.k,
                                          router=args.router, max_hops=2048))

    gt = exact_ground_truth(ds, k=args.k)
    lat, total_calls, all_ids = [], 0, []
    for b in range(args.batches):
        q = ds.queries[b * args.batch:(b + 1) * args.batch]
        t0 = time.time()
        ids, dists, stats = idx.search(q)
        lat.append(time.time() - t0)
        total_calls += int(stats.dist_calls)
        all_ids.append(ids)
    rec = recall_at_k(np.concatenate(all_ids), gt, args.k)
    qps = args.batch / np.median(lat)
    print(f"router={args.router}: recall@{args.k}={rec:.3f} "
          f"QPS={qps:.0f} p50={np.median(lat)*1e3:.1f}ms "
          f"dist_calls/query={total_calls/(args.batch*args.batches):.0f}")


if __name__ == "__main__":
    main()
